"""Fault drill: recovery-time benchmarks for the DESIGN.md §12 machinery.

Three scheduled-fault drills (``repro.data.faults.FaultPlan`` — the same
deterministic coordinates the chaos test batteries use), each emitting a
recovery-time row plus the correctness flag the recovery contract
promises:

  * ``fault/worker_respawn`` — a pooled frozen-snapshot ``fit`` loses a
    sampler worker mid-run; the supervisor respawns it and replays the
    stripe.  Records the respawn downtime and whether the losses came out
    bit-identical to the undisturbed run.
  * ``fault/resume`` — interrupt a run at the midpoint checkpoint and
    resume in a fresh session.  Records save/restore wall times and
    whether the resumed tail matched the uninterrupted trajectory
    bit-for-bit.
  * ``fault/degraded_serve`` — persistent primary-path failures trip the
    serving tier's circuit breaker into the degraded direct-store path.
    Records p50 latency, trip/recovery counts, and that zero callers were
    rejected.

``--smoke`` shrinks step counts for CI; records land in
``BENCH_fault.json`` via ``write_records``.
"""

from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from benchmarks._util import emit, write_records


def _config(steps: int, pooled: bool):
    from repro.api import (CacheConfig, DataConfig, FaultConfig, HetaConfig,
                           ModelConfig, PartitionConfig, PipelineConfig,
                           RunConfig)

    return HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                        batch_size=8),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(hidden=32),
        cache=CacheConfig(cache_mb=2, presample_epochs=1),
        run=RunConfig(executor="raf_spmd", steps=steps, lr=1e-2, seed=0),
        pipeline=PipelineConfig(enabled=pooled, num_workers=2 if pooled else 0,
                                depth=2, snapshot="fresh"),
        faults=FaultConfig(max_worker_restarts=2, worker_backoff_s=0.01),
    )


def drill_worker_respawn(smoke: bool) -> None:
    from repro.api import Heta
    from repro.data.faults import FaultPlan, FaultSpec

    steps = 8 if smoke else 20
    ref = Heta(_config(steps, pooled=True)).run()

    drill = Heta(_config(steps, pooled=True))
    drill.fault_plan = FaultPlan((FaultSpec("kill_worker", step=steps // 2),))
    try:
        t0 = time.perf_counter()
        got = drill.run()
        wall = time.perf_counter() - t0
        restarts = list(drill._pool_cache[2].restarts)
    finally:
        drill.close_pipeline()
    assert len(restarts) == 1, restarts
    downtime_s = restarts[0]["downtime_s"]
    bit_identical = got["losses"] == ref["losses"]
    emit("fault/worker_respawn", downtime_s * 1e6,
         f"{'bit-identical' if bit_identical else 'DIVERGED'}, "
         f"fit {wall:.2f} s",
         kind="worker_respawn", steps=steps, kill_at=steps // 2,
         restarts=len(restarts), exitcode=restarts[0]["exitcode"],
         downtime_s=round(downtime_s, 6), fit_wall_s=round(wall, 4),
         bit_identical=bit_identical, smoke=smoke)


def drill_resume(smoke: bool) -> None:
    from repro.api import Heta
    from repro.checkpoint import latest_step

    steps = 8 if smoke else 20
    half = steps // 2
    ref = Heta(_config(steps, pooled=False)).run()["losses"]

    with tempfile.TemporaryDirectory() as d:
        first = Heta(_config(steps, pooled=False))
        first.build_graph()
        first.partition()
        first.profile_and_cache()
        first.compile()
        first.fit(half)
        t0 = time.perf_counter()
        first.save(d)
        save_s = time.perf_counter() - t0
        assert latest_step(d) == half

        resumed = Heta(_config(steps, pooled=False))
        t0 = time.perf_counter()
        resumed.restore(d)  # runs the missing stages + loads the state
        restore_s = time.perf_counter() - t0
        tail = resumed.fit(steps - half)["losses"]
    bit_identical = tail == ref[half:]
    emit("fault/resume", restore_s * 1e6,
         f"{'bit-identical' if bit_identical else 'DIVERGED'}, "
         f"save {save_s*1e3:.1f} ms",
         kind="resume", steps=steps, interrupt_at=half,
         save_s=round(save_s, 4), restore_s=round(restore_s, 4),
         bit_identical=bit_identical, smoke=smoke)


def drill_degraded_serve(smoke: bool) -> None:
    from repro.api import Heta
    from repro.data.faults import FaultPlan, FaultSpec

    sess = Heta(_config(2, pooled=False))
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    sess.fit()
    sess.infer_all()
    # breaker_threshold=2 failures x (1 retry + 1) attempts = 4 faults
    sess.fault_plan = FaultPlan((FaultSpec("fail_flush", step=0, count=4),))
    server = sess.serve(max_batch=8, max_wait_ms=1.0, flush_retries=1,
                        retry_backoff_ms=0.1, breaker_threshold=2,
                        breaker_cooldown_ms=100.0)
    num_requests = 16 if smoke else 64
    n = sess.graph.num_nodes[sess.graph.target_type]
    rejected = 0
    t0 = time.perf_counter()
    for k in range(num_requests):
        try:
            server.query(np.arange(k % n, min(k % n + 4, n)))
        except Exception:
            rejected += 1
    wall = time.perf_counter() - t0
    time.sleep(0.15)  # past the cooldown: the next flush is the probe
    server.query(np.arange(4))
    stats = server.stats()
    sess.close_serving()
    emit("fault/degraded_serve", stats.p50_ms * 1e3,
         f"trips {stats.breaker_trips}, degraded {stats.degraded}, "
         f"rejected {rejected}",
         kind="degraded_serve", requests=num_requests + 1,
         rejected=rejected, trips=stats.breaker_trips,
         recoveries=stats.breaker_recoveries, degraded=stats.degraded,
         retries=stats.retries, breaker_state=stats.breaker_state,
         p50_ms=round(stats.p50_ms, 4), wall_s=round(wall, 4), smoke=smoke)
    assert rejected == 0, f"{rejected} callers rejected during degradation"


def run(smoke: bool = False) -> None:
    drill_worker_respawn(smoke)
    drill_resume(smoke)
    drill_degraded_serve(smoke)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized drills (same record schema)")
    ap.add_argument("--out", default="BENCH_fault.json")
    args = ap.parse_args()
    run(smoke=args.smoke)
    write_records(args.out)


if __name__ == "__main__":
    main()
