"""Paper Fig. 16 — accuracy equivalence: RAF trains the *same model* as the
vanilla execution (Prop 1 end-to-end), for every registered HGNN model.

All executors are driven through the uniform registry protocol
(``repro.api.executors``): one base config, ``with_executor()`` swaps the
execution model, and the sessions see identical seeds — hence identical
initial parameters, learnable tables and batch sequences.  The loss curves
must match step-for-step (the paper shows overlapping accuracy curves —
here the check is exact, not statistical).

The sweep covers all three models — rgcn, rgat and hgt — so the per-node-
type parameter structure (hgt, relation-module IR scopes) is exercised, not
just the per-relation one.  Tolerances: single-step equivalence is exact to
fp32 reassociation (the Prop-1 tests assert 1e-5/1e-6); *trained* curves
amplify that noise through Adam — attention models (rgat/hgt) more than
rgcn — so the step-for-step bound here is a few 1e-3 on a ~5.8 loss.
"""

from __future__ import annotations

from benchmarks._util import emit
from repro.api import DataConfig, Heta, HetaConfig, ModelConfig, PartitionConfig, RunConfig

MODELS = ("rgcn", "rgat", "hgt")
# (model, executor) -> max tolerated per-step loss deviation from vanilla.
# rgcn/rgat through the simulated raf executor are identical math modulo one
# reassociated sum (measured 0.0); raf_spmd adds the stacked representation
# + sparse learnable-row updates; hgt's attention stack amplifies fp noise
# hardest.  Bounds sit ~4x above measured so regressions trip them.
TOLERANCES = {
    ("rgcn", "raf"): 5e-4, ("rgat", "raf"): 5e-4, ("hgt", "raf"): 2e-2,
    ("rgcn", "raf_spmd"): 5e-3, ("rgat", "raf_spmd"): 1e-2,
    ("hgt", "raf_spmd"): 2e-2,
}
EXECUTORS = ("raf", "raf_spmd")


def run(steps: int = 8, model: str = None, executors=EXECUTORS):
    """Sweep models × executors; returns {model: {executor: max_diff}}."""
    models = (model,) if model else MODELS
    worst = {}
    for m in models:
        base = HetaConfig(
            data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(4, 3),
                            batch_size=32),
            partition=PartitionConfig(num_partitions=2),
            model=ModelConfig(model=m, hidden=32),
            run=RunConfig(steps=steps, lr=1e-2, seed=0),
        )
        losses = {
            ex: Heta(base.with_executor(ex)).run()["losses"]
            for ex in ("vanilla", *executors)
        }
        worst[m] = {}
        for ex in executors:
            tol = TOLERANCES[(m, ex)]
            max_diff = max(
                abs(lv - lx) for lv, lx in zip(losses["vanilla"], losses[ex])
            )
            worst[m][ex] = max_diff
            emit(f"equivalence/{m}/{ex}/max_loss_diff", 0.0,
                 f"{max_diff:.2e} (Prop 1, trained; tol {tol:.0e})")
            assert max_diff < tol, (m, ex, max_diff)
    return worst


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default=None, choices=MODELS,
                    help="restrict the sweep to one model")
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args()
    run(steps=args.steps, model=args.model)
