"""Paper Fig. 16 — accuracy equivalence: RAF trains the *same model* as the
vanilla execution (Prop 1 end-to-end).

Both executors start from identical parameters, share one logical copy of
the learnable features and classifier head (as Alg. 1 places them), and see
identical batches; the loss curves must match to float tolerance
step-for-step (the paper shows overlapping accuracy curves — here the check
is exact, not statistical)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit
from repro.core.hgnn import (
    HGNNConfig, batch_to_arrays, hgnn_loss, init_embed_tables, init_hgnn_params,
)
from repro.core.meta_partition import meta_partition
from repro.core.raf import assign_branches, raf_loss
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import ogbn_mag_like
from repro.optim.adam import AdamConfig, adam_init, adam_update


def run(steps: int = 8, model: str = "rgcn"):
    g = ogbn_mag_like(scale=0.002)
    mp = meta_partition(g, 2, num_layers=2)
    spec = SampleSpec.from_metatree(mp.metatree, (4, 3))
    sampler = NeighborSampler(g, spec, 32, seed=0)
    cfg = HGNNConfig(model=model, hidden=32, num_layers=2, num_classes=g.num_classes)
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    tables = {t: jnp.asarray(f) for t, f in g.features.items()}
    assignment = assign_branches(spec, mp)

    key = jax.random.PRNGKey(0)
    full = init_hgnn_params(key, cfg, spec, feat_dims)
    embed = init_embed_tables(jax.random.PRNGKey(1), cfg, g.num_nodes, feat_dims)
    head = full["head"]

    # one logical copy of shared leaves in both executors
    bundle_v = {"rel": full["rel"], "ntype": full["ntype"], "etype": full["etype"],
                "embed": embed, "head": head}
    rel_parts = [
        {k: init_hgnn_params(key, cfg, spec, feat_dims,
                             restrict_rels=assignment.relations_of(p, spec))[k]
         for k in ("rel", "ntype", "etype")}
        for p in range(2)
    ]
    bundle_r = {"parts": rel_parts, "embed": embed, "head": head}

    def vanilla_loss(bundle, a):
        return hgnn_loss(cfg, bundle, tables, a, spec)

    def raf_loss2(bundle, a):
        parts = [
            {**bundle["parts"][p], "embed": bundle["embed"], "head": bundle["head"]}
            for p in range(2)
        ]
        return raf_loss(cfg, parts, tables, a, spec, assignment)

    adam = AdamConfig(lr=1e-2)
    st_v = adam_init(bundle_v)
    st_r = adam_init(bundle_r)
    vgrad = jax.jit(jax.value_and_grad(vanilla_loss))
    rgrad = jax.jit(jax.value_and_grad(raf_loss2))

    max_diff = 0.0
    it = sampler.epoch(shuffle=True, seed=7)
    for i in range(steps):
        b = batch_to_arrays(next(it))
        lv, gv = vgrad(bundle_v, b)
        bundle_v, st_v = adam_update(adam, bundle_v, gv, st_v)
        lr_, gr = rgrad(bundle_r, b)
        bundle_r, st_r = adam_update(adam, bundle_r, gr, st_r)
        max_diff = max(max_diff, abs(float(lv) - float(lr_)))
        emit(f"equivalence/step{i}", 0.0,
             f"vanilla={float(lv):.6f} raf={float(lr_):.6f}")
    emit("equivalence/max_loss_diff", 0.0, f"{max_diff:.2e} (Prop 1, trained)")
    assert max_diff < 5e-4, max_diff
    return max_diff


if __name__ == "__main__":
    run()
