"""Paper Fig. 16 — accuracy equivalence: RAF trains the *same model* as the
vanilla execution (Prop 1 end-to-end).

Both executors are driven through the uniform registry protocol
(``repro.api.executors``): one base config, ``with_executor()`` swaps the
execution model, and the two sessions see identical seeds — hence identical
initial parameters, learnable tables and batch sequences.  The loss curves
must match to float tolerance step-for-step (the paper shows overlapping
accuracy curves — here the check is exact, not statistical)."""

from __future__ import annotations

from benchmarks._util import emit
from repro.api import DataConfig, Heta, HetaConfig, ModelConfig, PartitionConfig, RunConfig

EXECUTORS = ("vanilla", "raf")


def run(steps: int = 8, model: str = "rgcn"):
    base = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(4, 3),
                        batch_size=32),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(model=model, hidden=32),
        run=RunConfig(steps=steps, lr=1e-2, seed=0),
    )
    losses = {ex: Heta(base.with_executor(ex)).run()["losses"] for ex in EXECUTORS}

    max_diff = 0.0
    for i in range(steps):
        lv, lr_ = losses["vanilla"][i], losses["raf"][i]
        max_diff = max(max_diff, abs(lv - lr_))
        emit(f"equivalence/step{i}", 0.0, f"vanilla={lv:.6f} raf={lr_:.6f}")
    emit("equivalence/max_loss_diff", 0.0, f"{max_diff:.2e} (Prop 1, trained)")
    assert max_diff < 5e-4, max_diff
    return max_diff


if __name__ == "__main__":
    run()
