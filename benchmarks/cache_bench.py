"""Paper Fig. 11/12 — GPU cache ablation and hit rates.

Three cache configurations over a fixed sampled workload: no cache,
hotness-only allocation, and Heta's hotness × miss-penalty allocation.
Reported: per-node-type hit rates (Fig. 12) and the modeled miss time per
epoch (the penalty model is the same o_a used for allocation, so the
comparison isolates the *allocation policy*, which is the paper's claim)."""

from __future__ import annotations

import numpy as np

from benchmarks._util import emit
from repro.core.metatree import build_metatree
from repro.embed import EmbedEngine, presample_hotness, profile_miss_penalties
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import donor_like, mag240m_like


def _workload(g, spec, engine, batches, batch_size, seed=11):
    from repro.embed.profiler import row_bytes

    sampler = NeighborSampler(g, spec, batch_size, seed=seed)
    engine.cache.reset_stats()
    it = sampler.epoch(shuffle=True, seed=seed)
    uncached_time = 0.0  # types with no cache allocation: every row misses
    for _ in range(batches):
        try:
            b = next(it)
        except StopIteration:
            break
        for t, ids in b.unique_nodes_per_type().items():
            engine.fetch(t, ids)
            if t not in engine.cache.caches:
                pen = engine.penalties
                uncached_time += len(ids) * pen.ratios[t] * row_bytes(
                    pen.dims[t], pen.learnable[t]
                )
    return (
        engine.cache.miss_time(engine.penalties) + uncached_time,
        engine.cache.hit_rates(),
    )


def run(cache_kb: int = 256, batches: int = 10, batch_size: int = 128):
    results = {}
    for name, maker in (("mag240m", mag240m_like), ("donor", donor_like)):
        g = maker()
        tree = build_metatree(g.metagraph(), g.target_type, 2)
        spec = SampleSpec.from_metatree(tree, [10, 5])
        hot = presample_hotness(g, spec, batch_size, epochs=2, max_batches=20)
        pen = profile_miss_penalties(g, measured=False)

        times = {}
        for mode, kwargs in (
            ("none", dict(cache_bytes=0)),
            ("hotness", dict(cache_bytes=cache_kb << 10, hotness_only=True)),
            ("miss-penalty", dict(cache_bytes=cache_kb << 10)),
        ):
            eng = EmbedEngine(g, 64, hot, pen, **kwargs)
            t, hits = _workload(g, spec, eng, batches, batch_size)
            times[mode] = t
            if mode == "miss-penalty":
                for ty, hr in sorted(hits.items()):
                    emit(f"cache/{name}/hit_rate/{ty}", 0.0, f"{hr:.2f}")
        speed_none = times["none"] / max(times["miss-penalty"], 1e-12)
        speed_hot = times["hotness"] / max(times["miss-penalty"], 1e-12)
        emit(f"cache/{name}/miss_time_none", times["none"] * 1e6, "no cache")
        emit(f"cache/{name}/miss_time_hotness", times["hotness"] * 1e6, "hotness-only")
        emit(f"cache/{name}/miss_time_misspenalty", times["miss-penalty"] * 1e6,
             f"{speed_none:.2f}x vs none, {speed_hot:.2f}x vs hotness (paper: ≤1.6x/≤1.15x)")
        results[name] = times
        assert times["miss-penalty"] <= times["none"]
    return results


if __name__ == "__main__":
    run()
