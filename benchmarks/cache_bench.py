"""Paper Fig. 11/12 — GPU cache ablation and hit rates.

Three cache configurations over a fixed sampled workload: no cache,
hotness-only allocation, and Heta's hotness × miss-penalty allocation.
Reported: per-node-type hit rates (Fig. 12) and the modeled miss time per
epoch (the penalty model is the same o_a used for allocation, so the
comparison isolates the *allocation policy*, which is the paper's claim).

A fourth section races **online re-admission** against the one-shot
allocation on a Zipf-skewed trace whose hot set the pre-sampled profile
gets wrong: ``EmbedEngine.rebalance`` re-scores residency from the
observed access counters (§6 online extension), and the benchmark asserts
the online hit rate is at least the one-shot's.  Records land in
``BENCH_cache.json``."""

from __future__ import annotations

import numpy as np

from benchmarks._util import emit, write_records
from repro.core.metatree import build_metatree
from repro.embed import EmbedEngine, presample_hotness, profile_miss_penalties
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import donor_like, mag240m_like

OUT_JSON = "BENCH_cache.json"


def _workload(g, spec, engine, batches, batch_size, seed=11):
    from repro.embed.profiler import row_bytes

    sampler = NeighborSampler(g, spec, batch_size, seed=seed)
    engine.cache.reset_stats()
    it = sampler.epoch(shuffle=True, seed=seed)
    uncached_time = 0.0  # types with no cache allocation: every row misses
    for _ in range(batches):
        try:
            b = next(it)
        except StopIteration:
            break
        for t, ids in b.unique_nodes_per_type().items():
            engine.fetch(t, ids)
            if t not in engine.cache.caches:
                pen = engine.penalties
                uncached_time += len(ids) * pen.ratios[t] * row_bytes(
                    pen.dims[t], pen.learnable[t]
                )
    return (
        engine.cache.miss_time(engine.penalties) + uncached_time,
        engine.cache.hit_rates(),
    )


def _zipf_draw(rng, perm, n, k=256, a=1.5):
    """Zipf-skewed ids over a shuffled permutation (hot set ≠ low ids)."""
    return perm[np.minimum(rng.zipf(a, size=k) - 1, n - 1)]


def run_online(cache_kb: int = 128, rounds: int = 30):
    """Online re-admission vs one-shot allocation on a skewed trace.

    The engine's one-shot allocation trusts a deliberately *misleading*
    uniform hotness prior; the trace is Zipf over a shuffled permutation, so
    the true hot set is unknowable a priori.  After ``rounds`` batches the
    engine rebalances from its observed access counters and the same trace
    distribution is replayed.  Asserts online ≥ one-shot (the acceptance
    row for the §6 online extension)."""
    from repro.embed.profiler import HotnessProfile

    g = mag240m_like()
    pen = profile_miss_penalties(g, measured=False)
    uni = HotnessProfile(counts={t: np.ones(n) for t, n in g.num_nodes.items()})
    eng = EmbedEngine(g, 64, uni, pen, cache_bytes=cache_kb << 10)

    rng = np.random.default_rng(7)
    t = "author"
    n = g.num_nodes[t]
    perm = rng.permutation(n)

    eng.cache.reset_stats()
    for _ in range(rounds):
        eng.fetch(t, _zipf_draw(rng, perm, n))
    one_shot = eng.cache.hit_rates().get(t, 0.0)

    eng.rebalance()
    eng.cache.reset_stats()
    for _ in range(rounds):
        eng.fetch(t, _zipf_draw(rng, perm, n))
    online = eng.cache.hit_rates().get(t, 0.0)

    emit("cache/online/one_shot_hit_rate", 0.0, f"{one_shot:.3f} (uniform prior, Zipf trace)",
         hit_rate=round(one_shot, 4), ntype=t, policy="one_shot")
    emit("cache/online/online_hit_rate", 0.0,
         f"{online:.3f} after rebalance ({online - one_shot:+.3f} vs one-shot)",
         hit_rate=round(online, 4), ntype=t, policy="online",
         delta_vs_one_shot=round(online - one_shot, 4))
    assert online >= one_shot, (online, one_shot)
    assert eng.cache.consistency_check()
    return {"one_shot": one_shot, "online": online}


def run(cache_kb: int = 256, batches: int = 10, batch_size: int = 128):
    results = {}
    for name, maker in (("mag240m", mag240m_like), ("donor", donor_like)):
        g = maker()
        tree = build_metatree(g.metagraph(), g.target_type, 2)
        spec = SampleSpec.from_metatree(tree, [10, 5])
        hot = presample_hotness(g, spec, batch_size, epochs=2, max_batches=20)
        pen = profile_miss_penalties(g, measured=False)

        times = {}
        for mode, kwargs in (
            ("none", dict(cache_bytes=0)),
            ("hotness", dict(cache_bytes=cache_kb << 10, hotness_only=True)),
            ("miss-penalty", dict(cache_bytes=cache_kb << 10)),
        ):
            eng = EmbedEngine(g, 64, hot, pen, **kwargs)
            t, hits = _workload(g, spec, eng, batches, batch_size)
            times[mode] = t
            if mode == "miss-penalty":
                for ty, hr in sorted(hits.items()):
                    emit(f"cache/{name}/hit_rate/{ty}", 0.0, f"{hr:.2f}",
                         hit_rate=round(hr, 4), ntype=ty)
        speed_none = times["none"] / max(times["miss-penalty"], 1e-12)
        speed_hot = times["hotness"] / max(times["miss-penalty"], 1e-12)
        emit(f"cache/{name}/miss_time_none", times["none"] * 1e6, "no cache",
             policy="none")
        emit(f"cache/{name}/miss_time_hotness", times["hotness"] * 1e6, "hotness-only",
             policy="hotness")
        emit(f"cache/{name}/miss_time_misspenalty", times["miss-penalty"] * 1e6,
             f"{speed_none:.2f}x vs none, {speed_hot:.2f}x vs hotness (paper: ≤1.6x/≤1.15x)",
             policy="miss_penalty", speedup_vs_none=round(speed_none, 3),
             speedup_vs_hotness=round(speed_hot, 3))
        results[name] = times
        assert times["miss-penalty"] <= times["none"]
    return results


if __name__ == "__main__":
    run()
    run_online()
    write_records(OUT_JSON)
