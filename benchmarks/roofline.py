"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) on the single-pod mesh, derives the three roofline terms
from the compiled artifact (TPU v5e constants):

  compute    = HLO_FLOPs(per-device) / 197e12      [s]
  memory     = HLO_bytes(per-device) / 819e9       [s]
  collective = collective_bytes(per-device) / 50e9 [s]

cost_analysis() is evaluated on the per-device SPMD module, so device terms
come directly; collective bytes are parsed from the compiled HLO (result
shapes of all-reduce/all-gather/reduce-scatter/all-to-all/collective-permute).

MODEL_FLOPS uses the 6·N·T (train) / 2·N·T (inference) convention with
N = active parameters (MoE counts top-k experts only); the ratio
MODEL_FLOPS / (HLO_FLOPs × chips) shows how much compiled compute is
"useful" — remat recompute, attention FLOPs and optimizer work land in the
denominator.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 per chip, TPU v5e
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

__all__ = ["load_records", "roofline_row", "build_table", "render_markdown"]


def load_records(dryrun_dir: str, mesh: str = "pod16x16") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def model_flops(rec: Dict) -> float:
    """6·N_active·T for training, 2·N_active·T for prefill/decode."""
    from repro.configs.base import INPUT_SHAPES

    shape = INPUT_SHAPES[rec["shape"]]
    n = rec["active_params"]
    if rec.get("step_kind") == "train":
        return 6.0 * n * shape.tokens
    if rec.get("step_kind") == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["num_devices"]
    compute = rec["flops"] / PEAK_FLOPS
    memory = rec["bytes_accessed"] / HBM_BW
    coll = rec["collectives"].get("total", 0) / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    ratio = mf / max(rec["flops"] * chips, 1.0)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "kind": rec.get("step_kind"),
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": rec["flops"] * chips,
        "useful_ratio": ratio,
        "collectives": rec["collectives"],
        "memory_bytes": rec["memory"],
    }


_ADVICE = {
    "compute": "raise MFU: larger per-chip tiles (less padding), fuse elementwise chains, drop remat where memory allows",
    "memory": "cut HBM traffic: fuse producer→consumer chains (flash-attention-style), wider arithmetic intensity per pass, bf16 intermediates",
    "collective": "cut wire bytes: reduce-scatter+all-gather instead of all-reduce, shard the reduction axis differently, overlap collectives with compute",
}


def build_table(dryrun_dir: str, mesh: str = "pod16x16") -> List[Dict]:
    rows = []
    for rec in load_records(dryrun_dir, mesh):
        if rec.get("status") == "skip":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skip": rec["reason"]})
            continue
        row = roofline_row(rec)
        if row:
            row["advice"] = _ADVICE[row["dominant"]]
            rows.append(row)
    return rows


def render_markdown(rows: List[Dict]) -> str:
    head = (
        "| arch | shape | kind | compute (ms) | memory (ms) | collective (ms) "
        "| bound | useful (6NT/HLO) |\n|---|---|---|---|---|---|---|---|"
    )
    lines = [head]
    for r in rows:
        if "skip" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIP | {r['skip']} |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
            f"| {r['collective_s']*1e3:.2f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} |"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = build_table(args.dryrun_dir, args.mesh)
    print(render_markdown(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
