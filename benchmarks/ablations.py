"""Paper Fig. 13/14/15 — hidden-dim, scalability, and fanout ablations.

All three reduce to the same quantity the paper varies: per-batch
communication volume under each execution model.  Heta's is Θ(B·hidden) —
independent of partition count, fanout and hops (meta-partitioning confines
boundary nodes to targets); the vanilla model's grows with all of them.
Each point is one ``Heta`` session driven to the partition stage; bytes come
from ``PartitionReport.raf_bytes`` / ``session.comm_report``."""

from __future__ import annotations

from benchmarks._util import emit
from repro.api import DataConfig, Heta, HetaConfig, PartitionConfig


def _partitioned(dataset: str, scale: float, fanouts, batch: int, parts: int = 2,
                 graph=None):
    """One session driven to the partition stage; pass ``graph`` to reuse a
    built HetG across sweep points instead of re-synthesizing it."""
    sess = Heta(HetaConfig(
        data=DataConfig(dataset=dataset, scale=scale, fanouts=fanouts,
                        batch_size=batch),
        partition=PartitionConfig(num_partitions=parts),
    ))
    sess.build_graph(graph=graph)
    return sess, sess.partition()


def hidden_dim(batch: int = 1024):
    """Fig. 13: Heta comm grows linearly in hidden; stays far below feature
    fetching until hidden ≈ feature dims."""
    sess, part = _partitioned("ogbn-mag", 0.01, (25, 20), batch)
    v = sess.comm_report(bytes_per_elem=2)["vanilla_feat"]
    out = {}
    for h in (64, 128, 256, 512, 1024):
        m = part.raf_bytes(batch, h, 2)
        out[h] = m
        emit(f"ablation/hidden{h}/heta_MB", 0.0,
             f"{m/1e6:.2f}MB vs vanilla {v/1e6:.1f}MB ({v/m:.0f}x)")
    assert out[1024] == 16 * out[64]  # exactly linear in hidden
    return out


def scalability():
    """Fig. 14: Heta's comm per step is constant in the number of partitions
    (boundary = target nodes); vanilla's remote-feature share grows."""
    batch = 1024
    g = None
    for p in (2, 3, 4):
        sess, part = _partitioned("ogbn-mag", 0.01, (25, 20), batch, parts=p,
                                  graph=g)
        g = sess.graph  # build once, repartition per sweep point
        comm = sess.comm_report(bytes_per_elem=2)
        heta_per_worker = comm["raf_meta"] / p
        v = comm["vanilla_feat"] / p
        emit(f"ablation/parts{p}/per_worker_MB", 0.0,
             f"heta={heta_per_worker/1e6:.3f}MB vanilla={v/1e6:.2f}MB")


def fanout():
    """Fig. 15: larger fanouts / more hops grow vanilla comm; Heta constant."""
    batch = 256
    prev_v = 0
    g = None
    for fanouts in ((10, 10), (25, 20), (25, 20, 20)):
        sess, part = _partitioned("igb-het", 0.0005, fanouts, batch, graph=g)
        g = sess.graph  # build once, re-spec per fanout
        comm = sess.comm_report(bytes_per_elem=2)
        h, v = comm["raf_meta"], comm["vanilla_feat"]
        emit(f"ablation/fanout{'x'.join(map(str, fanouts))}", 0.0,
             f"heta={h/1e6:.3f}MB vanilla={v/1e6:.1f}MB ({v/max(h,1):.0f}x)")
        assert h == 2 * batch * 64 * 2  # constant: Θ(B·hidden), fanout-free
        assert v >= prev_v
        prev_v = v


def run():
    hidden_dim()
    scalability()
    fanout()
    return True


if __name__ == "__main__":
    run()
