"""Paper Fig. 13/14/15 — hidden-dim, scalability, and fanout ablations.

All three reduce to the same quantity the paper varies: per-batch
communication volume under each execution model.  Heta's is Θ(B·hidden) —
independent of partition count, fanout and hops (meta-partitioning confines
boundary nodes to targets); the vanilla model's grows with all of them."""

from __future__ import annotations

import numpy as np

from benchmarks._util import emit
from repro.core.comm import vanilla_comm_bytes
from repro.core.meta_partition import meta_partition, random_edge_cut
from repro.core.raf import assign_branches, raf_comm_bytes
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import igb_het_like, ogbn_mag_like


def hidden_dim(batch: int = 1024):
    """Fig. 13: Heta comm grows linearly in hidden; stays far below feature
    fetching until hidden ≈ feature dims."""
    g = ogbn_mag_like(scale=0.01)
    mp = meta_partition(g, 2, num_layers=2)
    spec = SampleSpec.from_metatree(mp.metatree, (25, 20))
    b = NeighborSampler(g, spec, batch, seed=0).sample_batch(g.train_nodes[:batch])
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    v = vanilla_comm_bytes(b, random_edge_cut(g, 2), feat_dims, bytes_per_elem=2)
    assign = assign_branches(spec, mp)
    out = {}
    for h in (64, 128, 256, 512, 1024):
        m = raf_comm_bytes(spec, assign, batch, h, 2)
        out[h] = m
        emit(f"ablation/hidden{h}/heta_MB", 0.0,
             f"{m/1e6:.2f}MB vs vanilla {v/1e6:.1f}MB ({v/m:.0f}x)")
    assert out[1024] == 16 * out[64]  # exactly linear in hidden
    return out


def scalability():
    """Fig. 14: Heta's comm per step is constant in the number of partitions
    (boundary = target nodes); vanilla's remote-feature share grows."""
    g = ogbn_mag_like(scale=0.01)
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    batch = 1024
    for p in (2, 3, 4):
        mp = meta_partition(g, p, num_layers=2)
        spec = SampleSpec.from_metatree(mp.metatree, (25, 20))
        b = NeighborSampler(g, spec, batch, seed=0).sample_batch(g.train_nodes[:batch])
        heta_per_worker = raf_comm_bytes(spec, assign_branches(spec, mp), batch, 64, 2) / p
        v = vanilla_comm_bytes(b, random_edge_cut(g, p), feat_dims, bytes_per_elem=2) / p
        emit(f"ablation/parts{p}/per_worker_MB", 0.0,
             f"heta={heta_per_worker/1e6:.3f}MB vanilla={v/1e6:.2f}MB")


def fanout():
    """Fig. 15: larger fanouts / more hops grow vanilla comm; Heta constant."""
    g = igb_het_like(scale=0.0005)
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    batch = 256
    prev_v = 0
    for fanouts in ((10, 10), (25, 20), (25, 20, 20)):
        mp = meta_partition(g, 2, num_layers=len(fanouts))
        spec = SampleSpec.from_metatree(mp.metatree, fanouts)
        b = NeighborSampler(g, spec, batch, seed=0).sample_batch(g.train_nodes[:batch])
        h = raf_comm_bytes(spec, assign_branches(spec, mp), batch, 64, 2)
        v = vanilla_comm_bytes(b, random_edge_cut(g, 2), feat_dims, bytes_per_elem=2)
        emit(f"ablation/fanout{'x'.join(map(str, fanouts))}", 0.0,
             f"heta={h/1e6:.3f}MB vanilla={v/1e6:.1f}MB ({v/max(h,1):.0f}x)")
        assert h == 2 * batch * 64 * 2  # constant: Θ(B·hidden), fanout-free
        assert v >= prev_v
        prev_v = v


def run():
    hidden_dim()
    scalability()
    fanout()
    return True


if __name__ == "__main__":
    run()
