"""Serving-tier benchmark: micro-batch latency/QPS + full-graph inference.

Drives the ``repro.serve`` tier end-to-end on a degree-capped
quickstart-sized graph: train briefly, materialize embeddings with
``Heta.infer_all`` (reported as nodes/s), then sweep micro-batch flush
settings — concurrent client threads firing lookups at the
``EmbeddingServer`` — recording p50/p99 latency, QPS and per-type cache
hit rates per setting.  Requests follow a Zipf-ish skew over node ids so
the serve-side ``FeatureCache`` sees a realistic hot set.

``--smoke`` shrinks the workload for CI and (as everywhere) the records
land in ``BENCH_serve.json`` via ``write_records``.
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from benchmarks._util import emit, write_records

# (max_batch, max_wait_ms): a latency-biased and a throughput-biased policy
SETTINGS = ((8, 1.0), (64, 4.0))


def _fire(server, *, num_requests: int, concurrency: int, ids_per_request: int,
          num_target: int, seed: int = 0) -> float:
    """Closed-loop clients: each thread submits its share of lookups with a
    Zipf-skewed id mix.  Returns the wall seconds for the whole volley."""

    def client(k: int) -> None:
        rng = np.random.default_rng(seed + k)
        for _ in range(num_requests // concurrency):
            # zipf over ranks, folded into the id range: a hot head + long tail
            nids = (rng.zipf(1.3, ids_per_request) - 1) % num_target
            server.query(nids)

    threads = [threading.Thread(target=client, args=(k,))
               for k in range(concurrency)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def run(smoke: bool = False):
    from repro.api import DataConfig, Heta, HetaConfig, ModelConfig, RunConfig
    from repro.serve import bounded_graph

    steps = 2 if smoke else 5
    num_requests = 64 if smoke else 512
    concurrency = 4 if smoke else 8
    cfg = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(4, 4),
                        batch_size=16),
        model=ModelConfig(model="rgcn", hidden=32, num_heads=2,
                          learnable_dim=16),
        run=RunConfig(executor="raf_spmd", steps=steps, seed=0),
    )
    sess = Heta(cfg)
    g = bounded_graph(sess.build_graph(), 8)
    sess.build_graph(g)
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    sess.fit()

    t0 = time.perf_counter()
    store = sess.infer_all()
    dt = time.perf_counter() - t0
    total_nodes = sum(a.shape[0] for a in store.embeddings.values())
    emit("serve/infer_all", dt * 1e6,
         f"{total_nodes / dt:,.0f} nodes/s",
         kind="infer_all", nodes=total_nodes, nodes_per_s=round(total_nodes / dt, 1),
         mib=round(store.nbytes / 2**20, 3), smoke=smoke)

    n_target = g.num_nodes[g.target_type]
    results = []
    for max_batch, max_wait_ms in SETTINGS:
        server = sess.serve(max_batch=max_batch, max_wait_ms=max_wait_ms)
        # warm the jitted scoring step out of the timed volley
        server.query(np.arange(min(4, n_target)))
        server.reset_stats()
        wall = _fire(server, num_requests=num_requests, concurrency=concurrency,
                     ids_per_request=4, num_target=n_target)
        stats = server.stats()
        emit(f"serve/query/b{max_batch}_w{max_wait_ms}",
             stats.p50_ms * 1e3,
             f"p99 {stats.p99_ms:.2f} ms, {stats.qps:,.0f} qps",
             kind="serve", max_batch=max_batch, max_wait_ms=max_wait_ms,
             concurrency=concurrency, requests=stats.count,
             flushes=stats.flushes,
             p50_ms=round(stats.p50_ms, 4), p99_ms=round(stats.p99_ms, 4),
             qps=round(stats.qps, 1),
             hit_rates={t: round(r, 4) for t, r in stats.hit_rates.items()},
             wall_s=round(wall, 4), smoke=smoke)
        results.append(stats)
        # sess.serve() memoizes one server per session; drop it so the next
        # setting builds a fresh batcher (the store stays materialized)
        srv, sess._server = sess._server, None
        srv.close()
    sess.close_serving()
    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload (same record schema)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    run(smoke=args.smoke)
    write_records(args.out)


if __name__ == "__main__":
    main()
