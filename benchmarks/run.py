"""Benchmark harness — one module per paper table/figure.

  comm_volume   — §4 worked example (92.3 → 8.0 → 0.5 MB)
  epoch_time    — Fig. 8/9 epoch time (measured + α-β projection)
  breakdown     — Fig. 10 stage breakdown
  partitioning  — Table 2 partitioning time/memory
  cache_bench   — Fig. 11/12 cache ablation + hit rates
  ablations     — Fig. 13/14/15 hidden-dim / scalability / fanout
  equivalence   — Fig. 16 accuracy (loss) equivalence, exact
  kernels_bench — Pallas kernel oracle timings + TPU static properties

Output: ``name,us_per_call,derived`` CSV rows (printed as each module runs).
Roofline tables (§Dry-run/§Roofline) are produced by ``benchmarks.roofline``
from the dry-run artifacts, which require the 512-device environment.
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        ablations,
        breakdown,
        cache_bench,
        comm_volume,
        epoch_time,
        equivalence,
        kernels_bench,
        partitioning,
    )

    modules = [
        ("comm_volume", comm_volume),
        ("partitioning", partitioning),
        ("cache_bench", cache_bench),
        ("ablations", ablations),
        ("equivalence", equivalence),
        ("kernels_bench", kernels_bench),
        ("breakdown", breakdown),
        ("epoch_time", epoch_time),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failed.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
