"""Paper §4 worked example — per-batch communication volume.

Setting mirrors the paper: 2-layer HGNN, hidden 64, fanout {25, 20}, batch
1024 training nodes, fp16 payloads, 2 partitions, MAG240M-like schema (paper
feature dim 768, learnable dim 64).  The paper reports 92.3 MB (vanilla
feature fetching) → 8.0 MB (RAF, naive relation placement) → 0.5 MB
(RAF + meta-partitioning).  Bytes are counted exactly by the session's
``comm_report`` stage — same accounting as the paper.

The sweep runs all three HGNN models: RAF's exchange payload is the root
partial [B, hidden] regardless of the relation module (Prop 2 — per-node-
type parameters like hgt's change *what* each partition computes, never
*what crosses the network*), so the per-model rows double as a regression
check that the §4 accounting stays model-invariant.
"""

from __future__ import annotations

from benchmarks._util import emit, net_time
from repro.api import DataConfig, Heta, HetaConfig, ModelConfig, PartitionConfig, RunConfig

MODELS = ("rgcn", "rgat", "hgt")


def run(scale: float = 0.0005, batch: int = 1024, hidden: int = 64,
        fanouts=(25, 20), seed: int = 0, models=MODELS):
    out = {}
    for model in models:
        sess = Heta(HetaConfig(
            data=DataConfig(dataset="mag240m", scale=scale, fanouts=fanouts,
                            batch_size=batch),
            partition=PartitionConfig(num_partitions=2),
            model=ModelConfig(model=model, hidden=hidden, learnable_dim=64),
            run=RunConfig(seed=seed),
        ))
        sess.build_graph()
        sess.partition()
        comm = sess.comm_report(bytes_per_elem=2)

        vanilla = comm["vanilla_feat"] + comm["vanilla_update"]
        naive, meta = comm["raf_naive"], comm["raf_meta"]

        emit(f"comm_volume/{model}/vanilla_MB", net_time(vanilla) * 1e6,
             f"{vanilla/1e6:.1f}MB (paper: 92.3MB at full scale)")
        emit(f"comm_volume/{model}/raf_naive_MB", net_time(naive) * 1e6,
             f"{naive/1e6:.2f}MB (paper: 8.0MB)")
        emit(f"comm_volume/{model}/raf_meta_MB", net_time(meta) * 1e6,
             f"{meta/1e6:.2f}MB (paper: 0.5MB)")
        ratio = vanilla / max(meta, 1)
        emit(f"comm_volume/{model}/reduction_x", 0.0, f"{ratio:.0f}x vanilla->meta")
        assert meta < naive < vanilla
        out[model] = {"vanilla": vanilla, "naive": naive, "meta": meta}
    # Prop-2 invariance: every counter — vanilla feature fetch, naive RAF,
    # meta RAF — is independent of the relation module
    first = out[next(iter(out))]
    assert all(out[m] == first for m in out), out
    return out


if __name__ == "__main__":
    run()
