"""Paper §4 worked example — per-batch communication volume.

Setting mirrors the paper: 2-layer R-GCN, hidden 64, fanout {25, 20}, batch
1024 training nodes, fp16 payloads, 2 partitions, MAG240M-like schema (paper
feature dim 768, learnable dim 64).  The paper reports 92.3 MB (vanilla
feature fetching) → 8.0 MB (RAF, naive relation placement) → 0.5 MB
(RAF + meta-partitioning).  Bytes here are counted exactly from a sampled
batch and the partition assignment — same accounting as the paper.
"""

from __future__ import annotations

import numpy as np

from benchmarks._util import emit, net_time
from repro.core.comm import vanilla_comm_bytes, vanilla_update_bytes
from repro.core.meta_partition import meta_partition, random_edge_cut
from repro.core.raf import assign_branches, raf_comm_bytes, random_branch_assignment
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import mag240m_like


def run(scale: float = 0.0005, batch: int = 1024, hidden: int = 64,
        fanouts=(25, 20), seed: int = 0):
    g = mag240m_like(scale=scale, seed=seed)
    mp = meta_partition(g, 2, num_layers=len(fanouts))
    spec = SampleSpec.from_metatree(mp.metatree, fanouts)
    sampler = NeighborSampler(g, spec, batch, seed=seed)
    b = sampler.sample_batch(g.train_nodes[:batch])
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}

    cut = random_edge_cut(g, 2, seed=seed)
    v_feat = vanilla_comm_bytes(b, cut, feat_dims, learnable_dim=64, bytes_per_elem=2)
    v_upd = vanilla_update_bytes(b, cut, g, learnable_dim=64, bytes_per_elem=2)
    vanilla = v_feat + v_upd

    naive = raf_comm_bytes(
        spec, random_branch_assignment(spec, 2, seed=seed + 1), batch, hidden, 2
    )
    meta = raf_comm_bytes(spec, assign_branches(spec, mp), batch, hidden, 2)

    emit("comm_volume/vanilla_MB", net_time(vanilla) * 1e6,
         f"{vanilla/1e6:.1f}MB (paper: 92.3MB at full scale)")
    emit("comm_volume/raf_naive_MB", net_time(naive) * 1e6,
         f"{naive/1e6:.2f}MB (paper: 8.0MB)")
    emit("comm_volume/raf_meta_MB", net_time(meta) * 1e6,
         f"{meta/1e6:.2f}MB (paper: 0.5MB)")
    ratio = vanilla / max(meta, 1)
    emit("comm_volume/reduction_x", 0.0, f"{ratio:.0f}x vanilla->meta")
    assert meta < naive < vanilla
    return {"vanilla": vanilla, "naive": naive, "meta": meta}


if __name__ == "__main__":
    run()
