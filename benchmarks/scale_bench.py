"""DESIGN.md §13 — hierarchical scale-out benchmarks.

Three legs, all machine-readable (``--records-out BENCH_scale.json``):

* ``run_comm_invariance`` — Prop-2 at 10x the comm bench's graph: a
  MAG240M-schema topology built **out-of-core** by ``mag240m_stream``
  (scale ≥ 0.005 vs ``comm_volume.py``'s 0.0005 in-RAM graphs), attached
  from its mmap store, hierarchically partitioned, and byte-counted by
  ``comm_report``'s ``hier_*`` keys for every relation module.  The
  inter-group RAF payload (``hier_level0_raf``) must be bit-equal across
  rgcn/rgat/hgt: per-node-type parameters change *what each group
  computes*, never *what crosses the network* (paper Prop 2).
* ``run_dp_parity`` — 2-trainer data-parallel fit (``scale.mode=
  "global"``, the stripe discipline) vs the single-process fit on the
  same config: the loss trajectories must match **bit for bit**
  (``repro.data.dp_trainer`` publishes exact state bytes; no tolerance).
* ``run_epoch_time`` — honest wall-clock of the same fit single-process
  vs 2-trainer DP.  ``cpus`` is stamped on every row: on a container
  with fewer cores than trainers the DP run *loses* (two jax processes
  time-slice one core) and the row says so — the speedup is a recording,
  never a gate.
"""

from __future__ import annotations

import os
import time

from benchmarks._util import emit, write_records
from repro.api import (
    DataConfig, Heta, HetaConfig, ModelConfig, PartitionConfig, RunConfig,
)

MODELS = ("rgcn", "rgat", "hgt")


def _stream_store(scale: float, seed: int = 4):
    from repro.graph.synthetic import mag240m_stream

    t0 = time.perf_counter()
    store = mag240m_stream(scale=scale, seed=seed)
    return store, time.perf_counter() - t0


def run_comm_invariance(scale: float = 0.005, batch: int = 1024,
                        hidden: int = 64, fanouts=(25, 20),
                        hierarchy=(2, 2), seed: int = 0, models=MODELS):
    """Prop-2 rows over the out-of-core store (see module docstring)."""
    from repro.graph.mmap_store import attach_any

    store, build_s = _stream_store(scale)
    att = attach_any(store.handle)
    g = att.graph
    num_edges = sum(csr.indices.size for csr in g.relations.values())
    emit("scale/comm/store_build", build_s * 1e6,
         f"{num_edges / 1e6:.1f}M edges, {store.nbytes / 1e9:.2f} GB "
         f"streamed out-of-core at scale={scale}",
         kind="hier_comm", scale=scale, num_edges=int(num_edges),
         store_bytes=int(store.nbytes))
    out = {}
    try:
        for model in models:
            sess = Heta(HetaConfig(
                data=DataConfig(dataset="mag240m", scale=scale,
                                fanouts=fanouts, batch_size=batch),
                partition=PartitionConfig(num_partitions=2),
                model=ModelConfig(model=model, hidden=hidden,
                                  learnable_dim=64),
                run=RunConfig(seed=seed),
            ).updated(scale=dict(num_trainers=hierarchy[0] * hierarchy[1],
                                 hierarchy=tuple(hierarchy))))
            sess.build_graph(graph=g)
            sess.partition()
            comm = sess.comm_report(bytes_per_elem=2)
            hier = {k: v for k, v in comm.items() if k.startswith("hier_")}
            emit(f"scale/comm/{model}/level0_raf_MB", 0.0,
                 f"{hier['hier_level0_raf'] / 1e6:.2f}MB inter-group RAF "
                 "partials", kind="hier_comm", model=model, scale=scale,
                 num_edges=int(num_edges), hierarchy=list(hierarchy),
                 **{k: int(v) for k, v in hier.items()})
            out[model] = hier
    finally:
        att.close()
        store.unlink()
    first = out[models[0]]
    assert all(out[m]["hier_level0_raf"] == first["hier_level0_raf"]
               for m in models), out
    assert all(out[m]["hier_total_wire"] == first["hier_total_wire"]
               for m in models), out
    emit("scale/comm/prop2_invariance", 0.0,
         f"level0_raf identical across {'/'.join(models)} at "
         f"{num_edges / 1e6:.1f}M edges", kind="hier_comm",
         models=list(models), invariant=True, num_edges=int(num_edges),
         level0_raf=int(first["hier_level0_raf"]))
    return out


def _fit_cfg(scale_on: bool, steps: int, store: str = "shm"):
    cfg = HetaConfig.from_dict(dict(
        data=dict(dataset="ogbn-mag", scale=0.01, fanouts=(4, 3),
                  batch_size=32),
        model=dict(hidden=32, num_heads=2, train_learnable=False),
        run=dict(executor="raf_spmd", steps=steps, seed=7, log_every=0),
        pipeline=dict(num_workers=0),
    ))
    if scale_on:
        cfg = cfg.updated(scale=dict(num_trainers=2, mode="global",
                                     store=store))
    return cfg


def _timed_fit(cfg):
    sess = Heta(cfg)
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    t0 = time.perf_counter()
    sess.fit()
    return sess, time.perf_counter() - t0


def run_dp_parity(steps: int = 6, store: str = "shm"):
    """Bit-identical loss parity: 2-trainer global-mode DP vs single."""
    single, t1 = _timed_fit(_fit_cfg(False, steps))
    dp, t2 = _timed_fit(_fit_cfg(True, steps, store))
    bit = list(map(float, single.losses)) == list(map(float, dp.losses))
    emit("scale/dp/parity", 0.0,
         f"{steps} steps {'bit-identical' if bit else 'DIVERGED'} "
         f"(2 trainers, mode=global, store={store})",
         kind="dp_parity", bit_identical=bool(bit), num_trainers=2,
         mode="global", store=store, steps=steps,
         losses=[float(x) for x in dp.losses])
    assert bit, (single.losses, dp.losses)
    return {"single_s": t1, "dp_s": t2, "bit_identical": bit}


def run_epoch_time(steps: int = 16):
    """Honest single vs 2-trainer wall clock (see module docstring)."""
    single, t1 = _timed_fit(_fit_cfg(False, steps))
    dp, t2 = _timed_fit(_fit_cfg(True, steps))
    for name, t, n in (("single", t1, 1), ("dp2", t2, 2)):
        emit(f"scale/dp/epoch_time_{name}", t / steps * 1e6,
             f"{t:.2f}s wall for {steps} steps, {n} trainer(s) on "
             f"{os.cpu_count()} cpus", kind="dp_epoch_time",
             num_trainers=n, steps=steps, wall_s=round(t, 3))
    emit("scale/dp/speedup_2t", 0.0,
         f"{t1 / t2:.2f}x vs single ({os.cpu_count()} cpus; <1 expected "
         "when trainers outnumber cores)", kind="dp_epoch_time",
         num_trainers=2, speedup_vs_single=round(t1 / t2, 3))
    return {"single_s": t1, "dp_s": t2}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--comm-scale", type=float, default=0.005,
                    help="mag240m_stream scale for the Prop-2 leg")
    ap.add_argument("--parity-steps", type=int, default=6)
    ap.add_argument("--epoch-steps", type=int, default=16)
    ap.add_argument("--skip-comm", action="store_true")
    ap.add_argument("--skip-dp", action="store_true")
    ap.add_argument("--records-out", type=str, default=None,
                    help="write machine-readable rows (BENCH_scale.json)")
    args = ap.parse_args()
    if not args.skip_comm:
        run_comm_invariance(scale=args.comm_scale)
    if not args.skip_dp:
        run_dp_parity(steps=args.parity_steps)
        run_epoch_time(steps=args.epoch_steps)
    if args.records_out:
        write_records(args.records_out)
