"""Paper Table 2 — partitioning time and peak memory.

Meta-partitioning operates on the metagraph (O(|A|log|A| + |R|)); the
edge-cut baselines (random hash, greedy-LDG as the offline METIS stand-in)
must at least stream every edge.  We measure wall time and peak traced
memory (tracemalloc) on an IGB-HET-like graph, and report the algorithmic
core time separately from partition materialization (the paper notes most
of its 549 s is saving partitions; metatree work is <1 s)."""

from __future__ import annotations

import tracemalloc

from benchmarks._util import emit, time_call
from repro.core.meta_partition import greedy_edge_cut, meta_partition, random_edge_cut
from repro.graph.synthetic import igb_het_like


def _peak_mb(fn):
    tracemalloc.start()
    fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return peak / 2**20


def run(scale: float = 0.002, parts: int = 2):
    g = igb_het_like(scale=scale)
    emit("partitioning/graph", 0.0,
         f"{g.total_nodes:,}nodes/{g.total_edges:,}edges")

    t_meta_algo = time_call(
        lambda: meta_partition(g, parts, num_layers=2, materialize=False), repeats=3
    )
    t_meta_full = time_call(lambda: meta_partition(g, parts, num_layers=2), repeats=3)
    t_rand = time_call(lambda: random_edge_cut(g, parts), repeats=3)
    t_greedy = time_call(lambda: greedy_edge_cut(g, parts), repeats=1, warmup=0)

    m_meta = _peak_mb(lambda: meta_partition(g, parts, num_layers=2))
    m_greedy = _peak_mb(lambda: greedy_edge_cut(g, parts))

    emit("partitioning/meta_algorithm", t_meta_algo * 1e6, "metagraph-only (paper: <1s)")
    emit("partitioning/meta_materialized", t_meta_full * 1e6, f"peak={m_meta:.0f}MB")
    emit("partitioning/random_edge_cut", t_rand * 1e6, "DGL-Random analog")
    emit("partitioning/greedy_ldg", t_greedy * 1e6,
         f"METIS stand-in, peak={m_greedy:.0f}MB")
    # Table 2's qualitative claim: meta is fastest and smallest
    assert t_meta_algo < t_greedy
    return {
        "meta_algo_s": t_meta_algo, "meta_full_s": t_meta_full,
        "random_s": t_rand, "greedy_s": t_greedy,
        "meta_peak_mb": m_meta, "greedy_peak_mb": m_greedy,
    }


if __name__ == "__main__":
    run()
