"""Paper Fig. 10 — per-stage training time breakdown.

Stages mirror the paper's: sampling, feature fetching, forward+backward
(train step), learnable-feature/model update.  Vanilla adds projected
network time for remote features; Heta's stages are all local (plus the
Θ(B·hidden) partial exchange, part of the step)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks._util import dram_random_time, emit, net_time
from repro.core.comm import vanilla_comm_bytes, vanilla_update_bytes
from repro.core.meta_partition import meta_partition, random_edge_cut
from repro.core import raf_spmd
from repro.core.hgnn import HGNNConfig, init_hgnn_params
from repro.core.raf import assign_branches
from repro.embed import EmbedEngine, presample_hotness, profile_miss_penalties
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import ogbn_mag_like
from repro.api.executors import _apply_feature_grads
from repro.optim.adam import AdamConfig, adam_init

import jax


def run(scale: float = 0.002, batch: int = 32, fanouts=(5, 4), steps: int = 4):
    g = ogbn_mag_like(scale=scale)
    mp = meta_partition(g, 2, num_layers=2)
    spec = SampleSpec.from_metatree(mp.metatree, fanouts)
    assignment = assign_branches(spec, mp).fold(1, spec)
    hot = presample_hotness(g, spec, batch, epochs=1, max_batches=8)
    pen = profile_miss_penalties(g, measured=False)
    engine = EmbedEngine(g, 64, hot, pen, cache_bytes=2 << 20)
    cfg = HGNNConfig(model="rgcn", hidden=64, num_layers=2,
                     num_classes=g.num_classes)
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    params = init_hgnn_params(jax.random.PRNGKey(0), cfg, spec, feat_dims)
    plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    stacks = raf_spmd.shard_stacks(plan, mesh, raf_spmd.stack_params_from_dict(plan, params))
    opt = adam_init(stacks)
    step = raf_spmd.make_train_step(plan, mesh, AdamConfig(lr=1e-3),
                                    data_axes=("data",), learn_feats=True)

    sampler = NeighborSampler(g, spec, batch, seed=3)
    stages = {"sample": 0.0, "fetch": 0.0, "step": 0.0, "update": 0.0}
    cut = random_edge_cut(g, 2)
    v_fetch = v_upd = 0.0
    it = sampler.epoch()
    for i in range(steps):
        t0 = time.perf_counter()
        b = next(it)
        stages["sample"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        tables = engine.tables_snapshot()
        arrays = raf_spmd.shard_arrays(plan, mesh, raf_spmd.stack_batch(plan, b, tables))
        stages["fetch"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        stacks, opt, loss, gf = step(stacks, opt, arrays)
        jax.block_until_ready(loss)
        stages["step"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        _apply_feature_grads(engine, plan, b, gf)
        stages["update"] += time.perf_counter() - t0

        v_fetch += net_time(vanilla_comm_bytes(b, cut, feat_dims, bytes_per_elem=2), 16)
        ub = vanilla_update_bytes(b, cut, g, bytes_per_elem=2)
        v_upd += net_time(ub, 8) + dram_random_time(ub)

    total = sum(stages.values())
    for k, v in stages.items():
        emit(f"breakdown/heta/{k}", v / steps * 1e6, f"{100*v/total:.0f}% of step")
    emit("breakdown/vanilla_extra/remote_fetch", v_fetch / steps * 1e6,
         "projected 100Gbps (Heta: 0)")
    emit("breakdown/vanilla_extra/remote_update", v_upd / steps * 1e6,
         "projected (Heta: local, cached)")
    return stages


if __name__ == "__main__":
    run()
