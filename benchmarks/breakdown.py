"""Paper Fig. 10 — per-stage training time breakdown.

Stages mirror the paper's: sampling, feature fetching (staging), forward+
backward (device step), learnable-feature/model update.  Vanilla adds
projected network time for remote features; Heta's stages are all local
(plus the Θ(B·hidden) partial exchange, part of the step).

Built entirely on the public session + staged-executor surface
(``Executor.stage`` / ``Executor.step_staged`` — no private imports, no
hand-rolled training loop), and reports the async-pipeline overlap: the
``pipelined`` mode re-runs the same steps through ``pipeline.enabled`` and
emits serial vs overlapped step time plus the overlap fraction
(host work hidden behind the device step)."""

from __future__ import annotations

import time

from benchmarks._util import dram_random_time, emit, net_time, timed_fit
from repro.api import (
    CacheConfig, DataConfig, Heta, HetaConfig, ModelConfig, PartitionConfig,
    RunConfig,
)
from repro.core.comm import vanilla_comm_bytes, vanilla_update_bytes
from repro.core.meta_partition import random_edge_cut


def _session(scale, batch, fanouts, steps, train_learnable=True, **pipeline):
    cfg = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=scale, fanouts=fanouts,
                        batch_size=batch),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(hidden=64, train_learnable=train_learnable),
        cache=CacheConfig(cache_mb=2),
        run=RunConfig(executor="raf_spmd", steps=steps, seed=3),
    )
    if pipeline:
        cfg = cfg.updated(pipeline=pipeline)
    sess = Heta(cfg)
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    return sess


def run(scale: float = 0.002, batch: int = 32, fanouts=(5, 4), steps: int = 4,
        pipelined: bool = True):
    sess = _session(scale, batch, fanouts, steps)
    ex, plan = sess.executor, sess.plan

    stages = {"sample": 0.0, "fetch": 0.0, "step": 0.0, "update": 0.0}
    cut = random_edge_cut(sess.graph, 2)
    v_fetch = v_upd = 0.0
    it = sess.sampler.epoch(shuffle=True, seed=sess.config.run.seed)
    for i in range(steps):
        t0 = time.perf_counter()
        b = next(it)
        stages["sample"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        arrays = ex.stage(sess, plan, b)
        stages["fetch"] += time.perf_counter() - t0

        sess.state, _, dt = ex.step_staged(sess, plan, sess.state, b, arrays)
        upd = getattr(plan, "last_update_s", 0.0)
        stages["step"] += dt - upd
        stages["update"] += upd

        v_fetch += net_time(vanilla_comm_bytes(b, cut, sess.feat_dims,
                                               bytes_per_elem=2), 16)
        ub = vanilla_update_bytes(b, cut, sess.graph, bytes_per_elem=2)
        v_upd += net_time(ub, 8) + dram_random_time(ub)

    total = sum(stages.values())
    for k, v in stages.items():
        emit(f"breakdown/heta/{k}", v / steps * 1e6, f"{100*v/total:.0f}% of step")
    emit("breakdown/vanilla_extra/remote_fetch", v_fetch / steps * 1e6,
         "projected 100Gbps (Heta: 0)")
    emit("breakdown/vanilla_extra/remote_update", v_upd / steps * 1e6,
         "projected (Heta: local, cached)")

    if pipelined:
        overlap_stats = run_pipelined(scale, batch, fanouts, steps)
        stages["pipelined"] = overlap_stats
    return stages


def run_pipelined(scale: float = 0.002, batch: int = 32, fanouts=(5, 4),
                  steps: int = 8):
    """Serial vs async-pipeline wall time over identical batches.

    Both runs train the same model on the same data (per-batch sampler
    RNG); the pipelined one prefetches sample+stage in the background, so
    its per-step wall time drops toward the device step time and the
    hidden-host-work share is reported as the overlap fraction.  Learnable
    features are frozen here so step shapes stay fixed — with them on, the
    per-batch unique-row counts force sparse-update recompiles whose
    process-warm jit cache would bias whichever mode runs second (the
    per-stage loop in :func:`run` still measures the learnable path)."""
    results = {}
    for mode, pipeline in (("serial", {}), ("overlapped", dict(enabled=True))):
        sess = _session(scale, batch, fanouts, steps, train_learnable=False,
                        **pipeline)
        wall_per_step, overlap = timed_fit(sess, steps)
        results[mode] = dict(wall_per_step_s=wall_per_step,
                             overlap_fraction=overlap)
        emit(f"breakdown/pipeline/{mode}_step", wall_per_step * 1e6,
             f"overlap fraction {overlap:.2f}")
    emit("breakdown/pipeline/overlap_fraction",
         results["overlapped"]["overlap_fraction"],
         "share of host sample+stage hidden behind the device step")
    return results


if __name__ == "__main__":
    run()
