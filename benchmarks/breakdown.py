"""Paper Fig. 10 — per-stage training time breakdown.

Stages mirror the paper's: sampling, feature fetching (staging), forward+
backward (device step), learnable-feature/model update.  Vanilla adds
projected network time for remote features; Heta's stages are all local
(plus the Θ(B·hidden) partial exchange, part of the step).

Built entirely on the public session + staged-executor surface
(``Executor.stage`` / ``Executor.step_staged`` — no private imports, no
hand-rolled training loop), and reports the async-pipeline overlap: the
``pipelined`` mode re-runs the same steps through ``pipeline.enabled`` and
emits serial vs overlapped step time plus the overlap fraction
(host work hidden behind the device step).

``--num-workers 0,1,2,4`` adds the multi-worker sampling sweep
(DESIGN.md §9): pure sampling throughput of the thread producer vs N-process
pools over the shared-memory graph store, identical batches per position at
every worker count.  Rows are machine-readable (``samples_per_s``, ``cpus``,
``speedup_vs_1w``) and land in ``BENCH_pipeline.json`` via
``--records-out`` — the host-pipeline leg of the perf trajectory."""

from __future__ import annotations

import os
import time

from benchmarks._util import (dram_random_time, emit, net_time, timed_fit,
                              write_records)
from repro.api import (
    CacheConfig, DataConfig, Heta, HetaConfig, ModelConfig, PartitionConfig,
    RunConfig,
)
from repro.core.comm import vanilla_comm_bytes, vanilla_update_bytes
from repro.core.meta_partition import random_edge_cut


def _session(scale, batch, fanouts, steps, train_learnable=True, **pipeline):
    cfg = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=scale, fanouts=fanouts,
                        batch_size=batch),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(hidden=64, train_learnable=train_learnable),
        cache=CacheConfig(cache_mb=2),
        run=RunConfig(executor="raf_spmd", steps=steps, seed=3),
    )
    if pipeline:
        cfg = cfg.updated(pipeline=pipeline)
    sess = Heta(cfg)
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    return sess


def run(scale: float = 0.002, batch: int = 32, fanouts=(5, 4), steps: int = 4,
        pipelined: bool = True):
    sess = _session(scale, batch, fanouts, steps)
    ex, plan = sess.executor, sess.plan

    stages = {"sample": 0.0, "fetch": 0.0, "step": 0.0, "update": 0.0}
    cut = random_edge_cut(sess.graph, 2)
    v_fetch = v_upd = 0.0
    it = sess.sampler.epoch(shuffle=True, seed=sess.config.run.seed)
    for i in range(steps):
        t0 = time.perf_counter()
        b = next(it)
        stages["sample"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        arrays = ex.stage(sess, plan, b)
        stages["fetch"] += time.perf_counter() - t0

        sess.state, _, dt = ex.step_staged(sess, plan, sess.state, b, arrays)
        upd = getattr(plan, "last_update_s", 0.0)
        stages["step"] += dt - upd
        stages["update"] += upd

        v_fetch += net_time(vanilla_comm_bytes(b, cut, sess.feat_dims,
                                               bytes_per_elem=2), 16)
        ub = vanilla_update_bytes(b, cut, sess.graph, bytes_per_elem=2)
        v_upd += net_time(ub, 8) + dram_random_time(ub)

    total = sum(stages.values())
    for k, v in stages.items():
        emit(f"breakdown/heta/{k}", v / steps * 1e6, f"{100*v/total:.0f}% of step")
    emit("breakdown/vanilla_extra/remote_fetch", v_fetch / steps * 1e6,
         "projected 100Gbps (Heta: 0)")
    emit("breakdown/vanilla_extra/remote_update", v_upd / steps * 1e6,
         "projected (Heta: local, cached)")

    if pipelined:
        overlap_stats = run_pipelined(scale, batch, fanouts, steps)
        stages["pipelined"] = overlap_stats
    return stages


def run_pipelined(scale: float = 0.002, batch: int = 32, fanouts=(5, 4),
                  steps: int = 8):
    """Serial vs async-pipeline wall time over identical batches.

    Both runs train the same model on the same data (per-batch sampler
    RNG); the pipelined one prefetches sample+stage in the background, so
    its per-step wall time drops toward the device step time and the
    hidden-host-work share is reported as the overlap fraction.  Learnable
    features are frozen here so step shapes stay fixed — with them on, the
    per-batch unique-row counts force sparse-update recompiles whose
    process-warm jit cache would bias whichever mode runs second (the
    per-stage loop in :func:`run` still measures the learnable path)."""
    results = {}
    for mode, pipeline in (("serial", {}), ("overlapped", dict(enabled=True))):
        sess = _session(scale, batch, fanouts, steps, train_learnable=False,
                        **pipeline)
        wall_per_step, overlap = timed_fit(sess, steps)
        results[mode] = dict(wall_per_step_s=wall_per_step,
                             overlap_fraction=overlap)
        emit(f"breakdown/pipeline/{mode}_step", wall_per_step * 1e6,
             f"overlap fraction {overlap:.2f}")
    emit("breakdown/pipeline/overlap_fraction",
         results["overlapped"]["overlap_fraction"],
         "share of host sample+stage hidden behind the device step")
    return results


def run_worker_sweep(scale: float = 0.01, batch: int = 64, fanouts=(10, 10),
                     steps: int = 32, workers=(0, 1, 2, 4),
                     repeats: int = 3, arena: bool = True,
                     legacy_diagnosis: bool = True):
    """Sampling-throughput scaling of the host pipeline's producer.

    Every configuration materializes the *same* batches for the same
    positions (``batch_at`` purity); only who computes them differs —
    the single thread (``workers=0``) or an N-process pool over the
    shared-memory graph store.  Throughput is measured at the consumer,
    after a warmup that absorbs spawn + first-touch cost, as the best of
    ``repeats`` consecutive ``steps``-batch segments (best-of de-noises
    interference from co-tenants on shared machines), so the number is
    the steady-state rate the device loop would see.  ``cpus`` is recorded
    with every row: scaling saturates at the core count, so a 2-core
    container cannot show more than 2x of *aggregate* CPU — though it can
    exceed 2x vs a 1-worker baseline that leaves the consumer core idle.

    ``arena=True`` (default) routes pool batches through the shm batch
    arena (DESIGN.md §11): the queue carries ~10^2-byte SlotRef
    descriptors instead of ~10^6-byte pickled batches.  The pickle
    transport was exactly the ``workers1`` regression of the PR-5 rows
    (50k vs 99.5k samples/s): one worker pays serialize + pipe-write and
    the consumer pays read + deserialize of the full batch it could have
    sampled itself — pure overhead the arena removes.
    ``legacy_diagnosis=True`` re-times ``workers=1`` over the pickle path
    and emits the ``queue_bytes_per_item`` of both transports so the
    regression (and its fix) stays visible in ``BENCH_pipeline.json``."""
    import pickle

    from repro.data.prefetch import Prefetcher
    from repro.data.staging import arena_fields, unpack_slot
    from repro.data.worker_pool import (EpochSchedule, SampleStageTask,
                                        WorkerPool)
    from repro.graph.sampler import NeighborSampler
    from repro.graph.shm import create_arena, share_graph

    sess = Heta(HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=scale, fanouts=fanouts,
                        batch_size=batch),
        partition=PartitionConfig(num_partitions=2),
        run=RunConfig(seed=3),
    ))
    sess.build_graph()
    sess.partition()
    g, spec = sess.graph, sess.spec
    E = NeighborSampler(g, spec, batch).steps_per_epoch()
    sched = EpochSchedule(7, E)
    warm = 2

    def time_config(w, use_arena):
        """(samples/s, mean queue item bytes) for one producer config."""
        n = steps * repeats + warm
        store = ring = None
        qbytes = []
        if w == 0:
            sampler = NeighborSampler(g, spec, batch, seed=1)

            def make(i, _s=sampler, _sched=sched):
                seed, idx = _sched.seed_and_index(i)
                return _s.batch_at(idx, epoch_seed=seed)

            src = Prefetcher(make, depth=2, num_items=n, name="sweep-thread")
        else:
            store = share_graph(g, include_features=False)
            if use_arena:
                probe = NeighborSampler(g, spec, batch,
                                        seed=1).batch_at(0, epoch_seed=7)
                ring = create_arena(arena_fields(probe), num_workers=w,
                                    depth=2)
            task = SampleStageTask(
                handle=store.handle, spec=spec, batch_size=batch,
                sampler_seed=1, schedule=sched,
                arena=ring.handle if ring is not None else None)
            src = WorkerPool(task, num_workers=w, depth=2, num_items=n)
        try:
            it = iter(src)

            def draw():
                item = next(it)
                if ring is not None:
                    if not qbytes:
                        qbytes.append(len(pickle.dumps(item)))
                    unpack_slot(ring.resolve(item.slot, item.use), spec)
                    ring.release(item.slot, item.use)
                elif w > 0 and not qbytes:
                    qbytes.append(len(pickle.dumps(item)))

            for _ in range(warm):
                draw()
            wall = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                for _ in range(steps):
                    draw()
                wall = min(wall, time.perf_counter() - t0)
        finally:
            src.close()
            if store is not None:
                store.unlink()
            if ring is not None:
                ring.unlink()
        return steps * batch / wall, (qbytes[0] if qbytes else 0)

    results = {}
    for w in workers:
        sps, qb = time_config(w, use_arena=arena)
        results[w] = sps
        emit(f"pipeline/sampling/workers{w}", batch / sps * 1e6,
             f"{sps:,.0f} samples/s"
             + (f", {qb} B/queue item" if w else ""),
             workers=w, samples_per_s=round(sps, 1), batch_size=batch,
             fanouts=list(fanouts), kind="sampling",
             queue_bytes_per_item=qb, arena=bool(arena and w),
             cpus=os.cpu_count())
    if legacy_diagnosis and arena and 1 in results:
        sps, qb = time_config(1, use_arena=False)
        emit("pipeline/sampling/workers1_legacy", batch / sps * 1e6,
             f"{sps:,.0f} samples/s over the pickle queue "
             f"({qb} B/item — the PR-5 workers1 regression)",
             workers=1, samples_per_s=round(sps, 1), batch_size=batch,
             fanouts=list(fanouts), kind="sampling",
             queue_bytes_per_item=qb, arena=False, cpus=os.cpu_count())
        emit("pipeline/sampling/workers1_arena_vs_legacy", 0.0,
             f"{results[1] / sps:.2f}x from descriptor-only queues",
             workers=1, speedup_vs_legacy=round(results[1] / sps, 3),
             kind="sampling_scaling", cpus=os.cpu_count())
    base = results.get(1)
    if base:
        for w in sorted(results):
            if w > 1:
                emit(f"pipeline/sampling/scaling_1_to_{w}",
                     0.0, f"{results[w] / base:.2f}x vs 1 worker "
                     f"({os.cpu_count()} cpus)",
                     workers=w, speedup_vs_1w=round(results[w] / base, 3),
                     kind="sampling_scaling", cpus=os.cpu_count())
    if 0 in results and len(results) > 1:
        best = max(v for k, v in results.items() if k > 0)
        emit("pipeline/sampling/pool_vs_thread", 0.0,
             f"best pool {best / results[0]:.2f}x the single thread",
             speedup_vs_thread=round(best / results[0], 3),
             kind="sampling_scaling", cpus=os.cpu_count())
    return results


def run_consumer_completion(scale: float = 0.01, batch: int = 64,
                            fanouts=(10, 10), repeats: int = 30):
    """Consumer-side completion cost of a worker-staged batch.

    With frozen tables the sampler workers assemble the full stacked host
    arrays (``stack_batch_host``); all the consumer does is finish staging.
    ``stage_from_host`` feeds those host views straight into the sharded
    ``device_put`` — the device-put-free path — while the reference row
    re-times the copying completion it replaced (``np.array`` per field,
    then the same device put).  Both produce bit-identical device arrays;
    the delta is pure consumer-thread overhead that the overlap window
    cannot hide.  ``cpus`` is stamped on every row as usual."""
    import numpy as np

    from repro.data.staging import stack_batch_host

    sess = _session(scale, batch, fanouts, steps=1, train_learnable=False)
    ex, plan = sess.executor, sess.plan
    recipe = ex.worker_stage_recipe(sess, plan)
    if recipe is None:  # pragma: no cover - frozen tables always have one
        raise SystemExit("no worker stage recipe; cannot probe completion")
    tables = sess.engine.tables_snapshot()
    b = sess._batch_for_step(0)
    host = stack_batch_host(recipe, b, tables)

    import jax

    def t_of(fn):
        fn()  # warmup (compile + first-touch)
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2]

    t_free = t_of(lambda: ex.stage_from_host(sess, plan, b, host))
    t_copy = t_of(lambda: ex.stage_from_host(
        sess, plan, b, {k: np.array(v) for k, v in host.items()}))
    nbytes = int(sum(v.nbytes for v in host.values()))
    emit("pipeline/consumer/stage_from_host", t_free * 1e6,
         f"device-put-free completion of {nbytes / 1e6:.1f} MB staged host "
         "arrays", kind="consumer_completion", copy_free=True,
         staged_bytes=nbytes, batch_size=batch, fanouts=list(fanouts))
    emit("pipeline/consumer/copying_reference", t_copy * 1e6,
         "same completion behind a per-field np.array copy",
         kind="consumer_completion", copy_free=False, staged_bytes=nbytes,
         batch_size=batch, fanouts=list(fanouts))
    emit("pipeline/consumer/copy_free_speedup", 0.0,
         f"{t_copy / t_free:.2f}x from skipping the host copy",
         kind="consumer_completion",
         speedup_vs_copy=round(t_copy / t_free, 3))
    return {"stage_from_host_s": t_free, "copying_s": t_copy}


def run_pinning_probe(scale: float = 0.01, batch: int = 64, fanouts=(10, 10),
                      steps: int = 32, workers: int = 2, repeats: int = 3):
    """``pipeline.pin_workers`` on/off over the arena pool, same batches.

    Pinning helps when the scheduler migrates sampler workers across cores
    mid-epoch (cold caches); on a container with fewer cores than workers
    it is expected to be a wash or a small loss — the rows record whichever
    way it goes, stamped with ``cpus`` so readers can tell the two regimes
    apart.  No timing gate anywhere."""
    from repro.data.prefetch import Prefetcher  # noqa: F401  (parity import)
    from repro.data.staging import arena_fields, unpack_slot
    from repro.data.worker_pool import (EpochSchedule, SampleStageTask,
                                        WorkerPool)
    from repro.graph.sampler import NeighborSampler
    from repro.graph.shm import create_arena, share_graph

    sess = Heta(HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=scale, fanouts=fanouts,
                        batch_size=batch),
        partition=PartitionConfig(num_partitions=2),
        run=RunConfig(seed=3),
    ))
    sess.build_graph()
    sess.partition()
    g, spec = sess.graph, sess.spec
    E = NeighborSampler(g, spec, batch).steps_per_epoch()
    sched = EpochSchedule(7, E)
    warm = 2

    def time_pool(pin: bool) -> float:
        n = steps * repeats + warm
        store = share_graph(g, include_features=False)
        probe = NeighborSampler(g, spec, batch, seed=1).batch_at(0,
                                                                 epoch_seed=7)
        ring = create_arena(arena_fields(probe), num_workers=workers, depth=2)
        task = SampleStageTask(handle=store.handle, spec=spec,
                               batch_size=batch, sampler_seed=1,
                               schedule=sched, arena=ring.handle,
                               pin_cpus=pin)
        src = WorkerPool(task, num_workers=workers, depth=2, num_items=n)
        try:
            it = iter(src)

            def draw():
                item = next(it)
                unpack_slot(ring.resolve(item.slot, item.use), spec)
                ring.release(item.slot, item.use)

            for _ in range(warm):
                draw()
            wall = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                for _ in range(steps):
                    draw()
                wall = min(wall, time.perf_counter() - t0)
        finally:
            src.close()
            store.unlink()
            ring.unlink()
        return steps * batch / wall

    base = time_pool(False)
    pinned = time_pool(True)
    for name, sps, pin in (("unpinned", base, False), ("pinned", pinned, True)):
        emit(f"pipeline/sampling/workers{workers}_{name}", batch / sps * 1e6,
             f"{sps:,.0f} samples/s", workers=workers, pin_workers=pin,
             samples_per_s=round(sps, 1), batch_size=batch,
             fanouts=list(fanouts), kind="sampling_pinning")
    emit(f"pipeline/sampling/pinning_effect_w{workers}", 0.0,
         f"{pinned / base:.2f}x pinned vs unpinned ({os.cpu_count()} cpus)",
         workers=workers, speedup_pinned=round(pinned / base, 3),
         kind="sampling_pinning")
    return {"unpinned": base, "pinned": pinned}


def _parse_workers(s: str):
    return tuple(int(x) for x in s.split(","))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-workers", type=_parse_workers, default=None,
                    help="comma list, e.g. 0,1,2,4: run the multi-worker "
                         "sampling-throughput sweep")
    ap.add_argument("--sweep-steps", type=int, default=48,
                    help="timed batches per sweep configuration")
    ap.add_argument("--records-out", type=str, default=None,
                    help="write machine-readable rows here "
                         "(e.g. BENCH_pipeline.json)")
    ap.add_argument("--skip-stages", action="store_true",
                    help="only the worker sweep, skip the per-stage breakdown")
    ap.add_argument("--no-arena", action="store_true",
                    help="sweep over the legacy pickle queues instead of the "
                         "shm batch arena")
    ap.add_argument("--consumer", action="store_true",
                    help="probe the consumer completion (device-put-free "
                         "stage_from_host vs the copying reference)")
    ap.add_argument("--pin-probe", type=int, default=0, metavar="W",
                    help="probe pipeline.pin_workers on/off with W workers")
    args = ap.parse_args()
    if not args.skip_stages:
        run()
    if args.num_workers is not None:
        run_worker_sweep(steps=args.sweep_steps, workers=args.num_workers,
                         arena=not args.no_arena)
    if args.consumer:
        run_consumer_completion()
    if args.pin_probe:
        run_pinning_probe(workers=args.pin_probe)
    if args.records_out:
        write_records(args.records_out)
