"""Kernel microbenchmarks.

On this CPU host the Pallas kernels run in interpret mode (a Python
emulation — NOT indicative of TPU wall-clock); the meaningful numbers are
the oracle timings (XLA:CPU) and the derived arithmetic-intensity /
VMEM-footprint figures for the TPU target, which are static properties.

Besides the printed CSV rows, every op row is emitted machine-readable and
the run writes ``BENCH_kernels.json`` (op, shape, µs, GFLOP/s, VMEM bytes)
— the repo's perf trajectory.  The headline comparison is the stacked
relation aggregation at ogbn-mag shapes: the **stacked XLA oracle** (slots
grouped by unique weight, each weight a static slice — no materialized
per-slot gather; ``stacked_agg_grouped``) against the **gather-then-vmap
oracle** the SPMD executor historically ran (``stacked_agg_ref``).  Shapes
with parameter sharing (the same relation under several parent branches at
level 2; HGT's per-node-type K/Q/V everywhere) are where the gather's
redundant weight movement costs — the reusability HiHGNN exploits and the
Pallas kernel's scalar-prefetch indirection removes entirely.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_call, write_records
from repro.core.relmod import ShapeCtx, get_relation_module
from repro.kernels.flash_attention import attention_ref
from repro.kernels.relation_agg import relation_agg_ref, relation_agg_vmem_bytes
from repro.kernels.stacked_relation_agg import (
    stacked_agg_grouped,
    stacked_agg_ref,
    stacked_mean_linear_vmem_bytes,
    stacked_softmax_combine_vmem_bytes,
)

OUT_JSON = "BENCH_kernels.json"


def _relation_agg_flops(n: int, f: int, di: int, do: int) -> int:
    """Masked mean + projection: the Σ_f mask·h contraction (2·n·f·di), the
    mask-count normalization (n·f adds + n·di divides) and the projection
    matmul — the old figure dropped the normalization terms entirely."""
    return 2 * n * f * di + n * f + n * di + 2 * n * di * do


def _bench_relation_agg():
    rng = np.random.default_rng(0)
    n, f, di, do = 25600, 20, 128, 64
    h = jnp.asarray(rng.standard_normal((n, f, di)), jnp.float32)
    m = jnp.asarray(rng.random((n, f)) > 0.2)
    w = jnp.asarray(rng.standard_normal((di, do)) * 0.1, jnp.float32)
    b = jnp.zeros(do, jnp.float32)
    fn = jax.jit(relation_agg_ref)
    t = time_call(lambda: jax.block_until_ready(fn(h, m, w, b)))
    flops = _relation_agg_flops(n, f, di, do)
    vmem = relation_agg_vmem_bytes(n, f, di, do)
    emit("kernel/relation_agg_ref", t * 1e6, f"{flops/t/1e9:.1f}GFLOP/s cpu",
         shape=[n, f, di, do], gflops=round(flops / t / 1e9, 1), vmem_bytes=vmem)
    # TPU-target static property, derived from the dispatch's actual blocks
    emit("kernel/relation_agg_vmem", 0.0,
         f"{vmem/2**20:.1f}MiB VMEM/step (16MiB budget), from dispatch blocks",
         shape=[n, f, di, do], vmem_bytes=vmem)


def _stacked_case(model, rb, n, f, di, do, U_of, slot_np, tag):
    """Time gather-then-vmap vs grouped stacked oracles for one workload."""
    rng = np.random.default_rng(1)
    mod = get_relation_module(model)
    nh = 8 if model == "hgt" else 4
    sc = ShapeCtx(do, nh, do // nh, di, di)
    stacks = {
        s.name: jnp.asarray(
            rng.standard_normal((U_of[s.scope],) + tuple(s.shape(sc))) * 0.1,
            jnp.float32,
        )
        for s in mod.specs
    }
    slot_u = {k: jnp.asarray(v) for k, v in slot_np.items()}
    h = jnp.asarray(rng.standard_normal((rb, n, f, di)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((rb, n, di)), jnp.float32)
    mask = jnp.asarray(rng.random((rb, n, f)) > 0.2)

    ref_fn = jax.jit(lambda s, u, h_, q_, m_: stacked_agg_ref(mod, s, u, h_, q_, m_))
    grp_fn = jax.jit(lambda s, h_, q_, m_: stacked_agg_grouped(mod, s, slot_np, h_, q_, m_))
    np.testing.assert_allclose(  # oracles must agree before we race them
        np.asarray(ref_fn(stacks, slot_u, h, q, mask)),
        np.asarray(grp_fn(stacks, h, q, mask)), atol=1e-5,
    )
    t_ref = time_call(lambda: jax.block_until_ready(ref_fn(stacks, slot_u, h, q, mask)),
                      repeats=9)
    t_grp = time_call(lambda: jax.block_until_ready(grp_fn(stacks, h, q, mask)),
                      repeats=9)
    if model == "rgcn":
        flops = rb * _relation_agg_flops(n, f, di, do)
        vmem = stacked_mean_linear_vmem_bytes(n, f, di, do)
    else:
        # projections dominate: k/v (2·2·n·f·di·do) + q + attn/msg einsums
        flops = rb * (4 * n * f * di * do + 2 * n * di * do + 4 * n * f * do * (do // nh))
        vmem = stacked_softmax_combine_vmem_bytes(n, f, nh, do // nh)
    shape = dict(model=model, rb=rb, n=n, f=f, d_in=di, d_out=do,
                 unique_weights={k: int(v) for k, v in
                                 ((s, len(set(slot_np[s].tolist()))) for s in slot_np)})
    emit(f"kernel/stacked_agg_gather_vmap/{tag}", t_ref * 1e6,
         f"{flops/t_ref/1e9:.1f}GFLOP/s cpu oracle",
         shape=shape, gflops=round(flops / t_ref / 1e9, 1), vmem_bytes=0)
    emit(f"kernel/stacked_agg_grouped/{tag}", t_grp * 1e6,
         f"{flops/t_grp/1e9:.1f}GFLOP/s cpu, {t_ref/t_grp:.2f}x vs gather+vmap",
         shape=shape, gflops=round(flops / t_grp / 1e9, 1),
         speedup_vs_gather_vmap=round(t_ref / t_grp, 3), vmem_bytes=0)
    emit(f"kernel/stacked_agg_pallas_vmem/{tag}", 0.0,
         f"{vmem/2**20:.2f}MiB VMEM/step (16MiB budget)",
         shape=shape, vmem_bytes=vmem)


def _bench_stacked():
    rng = np.random.default_rng(2)
    # ogbn-mag level 1, rgcn: one relation per slot — no sharing, so the
    # gather only duplicates small [128, 64] weights and the two oracles
    # run neck-and-neck on CPU; kept as the trajectory's control row
    _stacked_case("rgcn", 8, 1024, 25, 128, 64,
                  {"relation": 8}, {"relation": np.arange(8) % 8}, "mag_l1")
    # ogbn-mag level 2, rgcn: the same relation sampled under several
    # parent branches — slots share stack rows
    _stacked_case("rgcn", 12, 2048, 20, 64, 64,
                  {"relation": 6}, {"relation": np.arange(12) % 6}, "mag_l2_shared")
    # the headline: HGT at mag's type structure (4 node types / 8 edge
    # types over 8 relation slots) — per-node-type K/Q/V occupy several
    # slots each, so the gather-then-vmap oracle materializes every shared
    # projection per slot while the grouped oracle reads each weight once
    _stacked_case(
        "hgt", 8, 1024, 25, 128, 64,
        {"src_type": 4, "dst_type": 4, "etype": 8},
        {"src_type": rng.integers(0, 4, 8), "dst_type": rng.integers(0, 4, 8),
         "etype": np.arange(8) % 8},
        "mag_hgt",
    )


def _bench_flash_attention():
    rng = np.random.default_rng(3)
    # args passed, not closed over — closures constant-fold the whole
    # attention at compile time
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 128)), jnp.float32)
    fn2 = jax.jit(lambda a, b2, c: attention_ref(a, b2, c, causal=True))
    t2 = time_call(lambda: jax.block_until_ready(fn2(q, q, q)))
    emit("kernel/flash_attention_ref", t2 * 1e6, "oracle 8x1024x128 causal",
         shape=[1, 8, 1024, 128], vmem_bytes=0)
    emit("kernel/flash_attention_vmem", 0.0,
         "0.4MiB/step at bq=bk=128 — O(S·W) at window 8192 enables long_500k",
         shape=[1, 8, 1024, 128], vmem_bytes=int(0.4 * 2**20))


def run():
    _bench_relation_agg()
    _bench_stacked()
    _bench_flash_attention()
    write_records(OUT_JSON)
    return True


if __name__ == "__main__":
    run()
