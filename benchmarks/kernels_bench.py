"""Kernel microbenchmarks.

On this CPU host the Pallas kernels run in interpret mode (a Python
emulation — NOT indicative of TPU wall-clock); the meaningful numbers are
the oracle timings (XLA:CPU) and the derived arithmetic-intensity /
VMEM-footprint figures for the TPU target, which are static properties."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_call
from repro.kernels.flash_attention import attention_ref
from repro.kernels.relation_agg import relation_agg_ref


def run():
    rng = np.random.default_rng(0)

    # relation_agg: paper's R-GCN hot spot at ogbn-mag scale
    n, f, di, do = 25600, 20, 128, 64
    h = jnp.asarray(rng.standard_normal((n, f, di)), jnp.float32)
    m = jnp.asarray(rng.random((n, f)) > 0.2)
    w = jnp.asarray(rng.standard_normal((di, do)) * 0.1, jnp.float32)
    b = jnp.zeros(do, jnp.float32)
    fn = jax.jit(relation_agg_ref)
    t = time_call(lambda: jax.block_until_ready(fn(h, m, w, b)))
    flops = 2 * n * f * di + 2 * n * di * do
    emit("kernel/relation_agg_ref", t * 1e6, f"{flops/t/1e9:.1f}GFLOP/s cpu")
    # TPU-target static properties of the Pallas kernel
    vmem = (128 * f * 512 + 128 * f + 512 * 128 + 128 * 128) * 4
    emit("kernel/relation_agg_vmem", 0.0,
         f"{vmem/2**20:.1f}MiB VMEM/step (16MiB budget), MXU-aligned 128x512x128")

    # flash attention at prefill tile scale (args passed, not closed over —
    # closures constant-fold the whole attention at compile time)
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 128)), jnp.float32)
    fn2 = jax.jit(lambda a, b2, c: attention_ref(a, b2, c, causal=True))
    t2 = time_call(lambda: jax.block_until_ready(fn2(q, q, q)))
    emit("kernel/flash_attention_ref", t2 * 1e6, "oracle 8x1024x128 causal")
    emit("kernel/flash_attention_vmem", 0.0,
         "0.4MiB/step at bq=bk=128 — O(S·W) at window 8192 enables long_500k")
    return True


if __name__ == "__main__":
    run()
