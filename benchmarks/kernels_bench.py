"""Kernel microbenchmarks.

On this CPU host the Pallas kernels run in interpret mode (a Python
emulation — NOT indicative of TPU wall-clock); the meaningful numbers are
the oracle timings (XLA:CPU) and the derived arithmetic-intensity /
VMEM-footprint figures for the TPU target, which are static properties.

Besides the printed CSV rows, every op row is emitted machine-readable and
the run writes ``BENCH_kernels.json`` (op, shape, µs, GFLOP/s, VMEM bytes)
— the repo's perf trajectory.  The headline comparison is the stacked
relation aggregation at ogbn-mag shapes: the **stacked XLA oracle** (slots
grouped by unique weight, each weight a static slice — no materialized
per-slot gather; ``stacked_agg_grouped``) against the **gather-then-vmap
oracle** the SPMD executor historically ran (``stacked_agg_ref``).  Shapes
with parameter sharing (the same relation under several parent branches at
level 2; HGT's per-node-type K/Q/V everywhere) are where the gather's
redundant weight movement costs — the reusability HiHGNN exploits and the
Pallas kernel's scalar-prefetch indirection removes entirely.

Two further comparisons ride on the same discipline: the **fused attention
epilogue** factoring vs the attn_parts factoring (XLA:CPU, both jitted —
the reassociated contractions are the CPU-visible part of the fusion win),
and **autotuned vs default block sizes** (interpret-mode grid proxy +
analytic model costs; real TPU sweep is the ROADMAP follow-on).  Every
record carries ``backend``/``cpus`` so rows are only compared within one
substrate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_call, write_records
from repro.core.relmod import ShapeCtx, get_relation_module, masked_softmax
from repro.kernels.flash_attention import attention_ref
from repro.kernels.relation_agg import relation_agg_ref, relation_agg_vmem_bytes
from repro.kernels.stacked_relation_agg import (
    stacked_agg_grouped,
    stacked_agg_ref,
    stacked_attn_epilogue_vmem_bytes,
    stacked_mean_linear,
    stacked_mean_linear_vmem_bytes,
    stacked_softmax_combine_vmem_bytes,
)

OUT_JSON = "BENCH_kernels.json"


def _relation_agg_flops(n: int, f: int, di: int, do: int) -> int:
    """Masked mean + projection: the Σ_f mask·h contraction (2·n·f·di), the
    mask-count normalization (n·f adds + n·di divides) and the projection
    matmul — the old figure dropped the normalization terms entirely."""
    return 2 * n * f * di + n * f + n * di + 2 * n * di * do


def _bench_relation_agg():
    rng = np.random.default_rng(0)
    n, f, di, do = 25600, 20, 128, 64
    h = jnp.asarray(rng.standard_normal((n, f, di)), jnp.float32)
    m = jnp.asarray(rng.random((n, f)) > 0.2)
    w = jnp.asarray(rng.standard_normal((di, do)) * 0.1, jnp.float32)
    b = jnp.zeros(do, jnp.float32)
    fn = jax.jit(relation_agg_ref)
    t = time_call(lambda: jax.block_until_ready(fn(h, m, w, b)))
    flops = _relation_agg_flops(n, f, di, do)
    vmem = relation_agg_vmem_bytes(n, f, di, do)
    emit("kernel/relation_agg_ref", t * 1e6, f"{flops/t/1e9:.1f}GFLOP/s cpu",
         shape=[n, f, di, do], gflops=round(flops / t / 1e9, 1), vmem_bytes=vmem)
    # TPU-target static property, derived from the dispatch's actual blocks
    emit("kernel/relation_agg_vmem", 0.0,
         f"{vmem/2**20:.1f}MiB VMEM/step (16MiB budget), from dispatch blocks",
         shape=[n, f, di, do], vmem_bytes=vmem)


def _stacked_case(model, rb, n, f, di, do, U_of, slot_np, tag):
    """Time gather-then-vmap vs grouped stacked oracles for one workload."""
    rng = np.random.default_rng(1)
    mod = get_relation_module(model)
    nh = 8 if model == "hgt" else 4
    sc = ShapeCtx(do, nh, do // nh, di, di)
    stacks = {
        s.name: jnp.asarray(
            rng.standard_normal((U_of[s.scope],) + tuple(s.shape(sc))) * 0.1,
            jnp.float32,
        )
        for s in mod.specs
    }
    slot_u = {k: jnp.asarray(v) for k, v in slot_np.items()}
    h = jnp.asarray(rng.standard_normal((rb, n, f, di)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((rb, n, di)), jnp.float32)
    mask = jnp.asarray(rng.random((rb, n, f)) > 0.2)

    ref_fn = jax.jit(lambda s, u, h_, q_, m_: stacked_agg_ref(mod, s, u, h_, q_, m_))
    grp_fn = jax.jit(lambda s, h_, q_, m_: stacked_agg_grouped(mod, s, slot_np, h_, q_, m_))
    np.testing.assert_allclose(  # oracles must agree before we race them
        np.asarray(ref_fn(stacks, slot_u, h, q, mask)),
        np.asarray(grp_fn(stacks, h, q, mask)), atol=1e-5,
    )
    t_ref = time_call(lambda: jax.block_until_ready(ref_fn(stacks, slot_u, h, q, mask)),
                      repeats=9)
    t_grp = time_call(lambda: jax.block_until_ready(grp_fn(stacks, h, q, mask)),
                      repeats=9)
    if model == "rgcn":
        flops = rb * _relation_agg_flops(n, f, di, do)
        vmem = stacked_mean_linear_vmem_bytes(n, f, di, do)
    else:
        # projections dominate: k/v (2·2·n·f·di·do) + q + attn/msg einsums
        flops = rb * (4 * n * f * di * do + 2 * n * di * do + 4 * n * f * do * (do // nh))
        vmem = stacked_softmax_combine_vmem_bytes(n, f, nh, do // nh)
    shape = dict(model=model, rb=rb, n=n, f=f, d_in=di, d_out=do,
                 unique_weights={k: int(v) for k, v in
                                 ((s, len(set(slot_np[s].tolist()))) for s in slot_np)})
    emit(f"kernel/stacked_agg_gather_vmap/{tag}", t_ref * 1e6,
         f"{flops/t_ref/1e9:.1f}GFLOP/s cpu oracle",
         shape=shape, gflops=round(flops / t_ref / 1e9, 1), vmem_bytes=0)
    emit(f"kernel/stacked_agg_grouped/{tag}", t_grp * 1e6,
         f"{flops/t_grp/1e9:.1f}GFLOP/s cpu, {t_ref/t_grp:.2f}x vs gather+vmap",
         shape=shape, gflops=round(flops / t_grp / 1e9, 1),
         speedup_vs_gather_vmap=round(t_ref / t_grp, 3), vmem_bytes=0)
    emit(f"kernel/stacked_agg_pallas_vmem/{tag}", 0.0,
         f"{vmem/2**20:.2f}MiB VMEM/step (16MiB budget)",
         shape=shape, vmem_bytes=vmem)


def _bench_stacked():
    rng = np.random.default_rng(2)
    # ogbn-mag level 1, rgcn: one relation per slot — no sharing, so the
    # gather only duplicates small [128, 64] weights and the two oracles
    # run neck-and-neck on CPU; kept as the trajectory's control row
    _stacked_case("rgcn", 8, 1024, 25, 128, 64,
                  {"relation": 8}, {"relation": np.arange(8) % 8}, "mag_l1")
    # ogbn-mag level 2, rgcn: the same relation sampled under several
    # parent branches — slots share stack rows
    _stacked_case("rgcn", 12, 2048, 20, 64, 64,
                  {"relation": 6}, {"relation": np.arange(12) % 6}, "mag_l2_shared")
    # the headline: HGT at mag's type structure (4 node types / 8 edge
    # types over 8 relation slots) — per-node-type K/Q/V occupy several
    # slots each, so the gather-then-vmap oracle materializes every shared
    # projection per slot while the grouped oracle reads each weight once
    _stacked_case(
        "hgt", 8, 1024, 25, 128, 64,
        {"src_type": 4, "dst_type": 4, "etype": 8},
        {"src_type": rng.integers(0, 4, 8), "dst_type": rng.integers(0, 4, 8),
         "etype": np.arange(8) % 8},
        "mag_hgt",
    )


def _attn_case_operands(model, rb, n, f, di, do, U_of, slot_np, nh, seed=1):
    rng = np.random.default_rng(seed)
    mod = get_relation_module(model)
    sc = ShapeCtx(do, nh, do // nh, di, di)
    stacks = {
        s.name: jnp.asarray(
            rng.standard_normal((U_of[s.scope],) + tuple(s.shape(sc))) * 0.1,
            jnp.float32,
        )
        for s in mod.specs
    }
    slot_u = {k: jnp.asarray(v) for k, v in slot_np.items()}
    h = jnp.asarray(rng.standard_normal((rb, n, f, di)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((rb, n, di)), jnp.float32)
    mask = jnp.asarray(rng.random((rb, n, f)) > 0.2)
    return mod, stacks, slot_u, h, q, mask


def _attn_parts_path(mod, stacks, slot_u, h, q, mask):
    """The pre-fusion factoring: per-slot weight gather, vmapped
    projections, then the softmax+combine epilogue (the path
    ``fuse_epilogue=False`` keeps as the oracle)."""
    scope_of = {s.name: s.scope for s in mod.specs}
    p = {name: stacks[name][slot_u[scope_of[name]]] for name in stacks}
    e, v = jax.vmap(mod.attn_parts)(p, h, q)
    alpha = masked_softmax(e, mask[:, :, :, None], axis=2)
    rb, n, f, nh, dh = v.shape
    out = jnp.einsum("rnfh,rnfhd->rnhd", alpha, v).reshape(rb, n, nh * dh)
    bias = mod.attn_bias(p)
    return out if bias is None else out + bias[:, None, :]


def _fused_epilogue_path(mod, stacks, slot_u, h, q, mask):
    """XLA:CPU evaluation of the canonical fused-epilogue factoring
    (``AttnEpilogue`` operands + reassociated contractions).  The fusion
    contract lets the small per-head transforms fold into the query side
    (``pe``) and after the combine (``pv``), so no ``[rb, n, f, nh, dh]``
    *transformed* intermediate is ever materialized — the same dataflow
    the Pallas kernel runs per block on TPU."""

    def jnp_linear(w, u, x):
        return jnp.einsum("rnd,rdk->rnk", x, w[u])

    epi = mod.attn_epilogue(stacks, slot_u, q, linear=jnp_linear)
    rb, n, f, _ = h.shape
    nh, dh = epi.num_heads, epi.head_dim
    z0 = jnp.einsum("rnfd,rdk->rnfk", h, epi.we[epi.ue]).reshape(rb, n, f, nh, dh)
    v0 = z0 if epi.wv is None else jnp.einsum(
        "rnfd,rdk->rnfk", h, epi.wv[epi.uv]).reshape(rb, n, f, nh, dh)
    qv = epi.qv.reshape(rb, n, nh, dh)
    if epi.pe is not None:
        qv = jnp.einsum("rhde,rnhe->rnhd", epi.pe[epi.ua], qv)
    e = jnp.einsum("rnfhd,rnhd->rnfh", z0, qv) * epi.scale
    if epi.eb is not None:
        e = e + epi.eb[:, :, None, :]
    if epi.slope is not None:
        e = jax.nn.leaky_relu(e, negative_slope=epi.slope)
    alpha = masked_softmax(e, mask[:, :, :, None], axis=2)
    c = jnp.einsum("rnfh,rnfhd->rnhd", alpha, v0)
    if epi.pv is not None:
        c = jnp.einsum("rnhd,rhde->rnhe", c, epi.pv[epi.ua])
    out = c.reshape(rb, n, nh * dh)
    return out if epi.bias is None else out + epi.bias[:, None, :]


def _bench_fused_epilogue():
    """Fused attention epilogue vs the attn_parts factoring at mag shapes.

    Honest XLA:CPU timing of the two *factorings* — the CPU-visible win is
    the contraction reassociation the epilogue contract licenses; the
    stack-streaming (no per-slot weight gather) part of the win is
    TPU-only (scalar prefetch) and shows up in the VMEM rows + the TPU
    sweep (ROADMAP follow-on)."""
    rng = np.random.default_rng(4)
    for model, U_of, slot_np, nh, tag in (
        ("rgat", {"relation": 8}, {"relation": np.arange(8) % 8}, 4,
         "mag_rgat"),
        ("hgt", {"src_type": 4, "dst_type": 4, "etype": 8},
         {"src_type": rng.integers(0, 4, 8), "dst_type": rng.integers(0, 4, 8),
          "etype": np.arange(8) % 8}, 8, "mag_hgt"),
    ):
        rb, n, f, di, do = 8, 1024, 25, 128, 64
        mod, stacks, slot_u, h, q, mask = _attn_case_operands(
            model, rb, n, f, di, do, U_of, slot_np, nh)
        pf = jax.jit(lambda s, u, h_, q_, m_: _attn_parts_path(mod, s, u, h_, q_, m_))
        ff = jax.jit(lambda s, u, h_, q_, m_: _fused_epilogue_path(mod, s, u, h_, q_, m_))
        np.testing.assert_allclose(  # factorings must agree before we race them
            np.asarray(pf(stacks, slot_u, h, q, mask)),
            np.asarray(ff(stacks, slot_u, h, q, mask)), atol=2e-5,
        )
        t_p = time_call(lambda: jax.block_until_ready(pf(stacks, slot_u, h, q, mask)),
                        repeats=9)
        t_f = time_call(lambda: jax.block_until_ready(ff(stacks, slot_u, h, q, mask)),
                        repeats=9)
        shape = dict(model=model, rb=rb, n=n, f=f, d_in=di, d_out=do, nh=nh)
        emit(f"kernel/stacked_attn_parts/{tag}", t_p * 1e6,
             "gathered projections + softmax_combine, cpu oracle",
             shape=shape, vmem_bytes=0)
        emit(f"kernel/stacked_attn_fused_epilogue/{tag}", t_f * 1e6,
             f"{t_p/t_f:.2f}x vs attn_parts (canonical epilogue factoring)",
             shape=shape, speedup_vs_attn_parts=round(t_p / t_f, 3),
             vmem_bytes=0)
        vmem = stacked_attn_epilogue_vmem_bytes(
            n, f, di, nh, do // nh, shared_v=(model == "rgat"))
        emit(f"kernel/stacked_attn_epilogue_vmem/{tag}", 0.0,
             f"{vmem/2**20:.2f}MiB VMEM/step (16MiB budget)",
             shape=shape, vmem_bytes=vmem)


def _bench_autotune():
    """Autotuned vs default block sizes for the stacked mean+linear kernel
    at the mag level-1 shape.

    Wall-clock here is Pallas *interpret* mode — a structural proxy whose
    cost tracks grid-step count, the same quantity the analytic cost model
    minimizes; the committed-table analytic costs are emitted alongside.
    Real TPU wall-clock for the sweep is the ROADMAP follow-on."""
    from repro.kernels import autotune
    from repro.kernels.ops import DEFAULT_BLOCKS, lookup_blocks

    rb, n, f, di, do, U = 8, 1024, 25, 128, 64, 8
    tuned = lookup_blocks("stacked_mean_linear", n, f, di, do)
    if tuned is None:  # no committed table: nothing to compare against
        return
    rng = np.random.default_rng(5)
    h = jnp.asarray(rng.standard_normal((rb, n, f, di)), jnp.float32)
    mask = jnp.asarray(rng.random((rb, n, f)) > 0.2)
    w = jnp.asarray(rng.standard_normal((U, di, do)) * 0.1, jnp.float32)
    b = jnp.zeros((U, do), jnp.float32)
    u = jnp.arange(U, dtype=jnp.int32)

    def timed(blocks):
        bn, bo, bc = blocks
        return time_call(lambda: jax.block_until_ready(
            stacked_mean_linear(h, mask, w, b, u, block_n=bn, block_out=bo,
                                block_in=bc, interpret=True)), repeats=3)

    t_def, t_tuned = timed(DEFAULT_BLOCKS), timed(tuned)
    c_def = autotune.analytic_cost_us("stacked_mean_linear", n, f, di, do,
                                      *DEFAULT_BLOCKS)
    c_tuned = autotune.analytic_cost_us("stacked_mean_linear", n, f, di, do,
                                        *tuned)
    shape = dict(op="stacked_mean_linear", rb=rb, n=n, f=f, d_in=di, d_out=do)
    emit("kernel/autotune_default_blocks/mag_l1", t_def * 1e6,
         f"blocks={DEFAULT_BLOCKS}, interpret-mode grid proxy",
         shape=shape, blocks=list(DEFAULT_BLOCKS),
         analytic_us=round(c_def, 1), vmem_bytes=0)
    emit("kernel/autotune_tuned_blocks/mag_l1", t_tuned * 1e6,
         f"blocks={tuned}, {t_def/t_tuned:.2f}x vs default "
         f"(analytic {c_def/c_tuned:.2f}x)",
         shape=shape, blocks=list(tuned),
         speedup_vs_default=round(t_def / t_tuned, 3),
         analytic_us=round(c_tuned, 1),
         analytic_speedup_vs_default=round(c_def / c_tuned, 3), vmem_bytes=0)


def _bench_flash_attention():
    rng = np.random.default_rng(3)
    # args passed, not closed over — closures constant-fold the whole
    # attention at compile time
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 128)), jnp.float32)
    fn2 = jax.jit(lambda a, b2, c: attention_ref(a, b2, c, causal=True))
    t2 = time_call(lambda: jax.block_until_ready(fn2(q, q, q)))
    emit("kernel/flash_attention_ref", t2 * 1e6, "oracle 8x1024x128 causal",
         shape=[1, 8, 1024, 128], vmem_bytes=0)
    emit("kernel/flash_attention_vmem", 0.0,
         "0.4MiB/step at bq=bk=128 — O(S·W) at window 8192 enables long_500k",
         shape=[1, 8, 1024, 128], vmem_bytes=int(0.4 * 2**20))


def run():
    _bench_relation_agg()
    _bench_stacked()
    _bench_fused_epilogue()
    _bench_autotune()
    _bench_flash_attention()
    write_records(OUT_JSON)
    return True


if __name__ == "__main__":
    run()
