"""Shared benchmark utilities: timers, CSV rows, the machine-readable
record sink behind ``BENCH_kernels.json``, and the α-β cost model used to
project communication volumes to the paper's testbed wall-clock."""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

ROWS: List[str] = []
RECORDS: List[Dict] = []


def _host_fields() -> Dict:
    """Backend + core count stamped on every record: timing rows are only
    comparable against rows measured on the same substrate."""
    import os

    try:
        import jax

        backend = jax.default_backend()
    except Exception:  # pragma: no cover - jax always importable in-repo
        backend = "unknown"
    return {"backend": backend, "cpus": os.cpu_count()}


def emit(name: str, us_per_call: float, derived: str = "", **record) -> None:
    """Print + collect one benchmark row.

    Keyword fields (``shape=``, ``gflops=``, ``vmem_bytes=``, ...) make the
    row machine-readable: it lands in :data:`RECORDS` and is written out by
    :func:`write_records` — the repo's perf trajectory
    (``BENCH_kernels.json``) instead of print-only CSV lines.  Every record
    is stamped with the measuring backend and host core count."""
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
    if record:
        RECORDS.append({"op": name, "us": round(us_per_call, 3),
                        **_host_fields(), **record})


def write_records(path: str) -> None:
    """Dump the structured rows collected so far as a JSON array."""
    with open(path, "w") as f:
        json.dump(RECORDS, f, indent=2)
        f.write("\n")
    print(f"wrote {len(RECORDS)} records -> {path}", flush=True)


def time_call(fn: Callable, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall time of fn() in seconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


# --------------------------------------------------------------------------
# α-β model with the paper's testbed constants (§8.1): g4dn.metal instances,
# 100 Gbps network, T4 GPUs over PCIe3 x8 (≈8 GB/s effective per GPU)
# --------------------------------------------------------------------------

NET_BPS = 100e9 / 8  # bytes/s, 100 Gbps
NET_ALPHA = 30e-6  # per-message latency
PCIE_BPS = 8e9
DRAM_RANDOM_BPS = 2e9  # random-access effective DRAM bandwidth [49]


def net_time(bytes_: float, messages: int = 1) -> float:
    return NET_ALPHA * messages + bytes_ / NET_BPS


def pcie_time(bytes_: float, transfers: int = 1) -> float:
    return 10e-6 * transfers + bytes_ / PCIE_BPS


def dram_random_time(bytes_: float) -> float:
    return bytes_ / DRAM_RANDOM_BPS


def timed_fit(sess, steps: int, warmup: int = 2):
    """Warm up a compiled :class:`repro.api.Heta` session, then time
    ``fit(steps)``: returns ``(wall_per_step_s, overlap_fraction)`` over the
    timed steps only (the session's cumulative ``overlap_fraction`` would
    fold in the compile-dominated warmup)."""
    sess.fit(warmup)
    n0 = len(sess.step_times)
    t0 = time.perf_counter()
    sess.fit(steps)
    wall = time.perf_counter() - t0
    serial = sum(sess.host_times[n0:]) + sum(sess.step_times[n0:])
    overlap = max(0.0, 1.0 - wall / serial) if serial > 0 else 0.0
    return wall / steps, overlap
