"""Paper Fig. 8/9 — end-to-end epoch time, Heta vs the vanilla execution
model.

Two readings:
  * measured — actual per-step wall time of the SPMD executor on this CPU
    host for Heta (meta placement) vs the naive-placement ablation (the
    communication difference shows up as extra work in the inner psum).
  * projected — the α-β model over exact per-batch byte counts at the
    paper's testbed constants (100 Gbps, PCIe3), giving the epoch-time
    split the paper measures on 2×g4dn.metal.  Heta's speedup there comes
    from eliminating feature fetching + remote learnable updates.
"""

from __future__ import annotations

import numpy as np

from benchmarks._util import dram_random_time, emit, net_time, pcie_time
from repro.core.comm import vanilla_comm_bytes, vanilla_update_bytes
from repro.core.meta_partition import meta_partition, random_edge_cut
from repro.core.raf import assign_branches, raf_comm_bytes, random_branch_assignment
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import make_dataset
from repro.launch.train import train_hgnn


def projected_epoch(dataset: str, scale, batch: int, fanouts, hidden: int = 64):
    """α-β projection of one epoch's comm/update time, vanilla vs Heta."""
    g = make_dataset(dataset, scale=scale)
    mp = meta_partition(g, 2, num_layers=len(fanouts))
    spec = SampleSpec.from_metatree(mp.metatree, fanouts)
    sampler = NeighborSampler(g, spec, batch, seed=0)
    b = sampler.sample_batch(g.train_nodes[:batch])
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    cut = random_edge_cut(g, 2)
    steps = max(1, len(g.train_nodes) // batch)

    v_bytes = vanilla_comm_bytes(b, cut, feat_dims, bytes_per_elem=2)
    v_upd = vanilla_update_bytes(b, cut, g, bytes_per_elem=2)
    h_bytes = raf_comm_bytes(spec, assign_branches(spec, mp), batch, hidden, 2)
    t_vanilla = steps * (net_time(v_bytes, 64) + net_time(v_upd, 16)
                         + dram_random_time(v_upd))
    t_heta = steps * net_time(h_bytes, 4)
    return t_vanilla, t_heta, steps


def _measured_step(model: str, local: bool) -> float:
    """Warm, fixed-batch step time of the SPMD executor (device compute only;
    the host pipeline stages are measured separately in breakdown.py)."""
    import time

    import jax

    from repro.core import raf_spmd
    from repro.core.hgnn import HGNNConfig, init_embed_tables, init_hgnn_params
    from repro.core.raf import assign_branches, random_branch_assignment
    from repro.optim.adam import AdamConfig, adam_init

    g = make_dataset("ogbn-mag", scale=0.002)
    mp = meta_partition(g, 2, num_layers=2)
    spec = SampleSpec.from_metatree(mp.metatree, (5, 4))
    batch = NeighborSampler(g, spec, 32, seed=1).sample_batch(g.train_nodes[:32])
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    cfg = HGNNConfig(model=model, hidden=64, num_layers=2,
                     num_classes=g.num_classes)
    params = init_hgnn_params(jax.random.PRNGKey(0), cfg, spec, feat_dims)
    emb = init_embed_tables(jax.random.PRNGKey(1), cfg, g.num_nodes, feat_dims)
    tables = {t: np.asarray(f) for t, f in g.features.items()}
    tables.update({t: np.asarray(v) for t, v in emb.items()})
    assignment = (
        assign_branches(spec, mp) if local
        else random_branch_assignment(spec, 2, seed=0)
    ).fold(1, spec)
    plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    stacks = raf_spmd.shard_stacks(
        plan, mesh, raf_spmd.stack_params_from_dict(plan, params))
    arrays = raf_spmd.shard_arrays(plan, mesh, raf_spmd.stack_batch(plan, batch, tables))
    step = raf_spmd.make_train_step(plan, mesh, AdamConfig(), data_axes=("data",),
                                    local_combine=local)
    opt = adam_init(stacks)
    ts = []
    for i in range(6):
        t0 = time.perf_counter()
        stacks, opt, loss = step(stacks, opt, arrays)
        jax.block_until_ready(loss)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts[2:]))


def run():
    # measured: warm step time of the real executor, meta vs naive placement
    for model in ("rgcn", "rgat"):
        t_meta = _measured_step(model, local=True)
        t_naive = _measured_step(model, local=False)
        emit(f"epoch/measured/{model}/heta_step", t_meta * 1e6, "meta placement")
        emit(f"epoch/measured/{model}/naive_step", t_naive * 1e6,
             "naive placement (adds inner-level exchange; ~equal on 1 device)")

    # projected at the paper's constants (comm+update portion of the epoch)
    for ds, scale, batch in (("ogbn-mag", 0.01, 1024), ("mag240m", 0.0005, 1024)):
        tv, th, steps = projected_epoch(ds, scale, batch, (25, 20))
        emit(f"epoch/projected/{ds}/vanilla", tv * 1e6, f"{steps} steps/epoch")
        emit(f"epoch/projected/{ds}/heta", th * 1e6,
             f"comm speedup {tv/max(th,1e-12):.1f}x (paper e2e: 1.9-5.8x incl. compute)")
    return True


if __name__ == "__main__":
    run()
