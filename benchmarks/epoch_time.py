"""Paper Fig. 8/9 — end-to-end epoch time, Heta vs the vanilla execution
model.

Two readings:
  * measured — actual per-step wall time of the SPMD executor on this CPU
    host for Heta (meta placement) vs the naive-placement ablation (the
    communication difference shows up as extra work in the inner psum).
    Driven through the session API with a fixed batch and learnable-feature
    training frozen (``ModelConfig(train_learnable=False)``), so the timed
    region is the jitted device step alone — the same quantity the
    pre-session-API benchmark measured (host staging and the cache's sparse
    write-back are measured separately in breakdown.py).
  * projected — the α-β model over exact per-batch byte counts at the
    paper's testbed constants (100 Gbps, PCIe3), giving the epoch-time
    split the paper measures on 2×g4dn.metal.  Heta's speedup there comes
    from eliminating feature fetching + remote learnable updates.
"""

from __future__ import annotations

import numpy as np

from benchmarks._util import dram_random_time, emit, net_time, timed_fit
from repro.api import (
    CacheConfig, DataConfig, Heta, HetaConfig, ModelConfig, PartitionConfig,
    RunConfig,
)


def projected_epoch(dataset: str, scale, batch: int, fanouts, hidden: int = 64):
    """α-β projection of one epoch's comm/update time, vanilla vs Heta."""
    sess = Heta(HetaConfig(
        data=DataConfig(dataset=dataset, scale=scale, fanouts=fanouts,
                        batch_size=batch),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(hidden=hidden),
    ))
    g = sess.build_graph()
    sess.partition()
    comm = sess.comm_report(bytes_per_elem=2)
    steps = max(1, len(g.train_nodes) // batch)

    t_vanilla = steps * (net_time(comm["vanilla_feat"], 64)
                         + net_time(comm["vanilla_update"], 16)
                         + dram_random_time(comm["vanilla_update"]))
    t_heta = steps * net_time(comm["raf_meta"], 4)
    return t_vanilla, t_heta, steps


def _measured_step(model: str, local: bool) -> float:
    """Warm, fixed-batch device step time of the SPMD executor through the
    session (learnable features frozen: device compute only, as before)."""
    sess = Heta(HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(5, 4),
                        batch_size=32),
        partition=PartitionConfig(num_partitions=2,
                                  placement="meta" if local else "naive"),
        model=ModelConfig(model=model, hidden=64, train_learnable=False),
        cache=CacheConfig(cache_mb=2),
        run=RunConfig(executor="raf_spmd", mesh_shape=(1, 1), seed=1),
    ))
    g = sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    batch = sess.sampler.sample_batch(g.train_nodes[:32])
    for _ in range(6):
        sess.step(batch)
    return float(np.median(sess.step_times[2:]))


def _measured_fit(pipelined: bool, steps: int = 16,
                  num_workers: int = 0) -> tuple:
    """End-to-end fit wall time per step, async host pipeline on vs off —
    identical batches either way (per-batch sampler RNG), so the difference
    is purely the sample+stage work hidden behind the device step.  On a
    CPU-only host the win is modest (the producer shares cores + the GIL
    with the jitted step); the breakdown benchmark reports the overlap
    fraction the stream actually achieved.  ``num_workers`` selects the
    producer: the background thread (0) or a sampler process pool that also
    stages frozen-table batches worker-side (DESIGN.md §9)."""
    cfg = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(5, 4),
                        batch_size=32),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(hidden=64, train_learnable=False),
        cache=CacheConfig(cache_mb=2),
        run=RunConfig(executor="raf_spmd", mesh_shape=(1, 1), seed=1,
                      steps=steps),
    )
    if pipelined:
        cfg = cfg.updated(pipeline=dict(enabled=True,
                                        num_workers=num_workers))
    sess = Heta(cfg)
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    try:
        # warmup inside timed_fit spawns the pool; the timed fit reuses it,
        # so the figure is steady-state, not worker spawn cost
        wall, overlap = timed_fit(sess, steps)
        return wall, overlap, sess.results()["queue_bytes_per_step"]
    finally:
        sess.close_pipeline()


def run_worker_fit_sweep(workers=(0, 1, 2, 4), steps: int = 16):
    """End-to-end fit per-step wall time across sampler worker counts —
    same model, same batches (bit-identical for any worker count); emits
    machine-readable rows for ``BENCH_pipeline.json``."""
    import os

    t_serial, _, _ = _measured_fit(pipelined=False, steps=steps)
    emit("pipeline/fit/serial_step", t_serial * 1e6, "no pipeline",
         workers=-1, kind="fit", batch_size=32,
         queue_bytes_per_step=0, cpus=os.cpu_count())
    for w in workers:
        t_w, overlap, qbytes = _measured_fit(pipelined=True, steps=steps,
                                             num_workers=w)
        emit(f"pipeline/fit/workers{w}", t_w * 1e6,
             f"overlap {overlap:.2f}, {t_serial / max(t_w, 1e-12):.2f}x vs "
             f"serial, {qbytes:.0f} B/queue item",
             workers=w, kind="fit", batch_size=32,
             samples_per_s=round(32 / max(t_w, 1e-12), 1),
             overlap_fraction=round(overlap, 3),
             speedup_vs_serial=round(t_serial / max(t_w, 1e-12), 3),
             queue_bytes_per_step=round(qbytes, 1),
             cpus=os.cpu_count())


def run():
    # measured: warm step time of the real executor, meta vs naive placement
    for model in ("rgcn", "rgat"):
        t_meta = _measured_step(model, local=True)
        t_naive = _measured_step(model, local=False)
        emit(f"epoch/measured/{model}/heta_step", t_meta * 1e6, "meta placement")
        emit(f"epoch/measured/{model}/naive_step", t_naive * 1e6,
             "naive placement (adds inner-level exchange; ~equal on 1 device)")

    # ablation: async host pipeline on vs off (same batches, same model)
    t_serial, _, _ = _measured_fit(pipelined=False)
    t_pipe, overlap, _ = _measured_fit(pipelined=True)
    emit("epoch/pipeline/serial_step", t_serial * 1e6, "host stages in line")
    emit("epoch/pipeline/overlapped_step", t_pipe * 1e6,
         f"sample+stage prefetched; overlap fraction {overlap:.2f}")
    emit("epoch/pipeline/speedup", t_serial / max(t_pipe, 1e-12),
         "serial / overlapped wall per step")

    # projected at the paper's constants (comm+update portion of the epoch)
    for ds, scale, batch in (("ogbn-mag", 0.01, 1024), ("mag240m", 0.0005, 1024)):
        tv, th, steps = projected_epoch(ds, scale, batch, (25, 20))
        emit(f"epoch/projected/{ds}/vanilla", tv * 1e6, f"{steps} steps/epoch")
        emit(f"epoch/projected/{ds}/heta", th * 1e6,
             f"comm speedup {tv/max(th,1e-12):.1f}x (paper e2e: 1.9-5.8x incl. compute)")
    return True


if __name__ == "__main__":
    import argparse

    from benchmarks._util import write_records

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-workers", default=None,
                    help="comma list, e.g. 0,1,2,4: sweep sampler worker "
                         "counts through an end-to-end fit")
    ap.add_argument("--records-out", type=str, default=None,
                    help="write machine-readable rows here")
    ap.add_argument("--skip-main", action="store_true",
                    help="only the worker sweep, skip the epoch-time runs")
    args = ap.parse_args()
    if not args.skip_main:
        run()
    if args.num_workers is not None:
        run_worker_fit_sweep(
            workers=tuple(int(x) for x in str(args.num_workers).split(",")))
    if args.records_out:
        write_records(args.records_out)
