"""Deterministic fault injection (DESIGN.md §12).

Fault tolerance that is only exercised by real crashes is anecdotal; this
module makes every failure domain *schedulable* so the recovery guarantees
are regression-tested.  A :class:`FaultPlan` is a frozen, picklable list of
:class:`FaultSpec` triggers keyed on deterministic coordinates — pool item
index, worker id, attempt number, flush index — never wall-clock time, so
a drill replays identically on every run:

  * ``kill_worker``  — sampler worker ``worker`` calls ``os._exit(73)``
    *before* producing item ``step`` (first attempt only, so the
    supervisor's respawned replacement sails through the replay).  Drives
    the worker-supervision battery: a pooled frozen-mode ``fit`` must
    complete with bit-identical losses.
  * ``poison_slot``  — the worker completes item ``step``'s arena write
    but then corrupts the slot's ``write_seq`` stamp, so the consumer's
    ``resolve`` fails loudly (the torn-write detector battery).
  * ``raise_item``   — the worker raises :class:`InjectedFault` from
    ``task(step)`` (first attempt only): the classic transient error.
  * ``fail_flush``   — the serving tier's primary flush path raises
    :class:`InjectedFault` for ``count`` consecutive flushes starting at
    flush index ``step`` (drives retry-with-backoff and the circuit
    breaker into the degraded cache-bypass path).
  * ``delay_flush``  — the flush sleeps ``delay_s`` first (deadline
    drills).

Consumed by ``SampleStageTask``/``EmbeddingServer`` (both accept a
``faults=`` plan), the chaos test batteries, and
``benchmarks/fault_drill.py``.  Deliberately jax-free and numpy-free:
plans cross the spawn boundary into sampler workers.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

__all__ = ["FaultKind", "FaultSpec", "FaultPlan", "InjectedFault"]

FaultKind = str

KINDS = ("kill_worker", "poison_slot", "raise_item", "fail_flush",
         "delay_flush")

# exit code of an injected worker kill — distinctive in WorkerDiedError
# messages and never produced by a Python exception path
KILL_EXIT_CODE = 73


class InjectedFault(RuntimeError):
    """An error raised on purpose by a :class:`FaultPlan` trigger."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``step`` is the pool item index (worker-side kinds) or the flush index
    (serve-side kinds).  ``worker`` narrows worker-side kinds to one worker
    id (-1 = any).  ``count`` widens ``fail_flush``/``delay_flush`` to that
    many consecutive flushes.  ``first_attempt_only`` (default) makes
    ``kill_worker``/``raise_item`` fire only on a worker's first
    incarnation — the respawned replacement replays the stripe cleanly."""

    kind: FaultKind
    step: int
    worker: int = -1
    count: int = 1
    delay_s: float = 0.0
    first_attempt_only: bool = True

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.step < 0:
            raise ValueError(f"step must be >= 0, got {self.step}")
        if self.count < 1:
            raise ValueError(f"count must be >= 1, got {self.count}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of faults (see module docstring).

    Query helpers take the exact coordinates the hook sites have — worker
    id + attempt + item index, or flush index — and return whether/what to
    inject.  An empty plan injects nothing, so hook sites can hold a plan
    unconditionally."""

    faults: Tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    # -- worker-side queries ------------------------------------------------

    def _worker_match(self, kind: str, wid: int, attempt: int,
                      item: int) -> Optional[FaultSpec]:
        for f in self.faults:
            if f.kind != kind or f.step != item:
                continue
            if f.worker >= 0 and f.worker != wid:
                continue
            if f.first_attempt_only and attempt > 0:
                continue
            return f
        return None

    def kill_at(self, wid: int, attempt: int, item: int) -> bool:
        """Should worker ``wid`` (incarnation ``attempt``) die before
        producing ``item``?"""
        return self._worker_match("kill_worker", wid, attempt, item) is not None

    def raise_at(self, wid: int, attempt: int, item: int) -> bool:
        """Should the task raise :class:`InjectedFault` for ``item``?"""
        return self._worker_match("raise_item", wid, attempt, item) is not None

    def poison_at(self, wid: int, attempt: int, item: int) -> bool:
        """Should the arena slot written for ``item`` be stamp-corrupted?"""
        return self._worker_match("poison_slot", wid, attempt, item) is not None

    # -- serve-side queries --------------------------------------------------

    def flush_fault(self, flush_index: int) -> Optional[FaultSpec]:
        """The ``fail_flush`` spec covering ``flush_index``, if any."""
        for f in self.faults:
            if f.kind == "fail_flush" and f.step <= flush_index < f.step + f.count:
                return f
        return None

    def flush_delay(self, flush_index: int) -> float:
        """Seconds the flush at ``flush_index`` should sleep first."""
        for f in self.faults:
            if f.kind == "delay_flush" and f.step <= flush_index < f.step + f.count:
                return f.delay_s
        return 0.0

    # -- interchange ---------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(f) for f in self.faults])

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        return cls(faults=tuple(FaultSpec(**d) for d in json.loads(s)))

    def __bool__(self) -> bool:
        return bool(self.faults)
