"""Multi-process data-parallel RAF training over a shared graph store.

DESIGN.md §13: ``Heta.fit`` with ``scale.num_trainers = N > 1`` spawns
``N-1`` trainer processes (the session's own process is rank 0), each of
which attaches the *same* graph store — a ``/dev/shm`` segment
(:func:`repro.graph.shm.share_graph`) or an on-disk memory-mapped store
(:func:`repro.graph.mmap_store.mmap_share_graph`), per ``scale.store`` —
builds the identical deterministic session (same config, same
name-derived parameter init, same plan), and trains under one of two
disciplines (``scale.mode``):

``"global"`` — stripe parallelism over the *global* batch schedule.
    Trainer ``r`` samples, stages and computes global steps
    ``r, r+N, 2N+r, …`` with the executor's fused train step (the same
    jitted program the single-process fit runs, ``sync_stack_grads``
    included) and publishes the updated state bytes through the shm
    exchange; the other trainers adopt them. Because every step runs the
    single-process program on the single-process state sequence, the
    loss trajectory is **bit-identical** to ``fit`` with
    ``num_trainers = 1`` — while the expensive host work (sampling +
    staging, and each step's device compute) is owned by exactly one
    trainer. Works with any staged-protocol executor.

``"local"`` — hierarchy-owned sub-batch data parallelism (raf_spmd).
    :func:`repro.core.meta_partition.hierarchical_partition` assigns
    every train node to exactly one ``(group, sub-partition)``; trainer
    ``r`` samples sub-batches of ``batch_size // N`` seeds from the
    train nodes it owns, computes raw stack gradients
    (:func:`repro.core.raf_spmd.make_grad_step`), pre-scales them by its
    batch share and contributes them to the exchange, which sums
    contributions in **fixed rank order** — so the reduced gradient is
    bitwise identical on every rank — before each rank runs
    :func:`repro.core.raf_spmd.make_apply_step`
    (``sync_stack_grads`` + Adam) on the sum. Parameters therefore stay
    bit-identical *across trainers* (asserted via state hashes at the
    end of every DP fit); the trajectory differs from the single-process
    schedule (different seed routing), which is why parity CI runs
    ``"global"``.

The exchange itself (:class:`DPExchange`) is a fixed-slot ring over one
shm segment (:func:`repro.graph.shm.share_arrays` layout, so the
DESIGN.md §12 janitor discipline covers it): per slot an int64 control
record ``[writing, contrib, ready, consumed]`` mutated only under one
``multiprocessing.Condition``, float64 per-rank loss/batch-size rows,
and the flattened payload pytree. Writers block until the slot's
previous generation is fully consumed; readers block until the slot is
ready; every wait polls peer liveness and times out loudly. With
``scale.overlap`` (default) each trainer stages its next owned batch in
a daemon thread, so host sampling hides behind the exchange waits —
scale-out adds bandwidth, not a barrier.

v1 limits (recorded follow-ons, DESIGN.md §13): learnable-table
training is rejected when the engine would apply sparse row updates
(``plan.learn_feats``) — table-gradient exchange is not wired; periodic
mid-fit checkpointing is skipped during a DP fit (checkpoint before or
after); trainer processes are supervised (a dead peer fails the fit
loudly) but not respawned.
"""

from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graph.shm import (ArraysHandle, SharedArrays, _open_attached,
                             _view, share_arrays)

__all__ = [
    "DPError",
    "DPExchange",
    "attach_exchange",
    "create_exchange",
    "run_dp_fit",
]

_DEPTH = 4  # exchange ring slots (state/grad generations in flight)
_TIMEOUT_S = 300.0  # covers child startup: spawn + jax import + build + jit


class DPError(RuntimeError):
    """A DP trainer peer died, timed out, or diverged."""


# --------------------------------------------------------------------------
# shm exchange — fixed-slot ring, deterministic fixed-rank-order reduction
# --------------------------------------------------------------------------


def _leaf_template(leaves) -> Tuple[Tuple[Tuple[int, ...], str], ...]:
    """(shape, dtype) per payload leaf — the exchange's wire contract."""
    return tuple(
        (tuple(np.shape(x)), np.dtype(np.asarray(x).dtype).str) for x in leaves
    )


class DPExchange:
    """One slot-ring exchange among ``num_ranks`` trainer processes.

    See the module docstring for the protocol. All control-word mutation
    happens under ``cond``'s lock (full memory barriers on acquire and
    release), so no cross-process atomics are needed; payload reads
    happen outside the lock but only in the window where the slot's
    writers are blocked on its ``consumed`` count.
    """

    # ctl columns: [writing step, contributions done, ready step, consumers done]
    _WRITING, _CONTRIB, _READY, _CONSUMED = range(4)

    def __init__(self, views: Dict[str, np.ndarray], cond, rank: int,
                 num_ranks: int, depth: int, num_leaves: int,
                 timeout_s: float = _TIMEOUT_S,
                 alive: Optional[Callable[[], None]] = None,
                 owner_store: Optional[SharedArrays] = None,
                 attached_shm=None):
        self._ctl = views["ctl"]
        self._loss = views["loss"]
        self._bs = views["bs"]
        self._slots = [
            [views[f"s{j}/{n}"] for n in range(num_leaves)]
            for j in range(depth)
        ]
        self.cond = cond
        self.rank = rank
        self.num_ranks = num_ranks
        self.depth = depth
        self.timeout_s = timeout_s
        self.alive = alive
        self._owner_store = owner_store
        self._attached = attached_shm

    # -- waiting ------------------------------------------------------------

    def _await(self, pred, what: str) -> None:
        """Wait for ``pred`` under the (already held) condition, polling
        peer liveness every second; :class:`DPError` on timeout/dead peer."""
        deadline = time.monotonic() + self.timeout_s
        next_alive = 0.0
        while not pred():
            now = time.monotonic()
            if now >= deadline:
                raise DPError(
                    f"rank {self.rank}: timed out after {self.timeout_s:.0f}s "
                    f"waiting for {what}")
            if self.alive is not None and now >= next_alive:
                self.alive()  # raises DPError when a peer is gone
                next_alive = now + 1.0
            self.cond.wait(timeout=min(0.2, deadline - now))

    def _writable(self, slot: int, k: int) -> bool:
        c = self._ctl[slot]
        drained = c[self._CONSUMED] == self.num_ranks
        return drained and (c[self._READY] in (k - self.depth, -1))

    # -- protocol -----------------------------------------------------------

    def contribute(self, k: int, leaves: Sequence[np.ndarray], order: int,
                   num_contrib: int, loss: float, batch_size: int) -> None:
        """Add this rank's payload for ring step ``k``.

        ``order`` is this rank's index among the step's contributors (the
        fixed reduction order); the first contributor copies, later ones
        accumulate in turn, so the sum is associativity-deterministic.
        The last contribution marks the slot ready."""
        slot = k % self.depth
        ctl = self._ctl
        with self.cond:
            if order == 0:
                self._await(lambda: self._writable(slot, k),
                            f"slot {slot} to drain (step {k})")
                ctl[slot, self._WRITING] = k
                ctl[slot, self._CONTRIB] = 0
            else:
                self._await(
                    lambda: (ctl[slot, self._WRITING] == k
                             and ctl[slot, self._CONTRIB] == order),
                    f"reduction turn {order} of step {k}")
            for view, leaf in zip(self._slots[slot], leaves):
                arr = np.asarray(leaf)
                if order == 0:
                    np.copyto(view, arr, casting="no")
                else:
                    view += arr
            self._loss[slot, self.rank] = float(loss)
            self._bs[slot, self.rank] = int(batch_size)
            ctl[slot, self._CONTRIB] += 1
            if ctl[slot, self._CONTRIB] == num_contrib:
                ctl[slot, self._READY] = k
                ctl[slot, self._CONSUMED] = 0
            self.cond.notify_all()

    def consume(self, k: int) -> Tuple[List[np.ndarray], np.ndarray, np.ndarray]:
        """Copy step ``k``'s reduced payload out of the ring (then ack).

        Returns ``(leaf copies, loss row, batch-size row)`` — copies, so
        the slot can be recycled immediately after the ack."""
        slot = k % self.depth
        with self.cond:
            self._await(lambda: self._ctl[slot, self._READY] == k,
                        f"publication of step {k}")
        # safe outside the lock: writers of step k+depth are blocked on
        # this slot's consumed count until every rank acks
        leaves = [np.array(v) for v in self._slots[slot]]
        loss = self._loss[slot].copy()
        bs = self._bs[slot].copy()
        self.ack(k)
        return leaves, loss, bs

    def ack(self, k: int) -> None:
        """Mark step ``k`` consumed by this rank (contributors that keep
        their own copy ack without reading)."""
        slot = k % self.depth
        with self.cond:
            self._ctl[slot, self._CONSUMED] += 1
            self.cond.notify_all()

    # -- lifecycle ----------------------------------------------------------

    def close(self) -> None:
        self._slots = []
        self._ctl = self._loss = self._bs = None
        if self._attached is not None:
            att, self._attached = self._attached, None
            att.close()
        if self._owner_store is not None:
            self._owner_store.close()

    def unlink(self) -> None:
        self._slots = []
        self._ctl = self._loss = self._bs = None
        if self._owner_store is not None:
            store, self._owner_store = self._owner_store, None
            store.unlink()


def _exchange_arrays(template, num_ranks: int, depth: int) -> Dict[str, np.ndarray]:
    arrays: Dict[str, np.ndarray] = {}
    ctl = np.zeros((depth, 4), np.int64)
    ctl[:, DPExchange._WRITING] = -1
    ctl[:, DPExchange._READY] = -1
    ctl[:, DPExchange._CONSUMED] = num_ranks  # virgin slots are writable
    arrays["ctl"] = ctl
    arrays["loss"] = np.zeros((depth, num_ranks), np.float64)
    arrays["bs"] = np.zeros((depth, num_ranks), np.int64)
    for j in range(depth):
        for n, (shape, dtype) in enumerate(template):
            arrays[f"s{j}/{n}"] = np.zeros(shape, np.dtype(dtype))
    return arrays


def create_exchange(template_leaves, num_ranks: int, cond,
                    depth: int = _DEPTH,
                    timeout_s: float = _TIMEOUT_S) -> DPExchange:
    """Rank 0 (the session process) allocates the exchange segment sized
    for ``template_leaves`` (the flattened payload pytree) and returns its
    writable client; ``.handle`` on the client's ``owner_store`` travels
    to the spawned trainers."""
    template = _leaf_template(template_leaves)
    store = share_arrays(
        _exchange_arrays(template, num_ranks, depth),
        meta={"kind": "dp-exchange", "num_ranks": str(num_ranks),
              "depth": str(depth), "leaves": str(len(template))},
    )
    ex = DPExchange(store.arrays(), cond, 0, num_ranks, depth,
                    len(template), timeout_s, owner_store=store)
    ex.handle = store.handle
    return ex


def attach_exchange(handle: ArraysHandle, cond, rank: int,
                    template_leaves=None,
                    timeout_s: float = _TIMEOUT_S) -> DPExchange:
    """A spawned trainer's writable client of an existing exchange.

    When ``template_leaves`` is given, their (shape, dtype) layout is
    checked against the segment's — a mismatch means the child's
    deterministic rebuild diverged from the parent's, which would corrupt
    the reduction; fail before touching the ring."""
    meta = handle.meta_dict
    num_ranks = int(meta["num_ranks"])
    depth = int(meta["depth"])
    num_leaves = int(meta["leaves"])
    if template_leaves is not None:
        refs = dict(handle.arrays)
        want = _leaf_template(template_leaves)
        if len(want) != num_leaves:
            raise DPError(
                f"rank {rank}: exchange has {num_leaves} payload leaves, "
                f"local state has {len(want)}")
        for n, (shape, dtype) in enumerate(want):
            ref = refs[f"s0/{n}"]
            if tuple(ref.shape) != shape or np.dtype(ref.dtype) != np.dtype(dtype):
                raise DPError(
                    f"rank {rank}: payload leaf {n} mismatch — exchange "
                    f"{tuple(ref.shape)}/{ref.dtype}, local {shape}/{dtype}")
    shm = _open_attached(handle.segment, handle.owner_pid)
    views = {k: _view(shm.buf, r, writeable=True) for k, r in handle.arrays}
    return DPExchange(views, cond, rank, num_ranks, depth, num_leaves,
                      timeout_s, attached_shm=shm)


# --------------------------------------------------------------------------
# per-trainer loop
# --------------------------------------------------------------------------


class _Prefetch:
    """Sample+stage this trainer's upcoming batches in a daemon thread so
    host work overlaps the exchange waits (``scale.overlap``); with
    ``overlap=False`` staging runs inline (the barrier debugging mode).
    Errors surface on the consuming ``get``."""

    def __init__(self, make: Callable[[int], tuple], steps: Sequence[int],
                 depth: int = 2, overlap: bool = True):
        self._make = make
        self._overlap = overlap
        self._err: Optional[BaseException] = None
        if not overlap:
            return
        self._q: "queue.Queue" = queue.Queue(max(1, depth))
        self._stop = threading.Event()
        self._steps = list(steps)
        self._thread = threading.Thread(
            target=self._run, name="dp-prefetch", daemon=True)
        self._thread.start()

    def _run(self) -> None:
        try:
            for i in self._steps:
                t0 = time.perf_counter()
                item = self._make(i)
                host_s = time.perf_counter() - t0
                while not self._stop.is_set():
                    try:
                        self._q.put((i, item, host_s), timeout=0.2)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:  # surfaced by get()
            self._err = e
            try:
                self._q.put_nowait(None)
            except queue.Full:
                pass

    def get(self, i: int):
        if not self._overlap:
            t0 = time.perf_counter()
            item = self._make(i)
            return item, time.perf_counter() - t0
        while True:
            if self._err is not None:
                raise self._err
            try:
                got = self._q.get(timeout=0.5)
            except queue.Empty:
                continue
            if got is None:
                if self._err is not None:
                    raise self._err
                raise DPError("prefetch thread exited unexpectedly")
            step, item, host_s = got
            if step != i:
                raise DPError(f"prefetch out of order: wanted {i}, got {step}")
            return item, host_s

    def close(self) -> None:
        if not self._overlap:
            return
        self._stop.set()
        while True:  # unblock a producer stuck on a full queue
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=2.0)


def _flat(state) -> Tuple[list, object]:
    import jax

    return jax.tree_util.tree_flatten(state)


def _host_leaves(tree) -> List[np.ndarray]:
    # at-least-1-d is the exchange wire contract: shm's ascontiguousarray
    # promotes 0-d arrays (e.g. the Adam step counter) to (1,) anyway, so
    # canonicalise here and restore the true shape in _adopt
    import jax

    return [np.atleast_1d(np.asarray(x)) for x in jax.tree_util.tree_leaves(tree)]


def _adopt(tree, host_leaves: Sequence[np.ndarray]):
    """Rebuild ``tree`` from exchanged host bytes, device-putting each leaf
    with its predecessor's sharding (exact bytes in, exact values out)."""
    import jax

    leaves, treedef = _flat(tree)
    fresh = []
    for x, h in zip(leaves, host_leaves):
        h = np.asarray(h).reshape(np.shape(x))  # undo at-least-1-d wire shape
        fresh.append(jax.device_put(h, x.sharding)
                     if hasattr(x, "sharding") else h)
    return jax.tree_util.tree_unflatten(treedef, fresh)


def state_sha(state) -> str:
    """Order-stable content hash of a state pytree (cross-rank identity
    checks at the end of every DP fit)."""
    import hashlib

    import jax

    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(state):
        a = np.ascontiguousarray(np.asarray(leaf))
        h.update(str(a.shape).encode())
        h.update(a.dtype.str.encode())
        h.update(a.tobytes())
    return h.hexdigest()


def _hierarchy(sess):
    from repro.core.meta_partition import hierarchical_partition

    g, s = sess.config.scale.resolved_hierarchy
    return hierarchical_partition(
        sess.graph, g, s, num_layers=sess.config.num_layers,
        seed=sess.config.run.seed)


def _dp_loop_global(sess, exch: DPExchange, rank: int, num_ranks: int,
                    start_step: int, steps: int, overlap: bool) -> List[float]:
    """Stripe discipline: owner of step ``i`` (rank ``i % N``) runs the
    fused step and publishes the updated state; everyone else adopts it.
    Returns the global loss trajectory (bit-identical to single-process)."""
    ex, plan = sess.executor, sess.plan
    state = sess.state
    B = sess.config.data.batch_size
    owned = [start_step + k for k in range(steps)
             if k % num_ranks == rank]

    def make(i):
        b = sess._batch_for_step(i)
        return b, ex.stage(sess, plan, b)

    pf = _Prefetch(make, owned, overlap=overlap)
    losses: List[float] = []
    try:
        for k in range(steps):
            i = start_step + k
            if k % num_ranks == rank:
                (b, arrays), host_s = pf.get(i)
                state, loss, dt = ex.step_staged(sess, plan, state, b, arrays)
                exch.contribute(k, _host_leaves(state), order=0,
                                num_contrib=1, loss=loss, batch_size=B)
                exch.ack(k)  # the owner keeps its own copy
                sess.host_times.append(host_s)
                sess.step_times.append(dt)
            else:
                leaves, loss_row, _ = exch.consume(k)
                state = _adopt(state, leaves)
                loss = float(loss_row[k % num_ranks])
            losses.append(loss)
    finally:
        pf.close()
    sess.state = state
    return losses


def _dp_loop_local(sess, exch: DPExchange, rank: int, num_ranks: int,
                   start_step: int, steps: int, overlap: bool) -> List[float]:
    """Ownership discipline: each rank draws sub-batches from the train
    nodes its hierarchy sub-partition owns, raw stack gradients are summed
    in fixed rank order, and every rank applies ``sync_stack_grads`` +
    Adam to the identical sum."""
    import jax

    from repro.core import raf_spmd
    from repro.data.worker_pool import EpochSchedule
    from repro.graph.sampler import NeighborSampler

    cfg = sess.config
    plan = sess.plan
    hier = _hierarchy(sess)
    owned_nodes = hier.trainer_train_nodes(sess.graph, rank)
    local_bs = max(1, cfg.data.batch_size // num_ranks)
    if len(owned_nodes) < local_bs:
        raise DPError(
            f"rank {rank} owns {len(owned_nodes)} train nodes < local batch "
            f"size {local_bs}; use fewer trainers or a larger graph")
    local_graph = dataclasses.replace(sess.graph, train_nodes=owned_nodes)
    sampler = NeighborSampler(local_graph, sess.spec, local_bs,
                              seed=cfg.run.seed + 1)
    sched = EpochSchedule(cfg.run.seed + 2 + 7919 * (rank + 1),
                          sampler.steps_per_epoch(), start_step=start_step)
    grad_step = raf_spmd.make_grad_step(
        plan.plan, plan.mesh,
        local_combine=cfg.partition.placement == "meta",
        kernels=cfg.kernels)
    apply_step = raf_spmd.make_apply_step(plan.plan, sess.adam_cfg)
    share = 1.0 / num_ranks  # equal local batches -> sum of scaled = mean

    def make(k):
        es, idx = sched.seed_and_index(k)
        b = sampler.batch_at(idx, epoch_seed=es)
        return b, sess.executor.stage(sess, plan, b)

    pf = _Prefetch(make, range(steps), overlap=overlap)
    state = sess.state
    losses: List[float] = []
    try:
        for k in range(steps):
            (b, arrays), host_s = pf.get(k)
            t0 = time.perf_counter()
            loss_r, grads = grad_step(state["stacks"], arrays)
            grads = jax.tree_util.tree_map(lambda g: g * share, grads)
            loss_r = float(loss_r)
            exch.contribute(k, _host_leaves(grads), order=rank,
                            num_contrib=num_ranks, loss=loss_r,
                            batch_size=local_bs)
            # the prefetch thread stages batch k+1 while this blocks
            sum_leaves, loss_row, bs_row = exch.consume(k)
            gsum = _adopt(grads, sum_leaves)
            stacks, opt = apply_step(state["stacks"], state["opt"], gsum)
            jax.block_until_ready(stacks)
            state = {"stacks": stacks, "opt": opt}
            sess.host_times.append(host_s)
            sess.step_times.append(time.perf_counter() - t0)
            # fixed-order float64 combine -> identical float on every rank
            losses.append(float((loss_row * bs_row).sum() / bs_row.sum()))
    finally:
        pf.close()
    sess.state = state
    return losses


def _dp_loop(sess, exch, rank, num_ranks, start_step, steps, mode, overlap):
    if mode == "local":
        return _dp_loop_local(sess, exch, rank, num_ranks, start_step, steps,
                              overlap)
    return _dp_loop_global(sess, exch, rank, num_ranks, start_step, steps,
                           overlap)


def _payload_template(sess, mode):
    """The exchanged pytree per discipline: full executor state (global)
    or the stack gradients, which share the stacks' structure (local)."""
    tree = sess.state if mode == "global" else sess.state["stacks"]
    return _host_leaves(tree)


# --------------------------------------------------------------------------
# spawned trainer entry
# --------------------------------------------------------------------------


def _trainer_main(cfg_dict: Dict, store_handle, exch_handle, cond, rank: int,
                  num_ranks: int, start_step: int, steps: int, mode: str,
                  overlap: bool, parent_pid: int, result_q) -> None:
    """Entry of a spawned trainer: attach the shared store, rebuild the
    deterministic session, join the exchange, run the loop, report."""
    from repro.api.config import HetaConfig
    from repro.api.session import Heta
    from repro.graph.mmap_store import attach_any

    def parent_alive():
        try:
            os.kill(parent_pid, 0)
        except OSError:
            raise DPError(f"rank {rank}: parent process {parent_pid} is gone")

    attached = None
    exch = None
    try:
        # the pool-less profile pass is bit-identical to the pooled one;
        # don't nest sampler pools inside trainer processes
        cfg = HetaConfig.from_dict(cfg_dict).updated(
            pipeline=dict(num_workers=0))
        attached = attach_any(store_handle)
        sess = Heta(cfg)
        sess.build_graph(graph=attached.graph)
        sess.partition()
        sess.profile_and_cache()
        sess.compile()
        exch = attach_exchange(exch_handle, cond, rank,
                               template_leaves=_payload_template(sess, mode))
        exch.alive = parent_alive
        t0 = time.perf_counter()
        losses = _dp_loop(sess, exch, rank, num_ranks, start_step, steps,
                          mode, overlap)
        result_q.put({
            "rank": rank,
            "ok": True,
            "losses": losses,
            "state_sha": state_sha(sess.state),
            "wall_s": time.perf_counter() - t0,
            "host_s": float(sum(sess.host_times)),
            "device_s": float(sum(sess.step_times)),
        })
    except BaseException as e:
        try:
            result_q.put({"rank": rank, "ok": False,
                          "error": f"{type(e).__name__}: {e}"})
        except Exception:
            pass
        raise
    finally:
        if exch is not None:
            exch.close()
        if attached is not None:
            attached.close()


# --------------------------------------------------------------------------
# the fit driver (rank 0 = the calling session's process)
# --------------------------------------------------------------------------


def _share_store(sess):
    kind = sess.config.scale.store
    if kind == "mmap":
        from repro.graph.mmap_store import mmap_share_graph

        return mmap_share_graph(sess.graph, include_features=True)
    from repro.graph.shm import share_graph

    return share_graph(sess.graph, include_features=True)


def run_dp_fit(sess, steps: int, timeout_s: float = _TIMEOUT_S) -> Dict:
    """Drive one multi-process data-parallel fit (see module docstring).

    The calling session is trainer rank 0: it exports the graph into the
    configured shared store, allocates the exchange, spawns ranks
    ``1..N-1`` (spawn context — trainer children need their own jax),
    runs its own loop, then cross-checks every child's loss trajectory
    and final-state hash bitwise before tearing the segments down.
    Updates the session books (losses, step/host times, step position)
    exactly like the in-process fit, so ``results()``, ``evaluate()``
    and ``save()`` keep working afterwards."""
    from repro.api.session import HetaStageError

    cfg = sess.config
    sc = cfg.scale
    N = sc.num_trainers
    if getattr(sess.plan, "learn_feats", False) or (
            sc.mode == "local" and cfg.model.train_learnable):
        raise HetaStageError(
            "scale-out trains with frozen learnable tables "
            "(model.train_learnable=False): cross-trainer table-gradient "
            "exchange is a recorded DESIGN.md §13 follow-on")
    if sc.mode == "local" and sess.executor.name != "raf_spmd":
        raise HetaStageError(
            f"scale.mode='local' needs the raf_spmd executor (gradient "
            f"extraction), got {sess.executor.name!r}")
    start_step = sess._steps_done
    t_wall = time.perf_counter()
    n0 = len(sess.step_times)
    ctx = mp.get_context("spawn")
    cond = ctx.Condition()
    result_q = ctx.Queue()
    store = _share_store(sess)
    exch = create_exchange(_payload_template(sess, sc.mode), N, cond,
                           timeout_s=timeout_s)
    procs: List[mp.Process] = []
    try:
        from repro.data.worker_pool import _spawnable_main

        with _spawnable_main():  # heredoc-driver-safe spawn (see worker_pool)
            for rank in range(1, N):
                p = ctx.Process(
                    target=_trainer_main,
                    args=(cfg.to_dict(), store.handle, exch.handle, cond,
                          rank, N, start_step, steps, sc.mode, sc.overlap,
                          os.getpid(), result_q),
                    name=f"dp-trainer-{rank}",
                    daemon=True,
                )
                p.start()
                procs.append(p)

        def peers_alive():
            dead = [p.name for p in procs
                    if p.exitcode is not None and p.exitcode != 0]
            if dead:
                raise DPError(f"trainer process(es) died: {dead}")

        exch.alive = peers_alive
        losses = _dp_loop(sess, exch, 0, N, start_step, steps, sc.mode,
                          sc.overlap)
        sha0 = state_sha(sess.state)

        # collect + cross-check every child before declaring success
        reports: Dict[int, Dict] = {}
        deadline = time.monotonic() + timeout_s
        while len(reports) < N - 1:
            peers_alive()
            try:
                r = result_q.get(timeout=0.5)
            except queue.Empty:
                if time.monotonic() >= deadline:
                    missing = sorted(set(range(1, N)) - set(reports))
                    raise DPError(
                        f"timed out waiting for trainer report(s) {missing}")
                continue
            reports[r["rank"]] = r
        failed = {k: r["error"] for k, r in reports.items() if not r["ok"]}
        if failed:
            raise DPError(f"trainer failure(s): {failed}")
        for rank, r in sorted(reports.items()):
            if r["losses"] != losses:
                raise DPError(
                    f"rank {rank} loss trajectory diverged from rank 0 "
                    f"(determinism violation)")
            if r["state_sha"] != sha0:
                raise DPError(
                    f"rank {rank} final state hash {r['state_sha'][:12]}… != "
                    f"rank 0 {sha0[:12]}… (determinism violation)")
        for p in procs:
            p.join(timeout=30.0)
    finally:
        for p in procs:
            if p.is_alive():
                p.terminate()
        for p in procs:
            p.join(timeout=10.0)
        exch.unlink()
        store.unlink()
        result_q.close()

    # session books — mirrors the in-process fit's accounting
    sess.losses.extend(losses)
    sess._steps_done += steps
    wall = time.perf_counter() - t_wall
    sess._fit_wall_s += wall
    sess._fit_steps += steps
    sess._fit_serial_s += (sum(sess.host_times[n0:])
                           + sum(sess.step_times[n0:]))
    g, s = sc.resolved_hierarchy
    out = sess.results()
    out["scale"] = {
        "num_trainers": N,
        "hierarchy": [g, s],
        "mode": sc.mode,
        "store": sc.store,
        "overlap": sc.overlap,
        "state_sha": sha0,
        "trainer_wall_s": {r: rep["wall_s"] for r, rep in
                           sorted(reports.items())},
        "fit_wall_s": wall,
    }
    return out
