"""SampleStream — the async HGNN host pipeline facade.

Runs sample → snapshot → stack → shard in the background and yields
``(batch, arrays, host_seconds)`` ready for the device step, selecting one
of two engines (see the ``repro.data`` package docstring and DESIGN.md §9):

``num_workers == 0`` (default)
    Today's thread pipeline, bit-for-bit: one
    :class:`~repro.data.prefetch.Prefetcher` producer thread runs

      ``make_batch(i) -> batch``   deterministic batch for pipeline step ``i``
      ``stage(batch)  -> arrays``  the executor's host-staging seam

    ``defer_stage=True`` implements the ``"fresh"`` snapshot policy: the
    producer only samples and staging runs synchronously in ``__next__``.

``num_workers > 0``
    A :class:`~repro.data.worker_pool.WorkerPool` of sampler processes over
    a shared-memory graph store.  The caller supplies ``worker_task`` (a
    picklable :class:`~repro.data.worker_pool.SampleStageTask` yielding
    ``(batch, host_arrays | None, host_s)``) and ``finish_stage(batch,
    host_arrays) -> arrays`` — the consumer-side completion (device
    placement of worker-staged arrays, or the executor's full ``stage``
    when workers only sample, e.g. while learnable tables train).
    ``make_batch``/``stage``/``defer_stage`` are ignored in this mode; the
    time ``finish_stage`` spends on the consumer is added to the item's
    ``host_seconds`` (it is not overlapped).

    Alternatively the caller passes an already-running ``pool`` it owns
    (spawn cost amortized across many ``fit`` calls — the session does
    this): the stream then draws exactly ``num_steps`` items and its
    ``close()`` leaves the pool alive for the next stream.

    With a batch **arena** (DESIGN.md §11) the queue items are
    :class:`~repro.data.worker_pool.SlotRef` descriptors; the stream
    resolves each against ``arena``/``spec`` into zero-copy slot views and
    **defers the slot release by one step**: slot ``i`` is handed back to
    its writer only when step ``i+1`` is drawn (or on ``close()``), so the
    consuming device step may alias slot memory safely.  ``queue_bytes``
    records the pickled size of each queue item — the zero-pickle
    guarantee CI asserts on.

In both modes ``host_seconds`` is the sample+stage time actually spent on
the item, measured where it ran, so the consumer can compute the overlap
fraction (host work that ran concurrently with the device step costs no
wall time).  Exceptions raised in any producer — thread or process —
surface in the consumer's ``__next__``; ``close()`` joins everything and is
idempotent.
"""

from __future__ import annotations

import pickle
import time
from typing import Callable, List, Optional, Tuple

from repro.data.prefetch import Prefetcher

__all__ = ["SampleStream"]


class SampleStream:
    def __init__(
        self,
        make_batch: Optional[Callable[[int], object]] = None,
        stage: Optional[Callable[[object], object]] = None,
        num_steps: Optional[int] = None,
        depth: int = 2,
        defer_stage: bool = False,
        num_workers: int = 0,
        worker_task: Optional[object] = None,
        finish_stage: Optional[Callable[[object, object], object]] = None,
        pool: Optional[object] = None,
        arena: Optional[object] = None,
        spec: Optional[object] = None,
    ):
        if num_workers < 0:
            raise ValueError(f"num_workers must be >= 0, got {num_workers}")
        self._stage = stage
        self._defer = defer_stage
        self._finish = finish_stage
        self._pool = None
        self._owns_pool = True
        self._remaining = None
        self._prefetcher = None
        self._arena = arena
        self._spec = spec
        self._pending_release = None  # (slot, use) alive through the step
        self._legacy_item_bytes = None  # measured once; tuple items are big
        self.queue_bytes: List[int] = []  # pickled size of each queue item
        if arena is not None and spec is None:
            raise ValueError("arena mode requires the sampler spec")

        if num_workers == 0:
            if make_batch is None or stage is None:
                raise ValueError("thread mode requires make_batch and stage")

            def produce(i: int) -> Tuple[object, object, float]:
                t0 = time.perf_counter()
                batch = make_batch(i)
                arrays = None if defer_stage else stage(batch)
                return batch, arrays, time.perf_counter() - t0

            self._prefetcher = Prefetcher(produce, depth=depth,
                                          num_items=num_steps,
                                          name="sample-stream")
        else:
            if self._finish is None:
                if stage is None:
                    raise ValueError(
                        "pool mode requires finish_stage (or stage as the "
                        "consumer-side fallback)"
                    )
                self._finish = lambda batch, host: stage(batch)
            if pool is not None:
                # externally-owned, open-ended pool: draw num_steps items,
                # leave it running on close
                self._pool = pool
                self._owns_pool = False
                self._remaining = num_steps
            else:
                if worker_task is None:
                    raise ValueError(
                        "num_workers > 0 requires a picklable worker_task "
                        "(see repro.data.worker_pool.SampleStageTask) or an "
                        "already-running pool"
                    )
                from repro.data.worker_pool import WorkerPool

                self._pool = WorkerPool(worker_task, num_workers=num_workers,
                                        depth=depth, num_items=num_steps,
                                        name="sample-pool")

    @property
    def num_workers(self) -> int:
        return self._pool.num_workers if self._pool is not None else 0

    def __iter__(self) -> "SampleStream":
        return self

    def _release_pending(self) -> None:
        if self._pending_release is not None:
            slot, use = self._pending_release
            self._pending_release = None
            self._arena.release(slot, use)

    def __next__(self) -> Tuple[object, object, float]:
        if self._pool is not None:
            if self._remaining is not None:
                if self._remaining <= 0:
                    raise StopIteration
                self._remaining -= 1
            try:
                item = next(self._pool)
            except BaseException:
                self._release_pending()
                raise
            if self._arena is not None and hasattr(item, "slot"):
                from repro.data.staging import unpack_slot

                # the previous step's views (and any zero-copy device
                # aliases) are dead once the caller asks for the next item
                # — only now may the writer reuse that slot
                self._release_pending()
                self.queue_bytes.append(len(pickle.dumps(item)))
                views = self._arena.resolve(item.slot, item.use)
                batch, host = unpack_slot(views, self._spec)
                t0 = time.perf_counter()
                arrays = self._finish(batch, host)
                self._pending_release = (item.slot, item.use)
                return batch, arrays, item.host_s + time.perf_counter() - t0
            batch, host, host_s = item
            if self._legacy_item_bytes is None:
                # tuple payloads are ~MBs of pickled ndarrays; measure once
                # and reuse (the per-step cost is what the arena removes)
                self._legacy_item_bytes = len(pickle.dumps(item))
            self.queue_bytes.append(self._legacy_item_bytes)
            # consumer-side completion: device placement of worker-staged
            # arrays, or full (fresh) staging when workers only sampled —
            # either way this slice of host time is NOT overlapped
            t0 = time.perf_counter()
            arrays = self._finish(batch, host)
            return batch, arrays, host_s + time.perf_counter() - t0
        batch, arrays, host_s = next(self._prefetcher)
        if self._defer:
            # "fresh" snapshot policy: stage on the consumer, against the
            # current tables (this part of the host time is NOT overlapped)
            t0 = time.perf_counter()
            arrays = self._stage(batch)
            host_s += time.perf_counter() - t0
        return batch, arrays, host_s

    def close(self) -> None:
        self._release_pending()
        if self._pool is not None and self._owns_pool:
            self._pool.close()
        if self._prefetcher is not None:
            self._prefetcher.close()

    def __enter__(self) -> "SampleStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
