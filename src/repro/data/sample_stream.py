"""SampleStream — the async HGNN host pipeline (sample → snapshot → stack →
shard in the background, device step in the foreground).

Built on :class:`~repro.data.prefetch.Prefetcher`; see the ``repro.data``
package docstring for the staged-step protocol and the staleness policy this
implements.  The stream is deliberately decoupled from ``repro.api`` — it
takes two callables:

  ``make_batch(i)  -> batch``   deterministic batch for pipeline step ``i``
                                (``NeighborSampler.batch_at`` under the hood,
                                so prefetch order cannot change the data)
  ``stage(batch)   -> arrays``  the executor's public host-staging seam
                                (``Executor.stage``)

and yields ``(batch, arrays, host_seconds)`` tuples, where ``host_seconds``
is the sample+stage time actually spent on this item (measured inside the
producer, so the consumer can compute the overlap fraction: host work that
ran concurrently with the device step costs no wall time).

``defer_stage=True`` implements the ``"fresh"`` snapshot policy: the
producer only samples, and staging runs synchronously in ``__next__`` — used
when staging reads learnable tables and the caller wants bit-exact parity
with the serial loop instead of staleness-bounded overlap.
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Tuple

from repro.data.prefetch import Prefetcher

__all__ = ["SampleStream"]


class SampleStream:
    def __init__(
        self,
        make_batch: Callable[[int], object],
        stage: Callable[[object], object],
        num_steps: Optional[int] = None,
        depth: int = 2,
        defer_stage: bool = False,
    ):
        self._stage = stage
        self._defer = defer_stage

        def produce(i: int) -> Tuple[object, object, float]:
            t0 = time.perf_counter()
            batch = make_batch(i)
            arrays = None if defer_stage else stage(batch)
            return batch, arrays, time.perf_counter() - t0

        self._prefetcher = Prefetcher(produce, depth=depth,
                                      num_items=num_steps,
                                      name="sample-stream")

    def __iter__(self) -> "SampleStream":
        return self

    def __next__(self) -> Tuple[object, object, float]:
        batch, arrays, host_s = next(self._prefetcher)
        if self._defer:
            # "fresh" snapshot policy: stage on the consumer, against the
            # current tables (this part of the host time is NOT overlapped)
            t0 = time.perf_counter()
            arrays = self._stage(batch)
            host_s += time.perf_counter() - t0
        return batch, arrays, host_s

    def close(self) -> None:
        self._prefetcher.close()

    def __enter__(self) -> "SampleStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
