"""Process-pool sampling over a shared-memory graph (DESIGN.md §9).

The thread :class:`~repro.data.prefetch.Prefetcher` caps host throughput at
one CPU core; this module lifts the host pipeline onto N worker *processes*:

  * **stripe assignment** — worker ``w`` of ``W`` computes items
    ``w, w+W, w+2W, ...``.  Each worker produces its stripe strictly in
    order onto its own bounded queue, so the consumer reconstructs global
    step order by round-robining the queues (``step i`` is always the head
    of queue ``i % W``) — a reorder buffer with zero bookkeeping, and
    bounded lookahead of ``W × depth`` items.
  * **determinism** — tasks are pure functions of their item index
    (``NeighborSampler.batch_at`` under an :class:`EpochSchedule`), so the
    stripe decomposition cannot change the data: any worker count, including
    the thread path, yields bit-identical batches.
  * **zero-copy graph** — workers attach the shared-memory graph store
    (``repro.graph.shm``) named in the task; only the few-hundred-byte
    handle crosses the process boundary at startup, never the graph.
  * **failure discipline** — an exception anywhere in a worker (setup or
    per-item) is shipped to the consumer and re-raised from ``__next__``
    after the pool shuts down; a worker that dies without a word raises
    :class:`WorkerDiedError`.  ``close()`` is idempotent, drains the queues,
    joins every process, and terminates stragglers.

Workers are **spawned** (never forked — the parent owns jax threads) and
deliberately jax-free: a :class:`SampleStageTask` imports only numpy-level
modules, so spawn cost is numpy import plus a shared-memory attach.
"""

from __future__ import annotations

import contextlib
import dataclasses
import multiprocessing as mp
import os
import queue as _queue
import sys
import time
import traceback
from typing import Optional, Tuple

__all__ = [
    "WorkerPool",
    "WorkerDiedError",
    "EpochSchedule",
    "SampleStageTask",
]

_POLL_S = 0.05


class WorkerDiedError(RuntimeError):
    """A worker process exited without posting a result or a failure."""


class _Done:
    """Queue sentinel: this worker's stripe is exhausted."""


class _Failure:
    """Queue sentinel: a worker raised; carries the exception + traceback."""

    def __init__(self, exc: BaseException, tb: str):
        self.exc = exc
        self.tb = tb


def _put(q, stop, item) -> bool:
    """Blocking put that aborts (returns False) once the pool is stopping."""
    while not stop.is_set():
        try:
            q.put(item, timeout=_POLL_S)
            return True
        except _queue.Full:
            continue
    return False


@contextlib.contextmanager
def _spawnable_main():
    """Make ``spawn`` work when ``__main__`` has a phantom ``__file__``.

    ``python - <<EOF`` scripts (CI smoke jobs, ad-hoc drivers) leave
    ``__main__.__file__ = "<stdin>"``; spawn's preparation step would try to
    re-run that non-file in every worker and crash.  Hiding the attribute
    while the workers start makes spawn skip main-module re-execution —
    correct here, since pool tasks live in importable modules, never in
    ``__main__``."""
    main = sys.modules.get("__main__")
    path = getattr(main, "__file__", None)
    phantom = (
        main is not None and path is not None
        and getattr(main, "__spec__", None) is None
        and not os.path.exists(path)
    )
    if phantom:
        del main.__file__
    try:
        yield
    finally:
        if phantom:
            main.__file__ = path


def _picklable_failure(exc: BaseException) -> _Failure:
    """Wrap ``exc`` so it survives the queue (exotic exceptions that don't
    pickle are downgraded to a RuntimeError carrying their repr)."""
    import pickle

    tb = traceback.format_exc()
    try:
        pickle.loads(pickle.dumps(exc))
        return _Failure(exc, tb)
    except BaseException:
        return _Failure(RuntimeError(f"worker failure: {exc!r}"), tb)


def _worker_main(task, wid: int, num_workers: int,
                 num_items: Optional[int], q, stop) -> None:
    """Entry point of one spawned worker: setup, stripe loop, teardown."""
    try:
        task.setup()
    except BaseException as exc:  # noqa: BLE001 — delivered to the consumer
        _put(q, stop, _picklable_failure(exc))
        return
    try:
        i = wid
        while not stop.is_set() and (num_items is None or i < num_items):
            item = task(i)
            if not _put(q, stop, item):
                return
            i += num_workers
        if not stop.is_set():
            _put(q, stop, _Done())
    except BaseException as exc:  # noqa: BLE001
        _put(q, stop, _picklable_failure(exc))
    finally:
        try:
            task.teardown()
        except BaseException:
            pass


class WorkerPool:
    """Ordered fan-out of ``task(0), task(1), ...`` over N processes.

    ``task`` must be picklable with three hooks: ``setup()`` (once, in the
    worker), ``__call__(i)`` (the item for global index ``i``), and
    ``teardown()`` (best-effort, at exit).  Iterator + context manager;
    items come back strictly in index order.
    """

    def __init__(
        self,
        task,
        num_workers: int,
        depth: int = 2,
        num_items: Optional[int] = None,
        name: str = "sampler-pool",
    ):
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if num_items is not None and num_items < 0:
            raise ValueError(f"num_items must be >= 0, got {num_items}")
        ctx = mp.get_context("spawn")
        self.num_workers = num_workers
        self.num_items = num_items
        self._stop = ctx.Event()
        self._queues = [ctx.Queue(maxsize=depth) for _ in range(num_workers)]
        self._procs = []
        self._next = 0
        self._closed = False
        self._done = False
        try:
            with _spawnable_main():
                for w in range(num_workers):
                    p = ctx.Process(
                        target=_worker_main,
                        args=(task, w, num_workers, num_items,
                              self._queues[w], self._stop),
                        name=f"{name}-{w}",
                        daemon=True,
                    )
                    p.start()
                    self._procs.append(p)
        except BaseException:
            self.close()
            raise

    # -- consumer side -------------------------------------------------------

    def __iter__(self) -> "WorkerPool":
        return self

    def __next__(self):
        if self._closed:
            raise RuntimeError("WorkerPool is closed")
        if self._done:
            raise StopIteration
        w = self._next % self.num_workers
        q, proc = self._queues[w], self._procs[w]
        while True:
            try:
                item = q.get(timeout=_POLL_S)
                break
            except _queue.Empty:
                if not proc.is_alive():
                    # a last put may still be in flight in the feeder pipe
                    try:
                        item = q.get(timeout=_POLL_S)
                        break
                    except _queue.Empty:
                        self.close()
                        raise WorkerDiedError(
                            f"worker {w} exited (code {proc.exitcode}) without "
                            f"delivering item {self._next}"
                        ) from None
        if isinstance(item, _Done):
            # stripes interleave: worker w done at position i means every
            # worker's next index is >= num_items — iteration is complete
            self._done = True
            raise StopIteration
        if isinstance(item, _Failure):
            self.close()
            if item.tb:
                item.exc.__cause__ = RuntimeError(
                    f"worker traceback:\n{item.tb}")
            raise item.exc
        self._next += 1
        return item

    # -- lifecycle -----------------------------------------------------------

    def close(self, timeout: float = 5.0) -> None:
        """Stop all workers, drain the queues, join (terminate stragglers).

        Idempotent; after it returns ``__next__`` raises RuntimeError."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        deadline = time.monotonic() + timeout
        while any(p.is_alive() for p in self._procs):
            # drain so workers blocked on a full queue observe the stop event
            for q in self._queues:
                try:
                    while True:
                        q.get_nowait()
                except (_queue.Empty, OSError, ValueError):
                    pass
            if time.monotonic() >= deadline:
                break
            for p in self._procs:
                p.join(timeout=_POLL_S)
        for p in self._procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=1.0)
        for q in self._queues:
            try:
                q.cancel_join_thread()
                q.close()
            except BaseException:
                pass

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort: never leak processes
        try:
            self.close(timeout=0.5)
        except BaseException:
            pass


# --------------------------------------------------------------------------
# the sampling task
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EpochSchedule:
    """Maps a global step to ``(epoch_seed, step-in-epoch)``.

    Epoch ``e`` covers global steps ``[e*E, (e+1)*E)`` and shuffles with
    ``epoch_seed_base + e*E`` — the session's historical seeding, shared
    here so the serial loop, the thread stream and every pool worker derive
    identical batches from identical positions."""

    epoch_seed_base: int
    steps_per_epoch: int
    start_step: int = 0
    shuffle: bool = True

    def seed_and_index(self, i: int) -> Tuple[int, int]:
        s = self.start_step + i
        e, idx = divmod(s, self.steps_per_epoch)
        return self.epoch_seed_base + e * self.steps_per_epoch, idx


@dataclasses.dataclass
class SampleStageTask:
    """The pool task of the HGNN host pipeline: sample (and optionally
    stage) the batch at one global step.

    ``handle`` names the shared-memory graph store; ``recipe`` (a
    :class:`~repro.data.staging.StackRecipe`, or None) moves the frozen-table
    host staging into the worker — its feature tables must have been
    exported into the store (``share_graph(..., tables=...)``).  Returns
    ``(batch, host_arrays | None, host_seconds)`` per item, mirroring the
    thread stream's payload.
    """

    handle: object  # repro.graph.shm.GraphHandle
    spec: object  # repro.graph.sampler.SampleSpec
    batch_size: int
    sampler_seed: int
    schedule: EpochSchedule
    recipe: object = None

    def setup(self) -> None:
        from repro.graph.sampler import NeighborSampler
        from repro.graph.shm import attach

        self._attached = attach(self.handle)
        self._sampler = NeighborSampler(
            self._attached.graph, self.spec, self.batch_size,
            seed=self.sampler_seed,
        )
        self._tables = self._attached.tables

    def __call__(self, i: int):
        from repro.data.staging import stack_batch_host

        t0 = time.perf_counter()
        epoch_seed, idx = self.schedule.seed_and_index(i)
        batch = self._sampler.batch_at(
            idx, epoch_seed=epoch_seed, shuffle=self.schedule.shuffle)
        host = (
            stack_batch_host(self.recipe, batch, self._tables)
            if self.recipe is not None else None
        )
        return batch, host, time.perf_counter() - t0

    def teardown(self) -> None:
        attached = getattr(self, "_attached", None)
        if attached is not None:
            attached.close()
