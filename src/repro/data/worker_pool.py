"""Process-pool sampling over a shared-memory graph (DESIGN.md §9).

The thread :class:`~repro.data.prefetch.Prefetcher` caps host throughput at
one CPU core; this module lifts the host pipeline onto N worker *processes*:

  * **stripe assignment** — worker ``w`` of ``W`` computes items
    ``w, w+W, w+2W, ...``.  Each worker produces its stripe strictly in
    order onto its own bounded queue, so the consumer reconstructs global
    step order by round-robining the queues (``step i`` is always the head
    of queue ``i % W``) — a reorder buffer with zero bookkeeping, and
    bounded lookahead of ``W × depth`` items.
  * **determinism** — tasks are pure functions of their item index
    (``NeighborSampler.batch_at`` under an :class:`EpochSchedule`), so the
    stripe decomposition cannot change the data: any worker count, including
    the thread path, yields bit-identical batches.
  * **zero-copy graph** — workers attach the shared-memory graph store
    (``repro.graph.shm``) named in the task; only the few-hundred-byte
    handle crosses the process boundary at startup, never the graph.
  * **failure discipline** — an exception anywhere in a worker (setup or
    per-item) is shipped to the consumer and re-raised from ``__next__``
    after the pool shuts down; a worker that dies without a word raises
    :class:`WorkerDiedError`.  ``close()`` is idempotent, drains the queues,
    joins every process, and terminates stragglers.
  * **supervision** (DESIGN.md §12) — with ``max_restarts > 0`` a silent
    death (SIGKILL, OOM, ``os._exit``) is *survived* instead: the consumer
    detects it at the exact stripe position the dead worker owed
    (``__next__`` only ever blocks on queue ``i % W``), discards the dead
    worker's queue (any undelivered ``SlotRef`` in it is stale), invokes
    ``on_worker_death`` (the session poisons the worker's arena sub-ring
    there so stale refs fail loudly), and respawns a replacement that
    replays the stripe from that position — tasks are pure functions of
    the item index, so the replayed items are bit-identical and the
    consumer-visible stream is indistinguishable from a faultless run.
    Respawn ``r`` of a worker backs off ``restart_backoff_s * 2**r``
    first; once a worker exhausts the budget, :class:`WorkerDiedError`
    carries the exit code and the last stripe index it delivered.

Workers are **spawned** (never forked — the parent owns jax threads) and
deliberately jax-free: a :class:`SampleStageTask` imports only numpy-level
modules, so spawn cost is numpy import plus a shared-memory attach.
"""

from __future__ import annotations

import contextlib
import dataclasses
import multiprocessing as mp
import os
import queue as _queue
import sys
import time
import traceback
from typing import Dict, List, Optional, Tuple

__all__ = [
    "WorkerPool",
    "WorkerDiedError",
    "EpochSchedule",
    "SlotRef",
    "SampleStageTask",
    "HotnessCountTask",
]

_POLL_S = 0.05


class WorkerDiedError(RuntimeError):
    """A worker process exited without posting a result or a failure."""


class _Done:
    """Queue sentinel: this worker's stripe is exhausted."""


class _Failure:
    """Queue sentinel: a worker raised; carries the exception + traceback."""

    def __init__(self, exc: BaseException, tb: str):
        self.exc = exc
        self.tb = tb


def _put(q, stop, item) -> bool:
    """Blocking put that aborts (returns False) once the pool is stopping."""
    while not stop.is_set():
        try:
            q.put(item, timeout=_POLL_S)
            return True
        except _queue.Full:
            continue
    return False


@contextlib.contextmanager
def _spawnable_main():
    """Make ``spawn`` work when ``__main__`` has a phantom ``__file__``.

    ``python - <<EOF`` scripts (CI smoke jobs, ad-hoc drivers) leave
    ``__main__.__file__ = "<stdin>"``; spawn's preparation step would try to
    re-run that non-file in every worker and crash.  Hiding the attribute
    while the workers start makes spawn skip main-module re-execution —
    correct here, since pool tasks live in importable modules, never in
    ``__main__``."""
    main = sys.modules.get("__main__")
    path = getattr(main, "__file__", None)
    phantom = (
        main is not None and path is not None
        and getattr(main, "__spec__", None) is None
        and not os.path.exists(path)
    )
    if phantom:
        del main.__file__
    try:
        yield
    finally:
        if phantom:
            main.__file__ = path


def _picklable_failure(exc: BaseException) -> _Failure:
    """Wrap ``exc`` so it survives the queue (exotic exceptions that don't
    pickle are downgraded to a RuntimeError carrying their repr)."""
    import pickle

    tb = traceback.format_exc()
    try:
        pickle.loads(pickle.dumps(exc))
        return _Failure(exc, tb)
    except BaseException:
        return _Failure(RuntimeError(f"worker failure: {exc!r}"), tb)


def _worker_main(task, wid: int, num_workers: int,
                 num_items: Optional[int], q, stop,
                 start_item: Optional[int] = None, attempt: int = 0) -> None:
    """Entry point of one spawned worker: setup, stripe loop, teardown.

    ``start_item`` (default ``wid``) is where the stripe loop begins —
    the supervisor respawns a replacement at the consumer's next
    undelivered index so the stripe replays deterministically.
    ``attempt`` counts this worker slot's incarnations (0 = original);
    tasks with fault plans consult it so scheduled faults fire once."""
    try:
        # tasks that block outside the queues (the arena's backpressure
        # gate) need the stop event to exit promptly on pool shutdown
        bind = getattr(task, "bind_stop", None)
        if bind is not None:
            bind(stop)
        bind_w = getattr(task, "bind_worker", None)
        if bind_w is not None:
            bind_w(wid, attempt)
        task.setup()
    except BaseException as exc:  # noqa: BLE001 — delivered to the consumer
        _put(q, stop, _picklable_failure(exc))
        return
    try:
        i = wid if start_item is None else start_item
        while not stop.is_set() and (num_items is None or i < num_items):
            item = task(i)
            if not _put(q, stop, item):
                return
            i += num_workers
        if not stop.is_set():
            _put(q, stop, _Done())
    except BaseException as exc:  # noqa: BLE001
        _put(q, stop, _picklable_failure(exc))
    finally:
        try:
            task.teardown()
        except BaseException:
            pass


class WorkerPool:
    """Ordered fan-out of ``task(0), task(1), ...`` over N processes.

    ``task`` must be picklable with three hooks: ``setup()`` (once, in the
    worker), ``__call__(i)`` (the item for global index ``i``), and
    ``teardown()`` (best-effort, at exit).  Iterator + context manager;
    items come back strictly in index order.

    ``max_restarts`` arms supervision (see module docstring): each worker
    slot may be respawned that many times after a silent death, with
    exponential backoff from ``restart_backoff_s``; ``on_worker_death(wid)``
    runs in the consumer before each respawn (arena slot invalidation).
    ``restarts`` records one event dict per respawn —
    ``{"wid", "item", "exitcode", "attempt", "downtime_s"}`` — the
    recovery-time figure ``benchmarks/fault_drill.py`` reports.
    """

    def __init__(
        self,
        task,
        num_workers: int,
        depth: int = 2,
        num_items: Optional[int] = None,
        name: str = "sampler-pool",
        max_restarts: int = 0,
        restart_backoff_s: float = 0.05,
        on_worker_death=None,
    ):
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if num_items is not None and num_items < 0:
            raise ValueError(f"num_items must be >= 0, got {num_items}")
        if max_restarts < 0:
            raise ValueError(f"max_restarts must be >= 0, got {max_restarts}")
        ctx = mp.get_context("spawn")
        self.num_workers = num_workers
        self.num_items = num_items
        self.max_restarts = max_restarts
        self.restart_backoff_s = restart_backoff_s
        self.on_worker_death = on_worker_death
        self.restarts: List[Dict] = []  # one event dict per respawn
        self._ctx = ctx
        self._task = task
        self._depth = depth
        self._name = name
        self._restart_counts = [0] * num_workers
        self._stop = ctx.Event()
        self._queues = [ctx.Queue(maxsize=depth) for _ in range(num_workers)]
        self._procs = []
        self._next = 0
        self._closed = False
        self._done = False
        try:
            with _spawnable_main():
                for w in range(num_workers):
                    p = ctx.Process(
                        target=_worker_main,
                        args=(task, w, num_workers, num_items,
                              self._queues[w], self._stop),
                        name=f"{name}-{w}",
                        daemon=True,
                    )
                    p.start()
                    self._procs.append(p)
        except BaseException:
            self.close()
            raise

    # -- consumer side -------------------------------------------------------

    def __iter__(self) -> "WorkerPool":
        return self

    def __next__(self):
        if self._closed:
            raise RuntimeError("WorkerPool is closed")
        if self._done:
            raise StopIteration
        w = self._next % self.num_workers
        while True:
            q, proc = self._queues[w], self._procs[w]
            try:
                item = q.get(timeout=_POLL_S)
                break
            except _queue.Empty:
                if not proc.is_alive():
                    # a last put may still be in flight in the feeder pipe
                    try:
                        item = q.get(timeout=_POLL_S)
                        break
                    except _queue.Empty:
                        if self._restart_counts[w] < self.max_restarts:
                            self._respawn(w, proc.exitcode)
                            continue
                        last = self._next - self.num_workers
                        self.close()
                        raise WorkerDiedError(
                            f"worker {w} exited (code {proc.exitcode}) without "
                            f"delivering item {self._next} (last stripe index "
                            f"delivered: {last if last >= 0 else None}; "
                            f"restarts used: {self._restart_counts[w]}/"
                            f"{self.max_restarts})"
                        ) from None
        if isinstance(item, _Done):
            # stripes interleave: worker w done at position i means every
            # worker's next index is >= num_items — iteration is complete
            self._done = True
            raise StopIteration
        if isinstance(item, _Failure):
            self.close()
            if item.tb:
                item.exc.__cause__ = RuntimeError(
                    f"worker traceback:\n{item.tb}")
            raise item.exc
        self._next += 1
        return item

    # -- supervision ---------------------------------------------------------

    def _respawn(self, w: int, exitcode) -> None:
        """Replace silently-dead worker ``w``, replaying from ``self._next``.

        The dead worker's queue is discarded wholesale: per-producer FIFO
        means item ``self._next`` missing implies nothing later from this
        stripe is trustworthy either, and a late-arriving stale ``SlotRef``
        would shift the stream.  ``on_worker_death`` runs *before* the
        replacement spawns so the session can poison the worker's arena
        sub-ring first (DESIGN.md §12)."""
        t0 = time.monotonic()
        r = self._restart_counts[w]
        self._restart_counts[w] = r + 1
        if self.restart_backoff_s > 0:
            time.sleep(min(self.restart_backoff_s * (2 ** r), 5.0))
        # discard the dead worker's queue (stale refs) and give the
        # replacement a fresh one
        old_q = self._queues[w]
        try:
            while True:
                old_q.get_nowait()
        except (_queue.Empty, OSError, ValueError):
            pass
        try:
            old_q.cancel_join_thread()
            old_q.close()
        except BaseException:
            pass
        if self.on_worker_death is not None:
            self.on_worker_death(w)
        self._queues[w] = self._ctx.Queue(maxsize=self._depth)
        old_p = self._procs[w]
        with _spawnable_main():
            p = self._ctx.Process(
                target=_worker_main,
                args=(self._task, w, self.num_workers, self.num_items,
                      self._queues[w], self._stop, self._next, r + 1),
                name=f"{self._name}-{w}-r{r + 1}",
                daemon=True,
            )
            p.start()
        self._procs[w] = p
        old_p.join(timeout=1.0)
        self.restarts.append({
            "wid": w,
            "item": self._next,
            "exitcode": exitcode,
            "attempt": r + 1,
            "downtime_s": time.monotonic() - t0,
        })

    # -- lifecycle -----------------------------------------------------------

    def close(self, timeout: float = 5.0) -> None:
        """Stop all workers, drain the queues, join (terminate stragglers).

        Idempotent; after it returns ``__next__`` raises RuntimeError."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        deadline = time.monotonic() + timeout
        while any(p.is_alive() for p in self._procs):
            # drain so workers blocked on a full queue observe the stop event
            for q in self._queues:
                try:
                    while True:
                        q.get_nowait()
                except (_queue.Empty, OSError, ValueError):
                    pass
            if time.monotonic() >= deadline:
                break
            for p in self._procs:
                p.join(timeout=_POLL_S)
        for p in self._procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=1.0)
        for q in self._queues:
            try:
                q.cancel_join_thread()
                q.close()
            except BaseException:
                pass

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort: never leak processes
        try:
            self.close(timeout=0.5)
        except BaseException:
            pass


# --------------------------------------------------------------------------
# the sampling task
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EpochSchedule:
    """Maps a global step to ``(epoch_seed, step-in-epoch)``.

    Epoch ``e`` covers global steps ``[e*E, (e+1)*E)`` and shuffles with
    ``epoch_seed_base + e*seed_stride`` — by default ``seed_stride = E``,
    the session's historical seeding, shared here so the serial loop, the
    thread stream and every pool worker derive identical batches from
    identical positions.  The §6 pre-sampling sweep seeds epochs with
    ``seed + ep`` instead, which is ``seed_stride=1``."""

    epoch_seed_base: int
    steps_per_epoch: int
    start_step: int = 0
    shuffle: bool = True
    seed_stride: Optional[int] = None  # None = steps_per_epoch

    def seed_and_index(self, i: int) -> Tuple[int, int]:
        s = self.start_step + i
        e, idx = divmod(s, self.steps_per_epoch)
        stride = (self.steps_per_epoch if self.seed_stride is None
                  else self.seed_stride)
        return self.epoch_seed_base + e * stride, idx


@dataclasses.dataclass(frozen=True)
class SlotRef:
    """Queue descriptor of one arena-staged item (DESIGN.md §11).

    This ~200-byte record is the *entire* queue payload when the batch arena
    is active — the batch and staged arrays live in the slot it names.
    ``table_version`` stamps which staging-table publish the worker staged
    against (the staleness bound check); ``staged`` says whether ``h/``
    arrays are present."""

    step: int
    slot: int
    use: int  # slot generation; consumer releases with the same value
    host_s: float
    table_version: int = 0
    staged: bool = False


@dataclasses.dataclass
class SampleStageTask:
    """The pool task of the HGNN host pipeline: sample (and optionally
    stage) the batch at one global step.

    ``handle`` names the shared-memory graph store; ``recipe`` (a
    :class:`~repro.data.staging.StackRecipe`, or None) moves the host
    staging into the worker — its feature tables must have been exported
    into the store (``share_graph(..., tables=...)``) or, with an arena,
    into the arena's table region.  Without ``arena`` each item returns
    ``(batch, host_arrays | None, host_seconds)``, mirroring the thread
    stream's payload; with an :class:`~repro.graph.shm.ArenaHandle` the
    arrays are written straight into the item's ring slot and only a
    :class:`SlotRef` crosses the queue (zero pickled ndarrays).

    ``faults`` (a :class:`~repro.data.faults.FaultPlan`, or None) arms
    deterministic chaos drills: a scheduled ``kill_worker`` exits the
    process with :data:`~repro.data.faults.KILL_EXIT_CODE` before the item
    is produced, ``raise_item`` raises
    :class:`~repro.data.faults.InjectedFault`, and ``poison_slot`` corrupts
    the slot stamp after a completed write.  ``write_timeout_s`` bounds the
    arena backpressure wait — a dead consumer raises
    :class:`~repro.graph.shm.ArenaStalledError` instead of hanging the
    worker forever (DESIGN.md §12).
    """

    handle: object  # repro.graph.shm.GraphHandle | mmap_store.MmapGraphHandle
    spec: object  # repro.graph.sampler.SampleSpec
    batch_size: int
    sampler_seed: int
    schedule: EpochSchedule
    recipe: object = None
    arena: object = None  # repro.graph.shm.ArenaHandle
    faults: object = None  # repro.data.faults.FaultPlan
    write_timeout_s: float = 60.0
    pin_cpus: bool = False  # opt-in: pin worker w to core (w+1) % ncpu

    def bind_stop(self, stop) -> None:
        """Called by the pool runner so the arena backpressure wait can
        observe shutdown."""
        self._stop = stop

    def bind_worker(self, wid: int, attempt: int) -> None:
        """Called by the pool runner: this incarnation's identity, consulted
        by the fault plan so scheduled faults fire deterministically."""
        self._wid = wid
        self._attempt = attempt

    def setup(self) -> None:
        from repro.graph.mmap_store import attach_any
        from repro.graph.sampler import NeighborSampler
        from repro.graph.shm import attach_arena

        if self.pin_cpus:
            # opt-in affinity pin (pipeline.pin_workers): worker w sticks to
            # core (w+1) % ncpu, biasing core 0 toward the consumer — spares
            # the samplers' cache/NUMA locality from scheduler migration.
            # Best-effort: unsupported platforms (macOS) just skip it.
            try:
                ncpu = os.cpu_count() or 1
                os.sched_setaffinity(
                    0, {(getattr(self, "_wid", 0) + 1) % ncpu})
            except (AttributeError, OSError):
                pass

        self._attached = attach_any(self.handle)
        self._sampler = NeighborSampler(
            self._attached.graph, self.spec, self.batch_size,
            seed=self.sampler_seed,
        )
        self._tables = self._attached.tables
        self._arena = attach_arena(self.arena) if self.arena is not None else None
        if self._arena is not None and self._arena.handle.tables:
            if not self._arena.handle.tables_mutable:
                # frozen tables: zero-copy views, read once
                self._tables, _ = self._arena.read_tables()

    def __call__(self, i: int):
        from repro.data.staging import (HOST_PREFIX, pack_batch_into,
                                        stack_batch_host)

        t0 = time.perf_counter()
        if self.faults is not None and self.faults:
            from repro.data.faults import KILL_EXIT_CODE, InjectedFault

            wid = getattr(self, "_wid", 0)
            attempt = getattr(self, "_attempt", 0)
            if self.faults.kill_at(wid, attempt, i):
                os._exit(KILL_EXIT_CODE)  # a silent death: no queue message
            if self.faults.raise_at(wid, attempt, i):
                raise InjectedFault(
                    f"scheduled raise_item fault at item {i} "
                    f"(worker {wid}, attempt {attempt})")
        epoch_seed, idx = self.schedule.seed_and_index(i)
        batch = self._sampler.batch_at(
            idx, epoch_seed=epoch_seed, shuffle=self.schedule.shuffle)
        if self._arena is None:
            host = (
                stack_batch_host(self.recipe, batch, self._tables)
                if self.recipe is not None else None
            )
            return batch, host, time.perf_counter() - t0

        a = self._arena
        slot, use = a.handle.slot_for(i)
        # backpressure: the sub-ring is full until the consumer releases
        # this slot's previous generation
        stop = getattr(self, "_stop", None)
        if not a.wait_writable(slot, use, stop=stop,
                               timeout=self.write_timeout_s):
            if stop is not None and stop.is_set():
                return None  # pool is stopping; the queue put will abort too
            from repro.graph.shm import ArenaStalledError

            raise ArenaStalledError(
                f"arena slot {slot} (use {use}) not writable after "
                f"{self.write_timeout_s:.1f}s — consumer dead or wedged "
                f"(DESIGN.md §12)")
        table_version = 0
        a.begin_write(slot, use)
        try:
            views = a.slot_views(slot, writable=True)
            pack_batch_into(views, batch)
            if self.recipe is not None:
                tables, table_version = (
                    a.read_tables() if a.handle.tables_mutable
                    else (self._tables, a.table_version())
                )
                stack_batch_host(self.recipe, batch, tables,
                                 out=views, prefix=HOST_PREFIX)
        finally:
            a.end_write(slot, use)
        if self.faults is not None and self.faults and self.faults.poison_at(
                getattr(self, "_wid", 0), getattr(self, "_attempt", 0), i):
            a.poison_slot(slot)
        return SlotRef(step=i, slot=slot, use=use,
                       host_s=time.perf_counter() - t0,
                       table_version=table_version,
                       staged=self.recipe is not None)

    def teardown(self) -> None:
        attached = getattr(self, "_attached", None)
        if attached is not None:
            attached.close()
        arena = getattr(self, "_arena", None)
        if arena is not None:
            arena.close()


@dataclasses.dataclass
class HotnessCountTask:
    """Pool task of the §6 pre-sampling sweep: sample the batch at one
    global position and accumulate its node-visit counts locally.

    Counting is a sum over batches, hence order-independent: each worker
    returns ``None`` per item and ships its partial counts dict once, on
    its stripe's last item; the consumer sums the partials — bit-identical
    to the serial :func:`repro.embed.profiler.presample_hotness` loop."""

    handle: object  # repro.graph.shm.GraphHandle
    spec: object
    batch_size: int
    sampler_seed: int
    schedule: EpochSchedule
    num_items: int
    num_workers: int

    def setup(self) -> None:
        import numpy as np

        from repro.graph.sampler import NeighborSampler
        from repro.graph.shm import attach

        self._attached = attach(self.handle)
        self._sampler = NeighborSampler(
            self._attached.graph, self.spec, self.batch_size,
            seed=self.sampler_seed,
        )
        self._counts = {
            t: np.zeros(n, dtype=np.int64)
            for t, n in self._attached.graph.num_nodes.items()
        }

    def __call__(self, i: int):
        epoch_seed, idx = self.schedule.seed_and_index(i)
        batch = self._sampler.batch_at(
            idx, epoch_seed=epoch_seed, shuffle=self.schedule.shuffle)
        batch.count_visits(self._counts)
        if i + self.num_workers >= self.num_items:  # stripe's last item
            return self._counts
        return None

    def teardown(self) -> None:
        attached = getattr(self, "_attached", None)
        if attached is not None:
            attached.close()
