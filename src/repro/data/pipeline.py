"""Token data pipeline for the LM training path.

Offline container ⇒ the corpus is synthetic, but the pipeline is real:
deterministic sharded sequence generation (each host materializes only its
slice), host-side double-buffered prefetch, and device placement with the
production batch shardings.  The structure mirrors what a deployment would
swap a real tokenized dataset into (same iterator contract).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.data.prefetch import Prefetcher

__all__ = ["SyntheticCorpus", "TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    """Deterministic pseudo-corpus: shard s / sequence i is a pure function
    of (seed, s, i), so any host can materialize any slice independently —
    the property real sharded datasets provide via index files."""

    vocab: int
    seq_len: int
    num_shards: int = 16
    seed: int = 0
    # Zipf token distribution: realistic hot-token skew for embedding traffic
    zipf_a: float = 1.3

    def sequence(self, shard: int, index: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, shard, index])
        )
        ranks = rng.zipf(self.zipf_a, size=self.seq_len + 1)
        return np.minimum(ranks - 1, self.vocab - 1).astype(np.int32)

    def batch(self, shard: int, start: int, n: int) -> Dict[str, np.ndarray]:
        seqs = np.stack([self.sequence(shard, start + i) for i in range(n)])
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


class TokenPipeline:
    """Host-side prefetching batch iterator.

    ``global_batch`` sequences per step are drawn round-robin from the
    corpus shards owned by this host (all of them in single-host runs); a
    background :class:`~repro.data.prefetch.Prefetcher` keeps ``prefetch``
    batches ready so the accelerator never waits on generation (paper
    Fig. 3's sampler stage, LM flavor).  ``close()`` joins the producer
    thread; iterating after ``close()`` raises instead of hanging.
    """

    def __init__(
        self,
        corpus: SyntheticCorpus,
        global_batch: int,
        prefetch: int = 2,
        host_id: int = 0,
        num_hosts: int = 1,
        place_fn=None,  # optional: np batch -> device arrays (sharded put)
    ):
        self.corpus = corpus
        self.global_batch = global_batch
        self.host_batch = global_batch // num_hosts
        if global_batch % num_hosts:
            raise ValueError("global_batch must divide num_hosts")
        self.host_shards = [
            s for s in range(corpus.num_shards) if s % num_hosts == host_id
        ]
        self.place_fn = place_fn
        self._step = 0
        self._prefetcher = Prefetcher(self._make, depth=prefetch,
                                      name="token-pipeline")

    def _make(self, step: int) -> Dict[str, np.ndarray]:
        per_shard = -(-self.host_batch // len(self.host_shards))
        parts = []
        for j, s in enumerate(self.host_shards):
            n = min(per_shard, self.host_batch - j * per_shard)
            if n <= 0:
                break
            parts.append(self.corpus.batch(s, step * per_shard, n))
        return {
            k: np.concatenate([p[k] for p in parts]) for k in parts[0]
        }

    def __iter__(self) -> Iterator[Dict]:
        return self

    def __next__(self) -> Dict:
        batch = next(self._prefetcher)
        self._step += 1
        if self.place_fn is not None:
            return self.place_fn(batch)
        return batch

    def close(self):
        """Stop and join the producer thread (idempotent); ``__next__``
        afterwards raises :class:`RuntimeError` instead of hanging."""
        self._prefetcher.close()
