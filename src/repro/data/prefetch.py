"""Prefetcher — the shared double-buffered background producer.

One thread, one bounded queue, and a strict lifecycle contract; every host
pipeline in the repo (the LM :class:`~repro.data.pipeline.TokenPipeline`,
the HGNN :class:`~repro.data.sample_stream.SampleStream`) is built on it
rather than hand-rolling thread + queue management:

  * items are produced by calling ``make(i)`` for ``i = 0, 1, 2, ...`` in a
    daemon thread; up to ``depth`` finished items wait in the queue, so the
    consumer (the device-step loop) never blocks on host work that could
    have happened during the previous step;
  * an exception inside ``make`` is captured and re-raised *in the
    consumer* at the next ``__next__`` — background failures are never
    silent and never hang the training loop;
  * ``close()`` is idempotent, drains the queue, and **joins** the producer
    thread; ``__next__`` after ``close()`` raises :class:`RuntimeError`
    instead of blocking on an empty queue;
  * a finite ``num_items`` ends iteration with ``StopIteration`` once the
    producer is exhausted (infinite when ``None``).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

__all__ = ["Prefetcher"]

_POLL_S = 0.05  # producer/consumer poll interval while checking for shutdown


class _Done:
    """Queue sentinel: producer finished all ``num_items`` items."""


class _Failure:
    """Queue sentinel: producer raised; carries the exception to re-raise."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class Prefetcher:
    """Background producer of ``make(0), make(1), ...`` with bounded lookahead.

    Iterator protocol; also a context manager (``close()`` on exit).
    """

    def __init__(
        self,
        make: Callable[[int], object],
        depth: int = 2,
        num_items: Optional[int] = None,
        name: str = "prefetcher",
    ):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if num_items is not None and num_items < 0:
            raise ValueError(f"num_items must be >= 0, got {num_items}")
        self._make = make
        self.depth = depth
        self.num_items = num_items
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._closed = False
        self._thread = threading.Thread(target=self._producer, name=name,
                                        daemon=True)
        self._thread.start()

    # -- producer side -------------------------------------------------------

    def _producer(self):
        i = 0
        try:
            while not self._stop.is_set():
                if self.num_items is not None and i >= self.num_items:
                    self._put(_Done())
                    return
                item = self._make(i)
                i += 1
                if not self._put(item):
                    return  # closed while waiting for queue space
        except BaseException as exc:  # noqa: BLE001 — delivered to consumer
            try:
                if not self._stop.is_set():
                    self._put(_Failure(exc))
            except BaseException:
                # interpreter teardown: queue internals may already be gone;
                # a daemon thread must exit silently, not spray noise
                pass

    # queue.Full is bound as a default arg: at interpreter shutdown module
    # globals can be cleared under a daemon thread's feet, and a NameError
    # here would masquerade as a producer failure
    def _put(self, item, _Full=queue.Full) -> bool:
        """Blocking put that aborts (returns False) once close() is called."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=_POLL_S)
                return True
            except _Full:
                continue
        return False

    # -- consumer side -------------------------------------------------------

    def __iter__(self) -> "Prefetcher":
        return self

    def __next__(self):
        if self._closed:
            raise RuntimeError("Prefetcher is closed")
        while True:
            try:
                item = self._q.get(timeout=_POLL_S)
            except queue.Empty:
                if self._closed:
                    raise RuntimeError("Prefetcher is closed") from None
                if not self._thread.is_alive():
                    # producer died without posting a sentinel (should not
                    # happen, but never hang the training loop on it)
                    raise RuntimeError(
                        "Prefetcher producer exited unexpectedly"
                    ) from None
                continue
            if isinstance(item, _Done):
                self._q.put(item)  # keep the sentinel for repeated __next__
                raise StopIteration
            if isinstance(item, _Failure):
                self.close()
                raise item.exc
            return item

    # -- lifecycle -----------------------------------------------------------

    def close(self, timeout: float = 5.0, warn: bool = True,
              _Empty=queue.Empty) -> None:
        """Stop the producer, drain the queue, and join the thread.

        Idempotent — including after a producer failure already shut the
        stream down from ``__next__``, and when called again mid-teardown.
        After it returns ``__next__`` raises :class:`RuntimeError`.  A
        producer stuck inside ``make`` longer than ``timeout`` cannot be
        killed from here — that case is reported with a
        :class:`RuntimeWarning` (the daemon thread exits at its next
        queue/stop check and cannot re-enter ``make``).  ``warn=False``
        suppresses the warning — used by ``__del__``, where a stream GC'd
        mid-run at interpreter shutdown must not spray warnings from a
        half-torn-down runtime.
        """
        if getattr(self, "_closed", True):  # True: constructor failed early
            return
        self._closed = True
        stop = getattr(self, "_stop", None)
        thread = getattr(self, "_thread", None)
        if stop is None or thread is None:  # constructor failed part-way
            return
        stop.set()
        # the producer may be blocked on a full queue; drain so its
        # stop-aware put() observes the event and the thread exits
        try:
            while True:
                self._q.get_nowait()
        except _Empty:
            pass
        except BaseException:
            pass  # queue internals gone at interpreter shutdown
        try:
            thread.join(timeout=timeout)
        except RuntimeError:
            # joining from the thread itself / runtime tearing down
            return
        if warn and thread.is_alive():
            import warnings

            warnings.warn(
                f"{thread.name}: producer still inside make() after "
                f"{timeout}s close timeout; it will exit at its next stop "
                "check", RuntimeWarning, stacklevel=2,
            )

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        # best-effort: don't leak threads on GC, and stay silent when the
        # GC runs at interpreter shutdown (no warnings, no queue errors)
        try:
            self.close(timeout=0.1, warn=False)
        except BaseException:
            pass
