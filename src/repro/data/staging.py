"""Host-side batch staging, factored out of the SPMD executor.

``repro.core.raf_spmd.stack_batch`` assembles the stacked device arrays for
one sampled batch — masks, padded parent-feature gathers (``qfeat``), and
leaf-feature gathers (``hfeat``) laid out branch-major per shard.  All of
that work is pure numpy; only the final device placement needs jax.  This
module holds the numpy core so that:

  * the SPMD executor's ``stage`` and the multi-worker sampling pool
    (``repro.data.worker_pool``, DESIGN.md §9) run the **same** code — a
    worker-staged batch is bit-identical to a consumer-staged one by
    construction, not by parallel maintenance of two gather loops;
  * sampler worker processes stay jax-free: a :class:`StackRecipe` is a
    small picklable extract of the :class:`~repro.core.raf_spmd.StackedPlan`
    (slot→branch maps and type names — no jitted functions, no jnp arrays),
    so shipping it to a spawned worker costs a few kilobytes and no jax
    import.

The recipe is built by :meth:`StackRecipe.from_plan` via duck typing on the
plan's public attributes, keeping this module import-light in both
directions (no ``repro.core`` import here, no ``repro.data`` import needed
to *define* the plan).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

__all__ = ["StackRecipe", "stack_batch_host"]


@dataclasses.dataclass(frozen=True)
class StackRecipe:
    """Picklable description of the host staging of a stacked batch.

    Per level ``d`` (1-based, index ``d-1`` in the tuples below):
    ``slot_branch[d-1]`` maps ``[num_shards, rb]`` stack slots to original
    branch indices (-1 = padding slot); ``src_types``/``dst_types`` give the
    feature table feeding each branch; ``parents`` gives each branch's parent
    branch at level ``d-1``.  ``d_pad`` is the common padded feature width.
    """

    num_shards: int
    d_pad: int
    num_layers: int
    slot_branch: Tuple[np.ndarray, ...]
    src_types: Tuple[Tuple[str, ...], ...]
    dst_types: Tuple[Tuple[str, ...], ...]
    parents: Tuple[Tuple[int, ...], ...]

    @classmethod
    def from_plan(cls, plan) -> "StackRecipe":
        """Extract the staging recipe from a ``StackedPlan`` (duck-typed)."""
        spec = plan.spec
        return cls(
            num_shards=int(plan.num_shards),
            d_pad=int(plan.d_pad),
            num_layers=int(spec.num_layers),
            slot_branch=tuple(np.asarray(lp.slot_branch) for lp in plan.levels),
            src_types=tuple(tuple(row) for row in plan.src_types),
            dst_types=tuple(tuple(row) for row in plan.dst_types),
            parents=tuple(
                tuple(int(b.parent) for b in lv) for lv in spec.levels
            ),
        )

    def table_types(self) -> Tuple[str, ...]:
        """Node types whose feature tables staging reads."""
        out = set()
        for row in self.src_types:
            out.update(row)
        for row in self.dst_types:
            out.update(row)
        return tuple(sorted(out))


def _padded_gather(tab: np.ndarray, nids: np.ndarray, d_pad: int) -> np.ndarray:
    out = np.zeros((len(nids), d_pad), np.float32)
    out[:, : tab.shape[1]] = tab[nids]
    return out


def stack_batch_host(
    recipe: StackRecipe,
    batch,
    tables: Dict[str, np.ndarray],
) -> Dict[str, np.ndarray]:
    """The numpy core of ``raf_spmd.stack_batch``: assemble the stacked host
    arrays for one :class:`~repro.graph.sampler.SampledBatch`.

    ``tables`` must hold a feature table for every node type the recipe's
    branches touch (frozen learnable tables included).  Returns the
    ``seeds``/``labels``/``mask{d}``/``qfeat{d}``/``hfeat{k}`` dict the SPMD
    executor device-puts; values are plain numpy so a worker process can
    compute them and ship them over a queue.
    """
    k, dp, P = recipe.num_layers, recipe.d_pad, recipe.num_shards
    B = batch.batch_size
    out: Dict[str, np.ndarray] = {
        "seeds": np.asarray(batch.seeds),
        "labels": np.asarray(batch.labels),
    }
    n_prev = B
    for d in range(1, k + 1):
        sb = recipe.slot_branch[d - 1]
        rb = sb.shape[1]
        lv = batch.levels[d - 1]
        n_d = lv.nids.shape[1]
        mask = np.zeros((P, rb, n_d), bool)
        qfeat = np.zeros((P, rb, n_prev, dp), np.float32)
        hfeat = np.zeros((P, rb, n_d, dp), np.float32) if d == k else None
        for p in range(P):
            for s in range(rb):
                b = int(sb[p, s])
                if b < 0:
                    continue
                mask[p, s] = lv.mask[b]
                parent_nids = (
                    batch.seeds if d == 1
                    else batch.levels[d - 2].nids[recipe.parents[d - 1][b]]
                )
                qfeat[p, s] = _padded_gather(
                    tables[recipe.dst_types[d - 1][b]], parent_nids, dp)
                if d == k:
                    hfeat[p, s] = _padded_gather(
                        tables[recipe.src_types[d - 1][b]], lv.nids[b], dp)
        out[f"mask{d}"] = mask.reshape(P * rb, n_d)
        out[f"qfeat{d}"] = qfeat.reshape(P * rb, n_prev, dp)
        if d == k:
            out[f"hfeat{d}"] = hfeat.reshape(P * rb, n_d, dp)
        n_prev = n_d
    return out
