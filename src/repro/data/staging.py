"""Host-side batch staging, factored out of the SPMD executor.

``repro.core.raf_spmd.stack_batch`` assembles the stacked device arrays for
one sampled batch — masks, padded parent-feature gathers (``qfeat``), and
leaf-feature gathers (``hfeat``) laid out branch-major per shard.  All of
that work is pure numpy; only the final device placement needs jax.  This
module holds the numpy core so that:

  * the SPMD executor's ``stage`` and the multi-worker sampling pool
    (``repro.data.worker_pool``, DESIGN.md §9) run the **same** code — a
    worker-staged batch is bit-identical to a consumer-staged one by
    construction, not by parallel maintenance of two gather loops;
  * sampler worker processes stay jax-free: a :class:`StackRecipe` is a
    small picklable extract of the :class:`~repro.core.raf_spmd.StackedPlan`
    (slot→branch maps and type names — no jitted functions, no jnp arrays),
    so shipping it to a spawned worker costs a few kilobytes and no jax
    import.

The recipe is built by :meth:`StackRecipe.from_plan` via duck typing on the
plan's public attributes, keeping this module import-light in both
directions (no ``repro.core`` import here, no ``repro.data`` import needed
to *define* the plan).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

__all__ = [
    "StackRecipe",
    "stack_batch_host",
    "BATCH_PREFIX",
    "HOST_PREFIX",
    "pack_batch_arrays",
    "pack_batch_into",
    "arena_fields",
    "unpack_slot",
]

# key prefixes inside a batch-arena slot (DESIGN.md §11): raw sampled batch
# arrays vs pre-staged host arrays (the stack_batch_host outputs)
BATCH_PREFIX = "b/"
HOST_PREFIX = "h/"


@dataclasses.dataclass(frozen=True)
class StackRecipe:
    """Picklable description of the host staging of a stacked batch.

    Per level ``d`` (1-based, index ``d-1`` in the tuples below):
    ``slot_branch[d-1]`` maps ``[num_shards, rb]`` stack slots to original
    branch indices (-1 = padding slot); ``src_types``/``dst_types`` give the
    feature table feeding each branch; ``parents`` gives each branch's parent
    branch at level ``d-1``.  ``d_pad`` is the common padded feature width.
    """

    num_shards: int
    d_pad: int
    num_layers: int
    slot_branch: Tuple[np.ndarray, ...]
    src_types: Tuple[Tuple[str, ...], ...]
    dst_types: Tuple[Tuple[str, ...], ...]
    parents: Tuple[Tuple[int, ...], ...]

    @classmethod
    def from_plan(cls, plan) -> "StackRecipe":
        """Extract the staging recipe from a ``StackedPlan`` (duck-typed)."""
        spec = plan.spec
        return cls(
            num_shards=int(plan.num_shards),
            d_pad=int(plan.d_pad),
            num_layers=int(spec.num_layers),
            slot_branch=tuple(np.asarray(lp.slot_branch) for lp in plan.levels),
            src_types=tuple(tuple(row) for row in plan.src_types),
            dst_types=tuple(tuple(row) for row in plan.dst_types),
            parents=tuple(
                tuple(int(b.parent) for b in lv) for lv in spec.levels
            ),
        )

    def table_types(self) -> Tuple[str, ...]:
        """Node types whose feature tables staging reads."""
        out = set()
        for row in self.src_types:
            out.update(row)
        for row in self.dst_types:
            out.update(row)
        return tuple(sorted(out))


def _padded_gather(tab: np.ndarray, nids: np.ndarray, d_pad: int) -> np.ndarray:
    out = np.zeros((len(nids), d_pad), np.float32)
    out[:, : tab.shape[1]] = tab[nids]
    return out


def _gather_into(dst: np.ndarray, tab: np.ndarray, nids: np.ndarray) -> None:
    # in-place _padded_gather: dst is pre-zeroed, so only the real width
    # needs filling
    dst[:, : tab.shape[1]] = tab[nids]


def stack_batch_host(
    recipe: StackRecipe,
    batch,
    tables: Dict[str, np.ndarray],
    out: "Dict[str, np.ndarray] | None" = None,
    prefix: str = "",
) -> Dict[str, np.ndarray]:
    """The numpy core of ``raf_spmd.stack_batch``: assemble the stacked host
    arrays for one :class:`~repro.graph.sampler.SampledBatch`.

    ``tables`` must hold a feature table for every node type the recipe's
    branches touch (frozen learnable tables included).  Returns the
    ``seeds``/``labels``/``mask{d}``/``qfeat{d}``/``hfeat{k}`` dict the SPMD
    executor device-puts; values are plain numpy so a worker process can
    compute them and ship them over a queue.

    With ``out`` (the write-into-slot variant, DESIGN.md §11), every array is
    assembled **in place** inside ``out[prefix + name]`` — the batch-arena
    slot views — instead of freshly allocated; the returned dict then holds
    those views.  Both paths run the same fill loop over pre-zeroed
    destinations, so a worker-staged slot is bit-identical to a
    consumer-staged allocation.
    """
    k, dp, P = recipe.num_layers, recipe.d_pad, recipe.num_shards
    B = batch.batch_size

    res: Dict[str, np.ndarray] = {}

    def _dest(name: str, shape: Tuple[int, ...], dtype) -> np.ndarray:
        if out is None:
            arr = np.zeros(shape, dtype)
        else:
            arr = out[prefix + name].reshape(shape)
            arr[...] = 0
        return arr

    for name, src in (("seeds", np.asarray(batch.seeds)),
                      ("labels", np.asarray(batch.labels))):
        if out is None:
            res[name] = src
        else:
            np.copyto(out[prefix + name], src, casting="no")
            res[name] = out[prefix + name]

    n_prev = B
    for d in range(1, k + 1):
        sb = recipe.slot_branch[d - 1]
        rb = sb.shape[1]
        lv = batch.levels[d - 1]
        n_d = lv.nids.shape[1]
        mask = _dest(f"mask{d}", (P, rb, n_d), bool)
        qfeat = _dest(f"qfeat{d}", (P, rb, n_prev, dp), np.float32)
        hfeat = _dest(f"hfeat{d}", (P, rb, n_d, dp), np.float32) if d == k else None
        for p in range(P):
            for s in range(rb):
                b = int(sb[p, s])
                if b < 0:
                    continue
                mask[p, s] = lv.mask[b]
                parent_nids = (
                    batch.seeds if d == 1
                    else batch.levels[d - 2].nids[recipe.parents[d - 1][b]]
                )
                _gather_into(qfeat[p, s],
                             tables[recipe.dst_types[d - 1][b]], parent_nids)
                if d == k:
                    _gather_into(hfeat[p, s],
                                 tables[recipe.src_types[d - 1][b]], lv.nids[b])
        res[f"mask{d}"] = mask.reshape(P * rb, n_d)
        res[f"qfeat{d}"] = qfeat.reshape(P * rb, n_prev, dp)
        if d == k:
            res[f"hfeat{d}"] = hfeat.reshape(P * rb, n_d, dp)
        n_prev = n_d
    return res


# --------------------------------------------------------------------------
# batch-arena slot packing (DESIGN.md §11)
# --------------------------------------------------------------------------
#
# A slot holds the raw sampled batch under ``b/`` keys and, when the pool
# stages, the stack_batch_host outputs under ``h/`` keys.  Slot layouts are
# static — the sampler pads every level to fixed [R_d, N_d] and the recipe
# pads features to d_pad — so one probe batch sizes the whole arena.


def pack_batch_arrays(batch) -> Dict[str, np.ndarray]:
    """A sampled batch as a flat ``b/``-prefixed array dict (no copies)."""
    arrays = {
        BATCH_PREFIX + "seeds": np.asarray(batch.seeds),
        BATCH_PREFIX + "labels": np.asarray(batch.labels),
    }
    for d, lv in enumerate(batch.levels, start=1):
        arrays[f"{BATCH_PREFIX}nids{d}"] = np.asarray(lv.nids)
        arrays[f"{BATCH_PREFIX}mask{d}"] = np.asarray(lv.mask)
    return arrays


def pack_batch_into(views: Dict[str, np.ndarray], batch) -> None:
    """Write a sampled batch into a slot's ``b/`` views (worker side)."""
    for key, src in pack_batch_arrays(batch).items():
        np.copyto(views[key], src, casting="no")


def arena_fields(batch, recipe=None, tables=None) -> Dict[str, np.ndarray]:
    """Probe arrays sizing one arena slot: the batch layout plus, when the
    pool stages, the stacked host arrays (``shm.create_arena`` reads only
    shapes/dtypes)."""
    fields = pack_batch_arrays(batch)
    if recipe is not None:
        host = stack_batch_host(recipe, batch, tables)
        fields.update({HOST_PREFIX + k: v for k, v in host.items()})
    return fields


def unpack_slot(views: Dict[str, np.ndarray], spec):
    """Consumer side: rebuild ``(batch, host)`` from a slot's views.

    The returned batch's arrays alias the slot — the caller must not release
    the slot until every view (and anything zero-copy derived from it) is
    dead; ``SampleStream`` defers the release past the consuming step."""
    from repro.graph.sampler import Level, SampledBatch

    levels = [
        Level(nids=views[f"{BATCH_PREFIX}nids{d}"],
              mask=views[f"{BATCH_PREFIX}mask{d}"])
        for d in range(1, spec.num_layers + 1)
    ]
    batch = SampledBatch(
        spec=spec,
        seeds=views[BATCH_PREFIX + "seeds"],
        labels=views[BATCH_PREFIX + "labels"],
        levels=levels,
    )
    host = {k[len(HOST_PREFIX):]: v for k, v in views.items()
            if k.startswith(HOST_PREFIX)}
    return batch, (host or None)
