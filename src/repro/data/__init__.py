"""``repro.data`` — the unified async host-pipeline subsystem.

The breakdown benchmark (paper Fig. 10) shows host-side work — neighbor
sampling plus feature staging (table snapshot → ``stack_batch`` →
``shard_arrays``) — dominating step time once RAF has removed network
traffic.  This package overlaps that host work with the device step, the
DistDGLv2/HopGNN recipe, behind three pieces:

:class:`~repro.data.prefetch.Prefetcher`
    The shared double-buffered background producer (bounded queue, one
    daemon thread, exception propagation into the consumer, ``close()``
    joins).  Both :class:`TokenPipeline` (LM path) and
    :class:`~repro.data.sample_stream.SampleStream` (HGNN path) sit on it.

:class:`~repro.data.sample_stream.SampleStream`
    The host-pipeline facade: runs sample → snapshot → stack → shard in the
    background and yields ``(batch, arrays, host_seconds)`` ready for the
    device step.  ``num_workers=0`` selects the thread ``Prefetcher``
    (bit-for-bit today's behavior); ``num_workers>0`` selects the process
    pool below.

:class:`~repro.data.worker_pool.WorkerPool`
    N sampler *processes* over a shared-memory graph store
    (``repro.graph.shm``), lifting the one-CPU-core ceiling of the thread
    producer (paper Fig. 10 — host sampling dominates once RAF removes
    network traffic).  Worker ``w`` samples the interleaved stripe
    ``w, w+N, ...``; per-worker bounded queues round-robined by the
    consumer reconstruct strict step order; ``batch_at`` purity makes any
    worker count bit-identical.  Staging placement follows the snapshot
    policy: frozen-table and learnable-"stale" batches are staged *inside*
    workers via the shared numpy core
    (``repro.data.staging.stack_batch_host``), while learnable-"fresh"
    staging stays on the consumer.  Architecture: DESIGN.md §9.

The **batch arena** (DESIGN.md §11) closes the pool's last copy: instead of
pickling batches through the worker→consumer queues, workers write sampled
(and pre-staged) arrays directly into fixed seqlock-stamped slots of one
shared-memory ring buffer (``repro.graph.shm.create_arena``), and the queue
carries only a few-hundred-byte ``SlotRef`` descriptor — zero pickled
ndarrays on the hot path.  Slot layout, version-stamp discipline, the
bounded-staleness contract for learnable tables, and failure/unlink rules
are specified in DESIGN.md §11; ``repro.data.staging`` holds the slot
pack/unpack helpers and the write-into-slot staging variant.

**The staged-step protocol.**  Executors (``repro.api.executors``) split
one training step into two public methods::

    stage(sess, plan, batch)                 -> arrays   # host staging
    step_staged(sess, plan, state, batch, arrays)        # device step
    step(sess, plan, state, batch)  ==  step_staged(..., stage(...))

``stage`` is pure host work (safe to run in the producer thread for a
*future* batch while the device trains the current one); ``step_staged``
owns the timed compute + sparse-update region.  ``step`` remains the serial
composition for callers that don't pipeline.

**Determinism.**  ``NeighborSampler`` derives each batch's RNG from
``(seed, epoch_seed, step)`` (the ``SyntheticCorpus`` trick), so
``batch_at`` is a pure function of position and pipeline-on/off produce
bit-identical batches regardless of prefetch depth or thread scheduling.

**Snapshot staleness policy** (``PipelineConfig.snapshot``).  With frozen
feature tables staging is time-invariant, so the pipeline is bit-exact.
When learnable tables train (``ModelConfig.train_learnable`` with an
executor whose staging reads them, e.g. ``raf_spmd``), staging batch *i+k*
in the background observes tables before steps *i..i+k-1* wrote back:

* ``"stale"`` (default) — stage in the producer against a snapshot that may
  lag by at most ``depth + 1`` steps (the queue bound).  Maximum overlap;
  losses track the serial path within optimization noise, the standard
  bounded-staleness trade every async-pipeline system makes.
* ``"fresh"`` — producer only samples; table-reading staging runs on the
  consumer right before the step.  Bit-exact parity with the serial loop,
  overlapping only the sampling stage.
"""

from repro.data.pipeline import SyntheticCorpus, TokenPipeline
from repro.data.prefetch import Prefetcher
from repro.data.sample_stream import SampleStream
from repro.data.staging import (
    StackRecipe,
    arena_fields,
    pack_batch_into,
    stack_batch_host,
    unpack_slot,
)
from repro.data.faults import FaultPlan, FaultSpec, InjectedFault
from repro.data.worker_pool import (
    EpochSchedule,
    HotnessCountTask,
    SampleStageTask,
    SlotRef,
    WorkerDiedError,
    WorkerPool,
)

__all__ = [
    "SyntheticCorpus",
    "TokenPipeline",
    "Prefetcher",
    "SampleStream",
    "StackRecipe",
    "stack_batch_host",
    "arena_fields",
    "pack_batch_into",
    "unpack_slot",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "EpochSchedule",
    "HotnessCountTask",
    "SampleStageTask",
    "SlotRef",
    "WorkerDiedError",
    "WorkerPool",
]
