from repro.data.pipeline import SyntheticCorpus, TokenPipeline

__all__ = ["SyntheticCorpus", "TokenPipeline"]
