"""Public op: row gather with backend dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gather_rows.kernel import gather_rows_pallas
from repro.kernels.gather_rows.ref import gather_rows_ref

__all__ = ["gather_rows"]


def gather_rows(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if not use_pallas:
        return gather_rows_ref(table, idx)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gather_rows_pallas(table, idx, interpret=interpret)
