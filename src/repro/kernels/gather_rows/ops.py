"""Public op: row gather with backend dispatch.

``gather_rows`` is the scalar-prefetch cache-fetch kernel (paper §6); the
production consumer is ``repro.embed.cache.FeatureCache.fetch`` (device
cache hits), gated by the ``kernels.gather`` config knob via
:func:`gather_rows_cfg`.  The op carries a ``custom_vjp`` (backward is the
transpose scatter-add) so it is also safe on differentiated gather paths.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.gather_rows.kernel import gather_rows_pallas
from repro.kernels.gather_rows.ref import gather_rows_ref
from repro.kernels.ops import kernel_choice, zero_cotangent

__all__ = ["gather_rows", "gather_rows_cfg"]


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gather_pallas_vjp(interpret: bool, table, idx):
    return gather_rows_pallas(table, idx, interpret=interpret)


def _vjp_fwd(interpret, table, idx):
    return _gather_pallas_vjp(interpret, table, idx), (table.shape, table.dtype, idx)


def _vjp_bwd(interpret, res, g):
    shape, dtype, idx = res
    dt = jnp.zeros(shape, dtype).at[idx].add(g.astype(dtype))
    return dt, zero_cotangent(idx)


_gather_pallas_vjp.defvjp(_vjp_fwd, _vjp_bwd)


def gather_rows(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if not use_pallas:
        return gather_rows_ref(table, idx)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _gather_pallas_vjp(bool(interpret), table, idx)


def gather_rows_cfg(table: jnp.ndarray, idx: jnp.ndarray, opts=None) -> jnp.ndarray:
    """Config-gated gather: Pallas when the ``kernels.gather`` knob resolves
    to it for this backend (see ``repro.kernels.ops.kernel_choice``), else
    the jnp take."""
    use, interp = kernel_choice(opts, "gather")
    if not use or idx.shape[0] == 0:  # empty gather: nothing for the grid
        return gather_rows_ref(table, idx)
    return _gather_pallas_vjp(interp, table, idx)
