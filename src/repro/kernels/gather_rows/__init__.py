from repro.kernels.gather_rows.ops import gather_rows, gather_rows_cfg
from repro.kernels.gather_rows.ref import gather_rows_ref

__all__ = ["gather_rows", "gather_rows_cfg", "gather_rows_ref"]
