"""Oracle for the row-gather kernel: out[i] = table[idx[i]]."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gather_rows_ref"]


def gather_rows_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return table[idx]
