"""Pallas TPU kernel: scalar-prefetch row gather (feature/cache fetch).

Heta's cache fetch path is a batched gather of feature rows by node id
(paper §6).  On TPU the idiomatic shape is a *scalar-prefetched* grid: the
index vector is available to the BlockSpec ``index_map`` before the kernel
body runs, so each grid step's DMA engine pulls exactly the [rows_per_step,
d] slice of the HBM-resident table that the step needs — the gather happens
in the DMA schedule, not in compute.

Grid: (n_steps,) — step i copies ``table[idx[i]]`` into ``out[i]``.  With
rows ≥ lane width this saturates HBM bandwidth; the miss-penalty *fixed
overhead* the paper measures (Fig. 7a) corresponds to the per-DMA setup
cost, which is why small-dim node types have larger o_a.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_rows_pallas"]


def _kernel(idx_ref, tab_ref, out_ref):
    out_ref[...] = tab_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows_pallas(
    table: jnp.ndarray,  # [num_rows, d]
    idx: jnp.ndarray,  # [n] int32
    interpret: bool = True,
) -> jnp.ndarray:
    n = idx.shape[0]
    d = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)
