"""Public op: stacked relation aggregation — dispatch, padding, custom VJP.

:func:`stacked_agg` is the single entry point the SPMD executor's
``_agg_level`` calls per level (DESIGN.md §8).  Dispatch, driven by the
module's ``fused`` declaration and the resolved backend
(``repro.kernels.ops.kernel_choice``):

  * ``fused == "mean_linear"``     -> :func:`stacked_mean_linear` — the
    fully-fused Pallas kernel (scalar-prefetch slot→stack indirection).
  * ``fused == "softmax_combine"`` -> when the module declares an
    :meth:`~repro.core.relmod.RelationModule.attn_epilogue` (and
    ``fuse_epilogue`` is on), :func:`stacked_attn_epilogue` — the *fully
    fused* kernel whose per-slot logit/value projections stream from the
    ``[U, d_in, H]`` stacks via scalar prefetch (no materialized per-slot
    weight gather; custom VJP emits stack-form projection grads).
    Otherwise the oracle factoring: projections via the module's
    ``attn_parts`` (vmapped, XLA autodiff over gathered weights) + the
    Pallas masked softmax+combine epilogue.
  * anything else, or a non-TPU backend without forced interpret ->
    :func:`~repro.kernels.stacked_relation_agg.ref.stacked_agg_ref`, the
    gather-then-vmap oracle.

Both Pallas ops carry a ``jax.custom_vjp``:

  * ``stacked_mean_linear``'s backward produces the weight gradient
    **directly in stack form** ``[U, d_in, d_out]`` (per-slot contributions
    segment-summed over ``slot_u`` — autodiff of the gathered path would
    yield per-slot ``[rb, ...]`` grads scattered back afterwards), and the
    neighbor-activation gradient through the scalar-prefetch ``dh`` kernel,
    so the backward reads weights from the stack exactly like the forward.
    Cross-*shard* sharing stays ``sync_stack_grads``' job: this op sums
    within a shard's slots, the executor's existing sync sums across
    shards' stack rows — composition, no overlap.
  * ``stacked_softmax_combine``'s backward is the closed-form softmax
    Jacobian (recomputed probabilities, no saved alpha), matching autodiff
    of ``relmod.masked_softmax`` including the all-masked-row case.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.ops import (
    agg_blocks,
    agg_vmem_bytes,
    clamp_block,
    kernel_choice,
    pad_axes,
    pad_to,
    resolve_blocks,
    zero_cotangent,
)
from repro.kernels.stacked_relation_agg.kernel import (
    stacked_attn_dh_pallas,
    stacked_attn_epilogue_pallas,
    stacked_mean_linear_dh_pallas,
    stacked_mean_linear_pallas,
    stacked_softmax_combine_pallas,
)
from repro.kernels.stacked_relation_agg.ref import stacked_agg_grouped, stacked_agg_ref

__all__ = [
    "stacked_agg",
    "stacked_mean_linear",
    "stacked_softmax_combine",
    "stacked_attn_epilogue",
    "stacked_agg_ref",
    "stacked_agg_grouped",
    "stacked_mean_linear_blocks",
    "stacked_mean_linear_vmem_bytes",
    "stacked_softmax_combine_vmem_bytes",
    "stacked_attn_epilogue_vmem_bytes",
]


# --------------------------------------------------------------------------
# block derivation + VMEM accounting (single source for op and benchmarks)
# --------------------------------------------------------------------------


# the stacked forward's per-step working set matches the unstacked kernel's
# (the slot axis contributes a block edge of 1) — one shared formula in the
# ops layer, so BENCH figures can never drift from the dispatch
stacked_mean_linear_blocks = agg_blocks
stacked_mean_linear_vmem_bytes = agg_vmem_bytes


def stacked_softmax_combine_vmem_bytes(
    n: int, f: int, num_heads: int, head_dim: int,
    block_n: int = 128, bytes_per_elem: int = 4,
) -> int:
    bn = clamp_block(block_n, n)
    H = num_heads * head_dim
    elems = bn * f * num_heads + bn * f + bn * f * H + bn * H
    return elems * bytes_per_elem


def stacked_attn_epilogue_vmem_bytes(
    n: int, f: int, d_in: int, num_heads: int, head_dim: int,
    block_n: int = 128, block_in: int = 512,
    shared_v: bool = True, bytes_per_elem: int = 4,
) -> int:
    """Per-grid-step working set of the fused attention AGG_r: h block +
    mask + qv + streamed weight tile(s) + out tile (input dtype) plus the
    float32 projection accumulator(s)."""
    bn = clamp_block(block_n, n)
    bc = clamp_block(block_in, d_in)
    H = num_heads * head_dim
    n_acc = 1 if shared_v else 2
    elems = bn * f * bc + bn * f + bn * H + n_acc * bc * H + bn * H
    return elems * bytes_per_elem + n_acc * bn * f * H * 4


# --------------------------------------------------------------------------
# mean_linear: fused Pallas forward + stack-form custom VJP
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _MLCfg:
    bn: int
    bo: int
    bc: int
    interpret: bool


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _stacked_ml(cfg: _MLCfg, h, mask, w, b, slot_u):
    return _ml_fwd_impl(cfg, h, mask, w, b, slot_u)


def _ml_fwd_impl(cfg, h, mask, w, b, slot_u):
    rb, n, f, d_in = h.shape
    d_out = w.shape[2]
    hp = pad_axes(h, {1: cfg.bn, 3: cfg.bc})
    mp = pad_to(mask, 1, cfg.bn)
    wp = pad_axes(w, {1: cfg.bc, 2: cfg.bo})
    bp = pad_to(b, 1, cfg.bo)
    out = stacked_mean_linear_pallas(
        hp, mp, wp, bp, slot_u,
        block_n=cfg.bn, block_out=cfg.bo, block_in=cfg.bc, interpret=cfg.interpret,
    )
    return out[:, :n, :d_out]


def _ml_vjp_fwd(cfg, h, mask, w, b, slot_u):
    return _ml_fwd_impl(cfg, h, mask, w, b, slot_u), (h, mask, w, slot_u)


def _ml_vjp_bwd(cfg, res, g):
    h, mask, w, slot_u = res
    rb, n, f, d_in = h.shape
    U, _, d_out = w.shape
    # dh through the scalar-prefetch kernel — weight blocks read from the
    # stack, same indirection as the forward
    gp = pad_axes(g, {1: cfg.bn, 2: cfg.bo})
    mp = pad_to(mask, 1, cfg.bn)
    wp = pad_axes(w, {1: cfg.bc, 2: cfg.bo})
    dh = stacked_mean_linear_dh_pallas(
        gp, mp, wp, slot_u,
        block_n=cfg.bn, block_out=cfg.bo, block_in=cfg.bc, interpret=cfg.interpret,
    )[:, :n, :, :d_in]
    # dw/db accumulate straight into the [U, ...] stack: per-slot outer
    # products segment-summed over slot_u (slots sharing a stack row sum,
    # exactly like autodiff of the dict-form forward sums occurrences)
    mw = mask.astype(h.dtype)
    cnt = jnp.maximum(mw.sum(-1, keepdims=True), 1.0)
    mean = jnp.einsum("rnfd,rnf->rnd", h, mw) / cnt
    pw = jnp.einsum("rnd,rno->rdo", mean, g)
    dw = jax.ops.segment_sum(pw, slot_u, num_segments=U)
    db = jax.ops.segment_sum(jnp.sum(g, axis=1), slot_u, num_segments=U)
    return dh, zero_cotangent(mask), dw, db, zero_cotangent(slot_u)


_stacked_ml.defvjp(_ml_vjp_fwd, _ml_vjp_bwd)


def stacked_mean_linear(
    h: jnp.ndarray,  # [rb, n, f, d_in]
    mask: jnp.ndarray,  # [rb, n, f]
    w: jnp.ndarray,  # [U, d_in, d_out]
    b: jnp.ndarray,  # [U, d_out]
    slot_u: jnp.ndarray,  # [rb] int
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    rb, n, f, d_in = h.shape
    bn, bo, bc = stacked_mean_linear_blocks(
        n, f, d_in, w.shape[2], block_n, block_out, block_in
    )
    cfg = _MLCfg(bn, bo, bc, bool(interpret))
    return _stacked_ml(cfg, h, mask, w, b, slot_u.astype(jnp.int32))


# --------------------------------------------------------------------------
# softmax_combine: Pallas epilogue + closed-form custom VJP
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _SCCfg:
    bn: int
    num_heads: int
    head_dim: int
    interpret: bool


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _stacked_sc(cfg: _SCCfg, e, mask, v):
    return _sc_fwd_impl(cfg, e, mask, v)


def _sc_fwd_impl(cfg, e, mask, v):
    rb, n, f, nh = e.shape
    vf = v.reshape(rb, n, f, nh * cfg.head_dim)
    ep = pad_to(e, 1, cfg.bn)
    mp = pad_to(mask, 1, cfg.bn)
    vp = pad_to(vf, 1, cfg.bn)
    out = stacked_softmax_combine_pallas(
        ep, mp, vp, num_heads=nh, head_dim=cfg.head_dim,
        block_n=cfg.bn, interpret=cfg.interpret,
    )
    return out[:, :n]


def _sc_alpha(e, mask):
    neg = jnp.asarray(jnp.finfo(e.dtype).min, e.dtype)
    em = jnp.where(mask[:, :, :, None], e, neg)
    em = em - jnp.max(em, axis=2, keepdims=True)
    z = jnp.exp(em) * mask[:, :, :, None].astype(e.dtype)
    return z / jnp.maximum(jnp.sum(z, axis=2, keepdims=True), 1e-9)


def _sc_vjp_fwd(cfg, e, mask, v):
    return _sc_fwd_impl(cfg, e, mask, v), (e, mask, v)


def _sc_vjp_bwd(cfg, res, g):
    e, mask, v = res
    rb, n, f, nh = e.shape
    alpha = _sc_alpha(e, mask)  # [rb, n, f, nh]
    gh = g.reshape(rb, n, nh, cfg.head_dim)
    dalpha = jnp.einsum("rnfhd,rnhd->rnfh", v, gh)
    tot = jnp.sum(alpha * dalpha, axis=2, keepdims=True)
    de = alpha * (dalpha - tot)
    dv = jnp.einsum("rnfh,rnhd->rnfhd", alpha, gh)
    return de, zero_cotangent(mask), dv


_stacked_sc.defvjp(_sc_vjp_fwd, _sc_vjp_bwd)


def stacked_softmax_combine(
    e: jnp.ndarray,  # [rb, n, f, nh]
    mask: jnp.ndarray,  # [rb, n, f]
    v: jnp.ndarray,  # [rb, n, f, nh, dh]
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    rb, n, f, nh = e.shape
    dh = v.shape[-1]
    cfg = _SCCfg(clamp_block(block_n, n), nh, dh, bool(interpret))
    return _stacked_sc(cfg, e, mask, v)


# --------------------------------------------------------------------------
# fully fused attention epilogue: stack-streamed projections, custom VJP
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _AECfg:
    bn: int
    bc: int
    nh: int
    dh: int
    scale: float
    slope: object  # Optional[float]
    has_eb: bool
    has_post: bool
    shared_v: bool
    interpret: bool


def _ae_fwd_impl(cfg, h, mask, qv, eb, we, wv, pe, pv, us, with_residuals):
    rb, n, f, d_in = h.shape
    hp = pad_axes(h, {1: cfg.bn, 3: cfg.bc})
    mp = pad_to(mask, 1, cfg.bn)
    qp = pad_to(qv, 1, cfg.bn)
    ebp = pad_to(eb, 1, cfg.bn) if cfg.has_eb else None
    wep = pad_to(we, 1, cfg.bc)
    wvp = None if cfg.shared_v else pad_to(wv, 1, cfg.bc)
    pe_, pv_ = (pe, pv) if cfg.has_post else (None, None)
    res = stacked_attn_epilogue_pallas(
        hp, mp, qp, ebp, wep, wvp, pe_, pv_, us,
        num_heads=cfg.nh, head_dim=cfg.dh, scale=cfg.scale, slope=cfg.slope,
        with_residuals=with_residuals, block_n=cfg.bn, block_in=cfg.bc,
        interpret=cfg.interpret,
    )
    if not with_residuals:
        return res[:, :n]
    out = res[0][:, :n]
    z0 = res[1][:, :n]
    v0 = z0 if cfg.shared_v else res[2][:, :n]
    return out, z0, v0


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _stacked_ae(cfg: _AECfg, h, mask, qv, eb, we, wv, pe, pv, us):
    return _ae_fwd_impl(cfg, h, mask, qv, eb, we, wv, pe, pv, us, False)


def _ae_vjp_fwd(cfg, h, mask, qv, eb, we, wv, pe, pv, us):
    # the pre-transform projections z0/v0 come back as kernel residuals —
    # the backward never re-runs the big matmuls nor gathers a weight copy
    out, z0, v0 = _ae_fwd_impl(cfg, h, mask, qv, eb, we, wv, pe, pv, us, True)
    return out, (h, mask, qv, eb, we, wv, pe, pv, us, z0, v0)


def _ae_vjp_bwd(cfg, res, g):
    h, mask, qv, eb, we, wv, pe, pv, us, z0, v0 = res
    rb, n, f, d_in = h.shape
    nh, dh = cfg.nh, cfg.dh
    H = nh * dh
    z4 = z0.reshape(rb, n, f, nh, dh)
    v4 = v0.reshape(rb, n, f, nh, dh)
    ua = us[2]
    if cfg.has_post:
        peg, pvg = pe[ua], pv[ua]  # [rb, nh, dh, dh] — tiny per-slot gathers
        zt = jnp.einsum("rnfhd,rhde->rnfhe", z4, peg)
        vt = jnp.einsum("rnfhd,rhde->rnfhe", v4, pvg)
    else:
        zt, vt = z4, v4
    qv4 = qv.reshape(rb, n, nh, dh)
    e0 = jnp.einsum("rnfhe,rnhe->rnfh", zt, qv4) * cfg.scale
    if cfg.has_eb:
        e0 = e0 + eb[:, :, None, :]
    e = e0 if cfg.slope is None else jax.nn.leaky_relu(
        e0, negative_slope=cfg.slope)
    alpha = _sc_alpha(e, mask)  # [rb, n, f, nh]
    gh = g.reshape(rb, n, nh, dh)
    # closed-form softmax Jacobian (matches _sc_vjp_bwd)
    dalpha = jnp.einsum("rnfhd,rnhd->rnfh", vt, gh)
    tot = jnp.sum(alpha * dalpha, axis=2, keepdims=True)
    de = alpha * (dalpha - tot)
    dvt = jnp.einsum("rnfh,rnhd->rnfhd", alpha, gh)
    if cfg.slope is not None:
        de = de * jnp.where(e0 >= 0, 1.0, cfg.slope).astype(de.dtype)
    deb = jnp.sum(de, axis=2) if cfg.has_eb else jnp.zeros_like(eb)
    des = de * cfg.scale
    dqv = jnp.einsum("rnfh,rnfhe->rnhe", des, zt).reshape(rb, n, H)
    dzt = jnp.einsum("rnfh,rnhe->rnfhe", des, qv4)
    if cfg.has_post:
        dz4 = jnp.einsum("rnfhe,rhde->rnfhd", dzt, peg)
        dv4 = jnp.einsum("rnfhe,rhde->rnfhd", dvt, pvg)
        dpe = jax.ops.segment_sum(
            jnp.einsum("rnfhd,rnfhe->rhde", z4, dzt), ua,
            num_segments=pe.shape[0])
        dpv = jax.ops.segment_sum(
            jnp.einsum("rnfhd,rnfhe->rhde", v4, dvt), ua,
            num_segments=pv.shape[0])
    else:
        dz4, dv4 = dzt, dvt
        dpe, dpv = jnp.zeros_like(pe), jnp.zeros_like(pv)
    dz = dz4.reshape(rb, n, f, H)
    dv = dv4.reshape(rb, n, f, H)
    # projection-weight grads straight into stack form (segment-summed over
    # slot rows; cross-shard sharing stays sync_stack_grads' job)
    if cfg.shared_v:
        dcomb = dz + dv
        dwe = jax.ops.segment_sum(
            jnp.einsum("rnfc,rnfk->rck", h, dcomb), us[0],
            num_segments=we.shape[0])
        dwv = jnp.zeros_like(wv)
        dzp, dvp = pad_to(dcomb, 1, cfg.bn), None
    else:
        dwe = jax.ops.segment_sum(
            jnp.einsum("rnfc,rnfk->rck", h, dz), us[0],
            num_segments=we.shape[0])
        dwv = jax.ops.segment_sum(
            jnp.einsum("rnfc,rnfk->rck", h, dv), us[1],
            num_segments=wv.shape[0])
        dzp, dvp = pad_to(dz, 1, cfg.bn), pad_to(dv, 1, cfg.bn)
    # dh through the scalar-prefetch transpose kernel — weight blocks read
    # from the stack, same indirection as the forward
    dh_ = stacked_attn_dh_pallas(
        dzp, dvp, pad_to(we, 1, cfg.bc),
        None if cfg.shared_v else pad_to(wv, 1, cfg.bc), us,
        block_n=cfg.bn, block_in=cfg.bc, interpret=cfg.interpret,
    )[:, :n, :, :d_in]
    return (dh_, zero_cotangent(mask), dqv, deb, dwe, dwv, dpe, dpv,
            zero_cotangent(us))


_stacked_ae.defvjp(_ae_vjp_fwd, _ae_vjp_bwd)


def stacked_attn_epilogue(
    epi,  # relmod.AttnEpilogue
    h: jnp.ndarray,  # [rb, n, f, d_in]
    mask: jnp.ndarray,  # [rb, n, f]
    block_n: int = 128,
    block_in: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fully fused attention AGG_r from canonical epilogue operands."""
    rb, n, f, d_in = h.shape
    nh, dh = epi.num_heads, epi.head_dim
    shared_v = epi.wv is None
    has_post = epi.pe is not None
    ue = epi.ue.astype(jnp.int32)
    uv = ue if epi.uv is None else epi.uv.astype(jnp.int32)
    ua = jnp.zeros_like(ue) if epi.ua is None else epi.ua.astype(jnp.int32)
    us = jnp.stack([ue, uv, ua])
    dummy = jnp.zeros((1, 1, 1), h.dtype)
    cfg = _AECfg(
        bn=clamp_block(block_n, n), bc=clamp_block(block_in, d_in),
        nh=nh, dh=dh, scale=float(epi.scale),
        slope=None if epi.slope is None else float(epi.slope),
        has_eb=epi.eb is not None, has_post=has_post, shared_v=shared_v,
        interpret=bool(interpret),
    )
    out = _stacked_ae(
        cfg, h, mask, epi.qv,
        dummy if epi.eb is None else epi.eb,
        epi.we,
        dummy if shared_v else epi.wv,
        dummy if not has_post else epi.pe,
        dummy if not has_post else epi.pv,
        us,
    )
    return out if epi.bias is None else out + epi.bias[:, None, :]


# --------------------------------------------------------------------------
# the executor entry point
# --------------------------------------------------------------------------


def stacked_agg(
    module,
    stacks: Dict[str, jnp.ndarray],  # {leaf: [U_scope, ...]} one shard's slabs
    slot_u: Dict[str, jnp.ndarray],  # {scope: [rb] int} per-slot stack rows
    h: jnp.ndarray,  # [rb, n, f, d_in]
    q: jnp.ndarray,  # [rb, n, d_dst]
    mask: jnp.ndarray,  # [rb, n, f]
    opts=None,
    block_n: Optional[int] = None,
    block_out: Optional[int] = None,
    block_in: Optional[int] = None,
) -> jnp.ndarray:
    """One level's AGG_r for every branch slot (see module docstring).

    Block sizes resolve per (op, shape-class): explicit kwargs beat the
    ``opts`` overrides beat the committed tuning table (``opts.autotune``)
    beat the defaults — see ``repro.kernels.ops.resolve_blocks``."""
    use, interp = kernel_choice(opts, "stacked_agg")
    rb, n, f, d_in = h.shape

    def _blocks(op: str, d_out: int):
        bn, bo, bc = resolve_blocks(opts, op, n, f, d_in, d_out)
        return (block_n or bn, block_out or bo, block_in or bc)

    scope_of = {s.name: s.scope for s in module.specs}
    if use and module.fused == "mean_linear":
        # the family contract is leaves named w/b sharing one scope; fall
        # through to the oracle for exotic declarations rather than
        # miscompute (or crash on a missing leaf)
        if scope_of.get("w") is not None and scope_of.get("w") == scope_of.get("b"):
            bn, bo, bc = _blocks("stacked_mean_linear", stacks["w"].shape[2])
            return stacked_mean_linear(
                h, mask, stacks["w"], stacks["b"], slot_u[scope_of["w"]],
                block_n=bn, block_out=bo, block_in=bc, interpret=interp,
            )
    if use and module.fused == "softmax_combine":
        if getattr(opts, "fuse_epilogue", True):
            bn, bo, bc = _blocks("stacked_attn_epilogue",
                                 _epilogue_width(module, stacks))
            epi = module.attn_epilogue(
                stacks, slot_u, q,
                linear=partial(_epilogue_linear, block_n=bn, block_out=bo,
                               block_in=bc, interpret=interp),
            )
            if epi is not None:
                return stacked_attn_epilogue(
                    epi, h, mask, block_n=bn, block_in=bc, interpret=interp,
                )
        # attn_parts oracle path (fuse_epilogue off, or no epilogue decl):
        # projections vmapped under XLA autodiff over gathered weights
        p_slots = {name: stacks[name][slot_u[scope_of[name]]] for name in stacks}
        e, v = jax.vmap(module.attn_parts)(p_slots, h, q)
        nh_, dh_ = v.shape[3], v.shape[4]
        bn, _, _ = _blocks("stacked_softmax_combine", nh_ * dh_)
        out = stacked_softmax_combine(
            e, mask, v, block_n=bn, interpret=interp
        )
        bias = module.attn_bias(p_slots)  # [rb, hidden] or None
        return out if bias is None else out + bias[:, None, :]
    return stacked_agg_ref(module, stacks, slot_u, h, q, mask)


def _epilogue_width(module, stacks) -> int:
    """The attention hidden width nh*dh — the widest last dim among the
    module's ``[U, d, hidden]`` projection stacks."""
    return max(s.shape[-1] for s in stacks.values() if s.ndim == 3)


def _epilogue_linear(w_stack, u, x, *, block_n, block_out, block_in, interpret):
    """Per-slot projection ``x @ w_stack[u]`` for the q-side of an
    attention epilogue — routed through :func:`stacked_mean_linear` with a
    singleton fanout (masked mean over one slot is the identity), so the
    weight blocks stream from the stack and the VJP lands in stack form."""
    rb, n, d = x.shape
    zb = jnp.zeros((w_stack.shape[0], w_stack.shape[2]), w_stack.dtype)
    ones = jnp.ones((rb, n, 1), bool)
    return stacked_mean_linear(
        x[:, :, None, :], ones, w_stack, zb, u,
        block_n=block_n, block_out=block_out, block_in=block_in,
        interpret=interpret,
    )
