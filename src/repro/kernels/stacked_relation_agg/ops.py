"""Public op: stacked relation aggregation — dispatch, padding, custom VJP.

:func:`stacked_agg` is the single entry point the SPMD executor's
``_agg_level`` calls per level (DESIGN.md §8).  Dispatch, driven by the
module's ``fused`` declaration and the resolved backend
(``repro.kernels.ops.kernel_choice``):

  * ``fused == "mean_linear"``     -> :func:`stacked_mean_linear` — the
    fully-fused Pallas kernel (scalar-prefetch slot→stack indirection).
  * ``fused == "softmax_combine"`` -> logit/value projections via the
    module's ``attn_parts`` (vmapped, XLA autodiff) + the Pallas masked
    softmax+combine epilogue.
  * anything else, or a non-TPU backend without forced interpret ->
    :func:`~repro.kernels.stacked_relation_agg.ref.stacked_agg_ref`, the
    gather-then-vmap oracle.

Both Pallas ops carry a ``jax.custom_vjp``:

  * ``stacked_mean_linear``'s backward produces the weight gradient
    **directly in stack form** ``[U, d_in, d_out]`` (per-slot contributions
    segment-summed over ``slot_u`` — autodiff of the gathered path would
    yield per-slot ``[rb, ...]`` grads scattered back afterwards), and the
    neighbor-activation gradient through the scalar-prefetch ``dh`` kernel,
    so the backward reads weights from the stack exactly like the forward.
    Cross-*shard* sharing stays ``sync_stack_grads``' job: this op sums
    within a shard's slots, the executor's existing sync sums across
    shards' stack rows — composition, no overlap.
  * ``stacked_softmax_combine``'s backward is the closed-form softmax
    Jacobian (recomputed probabilities, no saved alpha), matching autodiff
    of ``relmod.masked_softmax`` including the all-masked-row case.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels.ops import (
    agg_blocks,
    agg_vmem_bytes,
    clamp_block,
    kernel_choice,
    pad_axes,
    pad_to,
    zero_cotangent,
)
from repro.kernels.stacked_relation_agg.kernel import (
    stacked_mean_linear_dh_pallas,
    stacked_mean_linear_pallas,
    stacked_softmax_combine_pallas,
)
from repro.kernels.stacked_relation_agg.ref import stacked_agg_grouped, stacked_agg_ref

__all__ = [
    "stacked_agg",
    "stacked_mean_linear",
    "stacked_softmax_combine",
    "stacked_agg_ref",
    "stacked_agg_grouped",
    "stacked_mean_linear_blocks",
    "stacked_mean_linear_vmem_bytes",
    "stacked_softmax_combine_vmem_bytes",
]


# --------------------------------------------------------------------------
# block derivation + VMEM accounting (single source for op and benchmarks)
# --------------------------------------------------------------------------


# the stacked forward's per-step working set matches the unstacked kernel's
# (the slot axis contributes a block edge of 1) — one shared formula in the
# ops layer, so BENCH figures can never drift from the dispatch
stacked_mean_linear_blocks = agg_blocks
stacked_mean_linear_vmem_bytes = agg_vmem_bytes


def stacked_softmax_combine_vmem_bytes(
    n: int, f: int, num_heads: int, head_dim: int,
    block_n: int = 128, bytes_per_elem: int = 4,
) -> int:
    bn = clamp_block(block_n, n)
    H = num_heads * head_dim
    elems = bn * f * num_heads + bn * f + bn * f * H + bn * H
    return elems * bytes_per_elem


# --------------------------------------------------------------------------
# mean_linear: fused Pallas forward + stack-form custom VJP
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _MLCfg:
    bn: int
    bo: int
    bc: int
    interpret: bool


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _stacked_ml(cfg: _MLCfg, h, mask, w, b, slot_u):
    return _ml_fwd_impl(cfg, h, mask, w, b, slot_u)


def _ml_fwd_impl(cfg, h, mask, w, b, slot_u):
    rb, n, f, d_in = h.shape
    d_out = w.shape[2]
    hp = pad_axes(h, {1: cfg.bn, 3: cfg.bc})
    mp = pad_to(mask, 1, cfg.bn)
    wp = pad_axes(w, {1: cfg.bc, 2: cfg.bo})
    bp = pad_to(b, 1, cfg.bo)
    out = stacked_mean_linear_pallas(
        hp, mp, wp, bp, slot_u,
        block_n=cfg.bn, block_out=cfg.bo, block_in=cfg.bc, interpret=cfg.interpret,
    )
    return out[:, :n, :d_out]


def _ml_vjp_fwd(cfg, h, mask, w, b, slot_u):
    return _ml_fwd_impl(cfg, h, mask, w, b, slot_u), (h, mask, w, slot_u)


def _ml_vjp_bwd(cfg, res, g):
    h, mask, w, slot_u = res
    rb, n, f, d_in = h.shape
    U, _, d_out = w.shape
    # dh through the scalar-prefetch kernel — weight blocks read from the
    # stack, same indirection as the forward
    gp = pad_axes(g, {1: cfg.bn, 2: cfg.bo})
    mp = pad_to(mask, 1, cfg.bn)
    wp = pad_axes(w, {1: cfg.bc, 2: cfg.bo})
    dh = stacked_mean_linear_dh_pallas(
        gp, mp, wp, slot_u,
        block_n=cfg.bn, block_out=cfg.bo, block_in=cfg.bc, interpret=cfg.interpret,
    )[:, :n, :, :d_in]
    # dw/db accumulate straight into the [U, ...] stack: per-slot outer
    # products segment-summed over slot_u (slots sharing a stack row sum,
    # exactly like autodiff of the dict-form forward sums occurrences)
    mw = mask.astype(h.dtype)
    cnt = jnp.maximum(mw.sum(-1, keepdims=True), 1.0)
    mean = jnp.einsum("rnfd,rnf->rnd", h, mw) / cnt
    pw = jnp.einsum("rnd,rno->rdo", mean, g)
    dw = jax.ops.segment_sum(pw, slot_u, num_segments=U)
    db = jax.ops.segment_sum(jnp.sum(g, axis=1), slot_u, num_segments=U)
    return dh, zero_cotangent(mask), dw, db, zero_cotangent(slot_u)


_stacked_ml.defvjp(_ml_vjp_fwd, _ml_vjp_bwd)


def stacked_mean_linear(
    h: jnp.ndarray,  # [rb, n, f, d_in]
    mask: jnp.ndarray,  # [rb, n, f]
    w: jnp.ndarray,  # [U, d_in, d_out]
    b: jnp.ndarray,  # [U, d_out]
    slot_u: jnp.ndarray,  # [rb] int
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    rb, n, f, d_in = h.shape
    bn, bo, bc = stacked_mean_linear_blocks(
        n, f, d_in, w.shape[2], block_n, block_out, block_in
    )
    cfg = _MLCfg(bn, bo, bc, bool(interpret))
    return _stacked_ml(cfg, h, mask, w, b, slot_u.astype(jnp.int32))


# --------------------------------------------------------------------------
# softmax_combine: Pallas epilogue + closed-form custom VJP
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _SCCfg:
    bn: int
    num_heads: int
    head_dim: int
    interpret: bool


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _stacked_sc(cfg: _SCCfg, e, mask, v):
    return _sc_fwd_impl(cfg, e, mask, v)


def _sc_fwd_impl(cfg, e, mask, v):
    rb, n, f, nh = e.shape
    vf = v.reshape(rb, n, f, nh * cfg.head_dim)
    ep = pad_to(e, 1, cfg.bn)
    mp = pad_to(mask, 1, cfg.bn)
    vp = pad_to(vf, 1, cfg.bn)
    out = stacked_softmax_combine_pallas(
        ep, mp, vp, num_heads=nh, head_dim=cfg.head_dim,
        block_n=cfg.bn, interpret=cfg.interpret,
    )
    return out[:, :n]


def _sc_alpha(e, mask):
    neg = jnp.asarray(jnp.finfo(e.dtype).min, e.dtype)
    em = jnp.where(mask[:, :, :, None], e, neg)
    em = em - jnp.max(em, axis=2, keepdims=True)
    z = jnp.exp(em) * mask[:, :, :, None].astype(e.dtype)
    return z / jnp.maximum(jnp.sum(z, axis=2, keepdims=True), 1e-9)


def _sc_vjp_fwd(cfg, e, mask, v):
    return _sc_fwd_impl(cfg, e, mask, v), (e, mask, v)


def _sc_vjp_bwd(cfg, res, g):
    e, mask, v = res
    rb, n, f, nh = e.shape
    alpha = _sc_alpha(e, mask)  # [rb, n, f, nh]
    gh = g.reshape(rb, n, nh, cfg.head_dim)
    dalpha = jnp.einsum("rnfhd,rnhd->rnfh", v, gh)
    tot = jnp.sum(alpha * dalpha, axis=2, keepdims=True)
    de = alpha * (dalpha - tot)
    dv = jnp.einsum("rnfh,rnhd->rnfhd", alpha, gh)
    return de, zero_cotangent(mask), dv


_stacked_sc.defvjp(_sc_vjp_fwd, _sc_vjp_bwd)


def stacked_softmax_combine(
    e: jnp.ndarray,  # [rb, n, f, nh]
    mask: jnp.ndarray,  # [rb, n, f]
    v: jnp.ndarray,  # [rb, n, f, nh, dh]
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    rb, n, f, nh = e.shape
    dh = v.shape[-1]
    cfg = _SCCfg(clamp_block(block_n, n), nh, dh, bool(interpret))
    return _stacked_sc(cfg, e, mask, v)


# --------------------------------------------------------------------------
# the executor entry point
# --------------------------------------------------------------------------


def stacked_agg(
    module,
    stacks: Dict[str, jnp.ndarray],  # {leaf: [U_scope, ...]} one shard's slabs
    slot_u: Dict[str, jnp.ndarray],  # {scope: [rb] int} per-slot stack rows
    h: jnp.ndarray,  # [rb, n, f, d_in]
    q: jnp.ndarray,  # [rb, n, d_dst]
    mask: jnp.ndarray,  # [rb, n, f]
    opts=None,
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
) -> jnp.ndarray:
    """One level's AGG_r for every branch slot (see module docstring)."""
    use, interp = kernel_choice(opts, "stacked_agg")
    scope_of = {s.name: s.scope for s in module.specs}
    if use and module.fused == "mean_linear":
        # the family contract is leaves named w/b sharing one scope; fall
        # through to the oracle for exotic declarations rather than
        # miscompute (or crash on a missing leaf)
        if scope_of.get("w") is not None and scope_of.get("w") == scope_of.get("b"):
            return stacked_mean_linear(
                h, mask, stacks["w"], stacks["b"], slot_u[scope_of["w"]],
                block_n=block_n, block_out=block_out, block_in=block_in,
                interpret=interp,
            )
    if use and module.fused == "softmax_combine":
        p_slots = {name: stacks[name][slot_u[scope_of[name]]] for name in stacks}
        e, v = jax.vmap(module.attn_parts)(p_slots, h, q)
        out = stacked_softmax_combine(
            e, mask, v, block_n=block_n, interpret=interp
        )
        bias = module.attn_bias(p_slots)  # [rb, hidden] or None
        return out if bias is None else out + bias[:, None, :]
    return stacked_agg_ref(module, stacks, slot_u, h, q, mask)
