"""Stacked relation-aggregation kernel family (DESIGN.md §8).

One Pallas call per metatree level: grid over (branch slot, node block),
per-slot scope indices as scalar-prefetch operands so weight blocks are
read directly from the ``[U, ...]`` parameter stacks in HBM — no
materialized per-slot weight gather.  ``stacked_agg`` is the dispatch the
SPMD executor's ``_agg_level`` consumes; the gather-then-vmap oracle and
the grouped "stacked XLA" oracle live in ``ref``.
"""

from repro.kernels.stacked_relation_agg.ops import (  # noqa: F401
    stacked_agg,
    stacked_agg_grouped,
    stacked_agg_ref,
    stacked_attn_epilogue,
    stacked_attn_epilogue_vmem_bytes,
    stacked_mean_linear,
    stacked_mean_linear_blocks,
    stacked_mean_linear_vmem_bytes,
    stacked_softmax_combine,
    stacked_softmax_combine_vmem_bytes,
)
