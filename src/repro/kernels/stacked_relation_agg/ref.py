"""Oracles for the stacked relation-aggregation kernel family.

Two reference implementations of "run one level's AGG_r for every branch
slot of a shard":

  * :func:`stacked_agg_ref` — the **gather-then-vmap oracle**: gather each
    declared leaf's per-slot parameters through the scope index arrays
    (materializing a ``[rb, ...]`` copy of every leaf — shared parameters
    duplicated across slots) and ``vmap`` the module's ``aggregate`` over
    the branch axis.  This is the SPMD executor's historical `_agg_level`
    math, kept verbatim as the correctness oracle and the non-TPU fallback.

  * :func:`stacked_agg_grouped` — the **stacked XLA oracle**: slots grouped
    at trace time by their full (static) parameter signature; each group
    evaluates ``aggregate`` once over the merged ``[g·n]`` batch with
    *statically sliced* leaves — one weight read per unique parameter
    combination, no materialized per-slot gather.  Requires concrete
    (numpy) slot indices, so it serves benchmarks and tests rather than the
    shard_map body (where slot indices are traced per-shard data — that is
    exactly what the Pallas kernels' scalar prefetch handles).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["stacked_agg_ref", "stacked_agg_grouped"]


def _scope_of(module) -> Dict[str, str]:
    return {s.name: s.scope for s in module.specs}


def stacked_agg_ref(module, stacks, slot_u, h, q, mask):
    """Gather-then-vmap oracle.

    stacks  {leaf: [U_scope, ...]}   one shard's per-scope parameter slabs
    slot_u  {scope: [rb] int}        per-slot index into that scope's slab
    h       [rb, n, f, d_in]         neighbor embeddings per slot
    q       [rb, n, d_dst]           destination input features per slot
    mask    [rb, n, f]               real-neighbor mask
    ->      [rb, n, hidden]
    """
    scope_of = _scope_of(module)
    p_slots = {name: stacks[name][slot_u[scope_of[name]]] for name in stacks}
    return jax.vmap(module.aggregate)(p_slots, h, q, mask)


def stacked_agg_grouped(module, stacks, slot_u_np, h, q, mask):
    """Stacked XLA oracle (static slot indices — see module docstring)."""
    scope_of = _scope_of(module)
    rb, n, f, d_in = h.shape
    groups: Dict[tuple, list] = {}
    for s in range(rb):
        sig = tuple(int(slot_u_np[sc][s]) for sc in module.scopes)
        groups.setdefault(sig, []).append(s)
    if module.fused == "mean_linear":
        # the f-reduction is weight-free and touches the bulk of the data —
        # run it once over the whole stack; only the [rb, n, d_in] means are
        # regrouped, and each unique weight is a static slice feeding one
        # flat matmul (this is the memory-movement shape the Pallas kernel
        # realizes per block on TPU).  Group outputs are concatenated and
        # un-permuted with ONE gather at the end: the earlier
        # ``out.at[sl].set`` formulation copied the whole [rb, n, d_out]
        # output once per group, which at rgcn shapes (every slot its own
        # relation ⇒ all-singleton groups) cost more than the grouping
        # saved — the 0.93x mag_l1/mag_l2 regression in BENCH_kernels.json.
        mw = mask.astype(h.dtype)
        cnt = jnp.maximum(mw.sum(-1, keepdims=True), 1.0)
        mean = jnp.einsum("rnfd,rnf->rnd", h, mw) / cnt
        chunks, order = [], []
        for sig, slots in groups.items():
            u_of = dict(zip(module.scopes, sig))
            uw = u_of[scope_of["w"]]
            sl = jnp.asarray(np.asarray(slots))
            g = len(slots)
            m_g = jnp.take(mean, sl, axis=0).reshape(g * n, d_in)
            o_g = (m_g @ stacks["w"][uw] + stacks["b"][u_of[scope_of["b"]]])
            chunks.append(o_g.reshape(g, n, -1))
            order.extend(slots)
        out = jnp.concatenate(chunks, axis=0)
        inv = np.argsort(np.asarray(order))
        return jnp.take(out, jnp.asarray(inv), axis=0)
    chunks, order = [], []
    for sig, slots in groups.items():
        u_of = dict(zip(module.scopes, sig))
        p = {name: stacks[name][u_of[scope_of[name]]] for name in stacks}
        sl = jnp.asarray(np.asarray(slots))
        g = len(slots)
        hg = jnp.take(h, sl, axis=0).reshape(g * n, f, d_in)
        qg = jnp.take(q, sl, axis=0).reshape(g * n, q.shape[-1])
        mg = jnp.take(mask, sl, axis=0).reshape(g * n, f)
        chunks.append(module.aggregate(p, hg, qg, mg).reshape(g, n, -1))
        order.extend(slots)
    out = jnp.concatenate(chunks, axis=0)
    inv = np.argsort(np.asarray(order))
    return jnp.take(out, jnp.asarray(inv), axis=0)
