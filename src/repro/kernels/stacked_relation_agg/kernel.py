"""Pallas TPU kernels: stacked relation aggregation for all branch slots.

One ``pallas_call`` runs a whole level of the SPMD executor — the grid's
leading dimension is the shard's branch-slot axis, and the per-slot scope
indices (``LevelPlan.slot_u``) ride in as **scalar-prefetch** operands.
Each grid step's ``index_map`` therefore reads its weight block *directly
from the ``[U, ...]`` stack in HBM*: a parameter shared by many slots is
DMA'd once per slot-step straight out of the single stacked copy — never
materialized as a gathered ``[rb, ...]`` duplicate in HBM, which is what
the gather-then-vmap path pays every step ("Characterizing and
Understanding HGNN Training on GPUs" finds exactly this redundant parameter
movement dominating HGNN kernels; HiHGNN builds on the same reusability).

Three kernels:

  * :func:`stacked_mean_linear_pallas` — the rgcn-family AGG_r: masked-mean
    over the fanout fused with the output projection.  Grid (slot, node
    block, d_out block, d_in chunk); float32 VMEM accumulator across d_in
    chunks; mean is never written to HBM.
  * :func:`stacked_mean_linear_dh_pallas` — the hand-written backward for
    the neighbor activations: ``dh = (g @ w[slot]ᵀ) · mask / cnt``, again
    reading weight blocks via scalar prefetch (no gathered ``wᵀ`` copies).
  * :func:`stacked_softmax_combine_pallas` — the attention-family epilogue
    (rgat/hgt): masked softmax over the fanout fused with the head-wise
    weighted combine, so attention probabilities never round-trip to HBM.
    Logit/value projections stay outside (they carry the module-specific
    einsums and remain under XLA autodiff).  Kept as the ``attn_parts``
    oracle path; superseded on the hot path by the kernel below.
  * :func:`stacked_attn_epilogue_pallas` — the *fully fused* attention
    AGG_r (DESIGN.md §8): the per-slot logit/value projections now stream
    from the ``[U, d_in, nh*dh]`` stacks via the same scalar-prefetch
    indirection, accumulate across d_in chunks in float32 VMEM scratch,
    and feed the masked softmax + combine epilogue in the same grid step —
    neither the projected logits/values *nor* a gathered weight copy ever
    round-trips through HBM on the forward.  Optional per-slot
    ``[nh, dh, dh]`` transforms (HGT's ``w_att``/``w_msg``) apply in the
    epilogue.  With ``with_residuals`` the pre-transform projections are
    written out once for the backward.
  * :func:`stacked_attn_dh_pallas` — the backward w.r.t. the neighbor
    activations: ``dh = dz @ we[slot]ᵀ (+ dv @ wv[slot]ᵀ)``, weight blocks
    again read via scalar prefetch.

All shapes arrive pre-padded to block multiples (``ops.py`` owns padding
and slicing); fanout ``f`` stays whole — sampled fanouts are 3–25, so the
reduction never crosses blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "stacked_mean_linear_pallas",
    "stacked_mean_linear_dh_pallas",
    "stacked_softmax_combine_pallas",
    "stacked_attn_epilogue_pallas",
    "stacked_attn_dh_pallas",
]


# --------------------------------------------------------------------------
# masked-mean + projection (rgcn family), forward
# --------------------------------------------------------------------------


def _mean_linear_kernel(u_ref, h_ref, m_ref, w_ref, b_ref, out_ref, acc_ref,
                        *, n_chunks: int):
    c = pl.program_id(3)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[0]  # [bn, f, bc]
    m = m_ref[0].astype(h.dtype)  # [bn, f]
    # identical formulation to relmod.masked_mean (operand order included),
    # so the interpret-mode forward is bit-equal to the vmap oracle
    s = jnp.einsum("nfd,nf->nd", h, m)
    cnt = jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0)
    mean = s / cnt
    acc_ref[...] += jax.lax.dot(
        mean.astype(w_ref.dtype), w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(c == n_chunks - 1)
    def _done():
        out_ref[0] = (
            acc_ref[...] + b_ref[0].astype(jnp.float32)[None, :]
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_out", "block_in", "interpret")
)
def stacked_mean_linear_pallas(
    h: jnp.ndarray,  # [rb, n, f, d_in]   (n, d_in pre-padded to blocks)
    mask: jnp.ndarray,  # [rb, n, f]
    w: jnp.ndarray,  # [U, d_in, d_out]
    b: jnp.ndarray,  # [U, d_out]
    slot_u: jnp.ndarray,  # [rb] int32 — slot -> stack row (scalar prefetch)
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    rb, n, f, d_in = h.shape
    d_out = w.shape[2]
    bn, bo, bc = block_n, block_out, block_in
    grid = (rb, pl.cdiv(n, bn), pl.cdiv(d_out, bo), pl.cdiv(d_in, bc))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, f, bc), lambda s, i, o, c, u: (s, i, 0, c)),
            pl.BlockSpec((1, bn, f), lambda s, i, o, c, u: (s, i, 0)),
            pl.BlockSpec((1, bc, bo), lambda s, i, o, c, u: (u[s], c, o)),
            pl.BlockSpec((1, bo), lambda s, i, o, c, u: (u[s], o)),
        ],
        out_specs=pl.BlockSpec((1, bn, bo), lambda s, i, o, c, u: (s, i, o)),
        scratch_shapes=[pltpu.VMEM((bn, bo), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_mean_linear_kernel, n_chunks=grid[3]),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rb, n, d_out), h.dtype),
        interpret=interpret,
    )(slot_u.astype(jnp.int32), h, mask, w, b)


# --------------------------------------------------------------------------
# masked-mean + projection, backward w.r.t. the neighbor activations
# --------------------------------------------------------------------------


def _mean_linear_dh_kernel(u_ref, g_ref, m_ref, w_ref, dh_ref, acc_ref,
                           *, n_chunks: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[0]  # [bn, bk]
    w = w_ref[0]  # [bc, bk]
    # dmean partial: g @ w^T accumulated over d_out chunks
    acc_ref[...] += jax.lax.dot_general(
        g.astype(w.dtype), w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_chunks - 1)
    def _done():
        m = m_ref[0].astype(jnp.float32)  # [bn, f]
        cnt = jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0)
        dmean = acc_ref[...] / cnt  # [bn, bc]
        dh_ref[0] = (dmean[:, None, :] * m[:, :, None]).astype(dh_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_out", "block_in", "interpret")
)
def stacked_mean_linear_dh_pallas(
    g: jnp.ndarray,  # [rb, n, d_out]
    mask: jnp.ndarray,  # [rb, n, f]
    w: jnp.ndarray,  # [U, d_in, d_out]
    slot_u: jnp.ndarray,  # [rb] int32
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    rb, n, d_out = g.shape
    f = mask.shape[2]
    d_in = w.shape[1]
    bn, bo, bc = block_n, block_out, block_in
    grid = (rb, pl.cdiv(n, bn), pl.cdiv(d_in, bc), pl.cdiv(d_out, bo))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bo), lambda s, i, c, k, u: (s, i, k)),
            pl.BlockSpec((1, bn, f), lambda s, i, c, k, u: (s, i, 0)),
            pl.BlockSpec((1, bc, bo), lambda s, i, c, k, u: (u[s], c, k)),
        ],
        out_specs=pl.BlockSpec((1, bn, f, bc), lambda s, i, c, k, u: (s, i, 0, c)),
        scratch_shapes=[pltpu.VMEM((bn, bc), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_mean_linear_dh_kernel, n_chunks=grid[3]),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rb, n, f, d_in), g.dtype),
        interpret=interpret,
    )(slot_u.astype(jnp.int32), g, mask, w)


# --------------------------------------------------------------------------
# masked softmax + head-wise combine (rgat/hgt epilogue)
# --------------------------------------------------------------------------


def _softmax_combine_kernel(e_ref, m_ref, v_ref, out_ref, *, num_heads: int,
                            head_dim: int):
    e = e_ref[0]  # [bn, f, nh]
    m = m_ref[0]  # [bn, f] bool
    v = v_ref[0]  # [bn, f, nh*dh]
    # identical numerics to relmod.masked_softmax
    neg = jnp.asarray(jnp.finfo(e.dtype).min, e.dtype)
    em = jnp.where(m[:, :, None], e, neg)
    em = em - jnp.max(em, axis=1, keepdims=True)
    z = jnp.exp(em) * m[:, :, None].astype(e.dtype)
    alpha = z / jnp.maximum(jnp.sum(z, axis=1, keepdims=True), 1e-9)
    bn, f, nh = alpha.shape
    ar = jnp.broadcast_to(
        alpha[:, :, :, None], (bn, f, nh, head_dim)
    ).reshape(bn, f, nh * head_dim)
    out_ref[0] = jnp.sum(ar * v.astype(ar.dtype), axis=1).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("num_heads", "head_dim", "block_n", "interpret")
)
def stacked_softmax_combine_pallas(
    e: jnp.ndarray,  # [rb, n, f, nh]
    mask: jnp.ndarray,  # [rb, n, f]
    v: jnp.ndarray,  # [rb, n, f, nh*dh]
    num_heads: int,
    head_dim: int,
    block_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    rb, n, f, nh = e.shape
    H = v.shape[3]
    bn = block_n
    grid = (rb, pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(
            _softmax_combine_kernel, num_heads=num_heads, head_dim=head_dim
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, f, nh), lambda s, i: (s, i, 0, 0)),
            pl.BlockSpec((1, bn, f), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, bn, f, H), lambda s, i: (s, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, H), lambda s, i: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((rb, n, H), e.dtype),
        interpret=interpret,
    )(e, mask, v)


# --------------------------------------------------------------------------
# fully fused attention AGG_r: stack-streamed projections + softmax+combine
# --------------------------------------------------------------------------


def _attn_epilogue_kernel(u_ref, *refs, n_chunks, num_heads, head_dim, scale,
                          slope, has_eb, has_post, shared_v, with_res):
    nh, dh = num_heads, head_dim
    it = iter(refs)
    h_ref, m_ref, qv_ref = next(it), next(it), next(it)
    eb_ref = next(it) if has_eb else None
    we_ref = next(it)
    wv_ref = None if shared_v else next(it)
    pe_ref = next(it) if has_post else None
    pv_ref = next(it) if has_post else None
    out_ref = next(it)
    z_ref = next(it) if with_res else None
    v_ref = next(it) if (with_res and not shared_v) else None
    acc_z = next(it)
    acc_v = None if shared_v else next(it)

    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        acc_z[...] = jnp.zeros_like(acc_z)
        if acc_v is not None:
            acc_v[...] = jnp.zeros_like(acc_v)

    h = h_ref[0]  # [bn, f, bc]
    bn, f, bc = h.shape
    hf = h.reshape(bn * f, bc)
    acc_z[...] += jax.lax.dot(
        hf.astype(we_ref.dtype), we_ref[0], preferred_element_type=jnp.float32
    ).reshape(bn, f, nh * dh)
    if acc_v is not None:
        acc_v[...] += jax.lax.dot(
            hf.astype(wv_ref.dtype), wv_ref[0],
            preferred_element_type=jnp.float32,
        ).reshape(bn, f, nh * dh)

    @pl.when(c == n_chunks - 1)
    def _done():
        z0 = acc_z[...]  # [bn, f, nh*dh] float32
        v0 = z0 if acc_v is None else acc_v[...]
        z4 = z0.reshape(bn, f, nh, dh)
        v4 = v0.reshape(bn, f, nh, dh)
        if has_post:
            zt = jnp.einsum("bfhd,hde->bfhe", z4,
                            pe_ref[0].astype(jnp.float32))
            vt = jnp.einsum("bfhd,hde->bfhe", v4,
                            pv_ref[0].astype(jnp.float32))
        else:
            zt, vt = z4, v4
        qv = qv_ref[0].reshape(bn, nh, dh).astype(jnp.float32)
        e = jnp.einsum("bfhe,bhe->bfh", zt, qv) * scale
        if has_eb:
            e = e + eb_ref[0].astype(jnp.float32)[:, None, :]
        if slope is not None:
            e = jax.nn.leaky_relu(e, negative_slope=slope)
        # identical numerics to relmod.masked_softmax
        m = m_ref[0]  # [bn, f] bool
        neg = jnp.asarray(jnp.finfo(e.dtype).min, e.dtype)
        em = jnp.where(m[:, :, None], e, neg)
        em = em - jnp.max(em, axis=1, keepdims=True)
        z = jnp.exp(em) * m[:, :, None].astype(e.dtype)
        alpha = z / jnp.maximum(jnp.sum(z, axis=1, keepdims=True), 1e-9)
        out = jnp.einsum("bfh,bfhd->bhd", alpha, vt).reshape(bn, nh * dh)
        out_ref[0] = out.astype(out_ref.dtype)
        if z_ref is not None:
            z_ref[0] = z0.astype(z_ref.dtype)
        if v_ref is not None:
            v_ref[0] = v0.astype(v_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("num_heads", "head_dim", "scale", "slope", "with_residuals",
                     "block_n", "block_in", "interpret"),
)
def stacked_attn_epilogue_pallas(
    h: jnp.ndarray,  # [rb, n, f, d_in]  (n, d_in pre-padded to blocks)
    mask: jnp.ndarray,  # [rb, n, f]
    qv: jnp.ndarray,  # [rb, n, nh*dh]
    eb,  # [rb, n, nh] or None
    we: jnp.ndarray,  # [Ue, d_in, nh*dh]
    wv,  # [Uv, d_in, nh*dh] or None (shares we)
    pe,  # [Ua, nh, dh, dh] or None
    pv,  # [Ua, nh, dh, dh] or None
    us: jnp.ndarray,  # [3, rb] int32 — rows (ue, uv, ua) (scalar prefetch)
    num_heads: int,
    head_dim: int,
    scale: float = 1.0,
    slope=None,
    with_residuals: bool = False,
    block_n: int = 128,
    block_in: int = 512,
    interpret: bool = True,
):
    rb, n, f, d_in = h.shape
    nh, dh = num_heads, head_dim
    H = nh * dh
    bn, bc = block_n, block_in
    has_eb, has_post, shared_v = eb is not None, pe is not None, wv is None
    grid = (rb, pl.cdiv(n, bn), pl.cdiv(d_in, bc))

    in_specs = [
        pl.BlockSpec((1, bn, f, bc), lambda s, i, c, u: (s, i, 0, c)),
        pl.BlockSpec((1, bn, f), lambda s, i, c, u: (s, i, 0)),
        pl.BlockSpec((1, bn, H), lambda s, i, c, u: (s, i, 0)),
    ]
    operands = [h, mask, qv]
    if has_eb:
        in_specs.append(pl.BlockSpec((1, bn, nh), lambda s, i, c, u: (s, i, 0)))
        operands.append(eb)
    in_specs.append(
        pl.BlockSpec((1, bc, H), lambda s, i, c, u: (u[0, s], c, 0)))
    operands.append(we)
    if not shared_v:
        in_specs.append(
            pl.BlockSpec((1, bc, H), lambda s, i, c, u: (u[1, s], c, 0)))
        operands.append(wv)
    if has_post:
        in_specs.append(
            pl.BlockSpec((1, nh, dh, dh), lambda s, i, c, u: (u[2, s], 0, 0, 0)))
        in_specs.append(
            pl.BlockSpec((1, nh, dh, dh), lambda s, i, c, u: (u[2, s], 0, 0, 0)))
        operands.extend([pe, pv])

    out_specs = [pl.BlockSpec((1, bn, H), lambda s, i, c, u: (s, i, 0))]
    out_shape = [jax.ShapeDtypeStruct((rb, n, H), h.dtype)]
    if with_residuals:
        out_specs.append(
            pl.BlockSpec((1, bn, f, H), lambda s, i, c, u: (s, i, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((rb, n, f, H), h.dtype))
        if not shared_v:
            out_specs.append(
                pl.BlockSpec((1, bn, f, H), lambda s, i, c, u: (s, i, 0, 0)))
            out_shape.append(jax.ShapeDtypeStruct((rb, n, f, H), h.dtype))

    scratch = [pltpu.VMEM((bn, f, H), jnp.float32)]
    if not shared_v:
        scratch.append(pltpu.VMEM((bn, f, H), jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        functools.partial(
            _attn_epilogue_kernel, n_chunks=grid[2], num_heads=nh, head_dim=dh,
            scale=scale, slope=slope, has_eb=has_eb, has_post=has_post,
            shared_v=shared_v, with_res=with_residuals,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(us.astype(jnp.int32), *operands)
    return out if with_residuals else out[0]


# --------------------------------------------------------------------------
# fused attention backward w.r.t. the neighbor activations
# --------------------------------------------------------------------------


def _attn_dh_kernel(u_ref, *refs, shared_v):
    it = iter(refs)
    dz_ref = next(it)
    dv_ref = None if shared_v else next(it)
    we_ref = next(it)
    wv_ref = None if shared_v else next(it)
    dh_ref = next(it)

    dz = dz_ref[0]  # [bn, f, H]
    bn, f, H = dz.shape
    we = we_ref[0]  # [bc, H]
    acc = jax.lax.dot_general(
        dz.reshape(bn * f, H).astype(we.dtype), we, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if dv_ref is not None:
        wv = wv_ref[0]
        acc += jax.lax.dot_general(
            dv_ref[0].reshape(bn * f, H).astype(wv.dtype), wv,
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
        )
    dh_ref[0] = acc.reshape(bn, f, -1).astype(dh_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_in", "interpret")
)
def stacked_attn_dh_pallas(
    dz: jnp.ndarray,  # [rb, n, f, H]
    dv,  # [rb, n, f, H] or None (shared projection)
    we: jnp.ndarray,  # [Ue, d_in, H]
    wv,  # [Uv, d_in, H] or None
    us: jnp.ndarray,  # [3, rb] int32
    block_n: int = 128,
    block_in: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    rb, n, f, H = dz.shape
    d_in = we.shape[1]
    bn, bc = block_n, block_in
    shared_v = dv is None
    grid = (rb, pl.cdiv(n, bn), pl.cdiv(d_in, bc))
    in_specs = [pl.BlockSpec((1, bn, f, H), lambda s, i, c, u: (s, i, 0, 0))]
    operands = [dz]
    if not shared_v:
        in_specs.append(
            pl.BlockSpec((1, bn, f, H), lambda s, i, c, u: (s, i, 0, 0)))
        operands.append(dv)
    in_specs.append(
        pl.BlockSpec((1, bc, H), lambda s, i, c, u: (u[0, s], c, 0)))
    operands.append(we)
    if not shared_v:
        in_specs.append(
            pl.BlockSpec((1, bc, H), lambda s, i, c, u: (u[1, s], c, 0)))
        operands.append(wv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bn, f, bc), lambda s, i, c, u: (s, i, 0, c)),
    )
    return pl.pallas_call(
        functools.partial(_attn_dh_kernel, shared_v=shared_v),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rb, n, f, d_in), dz.dtype),
        interpret=interpret,
    )(us.astype(jnp.int32), *operands)
