"""Pallas TPU kernels: stacked relation aggregation for all branch slots.

One ``pallas_call`` runs a whole level of the SPMD executor — the grid's
leading dimension is the shard's branch-slot axis, and the per-slot scope
indices (``LevelPlan.slot_u``) ride in as **scalar-prefetch** operands.
Each grid step's ``index_map`` therefore reads its weight block *directly
from the ``[U, ...]`` stack in HBM*: a parameter shared by many slots is
DMA'd once per slot-step straight out of the single stacked copy — never
materialized as a gathered ``[rb, ...]`` duplicate in HBM, which is what
the gather-then-vmap path pays every step ("Characterizing and
Understanding HGNN Training on GPUs" finds exactly this redundant parameter
movement dominating HGNN kernels; HiHGNN builds on the same reusability).

Three kernels:

  * :func:`stacked_mean_linear_pallas` — the rgcn-family AGG_r: masked-mean
    over the fanout fused with the output projection.  Grid (slot, node
    block, d_out block, d_in chunk); float32 VMEM accumulator across d_in
    chunks; mean is never written to HBM.
  * :func:`stacked_mean_linear_dh_pallas` — the hand-written backward for
    the neighbor activations: ``dh = (g @ w[slot]ᵀ) · mask / cnt``, again
    reading weight blocks via scalar prefetch (no gathered ``wᵀ`` copies).
  * :func:`stacked_softmax_combine_pallas` — the attention-family epilogue
    (rgat/hgt): masked softmax over the fanout fused with the head-wise
    weighted combine, so attention probabilities never round-trip to HBM.
    Logit/value projections stay outside (they carry the module-specific
    einsums and remain under XLA autodiff).

All shapes arrive pre-padded to block multiples (``ops.py`` owns padding
and slicing); fanout ``f`` stays whole — sampled fanouts are 3–25, so the
reduction never crosses blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "stacked_mean_linear_pallas",
    "stacked_mean_linear_dh_pallas",
    "stacked_softmax_combine_pallas",
]


# --------------------------------------------------------------------------
# masked-mean + projection (rgcn family), forward
# --------------------------------------------------------------------------


def _mean_linear_kernel(u_ref, h_ref, m_ref, w_ref, b_ref, out_ref, acc_ref,
                        *, n_chunks: int):
    c = pl.program_id(3)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[0]  # [bn, f, bc]
    m = m_ref[0].astype(h.dtype)  # [bn, f]
    # identical formulation to relmod.masked_mean (operand order included),
    # so the interpret-mode forward is bit-equal to the vmap oracle
    s = jnp.einsum("nfd,nf->nd", h, m)
    cnt = jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0)
    mean = s / cnt
    acc_ref[...] += jax.lax.dot(
        mean.astype(w_ref.dtype), w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(c == n_chunks - 1)
    def _done():
        out_ref[0] = (
            acc_ref[...] + b_ref[0].astype(jnp.float32)[None, :]
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_out", "block_in", "interpret")
)
def stacked_mean_linear_pallas(
    h: jnp.ndarray,  # [rb, n, f, d_in]   (n, d_in pre-padded to blocks)
    mask: jnp.ndarray,  # [rb, n, f]
    w: jnp.ndarray,  # [U, d_in, d_out]
    b: jnp.ndarray,  # [U, d_out]
    slot_u: jnp.ndarray,  # [rb] int32 — slot -> stack row (scalar prefetch)
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    rb, n, f, d_in = h.shape
    d_out = w.shape[2]
    bn, bo, bc = block_n, block_out, block_in
    grid = (rb, pl.cdiv(n, bn), pl.cdiv(d_out, bo), pl.cdiv(d_in, bc))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, f, bc), lambda s, i, o, c, u: (s, i, 0, c)),
            pl.BlockSpec((1, bn, f), lambda s, i, o, c, u: (s, i, 0)),
            pl.BlockSpec((1, bc, bo), lambda s, i, o, c, u: (u[s], c, o)),
            pl.BlockSpec((1, bo), lambda s, i, o, c, u: (u[s], o)),
        ],
        out_specs=pl.BlockSpec((1, bn, bo), lambda s, i, o, c, u: (s, i, o)),
        scratch_shapes=[pltpu.VMEM((bn, bo), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_mean_linear_kernel, n_chunks=grid[3]),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rb, n, d_out), h.dtype),
        interpret=interpret,
    )(slot_u.astype(jnp.int32), h, mask, w, b)


# --------------------------------------------------------------------------
# masked-mean + projection, backward w.r.t. the neighbor activations
# --------------------------------------------------------------------------


def _mean_linear_dh_kernel(u_ref, g_ref, m_ref, w_ref, dh_ref, acc_ref,
                           *, n_chunks: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[0]  # [bn, bk]
    w = w_ref[0]  # [bc, bk]
    # dmean partial: g @ w^T accumulated over d_out chunks
    acc_ref[...] += jax.lax.dot_general(
        g.astype(w.dtype), w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_chunks - 1)
    def _done():
        m = m_ref[0].astype(jnp.float32)  # [bn, f]
        cnt = jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0)
        dmean = acc_ref[...] / cnt  # [bn, bc]
        dh_ref[0] = (dmean[:, None, :] * m[:, :, None]).astype(dh_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_out", "block_in", "interpret")
)
def stacked_mean_linear_dh_pallas(
    g: jnp.ndarray,  # [rb, n, d_out]
    mask: jnp.ndarray,  # [rb, n, f]
    w: jnp.ndarray,  # [U, d_in, d_out]
    slot_u: jnp.ndarray,  # [rb] int32
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    rb, n, d_out = g.shape
    f = mask.shape[2]
    d_in = w.shape[1]
    bn, bo, bc = block_n, block_out, block_in
    grid = (rb, pl.cdiv(n, bn), pl.cdiv(d_in, bc), pl.cdiv(d_out, bo))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bo), lambda s, i, c, k, u: (s, i, k)),
            pl.BlockSpec((1, bn, f), lambda s, i, c, k, u: (s, i, 0)),
            pl.BlockSpec((1, bc, bo), lambda s, i, c, k, u: (u[s], c, k)),
        ],
        out_specs=pl.BlockSpec((1, bn, f, bc), lambda s, i, c, k, u: (s, i, 0, c)),
        scratch_shapes=[pltpu.VMEM((bn, bc), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_mean_linear_dh_kernel, n_chunks=grid[3]),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rb, n, f, d_in), g.dtype),
        interpret=interpret,
    )(slot_u.astype(jnp.int32), g, mask, w)


# --------------------------------------------------------------------------
# masked softmax + head-wise combine (rgat/hgt epilogue)
# --------------------------------------------------------------------------


def _softmax_combine_kernel(e_ref, m_ref, v_ref, out_ref, *, num_heads: int,
                            head_dim: int):
    e = e_ref[0]  # [bn, f, nh]
    m = m_ref[0]  # [bn, f] bool
    v = v_ref[0]  # [bn, f, nh*dh]
    # identical numerics to relmod.masked_softmax
    neg = jnp.asarray(jnp.finfo(e.dtype).min, e.dtype)
    em = jnp.where(m[:, :, None], e, neg)
    em = em - jnp.max(em, axis=1, keepdims=True)
    z = jnp.exp(em) * m[:, :, None].astype(e.dtype)
    alpha = z / jnp.maximum(jnp.sum(z, axis=1, keepdims=True), 1e-9)
    bn, f, nh = alpha.shape
    ar = jnp.broadcast_to(
        alpha[:, :, :, None], (bn, f, nh, head_dim)
    ).reshape(bn, f, nh * head_dim)
    out_ref[0] = jnp.sum(ar * v.astype(ar.dtype), axis=1).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("num_heads", "head_dim", "block_n", "interpret")
)
def stacked_softmax_combine_pallas(
    e: jnp.ndarray,  # [rb, n, f, nh]
    mask: jnp.ndarray,  # [rb, n, f]
    v: jnp.ndarray,  # [rb, n, f, nh*dh]
    num_heads: int,
    head_dim: int,
    block_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    rb, n, f, nh = e.shape
    H = v.shape[3]
    bn = block_n
    grid = (rb, pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(
            _softmax_combine_kernel, num_heads=num_heads, head_dim=head_dim
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, f, nh), lambda s, i: (s, i, 0, 0)),
            pl.BlockSpec((1, bn, f), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, bn, f, H), lambda s, i: (s, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, H), lambda s, i: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((rb, n, H), e.dtype),
        interpret=interpret,
    )(e, mask, v)
