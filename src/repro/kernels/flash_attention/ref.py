"""Pure-jnp oracle for blocked attention (causal / sliding-window / offset)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["attention_ref", "attention_mask"]


def attention_mask(
    sq: int, sk: int, causal: bool, window: Optional[int], q_offset: int
) -> np.ndarray:
    """[sq, sk] bool mask.  Query i sits at global position q_offset + i;
    causal allows keys ≤ that position; a window additionally restricts keys
    to the last ``window`` positions (sliding-window attention)."""
    qpos = np.arange(sq)[:, None] + q_offset
    kpos = np.arange(sk)[None, :]
    m = np.ones((sq, sk), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def attention_ref(
    q: jnp.ndarray,  # [b, h, sq, d]
    k: jnp.ndarray,  # [b, hk, sk, d]
    v: jnp.ndarray,  # [b, hk, sk, d]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    hk = k.shape[1]
    if h != hk:  # GQA: repeat kv heads
        k = jnp.repeat(k, h // hk, axis=1)
        v = jnp.repeat(v, h // hk, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = attention_mask(sq, k.shape[2], causal, window, q_offset)
    logits = jnp.where(mask[None, None], logits, jnp.finfo(logits.dtype).min)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p * mask[None, None]
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
