"""Pallas TPU flash attention (forward): online-softmax blocked attention.

Grid (bh, iq, jk), jk innermost ("arbitrary" — sequential revisit of the
output block).  Per step the [bq, d] query tile attends to a [bk, d]
key/value tile; running max/denominator live in VMEM scratch, so the
[sq, sk] score matrix never exists in HBM — the point of flash attention,
and on TPU the tiles feed the MXU at 128-alignment.

Causal and sliding-window structure is exploited by *skipping whole k
blocks* (pl.when) — for window attention the visited diagonal band makes
compute O(sq·window) instead of O(sq·sk), which is what lets the dense
architectures run the 500k-token decode shape (DESIGN.md §4).

VMEM per step: bq·d + 2·bk·d + bq·bk + 2·bq·128 floats ≈
(128·128 + 2·128·128 + 128·128 + 2·128·128)·4B ≈ 0.4 MB — deep in budget,
so ops.py can raise bq/bk to 256/512 for long sequences.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30
LANES = 128


def _kernel(
    q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: Optional[int], q_offset: int,
    bq: int, bk: int, n_k: int,
):
    jk = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level structure: skip k blocks entirely outside the band
    q_lo = iq * bq + q_offset  # global position of the block's first query
    q_hi = q_lo + bq - 1
    k_lo = jk * bk
    k_hi = k_lo + bk - 1
    live = True
    if causal:
        live = k_lo <= q_hi
    if window is not None:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live if not isinstance(live, bool) else True)
    def _compute():
        q = q_ref[0]  # [bq, d]
        k = k_ref[0]  # [bk, d]
        v = v_ref[0]  # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # masked slots: exp(NEG_INF - m) == 0
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jk == n_k - 1)
    def _done():
        out_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-20)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [bh, sq, d]
    k: jnp.ndarray,  # [bh, sk, d]
    v: jnp.ndarray,  # [bh, sk, d]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    scale = 1.0 / float(np.sqrt(d))
    grid = (bh, pl.cdiv(sq, bq), pl.cdiv(sk, bk))
    return pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale, causal=causal, window=window, q_offset=q_offset,
            bq=bq, bk=bk, n_k=grid[2],
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
