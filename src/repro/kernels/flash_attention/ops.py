"""Public op: flash attention with GQA, padding, and backend dispatch.

``flash_attention(q, k, v, ...)`` takes [b, h, s, d] tensors with possibly
fewer kv heads (GQA), pads sequence lengths to block multiples and dispatches
to the Pallas kernel (interpret mode off-TPU).  Sequence padding requires
causal masking (padded key positions fall strictly after every real query);
non-causal unpadded inputs work too, anything else falls back to the oracle.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention"]


def flash_attention(
    q: jnp.ndarray,  # [b, h, sq, d]
    k: jnp.ndarray,  # [b, hk, sk, d]
    v: jnp.ndarray,  # [b, hk, sk, d]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if not use_pallas:
        return attention_ref(q, k, v, causal, window, q_offset)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, sq, d = q.shape
    hk, sk = k.shape[1], k.shape[2]
    if h != hk:  # GQA -> repeat kv heads (production TPU path folds the
        # group axis into the q block instead; see kernels/README)
        k = jnp.repeat(k, h // hk, axis=1)
        v = jnp.repeat(v, h // hk, axis=1)

    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(sk, 8))
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if (pad_q or pad_k) and not causal:
        return attention_ref(q, k, v, causal, window, q_offset)

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_pallas(
        qf, kf, vf,
        causal=causal, window=window, q_offset=q_offset,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return out[:, :sq].reshape(b, h, sq, d)
