"""Block-size autotuner for the stacked kernel family (DESIGN.md §8).

The stacked ops historically ran every shape with the hardcoded
``DEFAULT_BLOCKS`` (128, 128, 512).  This pass sweeps clamped block
candidates per (op, shape-class, dtype) under the same VMEM budget
formulas the dispatch uses, and caches the winners in a committed JSON
tuning table (``repro/kernels/tuning_table.json``) that
``repro.kernels.ops.resolve_blocks`` consults when ``KernelConfig.autotune``
is on.

Two scoring modes:

  * ``mode="measured"`` — time the real op (compiled Pallas on TPU; the
    interpret-mode emulation elsewhere, useful only for relative grid-step
    overhead).  The real-TPU sweep is the production path; see ROADMAP.
  * ``mode="analytic"`` — a deterministic cost model (grid-step overhead +
    DMA bytes + MXU flops, all pure arithmetic of the shape and blocks).
    This is the **offline mode for CI**: repeat runs produce bit-identical
    tables, so the committed table can be validated and regenerated
    reproducibly on any host.

Table schema (version 1)::

    {"version": 1, "mode": "analytic", "backend": "cpu",
     "budget_bytes": 16777216,
     "entries": {"<op>/<dtype>/n<2^k>/f<f>/di<d>/do<d>":
                 {"block_n": int, "block_out": int, "block_in": int,
                  "source": "analytic" | "measured", "cost_us": float}}}

Regenerate with ``python -m repro.kernels.autotune --out
src/repro/kernels/tuning_table.json``.
"""

from __future__ import annotations

import argparse
import itertools
import json
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.kernels.ops import (
    DEFAULT_BLOCKS,
    TUNING_TABLE_PATH,
    VMEM_BUDGET_BYTES,
    clamp_block,
    load_tuning_table,
    shape_class,
)
from repro.kernels.stacked_relation_agg.ops import (
    stacked_attn_epilogue_vmem_bytes,
    stacked_mean_linear_vmem_bytes,
    stacked_softmax_combine_vmem_bytes,
)

__all__ = [
    "OPS",
    "candidates",
    "analytic_cost_us",
    "measured_cost_us",
    "autotune_op",
    "build_table",
    "save_table",
    "validate_table",
    "DEFAULT_SHAPES",
]

OPS = ("stacked_mean_linear", "stacked_attn_epilogue",
       "stacked_softmax_combine")

# candidate block edges; every tuple is clamped to the shape then deduped
CANDIDATE_BN = (32, 64, 128, 256, 512)
CANDIDATE_BO = (64, 128, 256)
CANDIDATE_BC = (128, 256, 512, 1024)

# deterministic cost-model constants (loosely TPU-shaped; only the *relative*
# ordering of candidates matters, and monotonicity in steps/bytes)
_STEP_US = 1.5  # per-grid-step fixed overhead (DMA setup, loop bookkeeping)
_BYTES_PER_US = 400e3  # ~400 GB/s effective HBM streaming
_FLOPS_PER_US = 100e6  # ~100 TFLOP/s effective MXU fp32


def _vmem_bytes(op: str, n: int, f: int, d_in: int, d_out: int,
                bn: int, bo: int, bc: int) -> int:
    if op == "stacked_mean_linear":
        return stacked_mean_linear_vmem_bytes(
            n, f, d_in, d_out, block_n=bn, block_out=bo, block_in=bc)
    if op == "stacked_attn_epilogue":
        nh, dh = _heads_of(d_out)
        return stacked_attn_epilogue_vmem_bytes(
            n, f, d_in, nh, dh, block_n=bn, block_in=bc, shared_v=False)
    if op == "stacked_softmax_combine":
        nh, dh = _heads_of(d_out)
        return stacked_softmax_combine_vmem_bytes(n, f, nh, dh, block_n=bn)
    raise ValueError(f"unknown autotune op {op!r}; ops: {OPS}")


def _heads_of(d_out: int, head_dim: int = 16) -> Tuple[int, int]:
    """Head split used by the cost/VMEM models — the epilogue working set
    depends only on the product nh*dh, so any consistent split works."""
    dh = min(head_dim, d_out)
    return max(1, d_out // dh), dh


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def candidates(op: str, n: int, f: int, d_in: int,
               d_out: int) -> List[Tuple[int, int, int]]:
    """Clamped, deduped (bn, bo, bc) candidates under the VMEM budget.

    Axes an op does not block over stay at their defaults, so the sweep
    space is the op's real knob set (mean_linear: all three; the fused
    epilogue: bn/bc; softmax_combine: bn only)."""
    bn0, bo0, bc0 = DEFAULT_BLOCKS
    bns: Iterable[int] = CANDIDATE_BN
    bos: Iterable[int] = CANDIDATE_BO if op == "stacked_mean_linear" else (bo0,)
    bcs: Iterable[int] = (
        CANDIDATE_BC if op in ("stacked_mean_linear", "stacked_attn_epilogue")
        else (bc0,)
    )
    seen, out = set(), []
    for bn, bo, bc in itertools.product(bns, bos, bcs):
        key = (clamp_block(bn, n), clamp_block(bo, d_out), clamp_block(bc, d_in))
        if key in seen:
            continue
        seen.add(key)
        if _vmem_bytes(op, n, f, d_in, d_out, *key) <= VMEM_BUDGET_BYTES:
            out.append(key)
    return sorted(out)


def analytic_cost_us(op: str, n: int, f: int, d_in: int, d_out: int,
                     bn: int, bo: int, bc: int,
                     bytes_per_elem: int = 4) -> float:
    """Deterministic per-call cost model: grid-step overhead + streamed
    bytes + MXU flops (pure arithmetic — CI's offline mode).  ``rb`` scales
    every term identically, so it cancels out of the candidate ordering and
    the model uses one slot."""
    if op == "stacked_mean_linear":
        steps = _cdiv(n, bn) * _cdiv(d_out, bo) * _cdiv(d_in, bc)
        step_bytes = (bn * f * bc + bn * f + bc * bo + bo + bn * bo) \
            * bytes_per_elem
        flops = 2 * n * f * d_in + 2 * n * d_in * d_out
    elif op == "stacked_attn_epilogue":
        steps = _cdiv(n, bn) * _cdiv(d_in, bc)
        H = d_out
        step_bytes = (bn * f * bc + bn * f + bn * H + 2 * bc * H + bn * H) \
            * bytes_per_elem
        flops = 2 * 2 * n * f * d_in * H + 4 * n * f * H
    elif op == "stacked_softmax_combine":
        nh, dh = _heads_of(d_out)
        steps = _cdiv(n, bn)
        step_bytes = (bn * f * nh + bn * f + bn * f * d_out + bn * d_out) \
            * bytes_per_elem
        flops = 6 * n * f * d_out
    else:
        raise ValueError(f"unknown autotune op {op!r}; ops: {OPS}")
    return steps * _STEP_US + steps * step_bytes / _BYTES_PER_US \
        + flops / _FLOPS_PER_US


def measured_cost_us(op: str, n: int, f: int, d_in: int, d_out: int,
                     bn: int, bo: int, bc: int, rb: int = 4,
                     repeats: int = 3, interpret: Optional[bool] = None) -> float:
    """Median wall time of the real op at the candidate blocks.

    On TPU this times the compiled kernel (``interpret=None`` auto-selects);
    elsewhere it times the interpret-mode emulation — meaningful only for
    relative grid-step overhead, which is why the committed table ships the
    analytic mode and the TPU sweep is a ROADMAP follow-on."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.stacked_relation_agg.kernel import (
        stacked_attn_epilogue_pallas,
        stacked_mean_linear_pallas,
        stacked_softmax_combine_pallas,
    )
    from repro.kernels.ops import pad_axes, pad_to

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    r = np.random.default_rng(0)
    U = max(2, rb // 2)
    u = jnp.asarray(r.integers(0, U, rb), jnp.int32)
    mask = jnp.asarray(r.random((rb, n, f)) > 0.3)
    if op == "stacked_mean_linear":
        h = jnp.asarray(r.standard_normal((rb, n, f, d_in)), jnp.float32)
        w = jnp.asarray(r.standard_normal((U, d_in, d_out)), jnp.float32)
        b = jnp.zeros((U, d_out), jnp.float32)
        hp = pad_axes(h, {1: bn, 3: bc})
        wp = pad_axes(w, {1: bc, 2: bo})

        def call():
            return stacked_mean_linear_pallas(
                hp, pad_to(mask, 1, bn), wp, pad_to(b, 1, bo), u,
                block_n=bn, block_out=bo, block_in=bc, interpret=interpret)
    elif op == "stacked_attn_epilogue":
        nh, dh = _heads_of(d_out)
        H = nh * dh
        h = jnp.asarray(r.standard_normal((rb, n, f, d_in)), jnp.float32)
        we = jnp.asarray(r.standard_normal((U, d_in, H)) * 0.1, jnp.float32)
        qv = jnp.asarray(r.standard_normal((rb, n, H)), jnp.float32)
        us = jnp.stack([u, u, u])
        hp = pad_axes(h, {1: bn, 3: bc})

        def call():
            return stacked_attn_epilogue_pallas(
                hp, pad_to(mask, 1, bn), pad_to(qv, 1, bn), None,
                pad_to(we, 1, bc), None, None, None, us,
                num_heads=nh, head_dim=dh, block_n=bn, block_in=bc,
                interpret=interpret)
    elif op == "stacked_softmax_combine":
        nh, dh = _heads_of(d_out)
        e = jnp.asarray(r.standard_normal((rb, n, f, nh)), jnp.float32)
        v = jnp.asarray(r.standard_normal((rb, n, f, nh * dh)), jnp.float32)

        def call():
            return stacked_softmax_combine_pallas(
                pad_to(e, 1, bn), pad_to(mask, 1, bn), pad_to(v, 1, bn),
                num_heads=nh, head_dim=dh, block_n=bn, interpret=interpret)
    else:
        raise ValueError(f"unknown autotune op {op!r}; ops: {OPS}")

    jax.block_until_ready(call())  # compile outside the timed region
    best = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        best.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(best))


def autotune_op(op: str, n: int, f: int, d_in: int, d_out: int,
                dtype: str = "float32", mode: str = "analytic",
                **measure_kw) -> Tuple[str, Dict]:
    """Sweep one shape class; returns ``(key, winning entry)``."""
    if mode not in ("analytic", "measured"):
        raise ValueError(f"mode must be analytic|measured, got {mode!r}")
    cost = analytic_cost_us if mode == "analytic" else (
        lambda *a: measured_cost_us(*a, **measure_kw))
    best, best_cost = None, float("inf")
    for bn, bo, bc in candidates(op, n, f, d_in, d_out):
        c = float(cost(op, n, f, d_in, d_out, bn, bo, bc))
        # strict < with sorted candidates: ties break toward smaller blocks,
        # deterministically
        if c < best_cost:
            best, best_cost = (bn, bo, bc), c
    assert best is not None, "no candidate fit the VMEM budget"
    key = shape_class(op, n, f, d_in, d_out, dtype)
    return key, {
        "block_n": best[0], "block_out": best[1], "block_in": best[2],
        "source": mode, "cost_us": round(best_cost, 3),
    }


# mag-shaped workload classes (mirrors benchmarks/kernels_bench.py) plus the
# paper-scale widths the VMEM tests pin down
DEFAULT_SHAPES: Tuple[Tuple[str, int, int, int, int], ...] = (
    ("stacked_mean_linear", 1024, 25, 128, 64),    # mag_l1
    ("stacked_mean_linear", 2048, 20, 64, 64),     # mag_l2_shared
    ("stacked_mean_linear", 4096, 25, 789, 349),   # donor-wide features
    ("stacked_mean_linear", 25600, 25, 1024, 64),  # IGB-HET-scale
    ("stacked_attn_epilogue", 1024, 25, 128, 64),  # mag rgat/hgt l1
    ("stacked_attn_epilogue", 2048, 20, 64, 64),   # mag l2
    ("stacked_attn_epilogue", 25600, 25, 1024, 64),
    ("stacked_softmax_combine", 1024, 25, 4, 64),
    ("stacked_softmax_combine", 2048, 20, 4, 64),
)


def build_table(shapes=DEFAULT_SHAPES, mode: str = "analytic",
                **measure_kw) -> Dict:
    import jax

    entries = {}
    for op, n, f, d_in, d_out in shapes:
        key, entry = autotune_op(op, n, f, d_in, d_out, mode=mode,
                                 **measure_kw)
        entries[key] = entry
    return {
        "version": 1,
        "mode": mode,
        "backend": jax.default_backend() if mode == "measured" else "any",
        "budget_bytes": VMEM_BUDGET_BYTES,
        "entries": dict(sorted(entries.items())),
    }


def save_table(table: Dict, path=None) -> Path:
    p = Path(path) if path else TUNING_TABLE_PATH
    with open(p, "w") as fh:
        json.dump(table, fh, indent=1, sort_keys=True)
        fh.write("\n")
    load_tuning_table.cache_clear()  # dispatch re-reads the new winners
    return p


def validate_table(table: Dict) -> None:
    """Schema check for the committed table (CI gate)."""
    if table.get("version") != 1:
        raise ValueError(f"bad tuning-table version: {table.get('version')!r}")
    entries = table.get("entries")
    if not isinstance(entries, dict):
        raise ValueError("tuning table has no 'entries' dict")
    for key, e in entries.items():
        try:
            op, _, nb, fb, dib, dob = key.split("/")
            n, f = int(nb[1:]), int(fb[1:])
            d_in, d_out = int(dib[2:]), int(dob[2:])
        except ValueError:
            raise ValueError(f"malformed tuning-table key {key!r}") from None
        if op not in OPS:
            raise ValueError(f"entry {key!r}: unknown op {op!r}")
        for field in ("block_n", "block_out", "block_in"):
            v = e.get(field)
            if not isinstance(v, int) or v <= 0:
                raise ValueError(f"entry {key!r}: {field} must be a positive "
                                 f"int, got {v!r}")
        if e.get("source") not in ("analytic", "measured"):
            raise ValueError(f"entry {key!r}: bad source {e.get('source')!r}")
        # winners must respect the same VMEM budget the dispatch enforces
        vb = _vmem_bytes(op, n, f, d_in, d_out,
                         e["block_n"], e["block_out"], e["block_in"])
        budget = table.get("budget_bytes", VMEM_BUDGET_BYTES)
        if vb > budget:
            raise ValueError(
                f"entry {key!r}: blocks need {vb} B of VMEM, over the "
                f"{budget} B budget")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=str(TUNING_TABLE_PATH),
                    help="tuning-table path to write")
    ap.add_argument("--mode", choices=("analytic", "measured"),
                    default="analytic")
    args = ap.parse_args(argv)
    table = build_table(mode=args.mode)
    p = save_table(table, args.out)
    print(f"wrote {len(table['entries'])} entries -> {p}")


if __name__ == "__main__":
    main()
