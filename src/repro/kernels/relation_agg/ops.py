"""Public op: relation aggregation with automatic padding + backend dispatch.

``relation_agg(h, mask, w, b)`` pads n/d_in/d_out up to block multiples,
invokes the Pallas kernel (interpret mode off-TPU), and slices the result.
``use_pallas=False`` falls back to the jnp oracle (same math, used by the
SPMD executors where XLA fusion already handles it well).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.relation_agg.kernel import relation_agg_pallas
from repro.kernels.relation_agg.ref import relation_agg_ref

__all__ = ["relation_agg"]


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def relation_agg(
    h: jnp.ndarray,
    mask: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    use_pallas: bool = True,
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if not use_pallas:
        return relation_agg_ref(h, mask, w, b)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, f, d_in = h.shape
    d_out = w.shape[1]
    bn = min(block_n, max(8, n))
    bo = min(block_out, max(8, d_out))
    bc = min(block_in, max(8, d_in))
    hp = _pad_to(_pad_to(h, 0, bn), 2, bc)
    mp = _pad_to(mask, 0, bn)
    wp = _pad_to(_pad_to(w, 0, bc), 1, bo)
    bp = _pad_to(b, 0, bo)
    out = relation_agg_pallas(
        hp, mp, wp, bp, block_n=bn, block_out=bo, block_in=bc, interpret=interpret
    )
    return out[:n, :d_out]
