"""Public op: relation aggregation with automatic padding + backend dispatch.

``relation_agg(h, mask, w, b)`` pads n/d_in/d_out up to block multiples,
invokes the Pallas kernel (interpret mode must be forced off-TPU), and
slices the result.  ``use_pallas=False`` — or the off-TPU default without a
forced interpret — falls back to the jnp oracle (same math; XLA fusion
already handles the dict-form executors well).

The Pallas path carries a ``jax.custom_vjp``: the backward recomputes the
masked mean and produces ``(dh, dw, db)`` as plain XLA contractions, so the
dict-form RAF executor can *train* through the fused kernel (the stacked
SPMD variant lives in ``repro.kernels.stacked_relation_agg``).

Blocking / padding / backend selection come from the shared
``repro.kernels.ops`` layer; :func:`relation_agg_vmem_bytes` derives the
per-grid-step VMEM working set from the same clamped block parameters the
dispatch uses (consumed by ``benchmarks/kernels_bench.py``).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ops import agg_blocks, agg_vmem_bytes, pad_to, zero_cotangent
from repro.kernels.relation_agg.kernel import relation_agg_pallas
from repro.kernels.relation_agg.ref import relation_agg_ref

__all__ = ["relation_agg", "relation_agg_blocks", "relation_agg_vmem_bytes"]

# blocking + VMEM accounting shared with the stacked family (ops layer)
relation_agg_blocks = agg_blocks
relation_agg_vmem_bytes = agg_vmem_bytes


@dataclasses.dataclass(frozen=True)
class _AggCfg:
    bn: int
    bo: int
    bc: int
    interpret: bool


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _relation_agg_pallas_vjp(cfg: _AggCfg, h, mask, w, b):
    return _pallas_fwd(cfg, h, mask, w, b)


def _pallas_fwd(cfg: _AggCfg, h, mask, w, b):
    n, f, d_in = h.shape
    d_out = w.shape[1]
    hp = pad_to(pad_to(h, 0, cfg.bn), 2, cfg.bc)
    mp = pad_to(mask, 0, cfg.bn)
    wp = pad_to(pad_to(w, 0, cfg.bc), 1, cfg.bo)
    bp = pad_to(b, 0, cfg.bo)
    out = relation_agg_pallas(
        hp, mp, wp, bp,
        block_n=cfg.bn, block_out=cfg.bo, block_in=cfg.bc, interpret=cfg.interpret,
    )
    return out[:n, :d_out]


def _vjp_fwd(cfg, h, mask, w, b):
    return _pallas_fwd(cfg, h, mask, w, b), (h, mask, w)


def _vjp_bwd(cfg, res, g):
    h, mask, w = res
    mw = mask.astype(h.dtype)
    cnt = jnp.maximum(mw.sum(-1, keepdims=True), 1.0)
    mean = jnp.einsum("nfd,nf->nd", h, mw) / cnt
    dmean = g @ w.T  # [n, d_in]
    dh = (dmean / cnt)[:, None, :] * mw[:, :, None]
    dw = mean.T @ g
    db = g.sum(0)
    return dh, zero_cotangent(mask), dw, db


_relation_agg_pallas_vjp.defvjp(_vjp_fwd, _vjp_bwd)


def relation_agg(
    h: jnp.ndarray,
    mask: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    use_pallas: bool = True,
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if not use_pallas:
        return relation_agg_ref(h, mask, w, b)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, f, d_in = h.shape
    bn, bo, bc = relation_agg_blocks(
        n, f, d_in, w.shape[1], block_n, block_out, block_in
    )
    return _relation_agg_pallas_vjp(_AggCfg(bn, bo, bc, bool(interpret)), h, mask, w, b)
