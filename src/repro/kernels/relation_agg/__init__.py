from repro.kernels.relation_agg.ops import (
    relation_agg,
    relation_agg_blocks,
    relation_agg_vmem_bytes,
)
from repro.kernels.relation_agg.ref import relation_agg_ref

__all__ = ["relation_agg", "relation_agg_blocks", "relation_agg_vmem_bytes",
           "relation_agg_ref"]
