from repro.kernels.relation_agg.ops import relation_agg
from repro.kernels.relation_agg.ref import relation_agg_ref

__all__ = ["relation_agg", "relation_agg_ref"]
