"""Pallas TPU kernel: fused masked-mean neighbor aggregation + projection.

The R-GCN relation-specific aggregation (paper Eq. 1) is the compute hot
spot of Heta's per-partition work.  A naive implementation materializes the
masked-mean intermediate [n, d_in] in HBM and then runs a separate matmul;
this kernel keeps the mean in VMEM and feeds the MXU directly:

  grid (i, o, c) over (target blocks, d_out blocks, d_in chunks)

  * the [bn, f, bc] neighbor block is reduced over f on the VPU,
  * the [bn, bc] mean tile multiplies the [bc, bo] weight tile on the MXU,
  * partials accumulate in a float32 VMEM scratch across the c dimension.

Block shapes default to MXU-aligned 128 multiples; the f axis stays whole
(fanouts are small: 4–25) so the reduction never crosses blocks.

HBM→VMEM traffic: h is read once (n·f·d_in), w once per target block,
out written once — the naive two-pass adds a full [n, d_in] HBM write +
read for the intermediate.  VMEM working set per step:
bn·f·bc + bn·f + bc·bo + bn·bo floats ≈ 128·25·512·4B ≈ 6.5 MB < 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["relation_agg_pallas"]


def _kernel(h_ref, mask_ref, w_ref, b_ref, out_ref, acc_ref, *, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...]  # [bn, f, bc]
    m = mask_ref[...].astype(h.dtype)  # [bn, f]
    # Σ_f mask·h as a batched (bn) [1,f]x[f,bc] contraction on the MXU/VPU
    s = jax.lax.dot_general(
        m[:, None, :], h, (((2,), (1,)), ((0,), (0,)))
    )[:, 0, :]  # [bn, bc]
    cnt = jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0)
    mean = s / cnt
    acc_ref[...] += jax.lax.dot(
        mean.astype(w_ref.dtype), w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(c == n_chunks - 1)
    def _done():
        out_ref[...] = (
            acc_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_out", "block_in", "interpret")
)
def relation_agg_pallas(
    h: jnp.ndarray,  # [n, f, d_in]
    mask: jnp.ndarray,  # [n, f]
    w: jnp.ndarray,  # [d_in, d_out]
    b: jnp.ndarray,  # [d_out]
    block_n: int = 128,
    block_out: int = 128,
    block_in: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    n, f, d_in = h.shape
    d_out = w.shape[1]
    bn = min(block_n, n)
    bo = min(block_out, d_out)
    bc = min(block_in, d_in)
    grid = (pl.cdiv(n, bn), pl.cdiv(d_out, bo), pl.cdiv(d_in, bc))

    return pl.pallas_call(
        functools.partial(_kernel, n_chunks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, f, bc), lambda i, o, c: (i, 0, c)),
            pl.BlockSpec((bn, f), lambda i, o, c: (i, 0)),
            pl.BlockSpec((bc, bo), lambda i, o, c: (c, o)),
            pl.BlockSpec((bo,), lambda i, o, c: (o,)),
        ],
        out_specs=pl.BlockSpec((bn, bo), lambda i, o, c: (i, o)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), h.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bo), jnp.float32)],
        interpret=interpret,
    )(h, mask, w, b)
