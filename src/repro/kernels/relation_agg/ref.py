"""Pure-jnp oracle for the fused relation aggregation kernel.

out[n] = ( Σ_f mask[n,f]·h[n,f,:] / max(Σ_f mask[n,f], 1) ) @ w + b

This is AGG_r for R-GCN (paper Eq. 1): masked-mean over the sampled
neighbors followed by the relation-specific projection.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["relation_agg_ref"]


def relation_agg_ref(
    h: jnp.ndarray,  # [n, f, d_in]
    mask: jnp.ndarray,  # [n, f] bool
    w: jnp.ndarray,  # [d_in, d_out]
    b: jnp.ndarray,  # [d_out]
) -> jnp.ndarray:
    mw = mask.astype(h.dtype)
    s = jnp.einsum("nfd,nf->nd", h, mw)
    mean = s / jnp.maximum(mw.sum(-1, keepdims=True), 1.0)
    return mean @ w + b
