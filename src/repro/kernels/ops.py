"""Shared padding / blocking / backend-dispatch layer of the kernel tree.

Every kernel package's public op resolves three questions the same way, so
the answers live here instead of being re-derived per op:

  * **backend selection** — :func:`kernel_choice` maps a
    :class:`KernelOptions`-shaped object (``repro.api.config.KernelConfig``
    satisfies it) to ``(use_pallas, interpret)``.  On TPU the compiled
    kernel is the default; off TPU the Pallas path runs only when
    ``interpret`` is explicitly forced (tests/CI), otherwise the caller's
    jnp oracle is the fallback — interpret mode is a Python emulation and
    must never be silently chosen on a hot path.
  * **block clamping** — :func:`clamp_block` keeps requested MXU-aligned
    block sizes within the actual (possibly tiny) array dims, with the
    ≥8-sublane floor TPU tiling wants.
  * **padding** — :func:`pad_to` / :func:`pad_axes` zero-pad axes up to
    block multiples; callers slice the result back to true shapes.

Keeping this in one place is what lets ``BENCH_kernels.json`` report VMEM
figures derived from the *same* block parameters the dispatch actually
uses (see ``benchmarks/kernels_bench.py``).
"""

from __future__ import annotations

import dataclasses
import functools
import json
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "KernelOptions",
    "kernel_choice",
    "clamp_block",
    "agg_blocks",
    "agg_vmem_bytes",
    "pad_to",
    "pad_axes",
    "zero_cotangent",
    "DEFAULT_BLOCKS",
    "VMEM_BUDGET_BYTES",
    "TUNING_TABLE_PATH",
    "shape_class",
    "load_tuning_table",
    "lookup_blocks",
    "resolve_blocks",
]


@dataclasses.dataclass(frozen=True)
class KernelOptions:
    """Kernel-layer knobs (mirrors ``repro.api.config.KernelConfig`` — any
    object with these attributes works, so the api layer stays jax-free).

    ``interpret``: ``None`` auto-selects (compiled on TPU, jnp fallback
    elsewhere); ``True`` forces Pallas interpret mode (parity tests);
    ``False`` forces the compiled kernel (TPU only — elsewhere it still
    falls back).
    """

    enabled: bool = True
    stacked_agg: bool = True
    relation_agg: bool = True
    gather: bool = True
    interpret: Optional[bool] = None
    # fully fused attention epilogue (stack-streamed projections); off keeps
    # the attn_parts factoring as the oracle path
    fuse_epilogue: bool = True
    # block-size resolution (resolve_blocks): explicit overrides beat the
    # committed tuning table (autotune=True) beat DEFAULT_BLOCKS
    autotune: bool = False
    block_n: Optional[int] = None
    block_out: Optional[int] = None
    block_in: Optional[int] = None


_DEFAULTS = KernelOptions()


def kernel_choice(opts, op: str) -> Tuple[bool, bool]:
    """Resolve ``(use_pallas, interpret)`` for the op toggle named ``op``.

    ``opts`` may be ``None`` (defaults), a :class:`KernelOptions`, or any
    object exposing ``enabled`` / ``interpret`` / per-op boolean attrs.
    """
    if opts is None:
        opts = _DEFAULTS
    if not getattr(opts, "enabled", True) or not getattr(opts, op, True):
        return False, False
    interpret = getattr(opts, "interpret", None)
    if jax.default_backend() == "tpu":
        return True, bool(interpret)
    # off-TPU: Pallas only when interpret is explicitly forced
    if interpret:
        return True, True
    return False, False


def clamp_block(requested: int, size: int, floor: int = 8) -> int:
    """Clamp a requested block edge to the array dim (≥ ``floor`` sublanes)."""
    return min(requested, max(floor, size))


def agg_blocks(
    n: int, f: int, d_in: int, d_out: int,
    block_n: int = 128, block_out: int = 128, block_in: int = 512,
) -> Tuple[int, int, int]:
    """The (bn, bo, bc) block edges the masked-mean+projection dispatches
    (stacked and unstacked) actually use for a shape."""
    return (
        clamp_block(block_n, n),
        clamp_block(block_out, d_out),
        clamp_block(block_in, d_in),
    )


def agg_vmem_bytes(
    n: int, f: int, d_in: int, d_out: int,
    block_n: int = 128, block_out: int = 128, block_in: int = 512,
    bytes_per_elem: int = 4,
) -> int:
    """Static VMEM working set per grid step of the masked-mean+projection
    kernels: h block + mask + weight tile + bias + out tile (input dtype)
    plus the float32 accumulator — one formula, derived from the same
    clamped blocks the dispatch uses, so benchmark VMEM figures can never
    drift from the ops."""
    bn, bo, bc = agg_blocks(n, f, d_in, d_out, block_n, block_out, block_in)
    elems = bn * f * bc + bn * f + bc * bo + bo + bn * bo
    return elems * bytes_per_elem + bn * bo * 4


# --------------------------------------------------------------------------
# block-size resolution: explicit overrides > tuning table > defaults
# --------------------------------------------------------------------------

DEFAULT_BLOCKS = (128, 128, 512)  # (block_n, block_out, block_in)
VMEM_BUDGET_BYTES = 16 * 2**20  # per-grid-step working-set ceiling
TUNING_TABLE_PATH = Path(__file__).parent / "tuning_table.json"
TUNING_TABLE_VERSION = 1


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def shape_class(op: str, n: int, f: int, d_in: int, d_out: int,
                dtype: str = "float32") -> str:
    """Canonical tuning-table key for one (op, shape-class, dtype).

    ``n`` (the minibatch-dependent node count) is bucketed to the next
    power of two so one sweep covers nearby batch sizes; the structural
    dims (fanout, feature widths) are exact."""
    return f"{op}/{dtype}/n{_next_pow2(max(8, n))}/f{f}/di{d_in}/do{d_out}"


@functools.lru_cache(maxsize=None)
def load_tuning_table(path: Optional[str] = None) -> Dict:
    """Load (and cache) a tuning table; missing file -> empty table."""
    p = Path(path) if path else TUNING_TABLE_PATH
    if not p.exists():
        return {"version": TUNING_TABLE_VERSION, "entries": {}}
    with open(p) as fh:
        table = json.load(fh)
    if table.get("version") != TUNING_TABLE_VERSION:
        raise ValueError(
            f"tuning table {p} has version {table.get('version')!r}; "
            f"this build reads version {TUNING_TABLE_VERSION}"
        )
    return table


def lookup_blocks(op: str, n: int, f: int, d_in: int, d_out: int,
                  dtype: str = "float32",
                  path: Optional[str] = None) -> Optional[Tuple[int, int, int]]:
    """Tuning-table winner for a shape class, or ``None`` on a miss."""
    entry = load_tuning_table(path).get("entries", {}).get(
        shape_class(op, n, f, d_in, d_out, dtype))
    if entry is None:
        return None
    bn0, bo0, bc0 = DEFAULT_BLOCKS
    return (int(entry.get("block_n", bn0)), int(entry.get("block_out", bo0)),
            int(entry.get("block_in", bc0)))


def resolve_blocks(opts, op: str, n: int, f: int, d_in: int, d_out: int,
                   path: Optional[str] = None) -> Tuple[int, int, int]:
    """The (block_n, block_out, block_in) a dispatch should use.

    Priority: explicit ``block_*`` overrides on ``opts`` > the committed
    tuning table (when ``opts.autotune``) > :data:`DEFAULT_BLOCKS`.  All
    results still pass through :func:`clamp_block` inside the ops."""
    bn, bo, bc = DEFAULT_BLOCKS
    if opts is not None and getattr(opts, "autotune", False):
        hit = lookup_blocks(op, n, f, d_in, d_out, path=path)
        if hit is not None:
            bn, bo, bc = hit
    if opts is not None:
        bn = getattr(opts, "block_n", None) or bn
        bo = getattr(opts, "block_out", None) or bo
        bc = getattr(opts, "block_in", None) or bc
    return bn, bo, bc


def pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to the next multiple of ``mult``."""
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_axes(x: jnp.ndarray, mults: Dict[int, int]) -> jnp.ndarray:
    """Zero-pad several axes at once: ``{axis: multiple}``."""
    for axis, mult in mults.items():
        x = pad_to(x, axis, mult)
    return x


def zero_cotangent(x):
    """The cotangent custom VJPs must return for bool/int primals (jax's
    ``float0`` convention — mask and index operands of the kernels)."""
    return np.zeros(jnp.shape(x), dtype=jax.dtypes.float0)
