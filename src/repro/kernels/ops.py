"""Shared padding / blocking / backend-dispatch layer of the kernel tree.

Every kernel package's public op resolves three questions the same way, so
the answers live here instead of being re-derived per op:

  * **backend selection** — :func:`kernel_choice` maps a
    :class:`KernelOptions`-shaped object (``repro.api.config.KernelConfig``
    satisfies it) to ``(use_pallas, interpret)``.  On TPU the compiled
    kernel is the default; off TPU the Pallas path runs only when
    ``interpret`` is explicitly forced (tests/CI), otherwise the caller's
    jnp oracle is the fallback — interpret mode is a Python emulation and
    must never be silently chosen on a hot path.
  * **block clamping** — :func:`clamp_block` keeps requested MXU-aligned
    block sizes within the actual (possibly tiny) array dims, with the
    ≥8-sublane floor TPU tiling wants.
  * **padding** — :func:`pad_to` / :func:`pad_axes` zero-pad axes up to
    block multiples; callers slice the result back to true shapes.

Keeping this in one place is what lets ``BENCH_kernels.json`` report VMEM
figures derived from the *same* block parameters the dispatch actually
uses (see ``benchmarks/kernels_bench.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "KernelOptions",
    "kernel_choice",
    "clamp_block",
    "agg_blocks",
    "agg_vmem_bytes",
    "pad_to",
    "pad_axes",
    "zero_cotangent",
]


@dataclasses.dataclass(frozen=True)
class KernelOptions:
    """Kernel-layer knobs (mirrors ``repro.api.config.KernelConfig`` — any
    object with these attributes works, so the api layer stays jax-free).

    ``interpret``: ``None`` auto-selects (compiled on TPU, jnp fallback
    elsewhere); ``True`` forces Pallas interpret mode (parity tests);
    ``False`` forces the compiled kernel (TPU only — elsewhere it still
    falls back).
    """

    enabled: bool = True
    stacked_agg: bool = True
    relation_agg: bool = True
    gather: bool = True
    interpret: Optional[bool] = None


_DEFAULTS = KernelOptions()


def kernel_choice(opts, op: str) -> Tuple[bool, bool]:
    """Resolve ``(use_pallas, interpret)`` for the op toggle named ``op``.

    ``opts`` may be ``None`` (defaults), a :class:`KernelOptions`, or any
    object exposing ``enabled`` / ``interpret`` / per-op boolean attrs.
    """
    if opts is None:
        opts = _DEFAULTS
    if not getattr(opts, "enabled", True) or not getattr(opts, op, True):
        return False, False
    interpret = getattr(opts, "interpret", None)
    if jax.default_backend() == "tpu":
        return True, bool(interpret)
    # off-TPU: Pallas only when interpret is explicitly forced
    if interpret:
        return True, True
    return False, False


def clamp_block(requested: int, size: int, floor: int = 8) -> int:
    """Clamp a requested block edge to the array dim (≥ ``floor`` sublanes)."""
    return min(requested, max(floor, size))


def agg_blocks(
    n: int, f: int, d_in: int, d_out: int,
    block_n: int = 128, block_out: int = 128, block_in: int = 512,
) -> Tuple[int, int, int]:
    """The (bn, bo, bc) block edges the masked-mean+projection dispatches
    (stacked and unstacked) actually use for a shape."""
    return (
        clamp_block(block_n, n),
        clamp_block(block_out, d_out),
        clamp_block(block_in, d_in),
    )


def agg_vmem_bytes(
    n: int, f: int, d_in: int, d_out: int,
    block_n: int = 128, block_out: int = 128, block_in: int = 512,
    bytes_per_elem: int = 4,
) -> int:
    """Static VMEM working set per grid step of the masked-mean+projection
    kernels: h block + mask + weight tile + bias + out tile (input dtype)
    plus the float32 accumulator — one formula, derived from the same
    clamped blocks the dispatch uses, so benchmark VMEM figures can never
    drift from the ops."""
    bn, bo, bc = agg_blocks(n, f, d_in, d_out, block_n, block_out, block_in)
    elems = bn * f * bc + bn * f + bc * bo + bo + bn * bo
    return elems * bytes_per_elem + bn * bo * 4


def pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to the next multiple of ``mult``."""
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_axes(x: jnp.ndarray, mults: Dict[int, int]) -> jnp.ndarray:
    """Zero-pad several axes at once: ``{axis: multiple}``."""
    for axis, mult in mults.items():
        x = pad_to(x, axis, mult)
    return x


def zero_cotangent(x):
    """The cotangent custom VJPs must return for bool/int primals (jax's
    ``float0`` convention — mask and index operands of the kernels)."""
    return np.zeros(jnp.shape(x), dtype=jax.dtypes.float0)
