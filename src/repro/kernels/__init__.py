"""Pallas TPU kernels for the perf-critical compute layers.

  * relation_agg   — fused masked-mean neighbor aggregation + projection
                     (R-GCN AGG_r hotspot, paper Eq. 1)
  * flash_attention — blocked online-softmax attention (R-GAT / LM stack;
                     sliding-window mode enables the 500k decode shape)
  * gather_rows    — scalar-prefetch embedding/feature row gather
                     (cache fetch path, paper §6)

Each package ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with padding + backend dispatch) and ref.py (pure-jnp oracle).
Kernels are validated in interpret mode on CPU; TPU is the target.
"""
