"""Pallas TPU kernels for the perf-critical compute layers.

  * stacked_relation_agg — one level's AGG_r for *all* branch slots in a
                     single call: grid over (slot, node block), per-slot
                     scope indices scalar-prefetched so weight blocks come
                     straight from the [U, ...] stacks (the SPMD executor's
                     default aggregation path, DESIGN.md §8)
  * relation_agg   — unstacked fused masked-mean aggregation + projection
                     (R-GCN AGG_r on the dict-form executors, paper Eq. 1)
  * flash_attention — blocked online-softmax attention (R-GAT / LM stack;
                     sliding-window mode enables the 500k decode shape)
  * gather_rows    — scalar-prefetch embedding/feature row gather
                     (cache fetch path, paper §6)

Each package ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper) and ref.py (pure-jnp oracle); padding, block clamping and backend
selection are shared via ``repro.kernels.ops``.  Backend policy
(``ops.kernel_choice``): compiled Pallas on TPU, the jnp/vmap oracle
elsewhere unless interpret mode is explicitly forced (tests/CI).  Kernels
are validated in interpret mode on CPU; TPU is the target.
"""
