"""Production mesh construction.

Single pod: 256 TPU v5e chips as (data=16, model=16).
Multi-pod:  2 pods × 256 chips as (pod=2, data=16, model=16) — the ``pod``
axis is pure data parallelism (per DESIGN.md §5), so cross-pod traffic is
gradient all-reduce only.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import and only then calls these.
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

__all__ = [
    "make_production_mesh",
    "make_test_mesh",
    "make_abstract_mesh",
    "data_axes",
    "MODEL_AXIS",
]

MODEL_AXIS = "model"


def make_abstract_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Device-free mesh for sharding-rule tables, portable across the
    AbstractMesh signature change (older jax takes ((name, size), ...))."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0) -> Mesh:
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
