"""Serving driver: batched prefill + decode for any assigned architecture.

Production configs are exercised via the 512-device dry-run
(``repro.launch.dryrun``); on a development host this driver runs the
``--reduced`` variant end-to-end with real tensors.

Usage:
  python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 64 --new-tokens 32 [--window 16]
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    # --reduced (default) / --no-reduced: the old store_true-with-default-True
    # made the flag a no-op and left full configs unreachable
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the reduced config (--no-reduced for full size)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window size (0 = full attention)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    import repro.configs.all_archs  # noqa: F401
    from repro.configs.base import ARCHS
    from repro.models import init_decode_cache, init_params, make_prefill_step, make_serve_step

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.is_decoder:
        raise SystemExit(f"{args.arch} is encoder-only (no decode step)")

    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    B, S, N = args.batch, args.prompt_len, args.new_tokens
    window = args.window or None

    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    t0 = time.time()
    if window:
        # window mode: ring-buffer cache; feed the prompt token-by-token
        cache = init_decode_cache(cfg, B, window)
        serve = make_serve_step(cfg, window=window, donate=False)
        logits = None
        for pos in range(S):
            logits, cache = serve(params, cache, prompts[:, pos:pos + 1],
                                  jnp.asarray(pos, jnp.int32))
    else:
        prefill = make_prefill_step(cfg)
        logits, cache = prefill(params, {"tokens": prompts})
        pad = [(0, 0)] * 6
        pad[3] = (0, N)
        if "k" in cache:
            cache["k"] = jnp.pad(cache["k"], pad)
            cache["v"] = jnp.pad(cache["v"], pad)
        serve = make_serve_step(cfg, donate=False)
    jax.block_until_ready(logits)
    print(f"prefill {B}x{S}: {(time.time()-t0)*1e3:.0f} ms")

    token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for pos in range(S, S + N):
        logits, cache = serve(params, cache, token, jnp.asarray(pos, jnp.int32))
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(token)
    dt = time.time() - t0
    print(f"decode {N} tokens: {dt*1e3:.0f} ms ({dt/N*1e3:.1f} ms/token, "
          f"window={window})")


if __name__ == "__main__":
    main()
