"""Serving drivers — the HGNN online-inference tier and the LM workbench.

Two tiers share this entry point:

  * **HGNN tier** (default; ``repro.serve``, DESIGN.md §10): train a
    quickstart-sized session, materialize every node's embedding via
    layer-wise full-graph inference (``Heta.infer_all``), start the
    micro-batching ``EmbeddingServer`` (``Heta.serve``) and drive it with
    concurrent lookup threads — printing p50/p99 latency, QPS and per-type
    cache hit rates.  All ``HetaConfig`` flags apply (``--serve-max-batch``,
    ``--serve-max-wait-ms``, ``--steps``, ``--scale``, ...).

  * **LM workbench** (``--arch NAME``): batched prefill + token-by-token
    decode for an assigned transformer architecture.  Production configs
    are exercised via the 512-device dry-run (``repro.launch.dryrun``); on
    a development host ``--reduced`` (the default) runs a shrunken config
    end-to-end with real tensors.

Usage:
  python -m repro.launch.serve --steps 5 --requests 256 --concurrency 8
  python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 64 --new-tokens 32 [--window 16]
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def _parser() -> argparse.ArgumentParser:
    from repro.api import add_config_args

    ap = argparse.ArgumentParser(
        description="Serving drivers: HGNN online-inference tier (default) "
                    "or the LM decode workbench (--arch).",
    )
    hg = ap.add_argument_group(
        "HGNN tier (default)",
        "layer-wise full-graph inference + micro-batching embedding server; "
        "HetaConfig flags below also apply",
    )
    hg.add_argument("--requests", type=int, default=256,
                    help="lookup requests to fire at the server (default: 256)")
    hg.add_argument("--concurrency", type=int, default=8,
                    help="concurrent client threads (default: 8)")
    hg.add_argument("--ids-per-request", type=int, default=4,
                    help="node ids per lookup (default: 4)")
    hg.add_argument("--max-degree", type=int, default=16,
                    help="cap the synthetic graph's in-degree so full-graph "
                         "inference stays laptop-sized (0 = uncapped)")
    lm = ap.add_argument_group("LM workbench (--arch)")
    lm.add_argument("--arch", default=None,
                    help="run the LM decode workbench for this architecture "
                         "instead of the HGNN tier (e.g. qwen2-1.5b)")
    lm.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="LM workbench only: run the reduced config "
                         "(--no-reduced for full size)")
    lm.add_argument("--batch", type=int, default=4,
                    help="LM workbench only: decode batch size")
    lm.add_argument("--prompt-len", type=int, default=64,
                    help="LM workbench only: prefill prompt length")
    lm.add_argument("--new-tokens", type=int, default=32,
                    help="LM workbench only: tokens to decode")
    lm.add_argument("--window", type=int, default=0,
                    help="LM workbench only: sliding-window size "
                         "(0 = full attention)")
    add_config_args(ap)  # HetaConfig flags (shared --seed, --steps, ...)
    return ap


# --------------------------------------------------------------------------
# HGNN tier
# --------------------------------------------------------------------------


def _serve_hgnn(args) -> None:
    from repro.api import (
        DataConfig, Heta, HetaConfig, ModelConfig, RunConfig,
        config_from_args,
    )
    from repro.serve import bounded_graph

    base = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(4, 4),
                        batch_size=16),
        model=ModelConfig(hidden=32, num_heads=2, learnable_dim=16),
        run=RunConfig(executor="raf_spmd", steps=5),
    )
    cfg = config_from_args(args, base)
    sess = Heta(cfg)
    g = sess.build_graph()
    if args.max_degree:
        g = bounded_graph(g, args.max_degree)
        sess.build_graph(g)
    print(f"graph: {g.name}  nodes={g.total_nodes:,}  edges={g.total_edges:,}")
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    sess.fit()
    print(f"trained {cfg.run.steps} steps "
          f"(loss {sess.losses[-1]:.4f})" if sess.losses else "no training")

    t0 = time.perf_counter()
    store = sess.infer_all()
    print(f"infer_all: {sum(a.shape[0] for a in store.embeddings.values()):,} "
          f"embeddings across {len(store.embeddings)} types "
          f"({store.nbytes / 2**20:.1f} MiB"
          f"{', shm-backed' if store.handle else ''}) "
          f"in {time.perf_counter() - t0:.2f} s")

    server = sess.serve()
    n_target = g.num_nodes[g.target_type]

    def client(k: int) -> None:
        rng = np.random.default_rng(cfg.run.seed + k)
        for _ in range(args.requests // args.concurrency):
            nids = rng.integers(0, n_target, args.ids_per_request)
            server.query(nids)

    threads = [threading.Thread(target=client, args=(k,))
               for k in range(args.concurrency)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    stats = server.stats()
    print(f"served {stats.count} requests in {wall:.2f} s "
          f"({args.concurrency} clients, flush policy: "
          f"max_batch={cfg.serve.max_batch}, "
          f"max_wait_ms={cfg.serve.max_wait_ms})")
    print(stats.render())

    ev = sess.evaluate(num_batches=2, use_full_graph=True)
    print(f"full-graph eval loss: {ev['loss']:.4f}")
    sess.close_serving()


# --------------------------------------------------------------------------
# LM workbench
# --------------------------------------------------------------------------


def _serve_lm(args) -> None:
    import jax
    import jax.numpy as jnp

    import repro.configs.all_archs  # noqa: F401
    from repro.configs.base import ARCHS
    from repro.models import init_decode_cache, init_params, make_prefill_step, make_serve_step

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.is_decoder:
        raise SystemExit(f"{args.arch} is encoder-only (no decode step)")

    seed = args.seed if args.seed is not None else 0
    rng = np.random.default_rng(seed)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    B, S, N = args.batch, args.prompt_len, args.new_tokens
    window = args.window or None

    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    t0 = time.time()
    if window:
        # window mode: ring-buffer cache; feed the prompt token-by-token
        cache = init_decode_cache(cfg, B, window)
        serve = make_serve_step(cfg, window=window, donate=False)
        logits = None
        for pos in range(S):
            logits, cache = serve(params, cache, prompts[:, pos:pos + 1],
                                  jnp.asarray(pos, jnp.int32))
    else:
        prefill = make_prefill_step(cfg)
        logits, cache = prefill(params, {"tokens": prompts})
        pad = [(0, 0)] * 6
        pad[3] = (0, N)
        if "k" in cache:
            cache["k"] = jnp.pad(cache["k"], pad)
            cache["v"] = jnp.pad(cache["v"], pad)
        serve = make_serve_step(cfg, donate=False)
    jax.block_until_ready(logits)
    print(f"prefill {B}x{S}: {(time.time()-t0)*1e3:.0f} ms")

    token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for pos in range(S, S + N):
        logits, cache = serve(params, cache, token, jnp.asarray(pos, jnp.int32))
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(token)
    dt = time.time() - t0
    print(f"decode {N} tokens: {dt*1e3:.0f} ms ({dt/N*1e3:.1f} ms/token, "
          f"window={window})")


def main():
    args = _parser().parse_args()
    if args.arch:
        _serve_lm(args)
    else:
        _serve_hgnn(args)


if __name__ == "__main__":
    main()
