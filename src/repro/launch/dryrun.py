import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination.

The two lines above run before ANY other import (jax locks the device count
on first init).  For each combination this script:

  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. constructs the jitted step (train/prefill/serve) with explicit
     in/out shardings from ``launch.sharding``,
  3. ``.lower()``s against ShapeDtypeStruct inputs (zero allocation),
  4. ``.compile()``s — a sharding mismatch, OOM-at-compile or unsupported
     collective here is a bug in the framework,
  5. records ``memory_analysis()`` / ``cost_analysis()`` and the collective
     mix parsed from the compiled HLO into results/dryrun/*.json for the
     roofline report (EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import json
import re
import time
import traceback
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ARCHS, INPUT_SHAPES, ArchConfig, InputShape
import repro.configs.all_archs  # noqa: F401
from repro.launch.mesh import make_production_mesh, data_axes
from repro.launch.sharding import (
    batch_pspecs,
    cache_pspecs,
    named,
    param_pspecs,
    state_pspecs,
)
from repro.launch.specs import (
    abstract_cache,
    abstract_params,
    abstract_state,
    input_specs,
    plan_step,
)
from repro.optim.adam import AdamConfig

__all__ = ["run_one", "collective_bytes"]


_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(f32|f16|bf16|s32|u32|s8|u8|pred|f64|s64|c64)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    This is the §Roofline ``collective_bytes`` source.  Result-shape bytes
    are the standard proxy: for all-reduce it equals the payload (ring moves
    2·(g-1)/g× that per device), for all-gather it is the gathered size.
    """
    out: Dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] = out.get(op, 0) + nbytes
        out["total"] = out.get("total", 0) + nbytes
        out[f"count_{op}"] = out.get(f"count_{op}", 0) + 1
    return out


def _build_lowered(cfg: ArchConfig, shape: InputShape, mesh, unroll: bool = False, variant: Optional[str] = None):
    """Construct the jitted step + abstract args and lower it."""
    from repro.models import transformer as tfm

    plan = plan_step(cfg, shape)
    specs = input_specs(cfg, shape)
    dp = data_axes(mesh)

    if plan.kind == "skip":
        return None, plan

    pctx = None
    remat = True
    if variant:  # §Perf variant string, e.g. "ep", "act", "q64", "ep,nr"
        from repro.models.transformer import ParallelCtx

        toks = set(variant.split(","))
        kw = {}
        if "ep" in toks:
            kw["moe"] = "expert_parallel"
        if "act" in toks:
            kw["constrain_activations"] = True
        if "sp" in toks:
            kw["sp_attention"] = True
        for t in toks:
            if t.startswith("q") and t[1:].isdigit():
                kw["ssd_chunk"] = int(t[1:])
            if t.startswith("fa") and t[2:].isdigit():
                kw["attn_chunk"] = int(t[2:])
        if "ssdbf16" in toks:
            kw["ssd_bf16"] = True
        if "rp" in toks:
            kw["remat_policy"] = "dots"
        if "nr" in toks:
            remat = False
        pctx = ParallelCtx(mesh=mesh, dp_axes=tuple(dp), **kw)

    if plan.kind == "train":
        state = abstract_state(cfg)
        st_specs = state_pspecs(cfg, state, mesh)
        b_specs = batch_pspecs(cfg, shape, specs, mesh)
        adam = AdamConfig(lr=3e-4, weight_decay=0.01, grad_clip=1.0)

        def step(state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: tfm.loss_fn(cfg, p, batch, use_pallas=False,
                                      unroll=unroll, pctx=pctx, remat=remat)
            )(state["params"])
            from repro.optim.adam import adam_update

            params, opt = adam_update(adam, state["params"], grads, state["opt"])
            return {"params": params, "opt": opt}, loss

        fn = jax.jit(
            step,
            in_shardings=(named(mesh, st_specs), named(mesh, b_specs)),
            out_shardings=(named(mesh, st_specs), None),
            donate_argnums=(0,),
        )
        lowered = fn.lower(state, specs)

    elif plan.kind == "prefill":
        params = abstract_params(cfg)
        p_specs = param_pspecs(cfg, params, mesh)
        b_specs = batch_pspecs(cfg, shape, specs, mesh)
        from repro.models.transformer import make_prefill_step

        raw = make_prefill_step(cfg, use_pallas=False, unroll=unroll, pctx=pctx)
        fn = jax.jit(
            lambda p, b: raw(p, b),
            in_shardings=(named(mesh, p_specs), named(mesh, b_specs)),
        )
        lowered = fn.lower(params, specs)

    else:  # decode
        params = abstract_params(cfg)
        cache = abstract_cache(cfg, shape)
        p_specs = param_pspecs(cfg, params, mesh)
        c_specs = cache_pspecs(cfg, cache, mesh)
        t_specs = {
            "token": batch_pspecs(cfg, shape, {"token": specs["token"]}, mesh)["token"],
            "pos": jax.sharding.PartitionSpec(),
        }
        from repro.models.transformer import make_serve_step

        raw = make_serve_step(cfg, window=plan.window, donate=False, unroll=unroll)
        fn = jax.jit(
            lambda p, c, t, pos: raw(p, c, t, pos),
            in_shardings=(
                named(mesh, p_specs),
                named(mesh, c_specs),
                named(mesh, t_specs["token"]),
                named(mesh, t_specs["pos"]),
            ),
            out_shardings=(None, named(mesh, c_specs)),
        )
        lowered = fn.lower(params, cache, specs["token"], specs["pos"])

    return lowered, plan


def _analyze(cfg, shape, mesh, unroll, variant=None):
    lowered, plan = _build_lowered(cfg, shape, mesh, unroll=unroll, variant=variant)
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    return {
        "plan": plan,
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "flops": cost.get("flops", 0.0) if isinstance(cost, dict) else 0.0,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if isinstance(cost, dict) else 0.0,
        "transcendentals": cost.get("transcendentals", 0.0) if isinstance(cost, dict) else 0.0,
        "collectives": collective_bytes(hlo),
    }


def _extrapolate(r1, r2, n_periods: int):
    """Exact linear-in-layers extrapolation from 1- and 2-period compiles:
    total = f(1) + (n_periods - 1) · (f(2) - f(1)).  Valid because unrolled
    periods are identical HLO; the constant term captures embed/head/loss.

    Tiny decode steps can fuse non-monotonically (f(2) slightly below f(1)
    for some counters); the per-period delta is clamped at ≥0 and the total
    at ≥f(2) so the extrapolation never goes negative."""
    out = {}
    for k in ("flops", "bytes_accessed", "transcendentals"):
        delta = max(r2[k] - r1[k], 0.0)
        out[k] = max(r1[k] + (n_periods - 1) * delta, r2[k])
    coll = {}
    keys = set(r1["collectives"]) | set(r2["collectives"])
    for k in keys:
        a = r1["collectives"].get(k, 0)
        b = r2["collectives"].get(k, 0)
        coll[k] = max(a + (n_periods - 1) * max(b - a, 0), b)
    out["collectives"] = coll
    return out


def run_one(
    arch: str, shape_name: str, multi_pod: bool = False, out_dir: Optional[str] = None,
    variant: Optional[str] = None,
) -> Dict:
    import dataclasses as dc

    cfg = ARCHS[arch]
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if variant:
        mesh_name += f"+{variant}"
    rec: Dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "variant": variant,
        "family": cfg.family,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "n_periods": cfg.n_periods,
    }
    plan = plan_step(cfg, shape)
    if plan.kind == "skip":
        rec.update(status="skip", reason=plan.skip_reason)
        _save(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh:
            # pass A: the FULL config with the compact layer loop — proves the
            # (arch × shape × mesh) combination lowers + compiles, and gives
            # the true memory analysis (all parameters/caches present).
            full = _analyze(cfg, shape, mesh, unroll=False, variant=variant)
            # pass B: 1-period and 2-period fully-unrolled variants; XLA:CPU
            # cost analysis does not multiply while-loop bodies, so per-layer
            # FLOPs/bytes/collectives are extrapolated exactly from these.
            cfg1 = dc.replace(cfg, name=cfg.name + "@1", num_layers=cfg.period)
            cfg2 = dc.replace(cfg, name=cfg.name + "@2", num_layers=2 * cfg.period)
            r1 = _analyze(cfg1, shape, mesh, unroll=True, variant=variant)
            r2 = _analyze(cfg2, shape, mesh, unroll=True, variant=variant)
        ext = _extrapolate(r1, r2, cfg.n_periods)
        rec.update(
            status="ok",
            step_kind=plan.kind,
            window=plan.window,
            total_s=round(time.time() - t0, 2),
            compile_s=full["compile_s"],
            memory=full["memory"],
            flops=ext["flops"],
            bytes_accessed=ext["bytes_accessed"],
            transcendentals=ext["transcendentals"],
            collectives=ext["collectives"],
            loop_collectives=full["collectives"],
            per_period={"flops_delta": r2["flops"] - r1["flops"]},
            num_devices=mesh.size,
        )
    except Exception as e:  # a failure here is a framework bug — surface it
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    _save(rec, out_dir)
    return rec


def _save(rec: Dict, out_dir: Optional[str]):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default=None, help="e.g. 'ep' (expert-parallel MoE)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    combos = []
    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = sorted(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    fails = 0
    for a, s, mp in combos:
        rec = run_one(a, s, multi_pod=mp, out_dir=args.out, variant=args.variant)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (
                f" kind={rec['step_kind']} total={rec['total_s']}s "
                f"compile={rec['compile_s']}s flops={rec.get('flops'):.3e} "
                f"coll={rec['collectives'].get('total', 0)/2**30:.2f}GiB"
            )
        elif status == "error":
            fails += 1
            extra = " " + rec["error"][:160]
        elif status == "skip":
            extra = " " + rec["reason"]
        print(f"[{status:>5}] {a} × {s} × {rec['mesh']}{extra}", flush=True)
    if fails:
        raise SystemExit(f"{fails} combinations failed")


if __name__ == "__main__":
    main()
