"""End-to-end Heta training driver (thin CLI over :mod:`repro.api`).

The full pipeline of the paper (Fig. 5) — synthetic HetG → meta-partitioning
(§5) → hotness + miss-penalty profiling → cache allocation (§6) → RAF
training (§4) — lives behind the :class:`repro.api.Heta` session; this module
keeps the historical entry points:

  * CLI — flags are *derived* from :class:`repro.api.HetaConfig`
    (``add_config_args``), not duplicated here::

      python -m repro.launch.train --dataset ogbn-mag --model rgcn \
          --partitions 4 --steps 100 [--mesh 2x4] [--executor raf_spmd] \
          [--placement naive] [--cache-policy hotness]

  * ``train_hgnn(...)`` — the legacy 18-kwarg programmatic entry, now a
    deprecated thin wrapper over ``Heta(HetaConfig.from_flat_kwargs(...)).run()``.
    Prefer the session API for new code.
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, Optional, Sequence, Tuple

__all__ = ["train_hgnn"]


def train_hgnn(
    dataset: str = "ogbn-mag",
    scale: Optional[float] = None,
    model: str = "rgcn",
    num_partitions: int = 4,
    mesh_shape: Tuple[int, int] = (1, 1),
    batch_size: int = 32,
    fanouts: Sequence[int] = (4, 3),
    hidden: int = 64,
    steps: int = 20,
    lr: float = 5e-3,
    cache_mb: int = 4,
    hotness_only: bool = False,
    naive_placement: bool = False,
    learnable_dim: int = 64,
    seed: int = 0,
    log_every: int = 0,
    executor: str = "raf_spmd",
) -> Dict:
    """Deprecated compatibility wrapper — use :class:`repro.api.Heta`.

    Equivalent to ``Heta(HetaConfig.from_flat_kwargs(**kwargs)).run()`` and
    returns the same result keys as always (``losses``, ``step_time_s``,
    ``setup_s``, ``hit_rates``, ``partitioning``, ``meta_local``,
    ``cache_allocation``).
    """
    from repro.api import Heta, HetaConfig

    cfg = HetaConfig.from_flat_kwargs(
        dataset=dataset, scale=scale, model=model, num_partitions=num_partitions,
        mesh_shape=tuple(mesh_shape), batch_size=batch_size,
        fanouts=tuple(fanouts), hidden=hidden, steps=steps, lr=lr,
        cache_mb=cache_mb, hotness_only=hotness_only,
        naive_placement=naive_placement, learnable_dim=learnable_dim,
        seed=seed, log_every=log_every, executor=executor,
    )
    return Heta(cfg).run()


def main():
    from repro.api import Heta, add_config_args, config_from_args, executors

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_config_args(ap)
    ap.add_argument("--naive", action="store_true",
                    help="legacy alias for --placement naive")
    ap.add_argument("--hotness-only", action="store_true",
                    help="legacy alias for --cache-policy hotness")
    ap.add_argument("--shm-cleanup", action="store_true",
                    help="sweep orphaned /dev/shm graph segments and on-disk "
                         "mmap stores left by crashed runs, then train as "
                         "usual")
    args = ap.parse_args()
    if args.shm_cleanup:
        from repro.graph.mmap_store import cleanup_stale_stores
        from repro.graph.shm import cleanup_stale_segments

        removed = cleanup_stale_segments()
        print(f"shm-cleanup: removed {len(removed)} stale segment(s)"
              + ("".join(f"\n  {n}" for n in removed)))
        reaped = cleanup_stale_stores()
        print(f"shm-cleanup: removed {len(reaped)} stale mmap store(s)"
              + ("".join(f"\n  {n}" for n in reaped)))
    cfg = config_from_args(args)
    if cfg.run.executor not in executors.available():
        ap.error(f"unknown --executor {cfg.run.executor!r}; "
                 f"available: {executors.available()}")
    if args.naive:
        cfg = cfg.updated(partition=dict(placement="naive"))
    if args.hotness_only:
        cfg = cfg.updated(cache=dict(policy="hotness"))
    if args.log_every is None:
        cfg = cfg.updated(run=dict(log_every=1))
    metrics = Heta(cfg).run()
    print(json.dumps({k: v for k, v in metrics.items() if k != "losses"}, indent=1,
                     default=str))
    print(f"final loss: {metrics['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
