"""End-to-end Heta training driver.

Wires the full pipeline of the paper (Fig. 5): synthetic HetG → meta-
partitioning (§5) → pre-sampling hotness + miss-penalty profiling → cache
allocation (§6) → SPMD RAF training (§4) with sparse learnable-feature
updates through the cache.

Usage (CLI):
  python -m repro.launch.train --dataset ogbn-mag --model rgcn \
      --partitions 4 --steps 100 [--mesh 2x4] [--naive] [--no-cache]

The ``train_hgnn`` function is the programmatic entry (used by tests,
benchmarks and examples).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["train_hgnn"]


def train_hgnn(
    dataset: str = "ogbn-mag",
    scale: Optional[float] = None,
    model: str = "rgcn",
    num_partitions: int = 4,
    mesh_shape: Tuple[int, int] = (1, 1),
    batch_size: int = 32,
    fanouts: Sequence[int] = (4, 3),
    hidden: int = 64,
    steps: int = 20,
    lr: float = 5e-3,
    cache_mb: int = 4,
    hotness_only: bool = False,
    naive_placement: bool = False,
    learnable_dim: int = 64,
    seed: int = 0,
    log_every: int = 0,
) -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.core import raf_spmd
    from repro.core.hgnn import HGNNConfig, init_hgnn_params
    from repro.core.meta_partition import meta_partition
    from repro.core.raf import assign_branches, random_branch_assignment
    from repro.embed import EmbedEngine, presample_hotness, profile_miss_penalties
    from repro.graph.sampler import NeighborSampler, SampleSpec
    from repro.graph.synthetic import make_dataset
    from repro.optim.adam import AdamConfig, adam_init

    t0 = time.perf_counter()
    g = make_dataset(dataset, scale=scale, seed=seed)
    k = len(fanouts)

    # §5: meta-partitioning
    mp = meta_partition(g, num_partitions, num_layers=k)
    spec = SampleSpec.from_metatree(mp.metatree, fanouts)
    assignment = (
        random_branch_assignment(spec, num_partitions, seed=seed)
        if naive_placement
        else assign_branches(spec, mp)
    )
    meta_local_prefold = assignment.meta_local
    if assignment.num_partitions != mesh_shape[1]:
        # mesh model axis ≠ partition count: fold partitions onto shards
        # (p % shards) — meta-locality is preserved (see BranchAssignment.fold)
        assignment = assignment.fold(mesh_shape[1], spec)

    # §6: pre-sampling + miss-penalty profiling + cache
    hotness = presample_hotness(g, spec, batch_size, epochs=2, max_batches=20, seed=seed)
    penalties = profile_miss_penalties(g, learnable_dim=learnable_dim, measured=False)
    engine = EmbedEngine(
        g, learnable_dim, hotness, penalties, cache_bytes=cache_mb << 20,
        adam=AdamConfig(lr=lr), hotness_only=hotness_only,
        num_shards=int(np.prod(mesh_shape)), seed=seed,
    )

    # §4: RAF over the (data, model) mesh
    cfg = HGNNConfig(
        model=model, hidden=hidden, num_layers=k, num_classes=g.num_classes,
        learnable_dim=learnable_dim,
    )
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    params = init_hgnn_params(jax.random.PRNGKey(seed), cfg, spec, feat_dims)
    plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    stacks = raf_spmd.shard_stacks(plan, mesh, raf_spmd.stack_params_from_dict(plan, params))
    opt = adam_init(stacks)
    step = raf_spmd.make_train_step(
        plan, mesh, AdamConfig(lr=lr), data_axes=("data",),
        local_combine=not naive_placement, learn_feats=bool(engine.learnable_types),
    )
    setup_s = time.perf_counter() - t0

    sampler = NeighborSampler(g, spec, batch_size, seed=seed + 1)
    losses, step_times = [], []
    it = iter([])
    learnable = set(engine.learnable_types)
    for i in range(steps):
        try:
            batch = next(it)
        except StopIteration:
            it = sampler.epoch(shuffle=True, seed=seed + 2 + i)
            batch = next(it)
        tables = engine.tables_snapshot()
        arrays = raf_spmd.shard_arrays(plan, mesh, raf_spmd.stack_batch(plan, batch, tables))
        t1 = time.perf_counter()
        if engine.learnable_types:
            stacks, opt, loss, gf = step(stacks, opt, arrays)
            _apply_feature_grads(engine, plan, batch, gf, learnable)
        else:
            stacks, opt, loss = step(stacks, opt, arrays)
        loss = float(loss)
        step_times.append(time.perf_counter() - t1)
        losses.append(loss)
        if log_every and i % log_every == 0:
            print(f"step {i:4d} loss {loss:.4f} ({step_times[-1]*1e3:.1f} ms)")

    # exclude jit-compile warmup from the reported step time
    timed = step_times[2:] if len(step_times) > 4 else step_times
    return {
        "losses": losses,
        "step_time_s": float(np.median(timed)),
        "setup_s": setup_s,
        "hit_rates": engine.cache.hit_rates(),
        "partitioning": mp.summary(),
        "meta_local": meta_local_prefold,
        "cache_allocation": dict(engine.allocation.rows),
    }


def _apply_feature_grads(engine, plan, batch, gf: Dict, learnable: set) -> None:
    """Route gradients of the gathered feature arrays back to the learnable
    tables (paper Fig. 3 step 5, via the §6 cache)."""
    import numpy as np

    spec = plan.spec
    k = spec.num_layers
    for d in range(1, k + 1):
        lp = plan.levels[d - 1]
        for key, types, get_ids in (
            (f"hfeat{d}", plan.src_types[d - 1], lambda b: batch.levels[d - 1].nids[b]),
            (
                f"qfeat{d}",
                plan.dst_types[d - 1],
                lambda b: (
                    batch.seeds if d == 1
                    else batch.levels[d - 2].nids[spec.levels[d - 1][b].parent]
                ),
            ),
        ):
            if key not in gf:
                continue
            grad = np.asarray(gf[key])  # [P*rb, N, d_pad]
            grad = grad.reshape(plan.num_shards, lp.rb, *grad.shape[1:])
            per_type: Dict[str, list] = {}
            for p in range(plan.num_shards):
                for s in range(lp.rb):
                    b = lp.slot_branch[p, s]
                    if b < 0:
                        continue
                    t = types[b]
                    if t not in learnable:
                        continue
                    dim = engine.learnable_dim
                    per_type.setdefault(t, []).append(
                        (get_ids(b), grad[p, s][:, :dim])
                    )
            for t, chunks in per_type.items():
                ids = np.concatenate([c[0] for c in chunks])
                gr = np.concatenate([c[1] for c in chunks])
                engine.apply_row_grads(t, ids, gr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ogbn-mag")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--model", default="rgcn", choices=["rgcn", "rgat"])
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--fanouts", default="4,3")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--cache-mb", type=int, default=4)
    ap.add_argument("--naive", action="store_true", help="naive relation placement")
    ap.add_argument("--hotness-only", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    metrics = train_hgnn(
        dataset=args.dataset, scale=args.scale, model=args.model,
        num_partitions=args.partitions, mesh_shape=mesh_shape,
        batch_size=args.batch_size,
        fanouts=tuple(int(f) for f in args.fanouts.split(",")),
        steps=args.steps, cache_mb=args.cache_mb,
        hotness_only=args.hotness_only, naive_placement=args.naive,
        seed=args.seed, log_every=1,
    )
    print(json.dumps({k: v for k, v in metrics.items() if k != "losses"}, indent=1,
                     default=str))
    print(f"final loss: {metrics['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
