"""Abstract input specs for every (architecture × input shape) pair.

``input_specs`` returns ShapeDtypeStructs only (weak-type-correct, shardable,
zero allocation) — the dry-run lowers against these; smoke tests materialize
small real arrays with the same structure.

Shape semantics (assignment brief):
  * train_4k / prefill_32k lower ``train_step`` / ``prefill_step`` on the
    full sequence;
  * decode_32k / long_500k lower ``serve_step`` — ONE token against a cache
    of ``seq_len`` context;
  * encoder-only archs (hubert) have no decode step → decode shapes are
    SKIPPED (reported, not silent);
  * long_500k requires sub-quadratic attention: SSM/hybrid run natively;
    pure-attention archs run the sliding-window variant (window 8192), the
    permitted dense path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape

__all__ = ["StepPlan", "plan_step", "input_specs", "abstract_state", "abstract_cache",
           "DENSE_WINDOW"]

DENSE_WINDOW = 8192  # sliding window for pure-attention archs at 500k context


@dataclasses.dataclass(frozen=True)
class StepPlan:
    kind: str  # train | prefill | decode | skip
    window: Optional[int] = None
    cache_len: int = 0
    skip_reason: str = ""


def plan_step(cfg: ArchConfig, shape: InputShape) -> StepPlan:
    if shape.kind in ("decode",) and not cfg.is_decoder:
        return StepPlan(
            "skip",
            skip_reason=f"{cfg.name} is encoder-only: no decode step (DESIGN.md §4)",
        )
    if shape.kind == "decode":
        window = None
        cache_len = shape.seq_len
        if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
            window = DENSE_WINDOW  # sub-quadratic requirement: sliding window
            cache_len = DENSE_WINDOW
        return StepPlan("decode", window=window, cache_len=cache_len)
    return StepPlan(shape.kind)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict:
    """Batch ShapeDtypeStructs for train/prefill; (token, pos) for decode."""
    B, S = shape.global_batch, shape.seq_len
    plan = plan_step(cfg, shape)
    if plan.kind == "skip":
        return {}
    if plan.kind == "decode":
        return {"token": _sds((B, 1), jnp.int32), "pos": _sds((), jnp.int32)}
    if cfg.frontend == "audio":
        return {
            "frames": _sds((B, S, cfg.frontend_dim), cfg.dtype),
            "labels": _sds((B, S), jnp.int32),
        }
    if cfg.frontend == "vision":
        Pt = cfg.frontend_tokens
        return {
            "tokens": _sds((B, S - Pt), jnp.int32),
            "patch_embeds": _sds((B, Pt, cfg.frontend_dim), cfg.dtype),
            "labels": _sds((B, S - Pt), jnp.int32),
        }
    return {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }


def abstract_state(cfg: ArchConfig):
    """Shape-only train state (params + Adam moments) — no allocation."""
    from repro.models.transformer import init_train_state

    return jax.eval_shape(lambda: init_train_state(cfg, jax.random.PRNGKey(0)))


def abstract_params(cfg: ArchConfig):
    from repro.models.transformer import init_params

    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def abstract_cache(cfg: ArchConfig, shape: InputShape):
    from repro.models.transformer import init_decode_cache

    plan = plan_step(cfg, shape)
    return jax.eval_shape(
        lambda: init_decode_cache(cfg, shape.global_batch, plan.cache_len)
    )
