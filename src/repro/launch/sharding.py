"""Sharding rules: parameter, batch, and cache PartitionSpecs per arch.

Baseline layout (hillclimbs in EXPERIMENTS.md §Perf modify these):

  * batch over (pod, data); sequence unsharded in training.
  * tensor parallelism over "model": attention heads, FFN hidden, vocab.
  * MoE experts over "model" (expert parallelism — the RAF mapping,
    DESIGN.md §4).
  * Mamba heads over "model" (B/C projections replicated; ngroups=1).
  * decode KV caches: batch over (pod, data) when divisible, sequence over
    "model" (and over everything for the batch-1 long-context shape).

Every rule guards on divisibility and falls back to replication — a 512-way
mesh must lower every architecture, including kv-head counts smaller than
the model axis.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import MODEL_AXIS, data_axes

__all__ = [
    "param_pspecs",
    "state_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "named",
]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _shard_if(mesh: Mesh, dim: int, axis) -> Optional[str]:
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def _leaf_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Sharding rule by parameter name (leaf of the params pytree)."""
    m = MODEL_AXIS
    name = path.split("/")[-1]
    none = (None,) * len(shape)

    def spec_at(i: int, axis=m) -> P:
        ax = _shard_if(mesh, shape[i], axis)
        out = list(none)
        out[i] = ax
        return P(*out)

    if name == "embed":
        return spec_at(0)  # vocab-sharded embedding table
    if name == "head":
        return spec_at(1)
    if name in ("final_norm", "frontend_proj"):
        return P(*none)
    # stacked block leaves: leading dims [n_periods, n_slots, ...]
    if name in ("wq", "w1", "w3", "wz", "wx", "wdt", "conv_w"):
        return spec_at(len(shape) - 1)
    if name in ("wk", "wv"):
        return spec_at(len(shape) - 1)
    if name in ("wo", "w2"):
        return spec_at(len(shape) - 2)
    if name in ("bq", "bk", "bv", "conv_b", "gnorm", "dt_bias", "A_log", "D_skip"):
        return spec_at(len(shape) - 1)
    if name == "router":
        return P(*none)
    if name in ("norm", "b"):
        return P(*none)
    if name in ("wB", "wC"):
        return P(*none)  # ngroups=1: B/C shared across heads
    return P(*none)


def _moe_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> Optional[P]:
    """MoE expert stacks [np, ns, E, D, F]: shard the expert axis (RAF-style
    expert parallelism) — takes precedence over the dense w1/w2/w3 rules."""
    if "/moe/" not in path:
        return None
    name = path.split("/")[-1]
    if name in ("w1", "w2", "w3"):
        ax = _shard_if(mesh, shape[2], MODEL_AXIS)
        return P(None, None, ax, None, None)
    if name == "router":
        return P(None, None, None, None)
    if name == "norm":
        return P(None, None, None)
    return None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_pspecs(cfg: ArchConfig, params: Any, mesh: Mesh) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in leaves:
        ps = _path_str(path)
        spec = _moe_spec(ps, leaf.shape, mesh) or _leaf_spec(ps, leaf.shape, mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def state_pspecs(cfg: ArchConfig, state: Any, mesh: Mesh) -> Any:
    """Train state {params, opt{m, v, step}} — optimizer moments shard with
    their parameters (ZeRO-free model parallelism: each shard's optimizer
    slice lives with its weights, as Heta co-locates optimizer states §6)."""
    pspec = param_pspecs(cfg, state["params"], mesh)
    return {
        "params": pspec,
        "opt": {
            "m": pspec,
            "v": pspec,
            "step": P(),
        },
    }


def batch_pspecs(
    cfg: ArchConfig, shape: InputShape, batch: Dict, mesh: Mesh
) -> Dict:
    dp = data_axes(mesh)
    specs = {}
    for k, v in batch.items():
        bdim = v.shape[0]
        ax = dp if bdim % _axis_size(mesh, dp) == 0 else None
        specs[k] = P(ax, *([None] * (len(v.shape) - 1)))
    return specs


def cache_pspecs(cfg: ArchConfig, cache: Dict, mesh: Mesh) -> Dict:
    """Decode caches: [np, ns, B, S, KV, hd] (attn) / [np, ns, B, ...] (ssm)."""
    dp = data_axes(mesh)
    specs = {}
    for k, v in cache.items():
        B = v.shape[2]
        b_ax = dp if B % _axis_size(mesh, dp) == 0 else None
        if k in ("k", "v"):
            S = v.shape[3]
            if b_ax is None:
                # batch-1 long-context: spread the sequence over every axis
                s_ax = ("pod", "data", MODEL_AXIS) if "pod" in mesh.axis_names else ("data", MODEL_AXIS)
                s_ax = s_ax if S % _axis_size(mesh, s_ax) == 0 else _shard_if(mesh, S, MODEL_AXIS)
            else:
                s_ax = _shard_if(mesh, S, MODEL_AXIS)
            specs[k] = P(None, None, b_ax, s_ax, None, None)
        elif k == "ssm":  # [np, ns, B, nh, hp, N]
            h_ax = _shard_if(mesh, v.shape[3], MODEL_AXIS)
            specs[k] = P(None, None, b_ax, h_ax, None, None)
        elif k == "conv":  # [np, ns, B, k-1, di]
            d_ax = _shard_if(mesh, v.shape[4], MODEL_AXIS)
            specs[k] = P(None, None, b_ax, None, d_ax)
        else:
            specs[k] = P(*([None] * len(v.shape)))
    return specs


def named(mesh: Mesh, tree_specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
