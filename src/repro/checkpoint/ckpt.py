"""Sharded checkpointing: flatten a pytree to npz shards + a JSON manifest.

Each host saves the addressable shards of its arrays (single-host here, so
everything), keyed by the pytree path.  Restore rebuilds the tree and
device_puts with the provided shardings.  No external deps (no orbax).

Durability contract (DESIGN.md §12): a checkpoint is *committed* by the
rename of its manifest — the npz payload is written to a temp file and
renamed first, then the manifest (temp + rename) last, so a crash at any
point leaves either a complete (npz, manifest) pair or junk that
:func:`latest_step` ignores.  The manifest records, per array, the shape,
the *logical* dtype (bf16, even though npz stores a ``uint16`` view), the
*stored* dtype, and a sha256 content hash; :func:`load_checkpoint`
verifies all of them and raises :class:`CheckpointError` on any corrupt,
truncated, or manifest-less checkpoint rather than restoring garbage.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import zipfile
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "CheckpointError"]

_MANIFEST_VERSION = 2


class CheckpointError(RuntimeError):
    """A checkpoint is missing, partial, or fails integrity verification."""


def _path_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    """Flatten to {path-key: stored array}; bf16 leaves become uint16 views
    (npz cannot store bf16 — the manifest keeps the logical dtype)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            arr = arr.view(np.uint16)
        flat[_path_key(path)] = arr
    return flat


def _logical_dtypes(tree: Any) -> Dict[str, str]:
    return {
        _path_key(path): str(np.asarray(leaf).dtype)
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save_checkpoint(directory: str, step: int, tree: Any, name: str = "ckpt",
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically write one checkpoint; returns the npz path.

    ``extra`` is a small JSON-able dict stored verbatim in the manifest
    (session metadata: config fingerprint, sampler position, RNG)."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    flat = _flatten(tree)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:  # file object: savez can't mangle the name
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    manifest = {
        "version": _MANIFEST_VERSION,
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": _logical_dtypes(tree),
        "stored_dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "sha256": {k: _sha256(v) for k, v in flat.items()},
        "extra": extra or {},
    }
    mtmp = path + ".json.tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(mtmp, path + ".json")  # the commit point
    return path


def latest_step(directory: str, name: str = "ckpt") -> Optional[int]:
    """The newest *committed* step: an npz whose manifest also exists."""
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.fullmatch(rf"{name}_(\d+)\.npz", f))
        and os.path.exists(os.path.join(directory, f + ".json"))
    ]
    return max(steps) if steps else None


def read_manifest(directory: str, step: int, name: str = "ckpt") -> Dict:
    path = os.path.join(directory, f"{name}_{step:08d}.npz.json")
    if not os.path.exists(path):
        raise CheckpointError(f"checkpoint manifest missing: {path}")
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError) as exc:
        raise CheckpointError(f"unreadable manifest {path}: {exc}") from exc


def load_checkpoint(directory: str, step: int, template: Any,
                    name: str = "ckpt", verify: bool = True) -> Any:
    """Restore into the structure of ``template`` (shapes must match).

    The manifest drives dtype restoration — a bf16 array stored as uint16
    comes back bf16 even when the template leaf has a different dtype —
    and (with ``verify``, the default) every array's sha256 is checked, so
    a torn or bit-rotten payload raises :class:`CheckpointError`."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    manifest = read_manifest(directory, step, name)
    if not os.path.exists(path):
        raise CheckpointError(f"checkpoint payload missing: {path}")
    try:
        data = np.load(path)
    except (OSError, ValueError, zipfile.BadZipFile) as exc:
        raise CheckpointError(f"unreadable checkpoint {path}: {exc}") from exc
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    want = {_path_key(p) for p, _ in leaves_with_path}
    have = set(manifest.get("keys", []))
    if want != have:
        raise CheckpointError(
            f"checkpoint {path} key mismatch: template-only="
            f"{sorted(want - have)[:4]} checkpoint-only={sorted(have - want)[:4]}")
    dtypes = manifest.get("dtypes", {})
    hashes = manifest.get("sha256", {})
    out = []
    for p, leaf in leaves_with_path:
        key = _path_key(p)
        try:
            arr = data[key]
        except KeyError:
            raise CheckpointError(
                f"checkpoint {path} payload missing array {key!r} "
                f"(torn write?)") from None
        except (OSError, ValueError, zipfile.BadZipFile) as exc:
            raise CheckpointError(
                f"checkpoint {path} array {key!r} unreadable: {exc}") from exc
        shape = manifest.get("shapes", {}).get(key)
        if shape is not None and list(arr.shape) != shape:
            raise CheckpointError(
                f"checkpoint {path} array {key!r}: stored shape "
                f"{list(arr.shape)} != manifest {shape}")
        if verify and key in hashes and _sha256(arr) != hashes[key]:
            raise CheckpointError(
                f"checkpoint {path} array {key!r} failed sha256 verification")
        logical = dtypes.get(key)
        if logical == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        elif logical is None and np.asarray(leaf).dtype == jax.numpy.bfloat16:
            # pre-v2 manifest: fall back to the template's dtype
            arr = arr.view(jax.numpy.bfloat16)
        if hasattr(leaf, "sharding"):
            arr = jax.device_put(arr, leaf.sharding)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
