"""Sharded checkpointing: flatten a pytree to npz shards + a JSON manifest.

Each host saves the addressable shards of its arrays (single-host here, so
everything), keyed by the pytree path.  Restore rebuilds the tree and
device_puts with the provided shardings.  No external deps (no orbax).
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:  # npz cannot store bf16
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any, name: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    flat = _flatten(tree)
    np.savez(path, **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def latest_step(directory: str, name: str = "ckpt") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.fullmatch(rf"{name}_(\d+)\.npz", f))
    ]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, template: Any, name: str = "ckpt") -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    data = np.load(path)
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves_with_path:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        if np.asarray(leaf).dtype == jax.numpy.bfloat16:
            arr = arr.view(jax.numpy.bfloat16)
        if hasattr(leaf, "sharding"):
            arr = jax.device_put(arr, leaf.sharding)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
