from repro.checkpoint.ckpt import (CheckpointError, latest_step,
                                   load_checkpoint, read_manifest,
                                   save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "read_manifest", "CheckpointError"]
