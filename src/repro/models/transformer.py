"""Period-structured transformer LM: one implementation, ten architectures.

The layer stack is ``lax.scan`` over *periods* (repeating layer groups, see
``configs/base.py``) with parameters stacked on the period axis — the 72-layer
398B Jamba lowers to the same small HLO as a 2-layer smoke model.  Block
kinds inside a period (attention / Mamba, dense-MLP / MoE) are static Python
structure.

Entry points:
  * ``init_params``      — materialize parameters (smoke tests) or shape-only
                           via ``jax.eval_shape`` (dry-run).
  * ``forward``          — training/prefill forward to logits.
  * ``make_train_step``  — CE loss + AdamW, donate-friendly.
  * ``init_decode_cache``/``make_serve_step`` — single-token decode against
                           KV / SSM caches (sliding-window ring buffer for
                           the 500k dense shape).

Batch dicts by family: decoder LMs take {tokens, labels}; VLM adds
``patch_embeds`` (vision frontend stub); audio takes {frames, labels}
(conv/mel frontend stub) — per the assignment brief, frontends provide
precomputed embeddings and the backbone is real.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import attn_params, attention_block, decode_attention_block
from repro.models.layers import embed_init, he_init, rms_norm
from repro.models.mamba2 import decode_mamba_block, mamba_block, mamba_params
from repro.models.moe import mlp_block, mlp_params, moe_block, moe_block_ep, moe_params
from repro.optim.adam import AdamConfig, adam_init, adam_update

__all__ = [
    "ParallelCtx",
    "init_params",
    "forward",
    "loss_fn",
    "make_train_step",
    "init_train_state",
    "init_decode_cache",
    "make_serve_step",
    "make_prefill_step",
]


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Optional explicit-parallelism context for beyond-GSPMD block variants
    (EXPERIMENTS.md §Perf).  ``moe='expert_parallel'`` switches MoE blocks to
    the shard_map all_to_all implementation (RAF-style expert parallelism)."""

    mesh: object
    dp_axes: tuple
    model_axis: str = "model"
    moe: str = "gspmd"  # gspmd | expert_parallel
    sp_attention: bool = False  # sequence-parallel attention (§Perf)
    attn_chunk: int = 0  # >0: chunked (flash-style) XLA attention (§Perf)
    ssd_chunk: int = 128  # SSD chunk length (memory/compute trade, §Perf)
    ssd_bf16: bool = False  # mixed-precision SSD (§Perf)
    remat_policy: str = "full"  # full | dots | none
    constrain_activations: bool = False  # pin residual stream to P(dp, ...)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _stacked(fn, key: jax.Array, n_periods: int, n_slots: int):
    if n_slots == 0:
        return None
    ks = jax.random.split(key, n_periods * n_slots)
    flat = jax.vmap(fn)(ks)
    return jax.tree.map(
        lambda a: a.reshape((n_periods, n_slots) + a.shape[1:]), flat
    )


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    n_attn = len(cfg.attn_slots)
    n_mamba = len(cfg.mamba_slots)
    n_moe = len(cfg.moe_slots)
    n_mlp = (cfg.period - n_moe) if cfg.d_ff > 0 else 0

    blocks: Dict = {}
    if n_attn:
        blocks["attn"] = _stacked(
            lambda k: attn_params(k, cfg, dtype), ks[0], cfg.n_periods, n_attn
        )
    if n_mamba:
        blocks["mamba"] = _stacked(
            lambda k: mamba_params(k, cfg, dtype), ks[1], cfg.n_periods, n_mamba
        )
    if n_mlp:
        blocks["mlp"] = _stacked(
            lambda k: mlp_params(k, cfg, dtype), ks[2], cfg.n_periods, n_mlp
        )
    if n_moe:
        blocks["moe"] = _stacked(
            lambda k: moe_params(k, cfg, dtype), ks[3], cfg.n_periods, n_moe
        )

    params: Dict = {
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.frontend != "audio":
        params["embed"] = embed_init(ks[4], (cfg.vocab, cfg.d_model), dtype)
    params["head"] = he_init(ks[5], (cfg.d_model, cfg.vocab), dtype, fan_in=cfg.d_model)
    if cfg.frontend:
        params["frontend_proj"] = he_init(
            ks[6], (cfg.frontend_dim, cfg.d_model), dtype, fan_in=cfg.frontend_dim
        )
    return params


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------


def _embed_inputs(cfg: ArchConfig, params: Dict, batch: Dict) -> jnp.ndarray:
    if cfg.frontend == "audio":
        return batch["frames"].astype(jnp.dtype(cfg.dtype)) @ params["frontend_proj"]
    x = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision":
        patches = batch["patch_embeds"].astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([patches, x], axis=1)
    return x


def _period_body(
    cfg: ArchConfig,
    x: jnp.ndarray,
    period: Dict,
    positions: jnp.ndarray,
    window: Optional[int],
    use_pallas: bool,
    pctx: Optional["ParallelCtx"] = None,
) -> jnp.ndarray:
    i = {"attn": 0, "mamba": 0, "mlp": 0, "moe": 0}

    def take(kind):
        p = jax.tree.map(lambda a: a[i[kind]], period[kind])
        i[kind] += 1
        return p

    for slot in range(cfg.period):
        if pctx is not None and pctx.constrain_activations:
            # keep the residual stream batch-sharded: without this GSPMD can
            # lose the batch axis through the layer stack and all-gather full
            # activations (the llava prefill pathology, §Perf)
            from jax.sharding import PartitionSpec as P

            x = jax.lax.with_sharding_constraint(
                x, P(pctx.dp_axes, None, None)
            )
        if slot in cfg.attn_slots:
            x = attention_block(
                take("attn"), cfg, x, positions, window=window,
                use_pallas=use_pallas, pctx=pctx,
            )
        else:
            import jax.numpy as _jnp

            x = mamba_block(
                take("mamba"), cfg, x,
                chunk=pctx.ssd_chunk if pctx is not None else 128,
                compute_dtype=(
                    _jnp.bfloat16
                    if pctx is not None and pctx.ssd_bf16
                    else _jnp.float32
                ),
            )
        if slot in cfg.moe_slots:
            if pctx is not None and pctx.moe == "expert_parallel":
                x = moe_block_ep(
                    take("moe"), cfg, x, pctx.mesh, pctx.dp_axes, pctx.model_axis
                )
            else:
                x = moe_block(take("moe"), cfg, x)
        elif cfg.d_ff > 0:
            x = mlp_block(take("mlp"), cfg, x)
    return x


def forward(
    cfg: ArchConfig,
    params: Dict,
    batch: Dict,
    window: Optional[int] = None,
    use_pallas: bool = False,
    remat: bool = True,
    unroll: bool = False,
    pctx: Optional[ParallelCtx] = None,
) -> jnp.ndarray:
    x = _embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(carry, period):
        out = _period_body(cfg, carry, period, positions, window, use_pallas, pctx)
        return out, None

    if remat:
        policy = None
        if pctx is not None and pctx.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, policy=policy)
    # unroll=True removes the while loop so XLA cost_analysis sees every
    # layer (CPU cost analysis does not multiply loop bodies by trip count);
    # the dry-run/roofline path uses it, training keeps the compact loop.
    x, _ = jax.lax.scan(
        body, x, params["blocks"], unroll=True if unroll else 1
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["head"]


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict, **kw) -> jnp.ndarray:
    logits = forward(cfg, params, batch, **kw)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        logits = logits[:, cfg.frontend_tokens :]  # loss on text positions only
    if cfg.is_decoder and cfg.frontend != "audio":
        logits, labels = logits[:, :-1], labels[:, 1:]  # next-token prediction
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------


def init_train_state(cfg: ArchConfig, key: jax.Array) -> Dict:
    params = init_params(cfg, key)
    return {"params": params, "opt": adam_init(params)}


def make_train_step(
    cfg: ArchConfig,
    adam_cfg: Optional[AdamConfig] = None,
    use_pallas: bool = False,
    donate: bool = True,
):
    adam_cfg = adam_cfg or AdamConfig(lr=3e-4, weight_decay=0.01, grad_clip=1.0)

    def step(state: Dict, batch: Dict) -> Tuple[Dict, jnp.ndarray]:
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, use_pallas=use_pallas)
        )(state["params"])
        params, opt = adam_update(adam_cfg, state["params"], grads, state["opt"])
        return {"params": params, "opt": opt}, loss

    return jax.jit(step, donate_argnums=(0,) if donate else ())


# --------------------------------------------------------------------------
# decode (serve)
# --------------------------------------------------------------------------


def init_decode_cache(
    cfg: ArchConfig,
    batch_size: int,
    cache_len: int,
    dtype=None,
) -> Dict:
    """Allocate the decode cache.  ``cache_len`` is the KV span: full context
    for exact attention, ``window`` for the sliding-window ring buffer."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    np_, B = cfg.n_periods, batch_size
    cache: Dict = {}
    n_attn = len(cfg.attn_slots)
    n_mamba = len(cfg.mamba_slots)
    if n_attn:
        shape = (np_, n_attn, B, cache_len, cfg.num_kv_heads, cfg.hd)
        cache["k"] = jnp.zeros(shape, dtype)
        cache["v"] = jnp.zeros(shape, dtype)
    if n_mamba:
        cache["conv"] = jnp.zeros(
            (np_, n_mamba, B, cfg.ssm_conv - 1, cfg.d_inner), dtype
        )
        cache["ssm"] = jnp.zeros(
            (np_, n_mamba, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        )
    return cache


def _decode_period(
    cfg: ArchConfig,
    x: jnp.ndarray,
    period: Dict,
    cache_slice: Dict,
    pos: jnp.ndarray,
    window: Optional[int],
) -> Tuple[jnp.ndarray, Dict]:
    i = {"attn": 0, "mamba": 0, "mlp": 0, "moe": 0}
    new_cache = {k: [] for k in cache_slice}

    def take(kind):
        p = jax.tree.map(lambda a: a[i[kind]], period[kind])
        return p

    for slot in range(cfg.period):
        if slot in cfg.attn_slots:
            p = take("attn")
            kc = cache_slice["k"][i["attn"]]
            vc = cache_slice["v"][i["attn"]]
            x, kc, vc = decode_attention_block(p, cfg, x, kc, vc, pos, window=window)
            new_cache["k"].append(kc)
            new_cache["v"].append(vc)
            i["attn"] += 1
        else:
            p = take("mamba")
            cs = cache_slice["conv"][i["mamba"]]
            ss = cache_slice["ssm"][i["mamba"]]
            x, cs, ss = decode_mamba_block(p, cfg, x, cs, ss)
            new_cache["conv"].append(cs)
            new_cache["ssm"].append(ss)
            i["mamba"] += 1
        if slot in cfg.moe_slots:
            x = moe_block(take("moe"), cfg, x)
            i["moe"] += 1
        elif cfg.d_ff > 0:
            x = mlp_block(take("mlp"), cfg, x)
            i["mlp"] += 1
    return x, {k: jnp.stack(v) for k, v in new_cache.items()}


def make_serve_step(cfg: ArchConfig, window: Optional[int] = None, donate: bool = True,
                    unroll: bool = False):
    """One-token decode: (params, cache, token [B,1], pos) -> (logits, cache)."""
    if not cfg.is_decoder:
        raise ValueError(f"{cfg.name} is encoder-only; no decode step (DESIGN.md §4)")

    def step(params: Dict, cache: Dict, token: jnp.ndarray, pos: jnp.ndarray):
        x = params["embed"][token]  # [B, 1, D]

        def body(carry, xs):
            period, cache_slice = xs
            out, new_slice = _decode_period(cfg, carry, period, cache_slice, pos, window)
            return out, new_slice

        x, new_cache = jax.lax.scan(
            body, x, (params["blocks"], cache),
            unroll=True if unroll else 1,
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["head"]
        return logits, new_cache

    return jax.jit(step, donate_argnums=(1,) if donate else ())


# --------------------------------------------------------------------------
# prefill: forward + cache construction
# --------------------------------------------------------------------------


def make_prefill_step(cfg: ArchConfig, use_pallas: bool = False, unroll: bool = False,
                      pctx: Optional[ParallelCtx] = None):
    """(params, batch) -> (last-position logits, decode cache)."""
    if not cfg.is_decoder:
        # encoder-only: "prefill" degenerates to a full forward (classification
        # per frame); no cache exists.
        def enc_step(params: Dict, batch: Dict):
            return (
                forward(cfg, params, batch, use_pallas=use_pallas, remat=False,
                        unroll=unroll),
                {},
            )

        return jax.jit(enc_step)

    def step(params: Dict, batch: Dict):
        x = _embed_inputs(cfg, params, batch)
        b, s, _ = x.shape
        positions = jnp.arange(s, dtype=jnp.int32)

        def body(carry, period):
            i = {"attn": 0, "mamba": 0}
            kv, conv, ssm = [], [], []
            x_ = carry
            for slot in range(cfg.period):
                if pctx is not None and pctx.constrain_activations:
                    from jax.sharding import PartitionSpec as P

                    x_ = jax.lax.with_sharding_constraint(
                        x_, P(pctx.dp_axes, None, None)
                    )
                if slot in cfg.attn_slots:
                    p = jax.tree.map(lambda a: a[i["attn"]], period["attn"])
                    x_, (k, v) = attention_block(
                        p, cfg, x_, positions, use_pallas=use_pallas,
                        return_kv=True, pctx=pctx,
                    )
                    kv.append((k, v))
                    i["attn"] += 1
                else:
                    p = jax.tree.map(lambda a: a[i["mamba"]], period["mamba"])
                    x_, st = _mamba_prefill(p, cfg, x_)
                    conv.append(st[0])
                    ssm.append(st[1])
                    i["mamba"] += 1
                if slot in cfg.moe_slots:
                    idx = cfg.moe_slots.index(slot)
                    pm = jax.tree.map(lambda a: a[idx], period["moe"])
                    if pctx is not None and pctx.moe == "expert_parallel":
                        x_ = moe_block_ep(
                            pm, cfg, x_, pctx.mesh, pctx.dp_axes, pctx.model_axis
                        )
                    else:
                        x_ = moe_block(pm, cfg, x_)
                elif cfg.d_ff > 0:
                    mlp_idx = [t for t in range(cfg.period) if t not in cfg.moe_slots].index(slot)
                    x_ = mlp_block(jax.tree.map(lambda a: a[mlp_idx], period["mlp"]), cfg, x_)
            out_cache = {}
            if kv:
                out_cache["k"] = jnp.stack([k for k, _ in kv])
                out_cache["v"] = jnp.stack([v for _, v in kv])
            if conv:
                out_cache["conv"] = jnp.stack(conv)
                out_cache["ssm"] = jnp.stack(ssm)
            return x_, out_cache

        x, cache = jax.lax.scan(
            body, x, params["blocks"], unroll=True if unroll else 1
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, -1:] @ params["head"]
        return logits, cache

    return jax.jit(step)


def _mamba_prefill(p: Dict, cfg: ArchConfig, x: jnp.ndarray):
    """Mamba block that also returns (conv_state, final ssm state)."""
    from repro.models.mamba2 import _causal_conv, _ssd_chunked  # internals

    b, s, D = x.shape
    di, nh, hp, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    z = h @ p["wz"]
    xproj = h @ p["wx"]
    xin = jax.nn.silu(_causal_conv(xproj, p["conv_w"], p["conv_b"]))
    B_ = h @ p["wB"]
    C_ = h @ p["wC"]
    dt = jax.nn.softplus((h @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(b, s, nh, hp)
    y, H = _ssd_chunked(xh, dt, A, B_, C_, return_state=True)
    y = y + (p["D_skip"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, s, di)
    y = rms_norm(y, p["gnorm"], cfg.norm_eps) * jax.nn.silu(z)
    conv_state = xproj[:, -(cfg.ssm_conv - 1) :, :]
    return x + y @ p["wo"], (conv_state, H)
