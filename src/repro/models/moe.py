"""Mixture-of-Experts MLP: top-k routing with capacity-based dispatch.

Baseline formulation (GSPMD-partitionable): tokens rank themselves into
per-expert capacity slots via a cumulative-sum over the top-k assignment
mask, are gathered into [E, C, D] expert batches, run the gated-SiLU expert
FFN as a batched einsum with the expert axis sharded over ``"model"``, and
are combined back with their router weights.  FLOPs are proportional to
*active* parameters (top-k · capacity_factor), not total experts.

This is structurally Heta's RAF paradigm (DESIGN.md §4): experts ≡
relations, the per-expert FFN ≡ relation-specific aggregation computed where
its parameters live, and the weighted combine ≡ the cross-relation
aggregation; the token movement is the partial-aggregation exchange.

An explicit shard_map expert-parallel variant (all_to_all token exchange) is
the §Perf hillclimb; see ``moe_shard_map`` below.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import he_init, rms_norm

__all__ = ["moe_params", "moe_block", "mlp_params", "mlp_block", "router_stats"]


def mlp_params(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w1": he_init(ks[0], (D, F), dtype, fan_in=D),
        "w3": he_init(ks[1], (D, F), dtype, fan_in=D),
        "w2": he_init(ks[2], (F, D), dtype, fan_in=F),
        "norm": jnp.ones((D,), dtype),
    }


def mlp_block(p: Dict, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    return x + (jax.nn.silu(h @ p["w1"]) * (h @ p["w3"])) @ p["w2"]


def moe_params(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    D, E, F = cfg.d_model, cfg.moe_experts, cfg.expert_ff
    ks = jax.random.split(key, 4)
    return {
        "router": he_init(ks[0], (D, E), jnp.float32, fan_in=D),
        "w1": he_init(ks[1], (E, D, F), dtype, fan_in=D),
        "w3": he_init(ks[2], (E, D, F), dtype, fan_in=D),
        "w2": he_init(ks[3], (E, F, D), dtype, fan_in=F),
        "norm": jnp.ones((D,), dtype),
    }


def _route(cfg: ArchConfig, h: jnp.ndarray, router: jnp.ndarray):
    """Top-k routing.  h [T, D] -> (expert_idx [T, k], weights [T, k], probs)."""
    logits = h.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    weights, idx = jax.lax.top_k(probs, cfg.moe_topk)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return idx, weights, probs


def _capacity(cfg: ArchConfig, T: int) -> int:
    c = int(T * cfg.moe_topk * cfg.capacity_factor / cfg.moe_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_block(
    p: Dict, cfg: ArchConfig, x: jnp.ndarray, return_aux: bool = False
):
    """x [b, s, D] -> [b, s, D] with top-k expert FFNs (dropping at capacity)."""
    b, s, D = x.shape
    T = b * s
    E, K = cfg.moe_experts, cfg.moe_topk
    C = _capacity(cfg, T)
    h = rms_norm(x, p["norm"], cfg.norm_eps).reshape(T, D)

    idx, weights, probs = _route(cfg, h, p["router"])  # [T, K]

    # position of each (token, k) within its expert's capacity
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # [T, K, E]
    flat = onehot.reshape(T * K, E)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat  # rank among same-expert picks
    pos = (pos_in_e * flat).sum(-1).reshape(T, K)  # [T, K]
    keep = pos < C

    # scatter token ids into [E, C] slots (dropped tokens never land)
    slot_e = idx.reshape(-1)  # [T*K]
    slot_c = pos.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), K)
    ok = keep.reshape(-1)
    slot_c = jnp.where(ok, slot_c, C)  # overflow bucket, sliced off
    gather_idx = jnp.zeros((E, C + 1), jnp.int32).at[slot_e, slot_c].set(
        tok.astype(jnp.int32), mode="drop"
    )[:, :C]
    slot_used = jnp.zeros((E, C + 1), jnp.bool_).at[slot_e, slot_c].set(
        ok, mode="drop"
    )[:, :C]

    xe = h[gather_idx] * slot_used[..., None].astype(h.dtype)  # [E, C, D]
    act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w3"]
    )
    ye = jnp.einsum("ecf,efd->ecd", act, p["w2"])  # [E, C, D]

    # combine: scatter-add expert outputs back to tokens, weighted
    w_slot = jnp.zeros((E, C + 1), jnp.float32).at[slot_e, slot_c].set(
        weights.reshape(-1), mode="drop"
    )[:, :C]
    contrib = ye * w_slot[..., None].astype(ye.dtype)
    out = jnp.zeros((T, D), ye.dtype).at[gather_idx.reshape(-1)].add(
        contrib.reshape(E * C, D)
    )
    y = x + out.reshape(b, s, D)
    if return_aux:
        # load-balance auxiliaries (Switch-style): fraction per expert
        me = probs.mean(0)
        ce = jax.nn.one_hot(idx[:, 0], E).mean(0)
        aux = E * jnp.sum(me * ce)
        return y, {"aux_loss": aux, "dropped": 1.0 - slot_used.mean()}
    return y


# --------------------------------------------------------------------------
# expert-parallel MoE (the §Perf hillclimb; RAF applied to experts)
# --------------------------------------------------------------------------


def moe_block_ep(
    p: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,
    mesh,
    dp_axes,
    model_axis: str = "model",
) -> jnp.ndarray:
    """Expert-parallel MoE via shard_map + all_to_all — Heta's RAF paradigm
    applied to experts (DESIGN.md §4): each model shard owns E/MP experts'
    parameters, tokens are routed *locally per shard* (capacity from local
    token counts, not global), dispatched expert-major by one all_to_all,
    transformed where their expert's weights live, and returned by a second
    all_to_all.

    vs the GSPMD baseline (``moe_block``): the baseline's routing tensors are
    data-dependent gathers over the *global* token axis, which GSPMD cannot
    shard — every device materializes and multiplies the full [E, C_global,
    D] expert batch.  Here per-device dispatch work is T/(DP·MP)·k·cf rows —
    proportional to *active* parameters (measured in EXPERIMENTS.md §Perf).

    x enters sharded [batch→dp, seq→model]; the surrounding attention blocks
    re-gather the sequence axis as needed (GSPMD inserts the collectives).
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map_nocheck

    E, K = cfg.moe_experts, cfg.moe_topk
    mp = mesh.shape[model_axis]
    assert E % mp == 0, (E, mp)

    def body(w1, w3, w2, router, norm_w, xs):
        b, s, D = xs.shape
        T = b * s
        C = _capacity(cfg, T)
        h = rms_norm(xs, norm_w, cfg.norm_eps).reshape(T, D)
        idx, weights, _ = _route(cfg, h, router)

        onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)
        flat = onehot.reshape(T * K, E)
        pos_in_e = jnp.cumsum(flat, axis=0) - flat
        pos = (pos_in_e * flat).sum(-1).reshape(T, K)
        keep = pos < C
        slot_e = idx.reshape(-1)
        slot_c = jnp.where(keep.reshape(-1), pos.reshape(-1), C)
        tok = jnp.repeat(jnp.arange(T), K)
        gather_idx = jnp.zeros((E, C + 1), jnp.int32).at[slot_e, slot_c].set(
            tok.astype(jnp.int32), mode="drop")[:, :C]
        slot_used = jnp.zeros((E, C + 1), jnp.bool_).at[slot_e, slot_c].set(
            keep.reshape(-1), mode="drop")[:, :C]
        w_slot = jnp.zeros((E, C + 1), jnp.float32).at[slot_e, slot_c].set(
            weights.reshape(-1), mode="drop")[:, :C]

        xe = h[gather_idx] * slot_used[..., None].astype(h.dtype)  # [E, C, D]
        # dispatch: expert-major exchange (RAF: compute where the params live)
        xe = jax.lax.all_to_all(xe, model_axis, split_axis=0, concat_axis=1,
                                tiled=True)  # [E/mp, C·mp, D]
        act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w1)) * jnp.einsum(
            "ecd,edf->ecf", xe, w3)
        ye = jnp.einsum("ecf,efd->ecd", act, w2)  # [E/mp, C·mp, D]
        # return partial results to the token owners
        ye = jax.lax.all_to_all(ye, model_axis, split_axis=1, concat_axis=0,
                                tiled=True)  # [E, C, D]
        contrib = ye * w_slot[..., None].astype(ye.dtype)
        out = jnp.zeros((T, D), ye.dtype).at[gather_idx.reshape(-1)].add(
            contrib.reshape(E * C, D))
        return xs + out.reshape(b, s, D)

    return shard_map_nocheck(
        body,
        mesh=mesh,
        in_specs=(
            P(model_axis, None, None),  # w1 [E, D, F] — expert-sharded
            P(model_axis, None, None),  # w3
            P(model_axis, None, None),  # w2
            P(None, None),  # router (replicated)
            P(None),  # norm
            P(dp_axes, model_axis, None),  # x: batch→dp, seq→model
        ),
        out_specs=P(dp_axes, model_axis, None),
    )(p["w1"], p["w3"], p["w2"], p["router"], p["norm"], x)


def router_stats(cfg: ArchConfig, p: Dict, x: jnp.ndarray) -> Dict:
    b, s, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps).reshape(b * s, D)
    idx, w, probs = _route(cfg, h, p["router"])
    counts = jnp.zeros(cfg.moe_experts).at[idx.reshape(-1)].add(1.0)
    return {"expert_load": counts / counts.sum(), "entropy": -(probs * jnp.log(probs + 1e-9)).sum(-1).mean()}
