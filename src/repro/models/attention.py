"""GQA attention: training/prefill (full-sequence) and cached decode.

Two execution paths:

  * ``use_pallas=True`` — the flash-attention Pallas kernel (interpret mode
    on CPU); used by the smoke tests and the TPU production path.
  * ``use_pallas=False`` — pure-XLA einsum attention; used by the dry-run so
    GSPMD can partition it (Pallas interpret mode is not partitionable), and
    as a numerically identical fallback.

Decode attends one new token against a KV cache laid out [B, S, KV, hd];
``long_500k`` shards the cache's sequence axis, and the baseline lets GSPMD
insert the collectives (§Perf hillclimbs this with a manual flash-decode).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, he_init, rms_norm

__all__ = ["attn_params", "attention_block", "decode_attention_block"]


def attn_params(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 5)
    p = {
        "wq": he_init(ks[0], (D, H * hd), dtype, fan_in=D),
        "wk": he_init(ks[1], (D, KV * hd), dtype, fan_in=D),
        "wv": he_init(ks[2], (D, KV * hd), dtype, fan_in=D),
        "wo": he_init(ks[3], (H * hd, D), dtype, fan_in=H * hd),
        "norm": jnp.ones((D,), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _qkv(p: Dict, cfg: ArchConfig, x: jnp.ndarray, positions) -> Tuple:
    b, s, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, H, hd)
    k = k.reshape(b, s, KV, hd)
    v = v.reshape(b, s, KV, hd)
    if cfg.causal or cfg.rope_fraction > 0:
        # encoder-only (hubert) uses learned-free sinusoid-free attention;
        # we still apply RoPE for positional structure unless disabled
        q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
    return q, k, v


def _xla_attention(
    q, k, v, causal: bool, window: Optional[int], q_offset: int = 0,
    kv_len_mask: Optional[jnp.ndarray] = None,
):
    """einsum attention; [b, s, h, hd] layout, GQA via head grouping."""
    b, sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(b, sq, KV, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits *= 1.0 / np.sqrt(hd)
    sk = k.shape[1]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len_mask is not None:  # [b, sk] valid-cache mask for decode
        mask = mask[None] & kv_len_mask[:, None, :]
        mask = mask[:, None, None]
    else:
        mask = mask[None, None, None]
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, H, hd)


def _xla_attention_chunked(
    q, k, v, causal: bool, window: Optional[int], chunk: int = 4096,
    unroll: bool = True,
):
    """Flash-attention expressed in jnp: scan over key chunks with an online
    softmax so the [sq, sk] score matrix never materializes in HBM.  This is
    the XLA-partitionable stand-in for the Pallas kernel (which is the TPU
    production path but cannot be SPMD-partitioned in interpret mode); it
    cuts the attention memory term by ~sk/chunk (EXPERIMENTS.md §Perf).

    ``unroll=True`` keeps the chunk loop out of a while op so the dry-run's
    cost analysis sees every chunk.
    """
    b, sq, H, hd = q.shape
    KV, sk = k.shape[2], k.shape[1]
    g = H // KV
    ck = min(chunk, sk)
    n = sk // ck
    if sk % ck:
        return _xla_attention(q, k, v, causal, window)
    qg = q.reshape(b, sq, KV, g, hd)
    scale = 1.0 / np.sqrt(hd)
    kc = k.reshape(b, n, ck, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n, ck, KV, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, j = inp
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb).astype(jnp.float32)
        logits = logits * scale
        kpos = j * ck + jnp.arange(ck)
        mask = jnp.ones((sq, ck), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        palpha = jnp.exp(m - m_new)
        probs = jnp.exp(logits - m_new[..., None])
        l_new = palpha * l + probs.sum(-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", probs.astype(vb.dtype), vb)
        acc_new = acc * palpha[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, KV, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, KV, g, sq), jnp.float32)
    a0 = jnp.zeros((b, KV, g, sq, hd), v.dtype)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n)), unroll=True if unroll else 1
    )
    out = acc / jnp.maximum(l, 1e-20)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, H, hd)


def attention_block(
    p: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,  # [b, s, D]
    positions: jnp.ndarray,
    window: Optional[int] = None,
    use_pallas: bool = False,
    return_kv: bool = False,
    pctx=None,
):
    """Pre-norm attention block with residual (training / prefill).

    ``pctx.sp_attention`` switches to *sequence-parallel attention*: queries
    are sharded along the sequence over the model axis and the (small, GQA)
    K/V are replicated across it.  This avoids the pathological score-matrix
    all-reduce GSPMD emits when the head count does not divide the model axis
    (llava's 56 heads on a 16-wide axis — EXPERIMENTS.md §Perf): per-layer
    wire cost becomes one K/V broadcast + one activation gather instead of an
    O(S²) score reduction.
    """
    b, s, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h, positions)
    if pctx is not None and getattr(pctx, "sp_attention", False):
        from jax.sharding import PartitionSpec as P

        dp, ma = pctx.dp_axes, pctx.model_axis
        q = jax.lax.with_sharding_constraint(q, P(dp, ma, None, None))
        k = jax.lax.with_sharding_constraint(k, P(dp, None, None, None))
        v = jax.lax.with_sharding_constraint(v, P(dp, None, None, None))
    if use_pallas:
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=cfg.causal, window=window,
        ).transpose(0, 2, 1, 3)
    elif pctx is not None and getattr(pctx, "attn_chunk", 0):
        out = _xla_attention_chunked(
            q, k, v, cfg.causal, window, chunk=pctx.attn_chunk
        )
    else:
        out = _xla_attention(q, k, v, cfg.causal, window)
    out = out.reshape(b, s, cfg.num_heads * cfg.hd) @ p["wo"]
    y = x + out
    if return_kv:
        return y, (k, v)
    return y


def decode_attention_block(
    p: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,  # [b, 1, D]
    k_cache: jnp.ndarray,  # [b, S, KV, hd]
    v_cache: jnp.ndarray,  # [b, S, KV, hd]
    pos: jnp.ndarray,  # scalar int32: index of the new token
    window: Optional[int] = None,
):
    """One-token cached decode.  Returns (y, new_k_cache, new_v_cache).

    With a sliding window the cache is ring-buffered at ``window`` slots, so
    the 500k-context shape holds O(window) state for dense architectures
    (DESIGN.md §4); otherwise the cache holds the full sequence.
    """
    b, _, D = x.shape
    S = k_cache.shape[1]
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    positions = jnp.broadcast_to(pos[None], (b, 1))
    q, k, v = _qkv(p, cfg, h, positions)
    slot = (pos % S) if window else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    kpos = jnp.arange(S)[None, :]
    if window:
        # ring buffer: slot i currently holds position p_i ≡ i (mod S), the
        # latest such position ≤ pos
        offset = pos - slot
        real_pos = jnp.where(kpos <= slot, kpos + offset, kpos + offset - S)
        valid = (real_pos >= 0) & (real_pos <= pos) & (real_pos > pos - window)
    else:
        valid = kpos <= pos
    valid = jnp.broadcast_to(valid, (b, S))
    out = _xla_attention(q, k_cache, v_cache, False, None, kv_len_mask=valid)
    out = out.reshape(b, 1, cfg.num_heads * cfg.hd) @ p["wo"]
    return x + out, k_cache, v_cache
