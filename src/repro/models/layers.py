"""Shared transformer layers: RMSNorm, RoPE variants, init helpers."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "apply_rope", "rope_frequencies", "he_init", "embed_init"]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_frequencies(head_dim: int, fraction: float, theta: float) -> int:
    """Number of head dims that get rotated (even).  ``fraction=0.5`` is the
    ChatGLM '2d RoPE': only the first half of each head rotates."""
    rot = int(head_dim * fraction)
    return rot - (rot % 2)


def apply_rope(
    x: jnp.ndarray,  # [b, s, h, hd]
    positions: jnp.ndarray,  # [b, s] or [s]
    fraction: float = 1.0,
    theta: float = 500_000.0,
) -> jnp.ndarray:
    b, s, h, hd = x.shape
    rot = rope_frequencies(hd, fraction, theta)
    if rot == 0:
        return x
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (b, s))
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [b, s, half]
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., :half], xr[..., half:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated, xp], axis=-1)


def he_init(key: jax.Array, shape: Tuple[int, ...], dtype, fan_in: Optional[int] = None):
    fan = fan_in or shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / np.sqrt(fan)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...], dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
