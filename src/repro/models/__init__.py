from repro.models.transformer import (
    forward,
    init_decode_cache,
    init_params,
    init_train_state,
    loss_fn,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "forward",
    "init_decode_cache",
    "init_params",
    "init_train_state",
    "loss_fn",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
]
