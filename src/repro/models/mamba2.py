"""Mamba-2 block: chunked SSD (state-space duality) + recurrent decode.

Training/prefill uses the SSD chunked algorithm [arXiv:2405.21060]: the
sequence is split into chunks; within a chunk the recurrence is evaluated as
a masked, decay-weighted attention-like quadratic form (MXU-friendly), and
chunk-crossing state is carried by a short ``lax.scan`` over chunks:

    h_t = exp(dt_t A) h_{t-1} + dt_t · x_t ⊗ B_t          (per head, [hp, N])
    y_t = C_t · h_t + D ⊙ x_t

Decode is the O(1) recurrence on a cached state.  Heads are the model-
parallel axis (DESIGN.md §4: the technique itself is inapplicable to the
scan — there is no relation decomposition — so the arch runs *without* it,
with heads sharded over ``"model"`` and sequence/batch over data axes).

Simplifications vs the reference implementation (documented): ngroups=1
(B/C shared across heads), depthwise conv applied to x only, no bias on
projections.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import he_init, rms_norm

__all__ = ["mamba_params", "mamba_block", "decode_mamba_block"]


def mamba_params(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    D, di, nh, N = cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    ks = jax.random.split(key, 8)
    return {
        "wz": he_init(ks[0], (D, di), dtype, fan_in=D),
        "wx": he_init(ks[1], (D, di), dtype, fan_in=D),
        "wB": he_init(ks[2], (D, N), dtype, fan_in=D),
        "wC": he_init(ks[3], (D, N), dtype, fan_in=D),
        "wdt": he_init(ks[4], (D, nh), dtype, fan_in=D),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) ∈ (-∞, 0)
        "D_skip": jnp.ones((nh,), jnp.float32),
        "conv_w": he_init(ks[5], (cfg.ssm_conv, di), dtype, fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((di,), dtype),
        "gnorm": jnp.ones((di,), dtype),
        "norm": jnp.ones((D,), dtype),
        "wo": he_init(ks[6], (di, D), dtype, fan_in=di),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time.  x [b, s, di], w [k, di]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return out + b


def _ssd_chunked(
    x: jnp.ndarray,  # [b, s, nh, hp]
    dt: jnp.ndarray,  # [b, s, nh] (post-softplus)
    A: jnp.ndarray,  # [nh] negative
    B_: jnp.ndarray,  # [b, s, N]
    C_: jnp.ndarray,  # [b, s, N]
    chunk: int = 128,
    return_state: bool = False,
    compute_dtype=jnp.float32,
):
    b, s, nh, hp = x.shape
    N = B_.shape[-1]
    Q = min(chunk, s)
    nc = s // Q
    assert s % Q == 0, "sequence must divide the SSD chunk"
    # decay/cumsum math stays f32 (exp of sums); the large tensors (xb, the
    # QxQ score block, state outer products) follow compute_dtype — the
    # mixed-precision SSD is the §Perf memory-term iteration for mamba2
    xb = x.reshape(b, nc, Q, nh, hp).astype(compute_dtype)
    dtb = dt.reshape(b, nc, Q, nh)
    Bb = B_.reshape(b, nc, Q, N).astype(compute_dtype)
    Cb = C_.reshape(b, nc, Q, N).astype(compute_dtype)

    dA = dtb * A  # [b, nc, Q, nh]
    cum = jnp.cumsum(dA, axis=2)

    # intra-chunk: y_i += Σ_{j≤i} (C_i·B_j) exp(cum_i - cum_j) dt_j x_j
    # mask in log space: the upper triangle has positive exponents (future
    # positions) that overflow exp() before the mask would zero them
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,Q,Q,nh]
    tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(tri, diff, -jnp.inf)).astype(compute_dtype)
    cb = jnp.einsum("bcin,bcjn->bcij", Cb, Bb)[..., None]  # [b,nc,Q,Q,1]
    scores = cb * decay * dtb[:, :, None, :, :].astype(compute_dtype)
    y = jnp.einsum("bcijh,bcjhp->bcihp", scores, xb)

    # chunk-final states S_c = Σ_j exp(cum_last - cum_j) dt_j x_j ⊗ B_j
    last = cum[:, :, -1:, :]  # [b, nc, 1, nh]
    w = (jnp.exp(last - cum) * dtb).astype(compute_dtype)  # [b, nc, Q, nh]
    Sc = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", w, xb, Bb)  # [b,nc,nh,hp,N]

    # inter-chunk scan: H entering chunk c
    chunk_decay = jnp.exp(last[:, :, 0, :])  # [b, nc, nh]

    def f(carry, inp):
        dec, S = inp  # [b, nh], [b, nh, hp, N]
        out = carry
        new = dec[..., None, None].astype(carry.dtype) * carry + S
        return new.astype(carry.dtype), out

    H0 = jnp.zeros((b, nh, hp, N), compute_dtype)
    Hfinal, Hprev = jax.lax.scan(
        f, H0, (chunk_decay.swapaxes(0, 1), Sc.swapaxes(0, 1))
    )  # [nc, b, nh, hp, N]
    Hprev = Hprev.swapaxes(0, 1)  # [b, nc, nh, hp, N]

    y = y + jnp.einsum("bcin,bchpn->bcihp", Cb, Hprev) * jnp.exp(cum)[
        ..., None
    ].astype(compute_dtype)
    y = y.reshape(b, s, nh, hp).astype(x.dtype)
    if return_state:
        return y, Hfinal
    return y


def mamba_block(
    p: Dict, cfg: ArchConfig, x: jnp.ndarray, chunk: int = 128,
    compute_dtype=jnp.float32,
) -> jnp.ndarray:
    """Pre-norm Mamba-2 block with residual (training / prefill)."""
    b, s, D = x.shape
    di, nh, hp, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    z = h @ p["wz"]
    xin = jax.nn.silu(_causal_conv(h @ p["wx"], p["conv_w"], p["conv_b"]))
    B_ = h @ p["wB"]
    C_ = h @ p["wC"]
    dt = jax.nn.softplus((h @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(b, s, nh, hp)
    y = _ssd_chunked(xh, dt, A, B_, C_, chunk, compute_dtype=compute_dtype)
    y = y + (p["D_skip"][:, None].astype(compute_dtype)
             * xh.astype(compute_dtype)).astype(y.dtype)
    y = y.reshape(b, s, di)
    y = rms_norm(y, p["gnorm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + y @ p["wo"]


def decode_mamba_block(
    p: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,  # [b, 1, D]
    conv_state: jnp.ndarray,  # [b, k-1, di]
    ssm_state: jnp.ndarray,  # [b, nh, hp, N] float32
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent decode step; returns (y, conv_state, ssm_state)."""
    b, _, D = x.shape
    di, nh, hp, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    h = rms_norm(x, p["norm"], cfg.norm_eps)[:, 0]  # [b, D]
    z = h @ p["wz"]
    xproj = h @ p["wx"]  # [b, di]
    window = jnp.concatenate([conv_state, xproj[:, None, :]], axis=1)  # [b,k,di]
    conv = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xin = jax.nn.silu(conv)
    new_conv_state = window[:, 1:]
    B_ = (h @ p["wB"]).astype(jnp.float32)
    C_ = (h @ p["wC"]).astype(jnp.float32)
    dt = jax.nn.softplus((h @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])  # [b,nh]
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(b, nh, hp).astype(jnp.float32)
    decay = jnp.exp(dt * A)  # [b, nh]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, B_)
    new_ssm = decay[..., None, None] * ssm_state + upd
    y = jnp.einsum("bn,bhpn->bhp", C_, new_ssm) + p["D_skip"][:, None] * xh
    y = y.reshape(b, di).astype(x.dtype)
    y = rms_norm(y, p["gnorm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + (y @ p["wo"])[:, None], new_conv_state, new_ssm
