"""Synthetic HetG generators mirroring the paper's datasets (Table 1).

The container is offline, so instead of downloading ogbn-mag / Freebase /
Donor / IGB-HET / MAG240M we generate random heterogeneous graphs with the
*same schema* (node types, relations incl. reverses, feature-dimension
profile, target type, class count) and a ``scale`` knob that multiplies node
counts.  Degree distributions are skewed (Zipf-like) to reproduce the hot-node
phenomenon the cache relies on (paper §6).

At ``scale=1.0`` the generators produce laptop-sized graphs; benchmarks that
report paper-scale numbers use the generators' *statistics* analytically.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graph.hetgraph import CSR, HetGraph, Relation, reverse_relation

__all__ = [
    "ogbn_mag_like",
    "freebase_like",
    "donor_like",
    "igb_het_like",
    "mag240m_like",
    "mag240m_stream",
    "DATASETS",
    "make_dataset",
]


def _zipf_ids(rng: np.random.Generator, n_ids: int, n_samples: int, a: float = 1.2):
    """Sample node ids with a Zipf-ish popularity skew (stable hot set)."""
    # ranks ~ Zipf; map rank -> id through a fixed permutation so hot ids are
    # spread over the id space (matches real datasets; defeats trivial caching)
    ranks = rng.zipf(a, size=n_samples)
    ranks = np.minimum(ranks - 1, n_ids - 1)
    perm = np.random.default_rng(12345).permutation(n_ids)  # fixed, per-graph
    return perm[ranks]


def _rand_relation(
    rng: np.random.Generator,
    num_src: int,
    num_dst: int,
    num_edges: int,
    skew_src: bool = True,
) -> CSR:
    src = (
        _zipf_ids(rng, num_src, num_edges)
        if skew_src
        else rng.integers(0, num_src, num_edges)
    )
    dst = rng.integers(0, num_dst, num_edges)
    return CSR.from_edges(src, dst, num_dst)


def _features(rng, n, dim, dtype=np.float32):
    return (rng.standard_normal((n, dim)) * 0.1).astype(dtype)


def _add_reverse(
    relations: Dict[Relation, CSR], num_nodes: Dict[str, int], skip: Sequence[str] = ()
) -> Dict[Relation, CSR]:
    out = dict(relations)
    for rel, csr in relations.items():
        if rel.etype in skip:
            continue
        rrel = reverse_relation(rel)
        s, d = csr.edges()
        out[rrel] = CSR.from_edges(d, s, num_nodes[rrel.dst])
    return out


# --------------------------------------------------------------------------
# ogbn-mag: 4 node types, 4 relations + 3 reverses, only "paper" featured
# --------------------------------------------------------------------------


def ogbn_mag_like(scale: float = 0.01, seed: int = 0, feat_dim: int = 128) -> HetGraph:
    rng = np.random.default_rng(seed)
    n = {
        "paper": max(int(736_389 * scale), 64),
        "author": max(int(1_134_649 * scale), 64),
        "institution": max(int(8_740 * scale), 8),
        "field_of_study": max(int(59_965 * scale), 16),
    }
    e = lambda x: max(int(x * scale), 256)
    base = {
        Relation("author", "writes", "paper"): _rand_relation(
            rng, n["author"], n["paper"], e(7_145_660)
        ),
        Relation("paper", "cites", "paper"): _rand_relation(
            rng, n["paper"], n["paper"], e(5_416_271)
        ),
        Relation("paper", "has_topic", "field_of_study"): _rand_relation(
            rng, n["paper"], n["field_of_study"], e(7_505_078)
        ),
        Relation("author", "affiliated_with", "institution"): _rand_relation(
            rng, n["author"], n["institution"], e(1_043_998)
        ),
    }
    # paper: 4 relations + 3 reverses (no reverse for cites) = 7 edge types
    relations = _add_reverse(base, n, skip=("cites",))
    return HetGraph(
        num_nodes=n,
        relations=relations,
        target_type="paper",
        num_classes=349,
        features={"paper": _features(rng, n["paper"], feat_dim)},
        name="ogbn-mag-like",
    )


# --------------------------------------------------------------------------
# Freebase: 8 node types, 64 edge types, NO features (all learnable)
# --------------------------------------------------------------------------


def freebase_like(scale: float = 0.002, seed: int = 1) -> HetGraph:
    rng = np.random.default_rng(seed)
    types = ["book", "film", "music", "sports", "people", "location", "org", "business"]
    n = {t: max(int(1_500_000 * scale * w), 64) for t, w in zip(types, [1.2, 0.9, 1.5, 0.4, 2.0, 0.8, 0.7, 0.5])}
    relations: Dict[Relation, CSR] = {}
    # 32 base relations + 32 reverses = 64 edge types; ensure the target type
    # ("book") has several in-relations so the metatree has multiple children.
    pairs: List[Tuple[str, str]] = []
    for i, s in enumerate(types):
        for j in range(4):
            d = types[(i + j + 1) % len(types)]
            pairs.append((s, d))
    for k, (s, d) in enumerate(pairs):
        rel = Relation(s, f"r{k}", d)
        relations[rel] = _rand_relation(
            rng, n[s], n[d], max(int(4_000_000 * scale), 128)
        )
    relations = _add_reverse(relations, n)
    return HetGraph(
        num_nodes=n,
        relations=relations,
        target_type="book",
        num_classes=8,
        features={},  # featureless: learnable features everywhere
        name="freebase-like",
    )


# --------------------------------------------------------------------------
# Donor: 7 node types, ALL featured with wildly varying dims (7..789)
# --------------------------------------------------------------------------


def donor_like(scale: float = 0.003, seed: int = 2) -> HetGraph:
    rng = np.random.default_rng(seed)
    dims = {
        "project": 789,
        "school": 300,
        "teacher": 7,
        "donor": 28,
        "donation": 64,
        "resource": 128,
        "category": 16,
    }
    n = {
        "project": max(int(1_100_000 * scale), 64),
        "school": max(int(72_000 * scale), 32),
        "teacher": max(int(400_000 * scale), 32),
        "donor": max(int(2_000_000 * scale), 64),
        "donation": max(int(4_600_000 * scale), 64),
        "resource": max(int(1_500_000 * scale), 64),
        "category": max(int(51 * 1.0), 51),
    }
    base = {
        Relation("school", "hosts", "project"): _rand_relation(rng, n["school"], n["project"], max(int(1_100_000 * scale), 128)),
        Relation("teacher", "submits", "project"): _rand_relation(rng, n["teacher"], n["project"], max(int(1_100_000 * scale), 128)),
        Relation("donation", "funds", "project"): _rand_relation(rng, n["donation"], n["project"], max(int(4_600_000 * scale), 128)),
        Relation("donor", "gives", "donation"): _rand_relation(rng, n["donor"], n["donation"], max(int(4_600_000 * scale), 128)),
        Relation("resource", "requested_by", "project"): _rand_relation(rng, n["resource"], n["project"], max(int(7_200_000 * scale), 128)),
        Relation("category", "tags", "project"): _rand_relation(rng, n["category"], n["project"], max(int(2_200_000 * scale), 128)),
        Relation("category", "groups", "resource"): _rand_relation(rng, n["category"], n["resource"], max(int(1_500_000 * scale), 128)),
    }
    relations = _add_reverse(base, n)
    return HetGraph(
        num_nodes=n,
        relations=relations,
        target_type="project",
        num_classes=2,
        features={t: _features(rng, n[t], d) for t, d in dims.items()},
        name="donor-like",
    )


# --------------------------------------------------------------------------
# IGB-HET: 4 node types, all featured, uniform dim 1024, many classes
# --------------------------------------------------------------------------


def igb_het_like(scale: float = 0.001, seed: int = 3, feat_dim: int = 1024) -> HetGraph:
    rng = np.random.default_rng(seed)
    n = {
        "paper": max(int(10_000_000 * scale), 64),
        "author": max(int(12_000_000 * scale), 64),
        "institute": max(int(26_000 * scale), 16),
        "fos": max(int(190_000 * scale), 16),
    }
    base = {
        Relation("author", "written_by", "paper"): _rand_relation(rng, n["author"], n["paper"], max(int(190_000_000 * scale), 256)),
        Relation("paper", "cites", "paper"): _rand_relation(rng, n["paper"], n["paper"], max(int(120_000_000 * scale), 256)),
        Relation("paper", "topic", "fos"): _rand_relation(rng, n["paper"], n["fos"], max(int(100_000_000 * scale), 256)),
        Relation("author", "affiliated_to", "institute"): _rand_relation(rng, n["author"], n["institute"], max(int(48_000_000 * scale), 256)),
    }
    relations = _add_reverse(base, n, skip=("cites",))
    return HetGraph(
        num_nodes=n,
        relations=relations,
        target_type="paper",
        num_classes=2983,
        features={t: _features(rng, cnt, feat_dim) for t, cnt in n.items()},
        name="igb-het-like",
    )


# --------------------------------------------------------------------------
# MAG240M: 3 node types, 5 edge types, only "paper" featured (dim 768)
# --------------------------------------------------------------------------


def mag240m_like(scale: float = 0.0002, seed: int = 4, feat_dim: int = 768) -> HetGraph:
    rng = np.random.default_rng(seed)
    n = {
        "paper": max(int(121_000_000 * scale), 64),
        "author": max(int(122_000_000 * scale), 64),
        "institution": max(int(26_000 * scale), 16),
    }
    base = {
        Relation("author", "writes", "paper"): _rand_relation(rng, n["author"], n["paper"], max(int(386_000_000 * scale), 256)),
        Relation("paper", "cites", "paper"): _rand_relation(rng, n["paper"], n["paper"], max(int(1_300_000_000 * scale), 256)),
        Relation("author", "affiliated_with", "institution"): _rand_relation(rng, n["author"], n["institution"], max(int(44_000_000 * scale), 256)),
    }
    # 3 base + reverses of writes/affiliated_with = 5 edge types (Table 1)
    relations = _add_reverse(base, n, skip=("cites",))
    return HetGraph(
        num_nodes=n,
        relations=relations,
        target_type="paper",
        num_classes=153,
        features={"paper": _features(rng, n["paper"], feat_dim, np.float16)},
        name="mag240m-like",
    )


# --------------------------------------------------------------------------
# streaming mag240m: billion-edge-schema CSRs built chunk-wise to an mmap
# store, never materializing the edge payload in RAM (DESIGN.md §13)
# --------------------------------------------------------------------------


def _stream_chunks(seed: int, rel_id: int, num_src: int, num_dst: int,
                   num_edges: int, chunk: int, perm: np.ndarray,
                   a: float = 1.2):
    """Deterministic COO chunks of one base relation.

    Chunk ``c`` is a pure function of ``(seed, rel_id, c)`` so the two-pass
    counting sort can regenerate the identical stream on each pass — the
    out-of-core analog of :func:`_rand_relation` (same Zipf-skewed sources
    through a fixed id permutation, uniform destinations)."""
    for c, start in enumerate(range(0, num_edges, chunk)):
        m = min(chunk, num_edges - start)
        rng = np.random.default_rng([seed, rel_id, c])
        ranks = np.minimum(rng.zipf(a, size=m) - 1, num_src - 1)
        src = perm[ranks]
        dst = rng.integers(0, num_dst, m)
        yield src, dst


def _stream_fill_csr(writer, rel_index: int, chunks, num_dst: int) -> None:
    """Two-pass chunked counting sort straight into the store's memmap views.

    Pass 1 accumulates per-destination degrees (O(num_dst) RAM) and cumsums
    them into ``indptr``; pass 2 regenerates the same chunks and scatters
    source ids to their final slots via per-destination write cursors.  The
    O(num_edges) ``indices`` array only ever exists on disk — this replaces
    the global ``argsort`` of :meth:`CSR.from_edges`, whose COO + order
    arrays would need ~3x the edge payload in RAM."""
    indptr = writer.array(f"rel/{rel_index}/indptr")
    indices = writer.array(f"rel/{rel_index}/indices")
    counts = np.zeros(num_dst, dtype=np.int64)
    for _, d in chunks():
        counts += np.bincount(d, minlength=num_dst)
    indptr[0] = 0
    np.cumsum(counts, out=indptr[1:])
    cursor = indptr[:-1].copy()
    for s, d in chunks():
        order = np.argsort(d, kind="stable")
        ds, ss = d[order], s[order]
        uniq, first, cnt = np.unique(ds, return_index=True,
                                     return_counts=True)
        offs = np.arange(ds.size, dtype=np.int64) - np.repeat(first, cnt)
        indices[cursor[ds] + offs] = ss
        cursor[uniq] += cnt


def mag240m_stream(scale: float = 0.005, seed: int = 4, feat_dim: int = 768,
                   chunk_edges: int = 1 << 20, include_features: bool = True,
                   root: Optional[str] = None):
    """MAG240M-schema graph built chunk-wise into an mmap store.

    Same schema as :func:`mag240m_like` (3 base relations + reverses of
    writes/affiliated_with, paper-featured, 153 classes) but constructed
    out-of-core: every CSR is filled by :func:`_stream_fill_csr` in
    ``chunk_edges``-sized pieces, so at ``scale=1.0`` the ~1.7B-edge
    topology (and the feature table) land directly in the store's
    ``data.bin`` while peak RAM stays O(nodes + chunk).  Deterministic in
    ``(seed, chunk_edges)`` — each chunk's RNG is keyed by its index, so
    the two passes replay identically; a different chunking draws a
    different (equally valid) graph.  Returns the owning
    :class:`~repro.graph.mmap_store.MmapHetGraph`; attach it (or hand its
    picklable handle to trainer processes) via
    :func:`~repro.graph.mmap_store.attach_mmap`.
    """
    from repro.graph.mmap_store import create_store_writer

    n = {
        "paper": max(int(121_000_000 * scale), 64),
        "author": max(int(122_000_000 * scale), 64),
        "institution": max(int(26_000 * scale), 16),
    }
    # base streams: (rel_id, src_type, dst_type, num_edges)
    base = {
        "writes": (0, "author", "paper", max(int(386_000_000 * scale), 256)),
        "cites": (1, "paper", "paper", max(int(1_300_000_000 * scale), 256)),
        "affiliated_with": (
            2, "author", "institution", max(int(44_000_000 * scale), 256)),
    }
    rels = {
        Relation("author", "writes", "paper"): ("writes", False),
        Relation("paper", "cites", "paper"): ("cites", False),
        Relation("author", "affiliated_with", "institution"): (
            "affiliated_with", False),
        Relation("paper", "rev_writes", "author"): ("writes", True),
        Relation("institution", "rev_affiliated_with", "author"): (
            "affiliated_with", True),
    }
    rel_order = sorted(rels)  # handle order matches mmap_share_graph's

    spec: Dict[str, Tuple[Tuple[int, ...], str]] = {}
    for i, rel in enumerate(rel_order):
        ename, _ = rels[rel]
        _, _, _, ne = base[ename]
        spec[f"rel/{i}/indptr"] = ((n[rel.dst] + 1,), "<i8")
        spec[f"rel/{i}/indices"] = ((ne,), "<i8")
    spec["labels"] = ((n["paper"],), "<i8")
    spec["train_nodes"] = ((n["paper"],), "<i8")
    if include_features:
        spec["feat/paper"] = ((n["paper"], feat_dim), "<f2")

    writer = create_store_writer(
        spec, num_nodes=n,
        relations=tuple((r.src, r.etype, r.dst) for r in rel_order),
        target_type="paper", num_classes=153, graph_name="mag240m-stream",
        root=root,
    )
    try:
        # hot-id permutations, one per base src type (matches _zipf_ids's
        # fixed per-graph permutation; O(nodes) RAM, reused across passes)
        perms = {
            t: np.random.default_rng(12345).permutation(n[t])
            for t in ("author", "paper")
        }
        for i, rel in enumerate(rel_order):
            ename, reverse = rels[rel]
            rel_id, src_t, dst_t, ne = base[ename]

            def chunks(_rid=rel_id, _s=src_t, _d=dst_t, _ne=ne, _rev=reverse):
                for s, d in _stream_chunks(seed, _rid, n[_s], n[_d], _ne,
                                           chunk_edges, perms[_s]):
                    yield (d, s) if _rev else (s, d)

            _stream_fill_csr(writer, i, chunks,
                             n[rel.dst])
        labels = writer.array("labels")
        train = writer.array("train_nodes")
        rng_rows = max(1, chunk_edges // max(feat_dim, 1))
        lab_rng = np.random.default_rng(0)  # matches HetGraph's auto labels
        labels[:] = lab_rng.integers(0, 153, n["paper"]).astype(np.int64)
        train[:] = np.arange(n["paper"], dtype=np.int64)
        if include_features:
            feat = writer.array("feat/paper")
            for start in range(0, n["paper"], rng_rows):
                stop = min(start + rng_rows, n["paper"])
                rng = np.random.default_rng([seed, 8, start])
                feat[start:stop] = (
                    rng.standard_normal((stop - start, feat_dim)) * 0.1
                ).astype(np.float16)
        return writer.commit()
    except BaseException:
        writer.abort()
        raise


DATASETS = {
    "ogbn-mag": ogbn_mag_like,
    "freebase": freebase_like,
    "donor": donor_like,
    "igb-het": igb_het_like,
    "mag240m": mag240m_like,
}


def make_dataset(name: str, scale: Optional[float] = None, seed: int = 0) -> HetGraph:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(DATASETS)}")
    kwargs = {"seed": seed}
    if scale is not None:
        kwargs["scale"] = scale
    return DATASETS[name](**kwargs)
