"""Memory-mapped out-of-core graph store (DESIGN.md §13).

The shm store (:mod:`repro.graph.shm`) caps out at RAM: ``/dev/shm`` is a
tmpfs, and ``share_graph`` copies a graph that already exists in one
address space.  The scale-out tier needs neither — a billion-edge-schema
graph should be *built* chunk-wise straight to disk and *shared* by every
trainer process on the host through the page cache.  This module grows the
shm contract into that shape, keeping its discipline intact:

* **Same attach contract.**  A picklable :class:`MmapGraphHandle` (same
  array-key scheme as :class:`~repro.graph.shm.GraphHandle` —
  ``rel/<i>/indptr|indices``, ``labels``, ``train_nodes``,
  ``feat/<ntype>``, ``table/<name>``); :func:`attach_mmap` rebuilds a
  read-only :class:`~repro.graph.hetgraph.HetGraph` of zero-copy views,
  exactly like :func:`repro.graph.shm.attach`.  :func:`attach_any`
  dispatches on handle type so pool/trainer code accepts either store.
* **Transactional create.**  A store is one directory
  ``heta-mmap-<pidhex>-<token>/`` under :func:`store_root` holding
  ``data.bin`` (every array at a 64-byte-aligned offset, the shm
  ``_layout``) and ``MANIFEST.json`` — written last, atomically (write +
  rename): a directory without a manifest is an uncommitted wreck.  Any
  failure before commit removes the directory before re-raising.
* **Idempotent lifecycle.**  ``close()`` unmaps, ``unlink()`` removes the
  directory tree (implies close, safe to repeat, also ``__exit__``/best-
  effort ``__del__``) — mirroring ``SharedHetGraph``.
* **Janitor-sweepable.**  The creator pid is embedded in the directory
  name; :func:`cleanup_stale_stores` reaps stores — committed or not —
  whose creator is dead, with the same conservatism as the shm janitor
  (live pids, foreign uids, unparsable names and the caller's own stores
  are skipped).  Wired into the session-start sweep (``Heta.build_graph``)
  and ``launch/train.py --shm-cleanup``.

Chunk-wise construction goes through :class:`MmapStoreWriter`: declare
array shapes up front, fill writable memmap views in chunks (the streaming
synthetic generator in :mod:`repro.graph.synthetic` does a two-pass
counting sort per relation), then ``commit()``.  Peak RAM is O(nodes) work
arrays; the O(edges) payload only ever exists on disk.

Attach-time validation note: building the ``HetGraph`` runs the usual CSR
/ index-range checks, which sequentially fault in the topology pages once
per process.  Exact at any scale; for truly disk-bound graphs a
skip-validation fast path is a recorded ROADMAP follow-on.

Like :mod:`repro.graph.shm`, this module is deliberately jax-free.
"""

from __future__ import annotations

import dataclasses
import json
import mmap
import os
import secrets
import shutil
import tempfile
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.graph.hetgraph import CSR, HetGraph, Relation
from repro.graph.shm import ArrayRef, GraphHandle, _view

__all__ = [
    "MmapGraphHandle",
    "MmapHetGraph",
    "AttachedMmapGraph",
    "MmapStoreWriter",
    "create_store_writer",
    "mmap_share_graph",
    "attach_mmap",
    "attach_any",
    "store_root",
    "live_stores",
    "cleanup_stale_stores",
    "STORE_PREFIX",
]

STORE_PREFIX = "heta-mmap-"
_DATA_FILE = "data.bin"
_MANIFEST = "MANIFEST.json"


def store_root() -> str:
    """Directory stores live under (``HETA_MMAP_ROOT`` or the tempdir)."""
    return os.environ.get("HETA_MMAP_ROOT") or tempfile.gettempdir()


@dataclasses.dataclass(frozen=True)
class MmapGraphHandle:
    """Picklable description of an mmap store (the disk-backed twin of
    :class:`~repro.graph.shm.GraphHandle`; same array-key scheme)."""

    path: str  # the store directory
    owner_pid: int
    num_nodes: Tuple[Tuple[str, int], ...]
    relations: Tuple[Tuple[str, str, str], ...]
    target_type: str
    num_classes: int
    graph_name: str
    arrays: Tuple[Tuple[str, ArrayRef], ...]

    @property
    def table_names(self) -> Tuple[str, ...]:
        return tuple(k[len("table/"):] for k, _ in self.arrays
                     if k.startswith("table/"))


def _handle_to_json(handle: MmapGraphHandle) -> str:
    d = dataclasses.asdict(handle)
    return json.dumps(d)


def _handle_from_json(text: str, path: str) -> MmapGraphHandle:
    d = json.loads(text)
    return MmapGraphHandle(
        path=path,  # the store may have been moved; trust where we found it
        owner_pid=int(d["owner_pid"]),
        num_nodes=tuple((t, int(n)) for t, n in d["num_nodes"]),
        relations=tuple(tuple(r) for r in d["relations"]),
        target_type=d["target_type"],
        num_classes=int(d["num_classes"]),
        graph_name=d["graph_name"],
        arrays=tuple(
            (k, ArrayRef(offset=int(r["offset"]), shape=tuple(r["shape"]),
                         dtype=r["dtype"]))
            for k, r in d["arrays"]
        ),
    )


def read_manifest(path: str) -> MmapGraphHandle:
    """Load the committed handle of the store directory at ``path``."""
    with open(os.path.join(path, _MANIFEST), "r", encoding="utf-8") as f:
        return _handle_from_json(f.read(), path)


def _map_file(path: str, writable: bool) -> Tuple[mmap.mmap, int]:
    fd = os.open(path, os.O_RDWR if writable else os.O_RDONLY)
    try:
        size = os.fstat(fd).st_size
        access = mmap.ACCESS_WRITE if writable else mmap.ACCESS_READ
        mm = mmap.mmap(fd, size, access=access)
    finally:
        os.close(fd)  # the mapping holds its own reference
    return mm, size


class MmapStoreWriter:
    """Chunk-wise store construction: declare shapes, fill views, commit.

    Created by :func:`create_store_writer`.  ``array(key)`` returns a
    writable memmap-backed view (zero-filled initially — ``data.bin`` is
    allocated sparse with ``ftruncate``); ``commit()`` writes the manifest
    atomically and returns the owning :class:`MmapHetGraph`.  If the
    writer is garbage-collected, ``__exit__``-ed or ``abort()``-ed before
    commit, the directory is removed — an uncommitted store never
    survives its builder."""

    def __init__(self, path: str, handle: MmapGraphHandle, mm: mmap.mmap):
        self._path = path
        self._handle = handle
        self._mm: Optional[mmap.mmap] = mm
        self._refs = dict(handle.arrays)
        self._committed = False

    @property
    def handle(self) -> MmapGraphHandle:
        return self._handle

    def array(self, key: str) -> np.ndarray:
        if self._mm is None:
            raise RuntimeError("writer is closed")
        return _view(self._mm, self._refs[key], writeable=True)

    def commit(self) -> "MmapHetGraph":
        if self._committed or self._mm is None:
            raise RuntimeError("store already committed or aborted")
        self._mm.flush()
        tmp = os.path.join(self._path, _MANIFEST + ".tmp")
        with open(tmp, "w", encoding="utf-8") as f:
            f.write(_handle_to_json(self._handle))
        os.replace(tmp, os.path.join(self._path, _MANIFEST))
        self._committed = True
        store = MmapHetGraph(self._handle, self._mm)
        self._mm = None  # ownership transferred
        return store

    def abort(self) -> None:
        """Drop an uncommitted store (idempotent; no-op after commit)."""
        if self._mm is not None:
            self._mm.close()
            self._mm = None
        if not self._committed:
            shutil.rmtree(self._path, ignore_errors=True)

    def __enter__(self) -> "MmapStoreWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.abort()

    def __del__(self):
        try:
            self.abort()
        except BaseException:
            pass


class MmapHetGraph:
    """Owner handle of a committed mmap store (twin of ``SharedHetGraph``)."""

    def __init__(self, handle: MmapGraphHandle, mm: Optional[mmap.mmap] = None):
        self.handle = handle
        if mm is None:
            mm, _ = _map_file(os.path.join(handle.path, _DATA_FILE),
                              writable=True)
        self._mm: Optional[mmap.mmap] = mm
        self._unlinked = False

    def _array(self, key: str) -> np.ndarray:
        refs = dict(self.handle.arrays)
        return _view(self._mm, refs[key], writeable=True)

    @property
    def nbytes(self) -> int:
        try:
            return os.path.getsize(os.path.join(self.handle.path, _DATA_FILE))
        except OSError:
            return 0

    def close(self) -> None:
        """Unmap the owner's view (the store stays on disk until unlink)."""
        if self._mm is not None:
            self._mm.close()
            self._mm = None

    def unlink(self) -> None:
        """Remove the store directory.  Idempotent; implies close()."""
        self.close()
        if not self._unlinked:
            self._unlinked = True
            shutil.rmtree(self.handle.path, ignore_errors=True)

    def __enter__(self) -> "MmapHetGraph":
        return self

    def __exit__(self, *exc) -> None:
        self.unlink()

    def __del__(self):  # best-effort: never leak a store on error paths
        try:
            self.unlink()
        except BaseException:
            pass


class AttachedMmapGraph:
    """A trainer's zero-copy, read-only view of a committed mmap store.

    ``graph`` is a fully functional read-only HetGraph whose arrays page
    in lazily from ``data.bin``; ``tables`` maps exported staging-table
    names to read-only views.  Same surface as
    :class:`~repro.graph.shm.AttachedHetGraph`."""

    def __init__(self, handle: MmapGraphHandle):
        self.handle = handle
        self._mm, _ = _map_file(os.path.join(handle.path, _DATA_FILE),
                                writable=False)
        self._closed = False
        refs = dict(handle.arrays)
        relations: Dict[Relation, CSR] = {}
        for i, (src, etype, dst) in enumerate(handle.relations):
            relations[Relation(src, etype, dst)] = CSR(
                indptr=_view(self._mm, refs[f"rel/{i}/indptr"]),
                indices=_view(self._mm, refs[f"rel/{i}/indices"]),
            )
        features = {
            k[len("feat/"):]: _view(self._mm, r)
            for k, r in refs.items() if k.startswith("feat/")
        }
        self.graph = HetGraph(
            num_nodes=dict(handle.num_nodes),
            relations=relations,
            target_type=handle.target_type,
            num_classes=handle.num_classes,
            features=features,
            labels=_view(self._mm, refs["labels"]),
            train_nodes=_view(self._mm, refs["train_nodes"]),
            name=handle.graph_name,
        )
        self.tables: Dict[str, np.ndarray] = {
            k[len("table/"):]: _view(self._mm, r)
            for k, r in refs.items() if k.startswith("table/")
        }

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self.graph = None
            self.tables = {}
            self._mm.close()

    def __enter__(self) -> "AttachedMmapGraph":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except BaseException:
            pass


def create_store_writer(
    arrays_spec: Dict[str, Tuple[Tuple[int, ...], str]],
    num_nodes: Dict[str, int],
    relations: Tuple[Tuple[str, str, str], ...],
    target_type: str,
    num_classes: int,
    graph_name: str,
    root: Optional[str] = None,
) -> MmapStoreWriter:
    """Open a writer for a new store (see :class:`MmapStoreWriter`).

    ``arrays_spec`` maps array keys (shm key scheme) to ``(shape, dtype)``;
    ``relations`` fixes the relation order the ``rel/<i>/...`` keys index.
    """
    # shm's _layout sizes from materialized arrays; here shapes are declared
    # up front (the payload never exists in RAM), so lay out from the specs
    # with the same 64-byte alignment rule.
    refs: Dict[str, ArrayRef] = {}
    off = 0
    align = 64
    for key, (shape, dt) in arrays_spec.items():
        dtype = np.dtype(dt)
        if dtype.hasobject:
            raise ValueError(f"array {key!r} has object dtype")
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        refs[key] = ArrayRef(offset=off, shape=tuple(int(s) for s in shape),
                             dtype=dtype.str)
        off += -(-nbytes // align) * align
    total = max(off, 1)

    path = os.path.join(
        root or store_root(),
        f"{STORE_PREFIX}{os.getpid():x}-{secrets.token_hex(4)}",
    )
    os.makedirs(path, exist_ok=False)
    try:
        data = os.path.join(path, _DATA_FILE)
        fd = os.open(data, os.O_CREAT | os.O_RDWR | os.O_EXCL, 0o600)
        try:
            os.ftruncate(fd, total)  # sparse: pages materialize on write
            mm = mmap.mmap(fd, total, access=mmap.ACCESS_WRITE)
        finally:
            os.close(fd)
    except BaseException:
        shutil.rmtree(path, ignore_errors=True)
        raise
    handle = MmapGraphHandle(
        path=path,
        owner_pid=os.getpid(),
        # insertion order, NOT sorted — attached twins must iterate node
        # types exactly like the source graph (type-arena offsets depend
        # on it; DESIGN.md §13)
        num_nodes=tuple((t, int(n)) for t, n in num_nodes.items()),
        relations=tuple(tuple(r) for r in relations),
        target_type=target_type,
        num_classes=int(num_classes),
        graph_name=graph_name,
        arrays=tuple(refs.items()),
    )
    return MmapStoreWriter(path, handle, mm)


def mmap_share_graph(
    graph: HetGraph,
    include_features: bool = True,
    tables: Optional[Dict[str, np.ndarray]] = None,
    root: Optional[str] = None,
) -> MmapHetGraph:
    """Export an in-RAM graph into an mmap store (disk-backed twin of
    :func:`repro.graph.shm.share_graph`; transactional the same way)."""
    rel_list: List[Tuple[Relation, CSR]] = sorted(
        graph.relations.items(), key=lambda rc: rc[0]
    )
    arrays: Dict[str, np.ndarray] = {}
    for i, (_, csr) in enumerate(rel_list):
        arrays[f"rel/{i}/indptr"] = csr.indptr
        arrays[f"rel/{i}/indices"] = csr.indices
    arrays["labels"] = np.asarray(graph.labels)
    arrays["train_nodes"] = np.asarray(graph.train_nodes)
    if include_features:
        for t, f in graph.features.items():
            arrays[f"feat/{t}"] = np.ascontiguousarray(f)
    for tname, tab in (tables or {}).items():
        arrays[f"table/{tname}"] = np.ascontiguousarray(tab)

    spec = {k: (tuple(a.shape), a.dtype.str) for k, a in arrays.items()}
    writer = create_store_writer(
        spec,
        num_nodes=graph.num_nodes,
        relations=tuple((r.src, r.etype, r.dst) for r, _ in rel_list),
        target_type=graph.target_type,
        num_classes=int(graph.num_classes),
        graph_name=graph.name,
        root=root,
    )
    try:
        for key, arr in arrays.items():
            np.copyto(writer.array(key), arr, casting="no")
        return writer.commit()
    except BaseException:
        writer.abort()
        raise


def attach_mmap(handle: MmapGraphHandle) -> AttachedMmapGraph:
    """Map the store described by ``handle`` (see :class:`AttachedMmapGraph`)."""
    return AttachedMmapGraph(handle)


def attach_any(handle):
    """Attach either store flavor: dispatches :class:`MmapGraphHandle` to
    :func:`attach_mmap` and :class:`~repro.graph.shm.GraphHandle` to
    :func:`repro.graph.shm.attach` — pool workers and DP trainers accept
    both transparently."""
    if isinstance(handle, MmapGraphHandle):
        return attach_mmap(handle)
    if isinstance(handle, GraphHandle):
        from repro.graph.shm import attach

        return attach(handle)
    raise TypeError(f"not a graph store handle: {type(handle).__name__}")


# --------------------------------------------------------------------------
# janitor (DESIGN.md §12/§13) — same conservatism as the shm sweep
# --------------------------------------------------------------------------


def live_stores(root: Optional[str] = None,
                prefix: str = STORE_PREFIX) -> List[str]:
    """Store directory names currently on disk (the leak check)."""
    base = root or store_root()
    try:
        return sorted(
            n for n in os.listdir(base)
            if n.startswith(prefix)
            and os.path.isdir(os.path.join(base, n))
        )
    except FileNotFoundError:
        return []


def _store_owner_pid(name: str, prefix: str = STORE_PREFIX) -> Optional[int]:
    """Parse the creator pid from a ``heta-mmap-<pidhex>-<token>`` name."""
    rest = name[len(prefix):]
    pid_hex, sep, _ = rest.partition("-")
    if not sep or not pid_hex:
        return None
    try:
        return int(pid_hex, 16)
    except ValueError:
        return None


def cleanup_stale_stores(root: Optional[str] = None,
                         prefix: str = STORE_PREFIX) -> List[str]:
    """Remove orphaned mmap stores whose creator pid is dead.

    Exactly the shm janitor's rules (``cleanup_stale_segments``) applied
    to store directories: a killed trainer or generator never runs
    ``unlink()``, so its store — committed or an uncommitted wreck without
    a manifest — sits on disk until swept.  Conservative: live pids (even
    recycled ones), foreign-uid pids, unparsable names and this process's
    own stores are skipped.  Runs from the session-start sweep and
    ``launch/train.py --shm-cleanup``.  Returns the names removed."""
    base = root or store_root()
    removed: List[str] = []
    for name in live_stores(base, prefix):
        pid = _store_owner_pid(name, prefix)
        if pid is None or pid == os.getpid():
            continue
        try:
            os.kill(pid, 0)
            continue  # creator alive: not ours to reap
        except ProcessLookupError:
            pass
        except PermissionError:
            continue  # pid exists under another uid
        try:
            shutil.rmtree(os.path.join(base, name))
            removed.append(name)
        except FileNotFoundError:
            pass  # lost the race to another janitor
        except OSError:
            pass  # best-effort: never fail session start over a sweep
    return removed
