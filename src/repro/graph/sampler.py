"""Fixed-fanout k-hop neighborhood sampling over the metatree.

JAX needs static shapes, so we sample a *fixed* number of in-neighbors per
node per relation (with replacement; degree-0 slots are masked).  The sampled
computation structure is exactly the metatree (paper §5): every metatree node
below the root becomes a *branch* — a stack of ``fanout`` samples per parent
node — and the HGNN evaluates branches bottom-up with relation-specific
aggregations, combining children by cross-relation summation (Eq. 1).

The branch representation is deliberately tensor-friendly:

  level d (1-based):  nids [R_d, N_d]  mask [R_d, N_d]
  with N_d = batch * f_1 * ... * f_d, R_d = number of metatree nodes at depth d

so relation-specific aggregation at level d is a single gather + reshape
[R_d, N_{d-1}, f_d, dim] + masked reduce — the shape the Pallas
``gather_agg`` kernel and the sharded RAF executor both consume.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.metatree import MetaTreeNode
from repro.graph.hetgraph import CSR, HetGraph, Relation

__all__ = [
    "BranchSpec",
    "SampleSpec",
    "Level",
    "SampledBatch",
    "NeighborSampler",
    "sample_neighbors",
]


@dataclasses.dataclass(frozen=True)
class BranchSpec:
    """Static description of one metatree branch (= one relation instance)."""

    rel: Relation
    parent: int  # branch index at the previous level (level 0 has one "branch")
    depth: int  # 1-based

    @property
    def src_type(self) -> str:
        return self.rel.src


@dataclasses.dataclass(frozen=True)
class SampleSpec:
    """Static sampling plan derived from a metatree + fanouts."""

    target_type: str
    fanouts: Tuple[int, ...]
    levels: Tuple[Tuple[BranchSpec, ...], ...]  # levels[d-1] = branches at depth d

    @property
    def num_layers(self) -> int:
        return len(self.fanouts)

    def branches(self) -> Iterator[BranchSpec]:
        for lv in self.levels:
            yield from lv

    def num_sampled(self, batch_size: int) -> Dict[int, int]:
        """N_d per depth (nodes sampled per branch)."""
        out, n = {}, batch_size
        for d, f in enumerate(self.fanouts, start=1):
            n = n * f
            out[d] = n
        return out

    @staticmethod
    def from_metatree(tree: MetaTreeNode, fanouts: Sequence[int]) -> "SampleSpec":
        k = len(fanouts)
        levels: List[List[BranchSpec]] = [[] for _ in range(k)]
        # walk the tree breadth-first, recording each node's branch index so
        # children can reference their parent's index at the previous level
        frontier: List[Tuple[MetaTreeNode, int]] = [(tree, 0)]
        for d in range(1, k + 1):
            nxt: List[Tuple[MetaTreeNode, int]] = []
            for node, idx in frontier:
                for child in node.children:
                    levels[d - 1].append(BranchSpec(child.rel, idx, d))
                    nxt.append((child, len(levels[d - 1]) - 1))
            frontier = nxt
        return SampleSpec(
            target_type=tree.ntype,
            fanouts=tuple(int(f) for f in fanouts),
            levels=tuple(tuple(lv) for lv in levels),
        )


@dataclasses.dataclass
class Level:
    """Sampled node ids for every branch at one depth."""

    nids: np.ndarray  # int32 [R_d, N_d]
    mask: np.ndarray  # bool  [R_d, N_d]


@dataclasses.dataclass
class SampledBatch:
    """One sampled minibatch: seeds (target nodes) + per-level branch samples."""

    spec: SampleSpec
    seeds: np.ndarray  # int64 [B]
    labels: np.ndarray  # int64 [B]
    levels: List[Level]

    @property
    def batch_size(self) -> int:
        return int(len(self.seeds))

    def nodes_at(self, depth: int, branch: int) -> Tuple[np.ndarray, np.ndarray]:
        """(nids, mask) of the nodes feeding branch ``branch`` at ``depth``."""
        if depth == 0:
            return self.seeds, np.ones_like(self.seeds, dtype=bool)
        lv = self.levels[depth - 1]
        return lv.nids[branch], lv.mask[branch]

    def total_sampled(self) -> int:
        return int(sum(lv.mask.sum() for lv in self.levels)) + self.batch_size

    def count_visits(self, counts: Dict[str, np.ndarray]) -> None:
        """Accumulate this batch's per-type node visit counts into ``counts``
        (the §6 pre-sampling statistic; shared by the serial profiler and the
        pooled hotness task so both count identically)."""
        np.add.at(counts[self.spec.target_type], self.seeds, 1)
        for lv, branches in zip(self.levels, self.spec.levels):
            for b, bs in enumerate(branches):
                ids = lv.nids[b][lv.mask[b]]
                np.add.at(counts[bs.src_type], ids, 1)

    def unique_nodes_per_type(self) -> Dict[str, np.ndarray]:
        """Unique node ids touched per node type (drives feature fetching,
        cache lookups and the vanilla-model communication accounting)."""
        acc: Dict[str, List[np.ndarray]] = {self.spec.target_type: [self.seeds]}
        for lv, branches in zip(self.levels, self.spec.levels):
            for b, spec in enumerate(branches):
                acc.setdefault(spec.src_type, []).append(lv.nids[b][lv.mask[b]])
        return {t: np.unique(np.concatenate(v)) for t, v in acc.items() if v}


def sample_neighbors(
    csr: CSR,
    parents: np.ndarray,
    parent_mask: np.ndarray,
    fanout: int,
    rng: np.random.Generator,
) -> Tuple[np.ndarray, np.ndarray]:
    """Sample ``fanout`` in-neighbors per parent, with replacement.

    Degree-0 parents (and invalid parents) yield masked slots pointing at 0.
    """
    n = len(parents)
    deg = csr.indptr[parents + 1] - csr.indptr[parents]  # [n]
    valid = (deg > 0) & parent_mask
    if csr.num_edges == 0:
        return np.zeros((n, fanout), np.int64), np.zeros((n, fanout), bool)
    safe_deg = np.maximum(deg, 1)
    offs = (rng.random((n, fanout)) * safe_deg[:, None]).astype(np.int64)
    raw = csr.indptr[parents][:, None] + offs
    raw = np.minimum(raw, csr.num_edges - 1)  # clamp degree-0 tail slots
    idx = np.where(valid[:, None], csr.indices[raw], 0)
    mask = np.broadcast_to(valid[:, None], (n, fanout)).copy()
    return idx, mask


class NeighborSampler:
    """Minibatch iterator producing :class:`SampledBatch` per step.

    The sampler is a host-side data-pipeline stage (paper Fig. 3 step 2); the
    RAF executor consumes its output.  Sampling uses only the mono-relation
    CSRs of the relations in ``spec`` — with meta-partitioning each partition
    owns complete mono-relation subgraphs for its relations, so its branches
    sample entirely locally (paper §4 "outer-hop features are local").

    **Determinism model.**  Every batch's randomness is derived from
    ``(seed, epoch_seed, step)`` via :func:`numpy.random.SeedSequence` — the
    :class:`~repro.data.pipeline.SyntheticCorpus` trick — instead of one
    shared mutating generator.  :meth:`batch_at` is therefore a *pure
    function* of its position: any batch can be (re)materialized
    independently, out of order, from another thread, or after a restart,
    and the async sample stream produces bit-identical batches to the
    serial loop.  Ad-hoc :meth:`sample_batch` calls without an explicit
    ``rng`` draw from a per-instance call counter, so a fresh sampler
    replayed through the same call sequence still reproduces itself.
    """

    def __init__(
        self,
        graph: HetGraph,
        spec: SampleSpec,
        batch_size: int,
        seed: int = 0,
        drop_last: bool = True,
    ):
        self.graph = graph
        self.spec = spec
        self.batch_size = int(batch_size)
        self.seed = int(seed)
        self.drop_last = drop_last
        self._draws = 0  # ad-hoc sample_batch() call counter
        self._epochs_started = 0  # seedless epoch() call counter
        self._order_cache: Dict[Tuple[bool, int], np.ndarray] = {}
        missing = [b.rel for b in spec.branches() if b.rel not in graph.relations]
        if missing:
            raise ValueError(f"graph lacks relations required by spec: {missing}")

    def _rng_for(self, *key: int) -> np.random.Generator:
        """Per-batch generator, a pure function of (seed, *key)."""
        return np.random.default_rng(
            np.random.SeedSequence([self.seed & 0xFFFFFFFF]
                                   + [int(k) & 0xFFFFFFFF for k in key])
        )

    def sample_batch(
        self, seeds: np.ndarray, rng: Optional[np.random.Generator] = None
    ) -> SampledBatch:
        seeds = np.asarray(seeds, dtype=np.int64)
        if rng is None:
            # deterministic per call index (not shared mutable state)
            rng = self._rng_for(0xAD0C, self._draws)
            self._draws += 1
        levels: List[Level] = []
        prev_nids: List[np.ndarray] = [seeds]  # per-branch node arrays, prev level
        prev_mask: List[np.ndarray] = [np.ones(len(seeds), dtype=bool)]
        for d, branches in enumerate(self.spec.levels, start=1):
            f = self.spec.fanouts[d - 1]
            nids = np.zeros((len(branches), len(prev_nids[0]) * f), dtype=np.int64)
            mask = np.zeros_like(nids, dtype=bool)
            for b, spec in enumerate(branches):
                csr = self.graph.relations[spec.rel]
                idx, m = sample_neighbors(
                    csr, prev_nids[spec.parent], prev_mask[spec.parent], f, rng
                )
                nids[b] = idx.reshape(-1)
                mask[b] = m.reshape(-1)
            levels.append(Level(nids=nids, mask=mask))
            prev_nids = [nids[b] for b in range(len(branches))]
            prev_mask = [mask[b] for b in range(len(branches))]
        labels = self.graph.labels[seeds]
        return SampledBatch(self.spec, seeds, labels, levels)

    def epoch_order(self, shuffle: bool = True, seed: Optional[int] = None) -> np.ndarray:
        """The (shuffled) train-node visit order of one epoch — pure in
        ``(shuffle, seed)``, memoized per sampler."""
        key = (bool(shuffle), int(seed or 0))
        order = self._order_cache.get(key)
        if order is None:
            order = self.graph.train_nodes.copy()
            if shuffle:
                np.random.default_rng(seed or 0).shuffle(order)
            if len(self._order_cache) >= 4:  # one live epoch + prefetch slack
                self._order_cache.pop(next(iter(self._order_cache)))
            self._order_cache[key] = order
        return order

    def batch_at(
        self, step: int, epoch_seed: Optional[int] = None, shuffle: bool = True
    ) -> SampledBatch:
        """Materialize epoch batch ``step`` as a pure function of
        ``(sampler seed, epoch_seed, step)`` — safe to call out of order,
        concurrently, or after a restart (the async-pipeline contract)."""
        if not 0 <= step < self.steps_per_epoch():
            raise IndexError(f"step {step} outside epoch of {self.steps_per_epoch()}")
        order = self.epoch_order(shuffle, epoch_seed)
        seeds = order[step * self.batch_size : (step + 1) * self.batch_size]
        return self.sample_batch(seeds, rng=self._rng_for(int(epoch_seed or 0), step))

    def epoch(self, shuffle: bool = True, seed: Optional[int] = None):
        """One epoch of batches (= ``batch_at(0..steps_per_epoch-1)``).

        ``seed`` is the epoch seed: with per-batch RNG, the *same* seed
        reproduces the *same* epoch bit-for-bit — pass a distinct seed per
        epoch (as the session and profilers do) for fresh neighbor draws.
        When ``seed`` is None, an internal per-sampler epoch counter is
        used, so repeated ``epoch()`` calls vary (matching the pre-per-batch
        expectation) while staying deterministic for a fresh sampler."""
        if seed is None:
            seed = 0x50C8 + self._epochs_started
            self._epochs_started += 1
        for i in range(self.steps_per_epoch()):
            yield self.batch_at(i, epoch_seed=seed, shuffle=shuffle)

    def steps_per_epoch(self) -> int:
        n = len(self.graph.train_nodes)
        return n // self.batch_size if self.drop_last else -(-n // self.batch_size)
