"""Heterogeneous graph container.

A HetG is ``G = (V, E, A, R)`` (paper §2.1): nodes/edges carry types, a
*relation* is a triple ``(src_type, edge_type, dst_type)`` and the HetG
decomposes into *mono-relation subgraphs*, one per relation.  We store each
mono-relation subgraph as an in-CSR indexed by destination node (message
passing aggregates in-neighbors), which is the layout both the sampler and
the Pallas aggregation kernel consume.

Everything here is host-side numpy; device arrays enter the picture only in
``core/raf.py`` / ``core/vanilla.py`` once a minibatch has been sampled.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

__all__ = [
    "Relation",
    "CSR",
    "HetGraph",
    "Metagraph",
    "reverse_relation",
]


@dataclasses.dataclass(frozen=True, order=True)
class Relation:
    """A relation triple (τ(u), φ(e), τ(v)); messages flow src → dst."""

    src: str
    etype: str
    dst: str

    def __str__(self) -> str:  # compact, used in logs/partition dumps
        return f"{self.src}-{self.etype}-{self.dst}"

    @property
    def key(self) -> str:
        return str(self)


def reverse_relation(rel: Relation) -> Relation:
    """The reverse relation r^{-1} = (τ(v), φ̄(e), τ(u)) (paper §2.1)."""
    if rel.etype.startswith("rev_"):
        return Relation(rel.dst, rel.etype[len("rev_"):], rel.src)
    return Relation(rel.dst, f"rev_{rel.etype}", rel.src)


@dataclasses.dataclass
class CSR:
    """In-CSR of one mono-relation subgraph: for each dst node, its in-edges.

    ``indptr`` has length ``num_dst + 1``; ``indices[indptr[v]:indptr[v+1]]``
    are the source node ids (of the relation's src type) of v's in-edges.
    """

    indptr: np.ndarray  # int64 [num_dst + 1]
    indices: np.ndarray  # int32/int64 [num_edges]

    def __post_init__(self) -> None:
        self.indptr = np.asarray(self.indptr, dtype=np.int64)
        self.indices = np.asarray(self.indices, dtype=np.int64)
        if self.indptr.ndim != 1 or self.indices.ndim != 1:
            raise ValueError("CSR arrays must be 1-D")
        if self.indptr[0] != 0 or self.indptr[-1] != len(self.indices):
            raise ValueError("inconsistent CSR indptr")

    @property
    def num_dst(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, num_dst: int) -> "CSR":
        """Build an in-CSR from a COO edge list."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if src.shape != dst.shape:
            raise ValueError("src/dst shape mismatch")
        order = np.argsort(dst, kind="stable")
        dst_sorted = dst[order]
        counts = np.bincount(dst_sorted, minlength=num_dst)
        indptr = np.zeros(num_dst + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSR(indptr=indptr, indices=src[order])

    def edges(self) -> tuple[np.ndarray, np.ndarray]:
        """Return (src, dst) COO arrays (inverse of :meth:`from_edges`)."""
        dst = np.repeat(np.arange(self.num_dst, dtype=np.int64), self.degrees())
        return self.indices.copy(), dst


@dataclasses.dataclass
class Metagraph:
    """Weighted metagraph M = (A, R): vertex weights = node counts, link
    weights = edge counts (paper §5, input to meta-partitioning)."""

    node_types: Dict[str, int]  # type -> num nodes (vertex weight)
    relations: Dict[Relation, int]  # relation -> num edges (link weight)

    def in_relations(self, ntype: str) -> List[Relation]:
        """Relations whose messages arrive at ``ntype`` (dst == ntype)."""
        return [r for r in self.relations if r.dst == ntype]

    def out_relations(self, ntype: str) -> List[Relation]:
        return [r for r in self.relations if r.src == ntype]

    @property
    def num_vertices(self) -> int:
        return len(self.node_types)

    @property
    def num_links(self) -> int:
        return len(self.relations)


@dataclasses.dataclass
class HetGraph:
    """A heterogeneous graph decomposed into mono-relation subgraphs.

    ``features[t]`` is a dense [num_nodes[t], feat_dim[t]] array for featured
    node types; featureless types (``t not in features``) receive *learnable*
    features managed by :mod:`repro.embed` (paper §2.1/§6).
    """

    num_nodes: Dict[str, int]
    relations: Dict[Relation, CSR]
    target_type: str
    num_classes: int
    features: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    labels: Optional[np.ndarray] = None  # [num_nodes[target_type]] int labels
    train_nodes: Optional[np.ndarray] = None  # subset of target nodes
    name: str = "hetg"

    def __post_init__(self) -> None:
        for rel, csr in self.relations.items():
            if rel.dst not in self.num_nodes or rel.src not in self.num_nodes:
                raise ValueError(f"relation {rel} references unknown node type")
            if csr.num_dst != self.num_nodes[rel.dst]:
                raise ValueError(
                    f"{rel}: CSR num_dst {csr.num_dst} != {self.num_nodes[rel.dst]}"
                )
            if csr.num_edges and csr.indices.max() >= self.num_nodes[rel.src]:
                raise ValueError(f"{rel}: src index out of range")
        if self.target_type not in self.num_nodes:
            raise ValueError("unknown target type")
        if self.train_nodes is None:
            self.train_nodes = np.arange(self.num_nodes[self.target_type])
        if self.labels is None:
            rng = np.random.default_rng(0)
            self.labels = rng.integers(
                0, self.num_classes, self.num_nodes[self.target_type]
            ).astype(np.int64)

    # ---- schema-level views -------------------------------------------------

    def metagraph(self) -> Metagraph:
        return Metagraph(
            node_types=dict(self.num_nodes),
            relations={r: c.num_edges for r, c in self.relations.items()},
        )

    def feat_dim(self, ntype: str) -> Optional[int]:
        f = self.features.get(ntype)
        return None if f is None else int(f.shape[1])

    @property
    def node_types(self) -> List[str]:
        return sorted(self.num_nodes)

    @property
    def total_nodes(self) -> int:
        return int(sum(self.num_nodes.values()))

    @property
    def total_edges(self) -> int:
        return int(sum(c.num_edges for c in self.relations.values()))

    # ---- subgraph extraction ------------------------------------------------

    def restrict(self, rels: Sequence[Relation], name: str = "") -> "HetGraph":
        """The sub-HetG containing the given complete mono-relation subgraphs
        (used to materialize a meta-partition, paper §5 step 4)."""
        rels = list(dict.fromkeys(rels))  # dedup, keep order
        ntypes = {self.target_type}
        for r in rels:
            ntypes.add(r.src)
            ntypes.add(r.dst)
        return HetGraph(
            num_nodes={t: self.num_nodes[t] for t in ntypes},
            relations={r: self.relations[r] for r in rels},
            target_type=self.target_type,
            num_classes=self.num_classes,
            features={t: f for t, f in self.features.items() if t in ntypes},
            labels=self.labels,
            train_nodes=self.train_nodes,
            name=name or f"{self.name}:restricted",
        )

    def storage_bytes(self) -> int:
        """Approximate host storage (topology + dense features)."""
        topo = sum(c.indptr.nbytes + c.indices.nbytes for c in self.relations.values())
        feat = sum(f.nbytes for f in self.features.values())
        return int(topo + feat)
