"""Shared-memory backing for :class:`~repro.graph.hetgraph.HetGraph`.

The multi-worker sampling pool (``repro.data.worker_pool``, DESIGN.md §9)
feeds N sampler processes from **one** copy of the graph: topology
(``indptr``/``indices`` per mono-relation CSR), labels, the train-node set,
and optionally frozen feature tables are exported once into a single
:mod:`multiprocessing.shared_memory` segment, and each worker maps them
zero-copy — no pickling of the graph per task, no per-worker replicas.

Three pieces:

:func:`share_graph`
    Owner side.  Copies the graph's arrays into a fresh named segment and
    returns a :class:`SharedHetGraph` whose picklable :attr:`~SharedHetGraph.
    handle` describes the layout.  Creation is transactional: any failure
    while populating the segment closes **and unlinks** it before re-raising,
    so an error path never leaks a ``/dev/shm`` segment.

:func:`attach`
    Worker side.  Maps the segment named by a :class:`GraphHandle` and
    rebuilds a read-only :class:`HetGraph` (plus any exported staging tables)
    whose numpy arrays are views into the shared buffer.  Attaching never
    registers with the ``resource_tracker`` (workers must not unlink the
    owner's segment at exit, nor warn about "leaked" memory they don't own).

Lifecycle
    ``SharedHetGraph.close()`` unmaps the owner's view; ``unlink()`` (also
    run by ``__exit__`` and, best-effort, ``__del__``) removes the segment
    from the OS.  ``AttachedHetGraph.close()`` unmaps a worker's view and is
    likewise idempotent.  :func:`live_segments` lists segments still present
    under ``/dev/shm`` — the leak check used by tests and CI.

This module is deliberately jax-free: sampler workers import it (via
``repro.data.worker_pool``) and must stay lightweight numpy processes.
"""

from __future__ import annotations

import dataclasses
import os
import secrets
import time
from multiprocessing import shared_memory
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.graph.hetgraph import CSR, HetGraph, Relation

__all__ = [
    "GraphHandle",
    "SharedHetGraph",
    "AttachedHetGraph",
    "share_graph",
    "attach",
    "ArraysHandle",
    "SharedArrays",
    "AttachedArrays",
    "share_arrays",
    "attach_arrays",
    "ArenaHandle",
    "BatchArena",
    "AttachedArena",
    "ArenaStalledError",
    "create_arena",
    "attach_arena",
    "live_segments",
    "cleanup_stale_segments",
]

_ALIGN = 64  # byte alignment of each array inside the segment
SEGMENT_PREFIX = "heta-shm-"


@dataclasses.dataclass(frozen=True)
class ArrayRef:
    """Location of one array inside the shared segment."""

    offset: int
    shape: Tuple[int, ...]
    dtype: str


@dataclasses.dataclass(frozen=True)
class GraphHandle:
    """Picklable description of a shared graph segment.

    Workers receive this (a few hundred bytes) instead of the graph itself;
    :func:`attach` turns it back into a :class:`HetGraph` of zero-copy views.
    Array keys: ``rel/<i>/indptr|indices`` (relation order matches
    :attr:`relations`), ``labels``, ``train_nodes``, ``feat/<ntype>`` and
    ``table/<name>`` for exported staging tables.
    """

    segment: str
    owner_pid: int
    num_nodes: Tuple[Tuple[str, int], ...]
    relations: Tuple[Tuple[str, str, str], ...]
    target_type: str
    num_classes: int
    graph_name: str
    arrays: Tuple[Tuple[str, ArrayRef], ...]

    @property
    def table_names(self) -> Tuple[str, ...]:
        return tuple(k[len("table/"):] for k, _ in self.arrays
                     if k.startswith("table/"))


def _layout(arrays: Dict[str, np.ndarray]) -> Tuple[Dict[str, ArrayRef], int]:
    refs, off = {}, 0
    for key, arr in arrays.items():
        if arr.dtype.hasobject:
            # object arrays are pointers — meaningless in another process
            raise ValueError(f"array {key!r} has object dtype; only plain "
                             "numeric/bool arrays can be shared")
        refs[key] = ArrayRef(offset=off, shape=tuple(arr.shape),
                             dtype=arr.dtype.str)
        off += -(-arr.nbytes // _ALIGN) * _ALIGN
    return refs, max(off, 1)


def _view(buf, ref: ArrayRef, writeable: bool = False) -> np.ndarray:
    arr = np.ndarray(ref.shape, dtype=np.dtype(ref.dtype), buffer=buf,
                     offset=ref.offset)
    if not writeable:
        arr.flags.writeable = False
    return arr


def _open_attached(name: str, owner_pid: int) -> shared_memory.SharedMemory:
    """Attach to an existing segment, tracker-neutrally.

    Sampler workers are always *spawned children* of the owner, and spawn
    hands them the owner's resource-tracker fd — so their attach-time
    registration is a set-level no-op on the tracker the owner already
    registered with, and the owner's eventual ``unlink()`` unregisters the
    single entry.  Explicit ``track=False`` / ``unregister`` games are not
    only unnecessary here, they *remove the owner's entry* (same tracker!)
    and break crash cleanup.  ``owner_pid`` is carried in the handle for
    diagnostics and for any future non-child attacher that would need its
    own untracking."""
    return shared_memory.SharedMemory(name=name)


class SharedHetGraph:
    """Owner handle of a shared graph segment (see module docstring)."""

    def __init__(self, shm: shared_memory.SharedMemory, handle: GraphHandle):
        self._shm = shm
        self.handle = handle
        self._closed = False
        self._unlinked = False

    # owner-side (writable) view, used by share_graph to populate and by
    # tests to verify the attach path is genuinely zero-copy
    def _array(self, key: str) -> np.ndarray:
        refs = dict(self.handle.arrays)
        return _view(self._shm.buf, refs[key], writeable=True)

    @property
    def nbytes(self) -> int:
        return self._shm.size

    def close(self) -> None:
        """Unmap the owner's view (the segment itself stays until unlink)."""
        if not self._closed:
            self._closed = True
            self._shm.close()

    def unlink(self) -> None:
        """Remove the segment from the OS.  Idempotent; implies close()."""
        self.close()
        if not self._unlinked:
            self._unlinked = True
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass

    def __enter__(self) -> "SharedHetGraph":
        return self

    def __exit__(self, *exc) -> None:
        self.unlink()

    def __del__(self):  # best-effort: never leak a segment on error paths
        try:
            self.unlink()
        except BaseException:
            pass


class AttachedHetGraph:
    """A worker's zero-copy view of a shared graph segment.

    ``graph`` is a fully functional read-only :class:`HetGraph`; ``tables``
    maps exported staging-table names to read-only arrays.  Keep this object
    alive as long as any view is in use; ``close()`` unmaps."""

    def __init__(self, handle: GraphHandle):
        self.handle = handle
        self._shm = _open_attached(handle.segment, handle.owner_pid)
        self._closed = False
        refs = dict(handle.arrays)
        relations: Dict[Relation, CSR] = {}
        for i, (src, etype, dst) in enumerate(handle.relations):
            relations[Relation(src, etype, dst)] = CSR(
                indptr=_view(self._shm.buf, refs[f"rel/{i}/indptr"]),
                indices=_view(self._shm.buf, refs[f"rel/{i}/indices"]),
            )
        features = {
            k[len("feat/"):]: _view(self._shm.buf, r)
            for k, r in refs.items() if k.startswith("feat/")
        }
        self.graph = HetGraph(
            num_nodes=dict(handle.num_nodes),
            relations=relations,
            target_type=handle.target_type,
            num_classes=handle.num_classes,
            features=features,
            labels=_view(self._shm.buf, refs["labels"]),
            train_nodes=_view(self._shm.buf, refs["train_nodes"]),
            name=handle.graph_name,
        )
        self.tables: Dict[str, np.ndarray] = {
            k[len("table/"):]: _view(self._shm.buf, r)
            for k, r in refs.items() if k.startswith("table/")
        }

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self.graph = None
            self.tables = {}
            self._shm.close()

    def __enter__(self) -> "AttachedHetGraph":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except BaseException:
            pass


def share_graph(
    graph: HetGraph,
    include_features: bool = True,
    tables: Optional[Dict[str, np.ndarray]] = None,
    name: Optional[str] = None,
) -> SharedHetGraph:
    """Export ``graph`` (and optional staging ``tables``) into one segment.

    ``include_features=False`` skips the graph's dense feature arrays —
    sampler-only pools never read them, and staging pools read the
    authoritative ``tables`` snapshot instead (which includes frozen
    learnable rows the graph doesn't carry).  Transactional: a failure while
    populating closes and unlinks the segment before re-raising.
    """
    rel_list: List[Tuple[Relation, CSR]] = sorted(
        graph.relations.items(), key=lambda rc: rc[0]
    )
    arrays: Dict[str, np.ndarray] = {}
    for i, (_, csr) in enumerate(rel_list):
        arrays[f"rel/{i}/indptr"] = csr.indptr
        arrays[f"rel/{i}/indices"] = csr.indices
    arrays["labels"] = np.asarray(graph.labels)
    arrays["train_nodes"] = np.asarray(graph.train_nodes)
    if include_features:
        for t, f in graph.features.items():
            arrays[f"feat/{t}"] = np.ascontiguousarray(f)
    for tname, tab in (tables or {}).items():
        arrays[f"table/{tname}"] = np.ascontiguousarray(tab)

    refs, total = _layout(arrays)
    segment = name or f"{SEGMENT_PREFIX}{os.getpid():x}-{secrets.token_hex(4)}"
    shm = shared_memory.SharedMemory(name=segment, create=True, size=total)
    handle = GraphHandle(
        segment=segment,
        owner_pid=os.getpid(),
        # insertion order, NOT sorted: per-type arena offsets downstream
        # follow the graph dict's iteration order, so the attached twin
        # must reproduce it exactly (DESIGN.md §13)
        num_nodes=tuple(graph.num_nodes.items()),
        relations=tuple((r.src, r.etype, r.dst) for r, _ in rel_list),
        target_type=graph.target_type,
        num_classes=int(graph.num_classes),
        graph_name=graph.name,
        arrays=tuple(refs.items()),
    )
    store = SharedHetGraph(shm, handle)
    try:
        for key, arr in arrays.items():
            np.copyto(store._array(key), arr, casting="no")
    except BaseException:
        store.unlink()
        raise
    return store


def attach(handle: GraphHandle) -> AttachedHetGraph:
    """Map the segment described by ``handle`` (see :class:`AttachedHetGraph`)."""
    return AttachedHetGraph(handle)


# --------------------------------------------------------------------------
# generic shared array bundles (the serving tier's embedding store backing)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArraysHandle:
    """Picklable description of a generic shared array bundle.

    The graph-shaped :class:`GraphHandle` above hard-codes the HetGraph
    layout; the serving tier (``repro.serve``, DESIGN.md §10) exports a
    *flat* dict of named arrays — per-type embedding tables plus the
    classifier head — so serving processes attach the materialized store
    zero-copy.  ``meta`` carries small string key/value pairs (target type,
    class count, per-type layer indices) alongside the array refs.
    """

    segment: str
    owner_pid: int
    arrays: Tuple[Tuple[str, ArrayRef], ...]
    meta: Tuple[Tuple[str, str], ...] = ()

    @property
    def meta_dict(self) -> Dict[str, str]:
        return dict(self.meta)


class SharedArrays:
    """Owner handle of a shared array bundle (same lifecycle discipline as
    :class:`SharedHetGraph`: ``close()`` unmaps, ``unlink()`` removes,
    ``__exit__``/``__del__`` never leak a segment)."""

    def __init__(self, shm: shared_memory.SharedMemory, handle: ArraysHandle):
        self._shm = shm
        self.handle = handle
        self._closed = False
        self._unlinked = False

    def array(self, key: str) -> np.ndarray:
        """Owner-side writable view of one array in the segment."""
        refs = dict(self.handle.arrays)
        return _view(self._shm.buf, refs[key], writeable=True)

    def arrays(self) -> Dict[str, np.ndarray]:
        """Owner-side writable views of every array, keyed as exported."""
        return {k: _view(self._shm.buf, r, writeable=True)
                for k, r in self.handle.arrays}

    @property
    def nbytes(self) -> int:
        return self._shm.size

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._shm.close()

    def unlink(self) -> None:
        self.close()
        if not self._unlinked:
            self._unlinked = True
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass

    def __enter__(self) -> "SharedArrays":
        return self

    def __exit__(self, *exc) -> None:
        self.unlink()

    def __del__(self):
        try:
            self.unlink()
        except BaseException:
            pass


class AttachedArrays:
    """A reader's zero-copy view of a shared array bundle.

    ``arrays`` maps exported names to read-only views into the segment; keep
    this object alive while any view is in use.  ``close()`` unmaps and is
    idempotent; attaching never unlinks the owner's segment."""

    def __init__(self, handle: ArraysHandle):
        self.handle = handle
        self._shm = _open_attached(handle.segment, handle.owner_pid)
        self._closed = False
        self.arrays: Dict[str, np.ndarray] = {
            k: _view(self._shm.buf, r) for k, r in handle.arrays
        }

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self.arrays = {}
            self._shm.close()

    def __enter__(self) -> "AttachedArrays":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except BaseException:
            pass


def share_arrays(
    arrays: Dict[str, np.ndarray],
    meta: Optional[Dict[str, str]] = None,
    name: Optional[str] = None,
) -> SharedArrays:
    """Export a dict of named arrays into one shared segment.

    Transactional like :func:`share_graph`: a failure while populating
    closes and unlinks the segment before re-raising, so error paths never
    leak ``/dev/shm`` space."""
    src = {k: np.ascontiguousarray(v) for k, v in arrays.items()}
    refs, total = _layout(src)
    segment = name or f"{SEGMENT_PREFIX}{os.getpid():x}-{secrets.token_hex(4)}"
    shm = shared_memory.SharedMemory(name=segment, create=True, size=total)
    handle = ArraysHandle(
        segment=segment,
        owner_pid=os.getpid(),
        arrays=tuple(refs.items()),
        meta=tuple(sorted((meta or {}).items())),
    )
    store = SharedArrays(shm, handle)
    try:
        for key, arr in src.items():
            np.copyto(store.array(key), arr, casting="no")
    except BaseException:
        store.unlink()
        raise
    return store


def attach_arrays(handle: ArraysHandle) -> AttachedArrays:
    """Map the bundle described by ``handle`` (see :class:`AttachedArrays`)."""
    return AttachedArrays(handle)


# --------------------------------------------------------------------------
# batch arena — fixed-slot shm ring buffer for the worker→consumer hot path
# --------------------------------------------------------------------------
#
# DESIGN.md §11.  N sampler workers write sampled batches (and, when a
# StackRecipe is active, the pre-staged host arrays) directly into fixed
# per-worker slots of one shared segment; the mp.Queue between worker and
# consumer carries only a tiny picklable slot descriptor — zero pickled
# ndarrays on the hot path.
#
# Concurrency model (pragmatic seqlock — single writer per word, aligned
# 8-byte loads/stores, which x86-64 and AArch64 perform atomically and
# in order for this single-producer/single-consumer pattern):
#
#   per slot:  write_seq   (worker-owned)   odd while the worker is writing,
#                                           ``2*use + 2`` once generation
#                                           ``use`` of the slot is complete
#              release_seq (consumer-owned) number of completed consumptions;
#                                           the worker may overwrite the slot
#                                           for generation ``use`` only once
#                                           ``release_seq >= use``
#   tables:    one global version word, odd while the trainer republishes
#              learnable tables; readers copy-then-revalidate (torn reads
#              retry).  Immutable table regions skip the copy and hand out
#              zero-copy views.
#
# Slot assignment is a pure function of the pool item index (stripe order,
# matching ``worker_pool``): worker ``w = i % stride`` owns the sub-ring
# ``[w*depth, (w+1)*depth)``, so no two writers ever share a slot and no
# cross-process allocator is needed.  Backpressure falls out of the
# release gate: when every slot of a worker's sub-ring is in flight the
# worker polls until the consumer releases one (or the pool stops).


_CTRL_WORDS = 2  # per-slot control: [write_seq, release_seq]

# write_seq value stamped by invalidate_worker_slots: odd (so resolve()
# rejects it as torn) and impossibly large (so it can never collide with a
# live generation's 2*use+1) — any SlotRef that still points at the slot
# fails loudly instead of reading a dead worker's half-written payload
_POISON_SEQ = (1 << 63) | 1


class ArenaStalledError(RuntimeError):
    """An arena writer's backpressure poll timed out (DESIGN.md §12).

    The release gate (`release_seq >= use`) is consumer-driven; if the
    consumer process dies without setting the pool stop event, a worker
    blocked on a full sub-ring would spin forever.  The bounded wait turns
    that hang into this error, so the worker exits and the death is
    observable."""


@dataclasses.dataclass(frozen=True)
class ArenaHandle:
    """Picklable description of a batch-arena segment.

    ``fields`` are slot-relative :class:`ArrayRef`\\ s (identical layout in
    every slot); ``tables`` are segment-absolute refs of the staging-table
    region.  ``stride`` is the worker count; ``slot_for`` maps a pool item
    index to its (slot, generation) pair."""

    segment: str
    owner_pid: int
    stride: int  # worker count; worker w owns slots [w*depth, (w+1)*depth)
    depth: int  # slots per worker (= pool prefetch depth)
    fields: Tuple[Tuple[str, ArrayRef], ...]  # slot-relative layout
    slot_bytes: int  # aligned byte stride between consecutive slots
    slots_offset: int  # absolute offset of slot 0
    tables: Tuple[Tuple[str, ArrayRef], ...] = ()  # absolute offsets
    tables_mutable: bool = False

    @property
    def n_slots(self) -> int:
        return self.stride * self.depth

    def slot_for(self, item: int) -> Tuple[int, int]:
        """Map pool item index -> (slot, use generation)."""
        w, k = item % self.stride, item // self.stride
        return w * self.depth + k % self.depth, k // self.depth


class _ArenaOps:
    """Slot/table protocol shared by the owner and attached sides."""

    _shm: shared_memory.SharedMemory
    handle: ArenaHandle

    def _bind_views(self) -> None:
        h = self.handle
        buf = self._shm.buf
        self._tver = np.ndarray((1,), dtype=np.uint64, buffer=buf, offset=0)
        self._ctrl = np.ndarray((h.n_slots, _CTRL_WORDS), dtype=np.uint64,
                                buffer=buf, offset=_ALIGN)
        self._table_refs = dict(h.tables)

    # -- slot protocol ----------------------------------------------------

    def slot_views(self, slot: int, writable: bool = False
                   ) -> Dict[str, np.ndarray]:
        """Views of one slot's arrays (writable only on the writing worker)."""
        base = self.handle.slots_offset + slot * self.handle.slot_bytes
        buf = self._shm.buf
        return {
            k: _view(buf, ArrayRef(base + r.offset, r.shape, r.dtype),
                     writeable=writable)
            for k, r in self.handle.fields
        }

    def slot_state(self, slot: int) -> Tuple[int, int]:
        """(write_seq, release_seq) of one slot."""
        return int(self._ctrl[slot, 0]), int(self._ctrl[slot, 1])

    def wait_writable(self, slot: int, use: int, stop=None,
                      timeout: Optional[float] = None,
                      poll: float = 5e-4) -> bool:
        """Block until generation ``use`` of ``slot`` may be written.

        Returns False if ``stop`` is set or ``timeout`` elapses first (the
        backpressure gate doubles as the pool-shutdown exit)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while int(self._ctrl[slot, 1]) < use:
            if stop is not None and stop.is_set():
                return False
            if deadline is not None and time.monotonic() >= deadline:
                return False
            time.sleep(poll)
        return True

    def begin_write(self, slot: int, use: int) -> None:
        self._ctrl[slot, 0] = 2 * use + 1  # odd: payload being written

    def end_write(self, slot: int, use: int) -> None:
        self._ctrl[slot, 0] = 2 * use + 2  # even: generation `use` complete

    def resolve(self, slot: int, use: int) -> Dict[str, np.ndarray]:
        """Consumer side: read-only views of a completed slot generation.

        The descriptor arrives on the queue strictly after ``end_write``, so
        an odd/short ``write_seq`` here is a protocol violation, not a race."""
        seq = int(self._ctrl[slot, 0])
        if seq == _POISON_SEQ:
            raise RuntimeError(
                f"arena slot {slot} generation {use}: slot was invalidated "
                "after its writer died (stale SlotRef; DESIGN.md §12)")
        if seq != 2 * use + 2:
            raise RuntimeError(
                f"arena slot {slot} generation {use}: write_seq={seq}, "
                f"expected {2 * use + 2} (torn or out-of-order write)")
        return self.slot_views(slot, writable=False)

    def release(self, slot: int, use: int) -> None:
        """Consumer side: hand generation ``use`` of ``slot`` back to its
        writer.  Call only once every view of the slot is dead."""
        self._ctrl[slot, 1] = use + 1

    def poison_slot(self, slot: int) -> None:
        """Stamp one slot's ``write_seq`` torn (fault injection; the slot
        heals on the next ``begin_write``/``end_write`` pair)."""
        self._ctrl[slot, 0] = _POISON_SEQ

    def invalidate_worker_slots(self, wid: int) -> None:
        """Poison the ``write_seq`` of worker ``wid``'s whole sub-ring
        (DESIGN.md §12 slot-invalidation rule).

        Called by the pool supervisor before respawning a dead worker: a
        crashed writer may have left any of its slots mid-write (odd seq)
        or stamped-complete-but-undelivered.  Stamping every slot with the
        poison generation makes any stale :class:`SlotRef` fail loudly in
        :meth:`resolve` instead of silently yielding a torn or duplicated
        payload; the replacement worker's own ``begin_write``/``end_write``
        restores valid stamps as it deterministically replays the stripe.
        ``release_seq`` is consumer-owned and left untouched — the
        replacement writer still honors the normal backpressure gate."""
        if not 0 <= wid < self.handle.stride:
            raise ValueError(
                f"wid must be in [0, {self.handle.stride}), got {wid}")
        d = self.handle.depth
        self._ctrl[wid * d:(wid + 1) * d, 0] = _POISON_SEQ

    # -- staging-table region ---------------------------------------------

    def table_view(self, name: str, writable: bool = False) -> np.ndarray:
        return _view(self._shm.buf, self._table_refs[name], writeable=writable)

    def table_version(self) -> int:
        return int(self._tver[0])

    def publish_tables(self, updates: Dict[str, np.ndarray]) -> None:
        """Owner side: republish mutable staging tables under the seqlock."""
        if not self.handle.tables_mutable:
            raise RuntimeError("arena tables are immutable")
        self._tver[0] += 1  # odd: republish in progress
        try:
            for name, arr in updates.items():
                if name in self._table_refs:
                    np.copyto(self.table_view(name, writable=True),
                              np.asarray(arr), casting="same_kind")
        finally:
            self._tver[0] += 1

    def read_tables(self, poll: float = 5e-4
                    ) -> Tuple[Dict[str, np.ndarray], int]:
        """Consistent staging tables + the version they correspond to.

        Immutable arenas return zero-copy views; mutable ones copy under the
        seqlock and retry torn reads until a stable version brackets the
        copy."""
        if not self.handle.tables_mutable:
            return ({k: self.table_view(k) for k in self._table_refs},
                    self.table_version())
        while True:
            v1 = self.table_version()
            if v1 % 2:  # republish in flight
                time.sleep(poll)
                continue
            out = {k: np.array(self.table_view(k), copy=True)
                   for k in self._table_refs}
            if self.table_version() == v1:
                return out, v1
            # torn read: a republish landed mid-copy — retry


class BatchArena(_ArenaOps):
    """Owner handle of a batch-arena segment (same lifecycle discipline as
    :class:`SharedHetGraph`: ``close()`` unmaps, ``unlink()`` removes,
    ``__exit__``/``__del__`` never leak a segment)."""

    def __init__(self, shm: shared_memory.SharedMemory, handle: ArenaHandle):
        self._shm = shm
        self.handle = handle
        self._closed = False
        self._unlinked = False
        self._bind_views()

    @property
    def nbytes(self) -> int:
        return self._shm.size

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._tver = self._ctrl = None
            self._shm.close()

    def unlink(self) -> None:
        self.close()
        if not self._unlinked:
            self._unlinked = True
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass

    def __enter__(self) -> "BatchArena":
        return self

    def __exit__(self, *exc) -> None:
        self.unlink()

    def __del__(self):
        try:
            self.unlink()
        except BaseException:
            pass


class AttachedArena(_ArenaOps):
    """A worker's view of a batch arena (write side of the slot protocol)."""

    def __init__(self, handle: ArenaHandle):
        self.handle = handle
        self._shm = _open_attached(handle.segment, handle.owner_pid)
        self._closed = False
        self._bind_views()

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._tver = self._ctrl = None
            self._shm.close()

    def __enter__(self) -> "AttachedArena":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except BaseException:
            pass


def create_arena(
    fields: Dict[str, np.ndarray],
    num_workers: int,
    depth: int,
    tables: Optional[Dict[str, np.ndarray]] = None,
    tables_mutable: bool = False,
    name: Optional[str] = None,
) -> BatchArena:
    """Create a batch arena sized from probe arrays.

    ``fields`` is a probe batch/staging dict — only shapes and dtypes are
    read (slot layouts are static: the sampler pads every level to fixed
    ``[R_d, N_d]`` and the recipe pads features to ``d_pad``).  ``tables``
    are copied into the table region; ``tables_mutable=True`` arms the
    seqlock so :meth:`~_ArenaOps.publish_tables` may republish them while
    workers stage.  Transactional like :func:`share_graph`."""
    if num_workers < 1 or depth < 1:
        raise ValueError(f"need num_workers >= 1 and depth >= 1, got "
                         f"{num_workers}, {depth}")
    slot_refs, slot_bytes = _layout(fields)
    slot_bytes = -(-slot_bytes // _ALIGN) * _ALIGN
    table_refs, table_bytes = _layout(tables or {})
    n_slots = num_workers * depth
    ctrl_bytes = n_slots * _CTRL_WORDS * 8
    tables_off = _ALIGN + (-(-ctrl_bytes // _ALIGN) * _ALIGN)
    slots_off = tables_off + (-(-table_bytes // _ALIGN) * _ALIGN)
    total = slots_off + n_slots * slot_bytes

    segment = name or f"{SEGMENT_PREFIX}{os.getpid():x}-{secrets.token_hex(4)}"
    shm = shared_memory.SharedMemory(name=segment, create=True, size=total)
    handle = ArenaHandle(
        segment=segment,
        owner_pid=os.getpid(),
        stride=num_workers,
        depth=depth,
        fields=tuple(slot_refs.items()),
        slot_bytes=slot_bytes,
        slots_offset=slots_off,
        tables=tuple((k, ArrayRef(tables_off + r.offset, r.shape, r.dtype))
                     for k, r in table_refs.items()),
        tables_mutable=tables_mutable,
    )
    arena = BatchArena(shm, handle)
    try:
        arena._tver[0] = 0
        arena._ctrl[:] = 0
        for tname, tab in (tables or {}).items():
            np.copyto(arena.table_view(tname, writable=True),
                      np.ascontiguousarray(tab), casting="no")
    except BaseException:
        arena.unlink()
        raise
    return arena


def attach_arena(handle: ArenaHandle) -> AttachedArena:
    """Map the arena described by ``handle`` (see :class:`AttachedArena`)."""
    return AttachedArena(handle)


def live_segments(prefix: str = SEGMENT_PREFIX) -> List[str]:
    """Names of shared-memory segments currently present (the leak check).

    Reads ``/dev/shm``; returns ``[]`` on platforms without it (the tests
    that use this skip there)."""
    try:
        return sorted(n for n in os.listdir("/dev/shm") if n.startswith(prefix))
    except FileNotFoundError:
        return []


def _segment_owner_pid(name: str) -> Optional[int]:
    """Parse the creator pid encoded in a ``heta-shm-<pidhex>-<token>``
    segment name (None when the name doesn't follow the convention)."""
    rest = name[len(SEGMENT_PREFIX):]
    pid_hex, sep, _ = rest.partition("-")
    if not sep or not pid_hex:
        return None
    try:
        return int(pid_hex, 16)
    except ValueError:
        return None


def cleanup_stale_segments(prefix: str = SEGMENT_PREFIX) -> List[str]:
    """Unlink orphaned ``/dev/shm`` segments whose creator is dead
    (the shm janitor; DESIGN.md §12).

    Every segment this package creates embeds its creator's pid in the
    name (``heta-shm-<pidhex>-<token>``).  A hard-crashed owner — SIGKILL,
    OOM — never runs ``unlink()``, and when the crash takes the
    ``resource_tracker`` down with it nothing reclaims the segment: the
    leak survives until reboot.  This sweep runs at session start
    (``Heta.build_graph``; also ``launch/train.py --shm-cleanup``): any
    segment under ``prefix`` whose named creator no longer exists is
    unlinked.  Conservative by construction — a live pid (even a recycled
    one), an unparsable name, or this process's own segments are skipped,
    so a concurrent healthy run is never touched.  Returns the names
    removed."""
    removed: List[str] = []
    for name in live_segments(prefix):
        pid = _segment_owner_pid(name)
        if pid is None or pid == os.getpid():
            continue
        try:
            os.kill(pid, 0)
            continue  # creator alive: not ours to reap
        except ProcessLookupError:
            pass
        except PermissionError:
            continue  # pid exists under another uid
        try:
            os.unlink(os.path.join("/dev/shm", name))
            removed.append(name)
        except FileNotFoundError:
            pass  # lost the race to another janitor
        except OSError:
            pass  # best-effort: never fail session start over a sweep
    return removed
