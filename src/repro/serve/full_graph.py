"""Layer-wise full-graph inference over the metatree plan (DESIGN.md §10).

Training samples fixed-fanout subtrees per seed; inference wants the
embedding of *every* node, and re-sampling a tree per query does redundant
work proportional to fanout^k.  Following GraphStorm's ``dist_inference``
pattern, this module computes level-l representations for **all** nodes of
every type before advancing to level l+1, so each node's layer-l value is
computed exactly once and reused by every consumer at layer l+1.

Equivalence with the minibatch forward (the serving tier's Prop-1):

  * the metatree expands *every* in-relation of every frontier type, so the
    relation set feeding a node depends only on (node type, layer) — not on
    which branch of which seed's tree the node appeared in;
  * attention queries are always the destination node's *input* features
    (DESIGN.md §7), so a node's layer-l value needs only (a) its own input
    features and (b) its in-neighbors' layer-(l-1) values;
  * with exhaustive neighborhoods (fanout = max in-degree, full CSR
    neighbor lists, padding masked) the sampled tree around any seed
    contains exactly the full neighborhoods the recurrence uses.

Hence the recurrence, for layer l = 1..k over level d = k-l+1 of the plan:

    REP[l][t][v] = sum_r AGG_r(params(r, t, l), {h_u : u in N_r(v)}, q=x_t[v])

with h_u = padded input features at l=1, else relu(REP[l-1][src(r)][u])
(zeros for types with no in-relations — the tree's leaf-at-intermediate-
depth case), and logits = relu(REP[k][target]) @ head.  Branch parameters
are gathered *from the same [P, U, ...] stacks the SPMD executor trains*
(via the plan's slot tables), and the per-level compute is the same
``stacked_agg`` dispatch — fused Pallas kernels or the vmap oracle — the
training step runs, with the same combine structure (``segment_sum`` at
inner levels, ``jnp.sum`` + head at the root).  ``tests/
test_serve_full_graph.py`` asserts per-node equality against the minibatch
``raf_spmd`` forward for rgcn/rgat/hgt.

The materialized :class:`EmbeddingStore` holds one float32 host array per
node type (pre-ReLU top-layer representations) plus the classifier head;
``shm=True`` backs it with a ``repro.graph.shm`` segment so serving
processes attach zero-copy (:meth:`EmbeddingStore.attach`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.data.staging import _padded_gather
from repro.graph.hetgraph import CSR, HetGraph
from repro.graph.sampler import Level, SampledBatch, SampleSpec
from repro.graph.shm import ArraysHandle, AttachedArrays, SharedArrays, attach_arrays, share_arrays

__all__ = [
    "EmbeddingStore",
    "infer_all",
    "exhaustive_fanouts",
    "exhaustive_batch",
    "bounded_graph",
    "spmd_logits_for_batch",
]

# cap on one chunk's gathered-neighbor tensor [n_sel, block, f, d_in]; the
# effective node block shrinks below ServeConfig.node_block when a level's
# fanout (= max in-degree) would otherwise blow host/device memory
_BLOCK_BUDGET_BYTES = 128 << 20


# --------------------------------------------------------------------------
# exhaustive neighborhoods (full CSR lists, padding masked)
# --------------------------------------------------------------------------


def _full_neighbors(
    csr: CSR, parents: np.ndarray, parent_mask: np.ndarray, fanout: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Every in-neighbor of each parent, CSR order, padded to ``fanout``.

    The deterministic counterpart of ``sample_neighbors``: slot j of parent v
    holds ``indices[indptr[v] + j]`` for j < deg(v), masked beyond.  Raises
    when any parent's degree exceeds ``fanout`` (exhaustiveness violated)."""
    n = len(parents)
    if csr.num_edges == 0:
        return np.zeros((n, fanout), np.int64), np.zeros((n, fanout), bool)
    deg = csr.indptr[parents + 1] - csr.indptr[parents]
    if int(deg.max(initial=0)) > fanout:
        raise ValueError(
            f"fanout {fanout} < max in-degree {int(deg.max())}: exhaustive "
            "neighborhoods need fanout >= the level's max in-degree"
        )
    cols = np.arange(fanout)
    raw = csr.indptr[parents][:, None] + cols[None, :]
    valid = (cols[None, :] < deg[:, None]) & parent_mask[:, None]
    raw = np.minimum(raw, csr.num_edges - 1)
    idx = np.where(valid, csr.indices[raw], 0)
    return idx, valid


def exhaustive_fanouts(graph: HetGraph, spec: SampleSpec) -> Tuple[int, ...]:
    """Per-level fanouts that make sampling exhaustive: the max in-degree
    over the level's relations (min 1).  A batch sampled with these fanouts
    via :func:`exhaustive_batch` contains every neighbor of every node."""
    out = []
    for branches in spec.levels:
        f = 1
        for b in branches:
            csr = graph.relations[b.rel]
            deg = csr.indptr[1:] - csr.indptr[:-1]
            if len(deg):
                f = max(f, int(deg.max(initial=0)))
        out.append(f)
    return tuple(out)


def bounded_graph(graph: HetGraph, cap: int) -> HetGraph:
    """A copy of ``graph`` with per-node in-degree capped at ``cap`` (the
    first ``cap`` CSR neighbors kept).

    The synthetic dataset family's Zipf skew produces hub nodes with
    thousands of in-edges, which makes exhaustive neighborhoods — fanout =
    max in-degree — intractable for the minibatch side of a parity check.
    Tests, benchmarks and demos train *and* infer on the capped graph, so
    the equivalence being asserted is unaffected."""
    rels = {}
    for rel, csr in graph.relations.items():
        deg = csr.indptr[1:] - csr.indptr[:-1]
        keep = np.minimum(deg, cap)
        indptr = np.zeros(len(deg) + 1, csr.indptr.dtype)
        np.cumsum(keep, out=indptr[1:])
        pos = (np.repeat(csr.indptr[:-1], keep)
               + np.arange(int(keep.sum())) - np.repeat(indptr[:-1], keep))
        rels[rel] = CSR(indptr=indptr, indices=csr.indices[pos])
    return HetGraph(
        num_nodes=dict(graph.num_nodes),
        relations=rels,
        target_type=graph.target_type,
        num_classes=graph.num_classes,
        features=dict(graph.features),
        labels=graph.labels,
        train_nodes=graph.train_nodes,
        name=f"{graph.name}-deg{cap}",
    )


def exhaustive_batch(
    graph: HetGraph, spec: SampleSpec, seeds: np.ndarray
) -> SampledBatch:
    """A :class:`SampledBatch` whose levels hold *full* neighbor lists.

    Requires ``spec.fanouts >= exhaustive_fanouts(graph, spec)`` per level.
    The minibatch forward on such a batch sees exactly the neighborhoods the
    layer-wise engine aggregates — the per-node parity fixture."""
    seeds = np.asarray(seeds, dtype=np.int64)
    levels: List[Level] = []
    prev_nids: List[np.ndarray] = [seeds]
    prev_mask: List[np.ndarray] = [np.ones(len(seeds), dtype=bool)]
    for d, branches in enumerate(spec.levels, start=1):
        f = spec.fanouts[d - 1]
        nids = np.zeros((len(branches), len(prev_nids[0]) * f), dtype=np.int64)
        mask = np.zeros_like(nids, dtype=bool)
        for b, bs in enumerate(branches):
            csr = graph.relations[bs.rel]
            idx, m = _full_neighbors(
                csr, prev_nids[bs.parent], prev_mask[bs.parent], f
            )
            nids[b] = idx.reshape(-1)
            mask[b] = m.reshape(-1)
        levels.append(Level(nids=nids, mask=mask))
        prev_nids = [nids[b] for b in range(len(branches))]
        prev_mask = [mask[b] for b in range(len(branches))]
    labels = graph.labels[seeds]
    return SampledBatch(spec, seeds, labels, levels)


# --------------------------------------------------------------------------
# the materialized store
# --------------------------------------------------------------------------


@dataclasses.dataclass
class EmbeddingStore:
    """Per-type top-layer representations + classifier head (DESIGN.md §10).

    ``embeddings[t]`` is the float32 **pre-ReLU** layer-``layer_of[t]``
    representation of every node of type ``t`` (the value the next layer —
    or the head — would consume through ``relu``); only types that are a
    destination somewhere in the metatree have an entry (pure leaf types
    keep their input features as their representation).  ``scores`` applies
    ``relu`` + the head to target-type rows.  When shm-backed, ``handle``
    is picklable and :meth:`attach` maps the store zero-copy in another
    process; :meth:`close` unlinks (owner) or unmaps (attached)."""

    target_type: str
    num_classes: int
    hidden: int
    embeddings: Dict[str, np.ndarray]
    layer_of: Dict[str, int]
    head: Dict[str, np.ndarray]
    handle: Optional[ArraysHandle] = None
    _segment: object = None  # SharedArrays (owner) | AttachedArrays | None
    _score_fn: object = dataclasses.field(default=None, repr=False)

    def embedding(self, ntype: str, nids) -> np.ndarray:
        """Stored (pre-ReLU) rows for ``nids`` of ``ntype``."""
        return self.embeddings[ntype][np.asarray(nids)]

    def scores(self, nids) -> np.ndarray:
        """Class logits for target-type nodes: relu(rep) @ W + b."""
        import jax
        import jax.numpy as jnp

        if self._score_fn is None:
            w = jnp.asarray(self.head["w"])
            b = jnp.asarray(self.head["b"])
            self._score_fn = jax.jit(
                lambda e: jax.nn.relu(e) @ w + b)
        emb = self.embeddings[self.target_type][np.asarray(nids)]
        return np.asarray(self._score_fn(jnp.asarray(emb)))

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in self.embeddings.values()) + sum(
            a.nbytes for a in self.head.values())

    @classmethod
    def attach(cls, handle: ArraysHandle) -> "EmbeddingStore":
        """Map a shm-backed store exported by :func:`infer_all` zero-copy."""
        seg = attach_arrays(handle)
        meta = handle.meta_dict
        embeddings = {k[len("emb/"):]: v for k, v in seg.arrays.items()
                      if k.startswith("emb/")}
        return cls(
            target_type=meta["target_type"],
            num_classes=int(meta["num_classes"]),
            hidden=int(meta["hidden"]),
            embeddings=embeddings,
            layer_of={t: int(meta[f"layer/{t}"]) for t in embeddings},
            head={"w": seg.arrays["head/w"], "b": seg.arrays["head/b"]},
            handle=handle,
            _segment=seg,
        )

    def close(self) -> None:
        """Release shm backing: owners unlink the segment, attached readers
        unmap their view.  Idempotent; plain-array stores are a no-op."""
        seg, self._segment = self._segment, None
        if seg is None:
            return
        self.embeddings = {}
        self.head = {}
        if isinstance(seg, SharedArrays):
            seg.unlink()
        else:
            seg.close()


def _shm_backed(store: EmbeddingStore) -> EmbeddingStore:
    """Re-materialize a store's arrays inside one shared segment."""
    arrays = {f"emb/{t}": a for t, a in store.embeddings.items()}
    arrays["head/w"] = store.head["w"]
    arrays["head/b"] = store.head["b"]
    meta = {
        "target_type": store.target_type,
        "num_classes": str(store.num_classes),
        "hidden": str(store.hidden),
        **{f"layer/{t}": str(l) for t, l in store.layer_of.items()},
    }
    seg = share_arrays(arrays, meta=meta)
    views = seg.arrays()
    store.embeddings = {t: views[f"emb/{t}"] for t in store.embeddings}
    store.head = {"w": views["head/w"], "b": views["head/b"]}
    store.handle = seg.handle
    store._segment = seg
    return store


# --------------------------------------------------------------------------
# the layer-wise engine
# --------------------------------------------------------------------------


def _host_stacks(stacks: Dict) -> Dict:
    """Pull the (possibly sharded) trained stacks to host numpy once."""
    return {
        layer: {leaf: np.asarray(v) for leaf, v in entry.items()}
        for layer, entry in stacks.items()
    }


def _slot_of(lp) -> Dict[int, Tuple[int, int]]:
    """Invert ``slot_branch``: original branch index -> (shard, slot)."""
    out: Dict[int, Tuple[int, int]] = {}
    sb = lp.slot_branch
    for p in range(sb.shape[0]):
        for s in range(sb.shape[1]):
            b = int(sb[p, s])
            if b >= 0:
                out[b] = (p, s)
    return out


def _dedup_groups(plan, d: int) -> Dict[str, List[int]]:
    """Branches at level ``d`` grouped by dst type, one per relation.

    The metatree repeats (dst type, relation) pairs once per parent branch
    of that type; parameters and neighbor sets depend only on the pair, so
    the engine aggregates each relation once per type — first occurrence,
    which preserves the child order (= sorted in-relation order) any single
    parent's children have in the minibatch tree."""
    groups: Dict[str, List[int]] = {}
    seen: Dict[str, set] = {}
    for b, bs in enumerate(plan.spec.levels[d - 1]):
        t = plan.dst_types[d - 1][b]
        if bs.rel not in seen.setdefault(t, set()):
            seen[t].add(bs.rel)
            groups.setdefault(t, []).append(b)
    return groups


def _gather_branch_params(plan, lp, host_stacks, sel, slot_of):
    """Per-leaf ``[n_sel, ...]`` parameter rows for the selected branches,
    gathered from the trained ``[P, U, ...]`` stacks via the plan's slot
    tables — no unstacking back to dict form."""
    module = plan.module
    scope_of = {s.name: s.scope for s in module.specs}
    layer_entry = host_stacks[f"layer{lp.layer}"]
    out = {}
    for leaf, slab in layer_entry.items():
        rows = []
        for b in sel:
            p, s = slot_of[b]
            u = int(lp.slot_u[scope_of[leaf]][p, s])
            rows.append(slab[p, u])
        out[leaf] = np.stack(rows)
    return out


def _group_fanout(graph: HetGraph, plan, d: int, sel: List[int]) -> int:
    """Max in-degree over the selected branches' relations (min 1).

    Masked padding slots contribute exact zeros to every aggregation, so a
    per-group fanout (tighter than the level-wide max) changes nothing
    numerically while bounding the gathered tensor."""
    f = 1
    for b in sel:
        csr = graph.relations[plan.spec.levels[d - 1][b].rel]
        deg = csr.indptr[1:] - csr.indptr[:-1]
        if len(deg):
            f = max(f, int(deg.max(initial=0)))
    return f


def infer_all(
    graph: HetGraph,
    plan,
    stacks: Dict,
    tables: Dict[str, np.ndarray],
    *,
    node_block: int = 1024,
    kernels=None,
    shm: bool = False,
) -> EmbeddingStore:
    """Materialize top-layer representations for every node of every type.

    ``plan``/``stacks`` are the SPMD executor's :class:`~repro.core.
    raf_spmd.StackedPlan` and trained parameter stacks; ``tables`` is a full
    feature-table snapshot (``EmbedEngine.tables_snapshot()``).  Nodes are
    processed in ``node_block`` chunks (shrunk automatically when a level's
    max in-degree would blow the block budget); ``shm=True`` backs the
    returned store with a shared segment for zero-copy serving attach."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.stacked_relation_agg import stacked_agg

    spec = plan.spec
    module = plan.module
    k = spec.num_layers
    hidden = plan.cfg.hidden
    d_pad = plan.d_pad

    def make_block_fn(root: bool):
        def fn(stacks_sel, h, q, mask):
            slot_u = {
                scope: jnp.arange(h.shape[0], dtype=jnp.int32)
                for scope in module.scopes
            }
            out = stacked_agg(module, stacks_sel, slot_u, h, q, mask,
                              opts=kernels)
            if root:
                return jnp.sum(out, axis=0)
            # mirror the inner-level combine of the minibatch forward
            # (segment_sum) so reduction structure — hence bit behavior —
            # matches the training step's
            seg = jnp.zeros((out.shape[0],), jnp.int32)
            return jax.ops.segment_sum(out, seg, num_segments=1)[0]

        return jax.jit(fn)

    block_fns = {True: make_block_fn(True), False: make_block_fn(False)}
    host_stacks = _host_stacks(stacks)

    prev_rep: Dict[str, np.ndarray] = {}
    final_rep: Dict[str, np.ndarray] = {}
    layer_of: Dict[str, int] = {}
    for l in range(1, k + 1):
        d = k - l + 1
        lp = plan.levels[d - 1]
        slot_of = _slot_of(lp)
        cur_rep: Dict[str, np.ndarray] = {}
        for t, sel in _dedup_groups(plan, d).items():
            n_sel = len(sel)
            f = _group_fanout(graph, plan, d, sel)
            d_in = lp.d_in
            num_nodes = graph.num_nodes[t]
            block = max(1, min(
                node_block, _BLOCK_BUDGET_BYTES // max(1, n_sel * f * d_in * 4)
            ))
            p_sel = jax.tree.map(jnp.asarray,
                                 _gather_branch_params(plan, lp, host_stacks,
                                                       sel, slot_of))
            rels = [spec.levels[d - 1][b].rel for b in sel]
            rep = np.zeros((num_nodes, hidden), np.float32)
            for lo in range(0, num_nodes, block):
                chunk = np.arange(lo, min(lo + block, num_nodes),
                                  dtype=np.int64)
                nb = len(chunk)
                ones = np.ones(nb, bool)
                h = np.zeros((n_sel, nb, f, d_in), np.float32)
                mask = np.zeros((n_sel, nb, f), bool)
                for i, rel in enumerate(rels):
                    csr = graph.relations[rel]
                    idx, m = _full_neighbors(csr, chunk, ones, f)
                    mask[i] = m
                    if l == 1:
                        h[i] = _padded_gather(
                            tables[rel.src], idx.reshape(-1), d_in
                        ).reshape(nb, f, d_in)
                    else:
                        src_rep = prev_rep.get(rel.src)
                        if src_rep is not None:
                            # relu of the previous layer; types with no
                            # in-relations stay zeros (the tree's
                            # leaf-at-intermediate-depth case)
                            h[i] = np.maximum(
                                src_rep[idx.reshape(-1)], 0.0
                            ).reshape(nb, f, hidden)
                q = np.broadcast_to(
                    _padded_gather(tables[t], chunk, d_pad)[None],
                    (n_sel, nb, d_pad),
                )
                out = block_fns[d == 1](
                    p_sel, jnp.asarray(h), jnp.asarray(q), jnp.asarray(mask)
                )
                rep[lo:lo + nb] = np.asarray(out)
            cur_rep[t] = rep
            final_rep[t] = rep
            layer_of[t] = l
        prev_rep = cur_rep

    store = EmbeddingStore(
        target_type=spec.target_type,
        num_classes=int(plan.cfg.num_classes),
        hidden=hidden,
        embeddings=final_rep,
        layer_of=layer_of,
        head={leaf: np.asarray(v) for leaf, v in stacks["head"].items()},
    )
    return _shm_backed(store) if shm else store


# --------------------------------------------------------------------------
# the minibatch reference (parity fixture for tests and CI)
# --------------------------------------------------------------------------


def spmd_logits_for_batch(plan, stacks, batch, tables, kernels=None):
    """Logits of one batch through the minibatch ``raf_spmd`` forward.

    The exact math of the training step's forward — ``shard_map`` over a
    (1, 1) mesh, same ``stacked_agg`` dispatch, head outside the shard_map —
    packaged for the serving tier's Prop-1 parity checks.  Requires a
    single-shard plan (fold the assignment to 1 before ``build_plan``)."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.core import raf_spmd

    if plan.num_shards != 1:
        raise ValueError(
            f"parity reference needs a 1-shard plan, got {plan.num_shards}")
    arrays = raf_spmd.stack_batch(plan, batch, tables)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rel_stacks = {k2: v for k2, v in stacks.items() if k2 != "head"}
    feats = {k2: v for k2, v in arrays.items() if "feat" in k2}
    rest = {k2: v for k2, v in arrays.items() if "feat" not in k2}

    def body(stacks_s, feats_s, rest_s):
        return raf_spmd.raf_spmd_forward(
            plan, stacks_s, {**feats_s, **rest_s}, "model", True, kernels)

    stack_specs = raf_spmd._stack_specs(plan)
    rel_specs = {k2: v for k2, v in stack_specs.items() if k2 != "head"}
    arr_specs = raf_spmd._array_specs(plan, ("data",), "model")
    root = raf_spmd.shard_map_nocheck(
        body,
        mesh=mesh,
        in_specs=(
            rel_specs,
            {k2: arr_specs[k2] for k2 in feats},
            {k2: arr_specs[k2] for k2 in rest},
        ),
        out_specs=P(("data",), None),
    )(rel_stacks, feats, rest)
    h = jax.nn.relu(root)
    return np.asarray(h @ stacks["head"]["w"] + stacks["head"]["b"])
