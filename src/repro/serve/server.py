"""Micro-batching serving executor over a materialized store (DESIGN.md §10).

Online queries arrive one at a time; the device wants big batches.  The
:class:`MicroBatcher` bridges the two with the classic latency-budget
policy: requests queue until either ``max_batch`` of them are pending or
the *oldest* has waited ``max_wait_ms``, then the whole group flushes as
one batch.  The queue is bounded (``max_queue``) — submitters block when
it is full (backpressure) rather than growing memory without bound — and a
flush failure is propagated to exactly the callers whose requests were in
that flush, mirroring the ``Prefetcher``/``WorkerPool`` failure discipline.

:class:`EmbeddingServer` is the HGNN tier's hot path: a micro-batcher whose
flush groups the queued lookups per node type, issues **one**
``FeatureCache.fetch_many`` gather per type from the layer-wise
:class:`~repro.serve.full_graph.EmbeddingStore`, and scores target-type
rows with a jitted ``relu(e) @ W + b`` step placed on the serving mesh
(``make_production_mesh`` in production; any mesh — or none — in tests).
The cache fronts the store's host arrays (which may be a zero-copy shm
attach), so repeated hot-node lookups never touch host memory twice.

Degradation (DESIGN.md §12): the primary flush path (cache gather + jitted
scoring) is wrapped in retry-with-backoff for transient failures, and a
circuit breaker — ``closed`` → (``breaker_threshold`` consecutive flush
failures) → ``open`` → (after ``breaker_cooldown_ms``) → ``half_open`` →
one probe flush → ``closed`` again or back to ``open`` — trips into a
*degraded* cache-bypass path: a direct numpy gather from the store's host
embedding arrays plus a numpy head application.  Degraded answers are
slower but correct, so callers are never rejected; trips, recoveries,
retries and degraded-answer counts surface in :class:`ServeStats`.
Per-request deadlines (``deadline_ms``) bound both the retry budget and
the default ``query`` wait.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.embed.cache import CacheAllocation, FeatureCache, allocate_cache
from repro.embed.profiler import HotnessProfile, MissPenaltyProfile
from repro.serve.full_graph import EmbeddingStore

__all__ = ["MicroBatcher", "EmbeddingServer", "ServeResult", "ServeStats"]


# --------------------------------------------------------------------------
# the micro-batcher
# --------------------------------------------------------------------------


class _Future:
    """Single-use result slot (set exactly once: value or exception)."""

    def __init__(self) -> None:
        self._event = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None

    def set_result(self, value) -> None:
        self._value = value
        self._event.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError("serve request timed out")
        if self._exc is not None:
            raise self._exc
        return self._value


class MicroBatcher:
    """Coalesce concurrent ``submit`` calls into bounded batches.

    ``process(items) -> results`` is called on a dedicated flusher thread
    with 1..``max_batch`` queued items whenever the batch fills or the
    oldest pending item ages past ``max_wait_ms``.  ``submit`` returns a
    future; it blocks while ``max_queue`` items are pending (backpressure)
    and raises once the batcher is closed.  ``close`` drains every pending
    item before the flusher exits, so in-flight callers always get an
    answer; an exception from ``process`` is delivered to exactly the
    callers in that flush and the batcher keeps serving."""

    def __init__(
        self,
        process: Callable[[List], List],
        *,
        max_batch: int = 64,
        max_wait_ms: float = 2.0,
        max_queue: int = 1024,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._process = process
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.max_queue = int(max_queue)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: deque = deque()  # (item, future, t_submit)
        self._closed = False
        self.flushes = 0
        self._thread = threading.Thread(
            target=self._run, name="serve-microbatcher", daemon=True
        )
        self._thread.start()

    # -- producer side ------------------------------------------------------

    def submit(self, item) -> _Future:
        fut = _Future()
        with self._cond:
            while not self._closed and len(self._pending) >= self.max_queue:
                self._cond.wait(0.05)
            if self._closed:
                raise RuntimeError("MicroBatcher is closed")
            self._pending.append((item, fut, time.monotonic()))
            self._cond.notify_all()
        return fut

    def __call__(self, item, timeout: Optional[float] = None):
        """Blocking submit: enqueue and wait for the flush result."""
        return self.submit(item).result(timeout)

    # -- flusher side -------------------------------------------------------

    def _take_batch(self) -> List[Tuple]:
        """Wait until a flush is due, then pop up to ``max_batch`` items.
        Returns [] only when closed with nothing left to drain."""
        budget = self.max_wait_ms / 1e3
        with self._cond:
            while True:
                if self._pending:
                    age = time.monotonic() - self._pending[0][2]
                    if (
                        len(self._pending) >= self.max_batch
                        or age >= budget
                        or self._closed
                    ):
                        n = min(len(self._pending), self.max_batch)
                        batch = [self._pending.popleft() for _ in range(n)]
                        self._cond.notify_all()  # wake backpressured submitters
                        return batch
                    self._cond.wait(budget - age)
                elif self._closed:
                    return []
                else:
                    self._cond.wait()

    def _run(self) -> None:
        while True:
            batch = self._take_batch()
            if not batch:
                return
            items = [item for item, _, _ in batch]
            try:
                results = self._process(items)
                if len(results) != len(items):
                    raise RuntimeError(
                        f"process returned {len(results)} results for "
                        f"{len(items)} items"
                    )
            except BaseException as exc:  # propagate to exactly this flush
                for _, fut, _ in batch:
                    fut.set_exception(exc)
                continue
            self.flushes += 1
            for (_, fut, _), res in zip(batch, results):
                fut.set_result(res)

    # -- lifecycle ----------------------------------------------------------

    def close(self) -> None:
        """Stop accepting work, drain in-flight requests, join the flusher."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._thread.join()

    def __enter__(self) -> "MicroBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# --------------------------------------------------------------------------
# the embedding server
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ServeResult:
    """One answered lookup: stored rows (pre-ReLU), class scores for
    target-type requests (None otherwise), and the request's end-to-end
    latency (submit -> flush complete)."""

    ntype: str
    embeddings: np.ndarray
    scores: Optional[np.ndarray]
    latency_ms: float


@dataclasses.dataclass
class ServeStats:
    count: int
    flushes: int
    p50_ms: float
    p99_ms: float
    qps: float
    hit_rates: Dict[str, float]
    # degradation bookkeeping (DESIGN.md §12)
    breaker_state: str = "closed"
    breaker_trips: int = 0
    breaker_recoveries: int = 0
    degraded: int = 0  # requests answered via the cache-bypass path
    retries: int = 0  # primary-path retry attempts

    def render(self) -> str:
        lines = [
            f"  requests={self.count}  flushes={self.flushes}  "
            f"p50={self.p50_ms:.3f} ms  p99={self.p99_ms:.3f} ms  "
            f"qps={self.qps:,.0f}"
        ]
        if self.breaker_trips or self.degraded or self.retries:
            lines.append(
                f"    breaker={self.breaker_state}  trips={self.breaker_trips}"
                f"  recoveries={self.breaker_recoveries}"
                f"  degraded={self.degraded}  retries={self.retries}")
        for t, r in sorted(self.hit_rates.items()):
            lines.append(f"    cache[{t}] hit-rate={r:.2%}")
        return "\n".join(lines)


def _build_serve_cache(
    store: EmbeddingStore, cache_mb: int, kernels=None,
    hotness: Optional[HotnessProfile] = None,
) -> FeatureCache:
    """A read-only :class:`FeatureCache` over the store's embedding tables.

    Serving has no training-time hotness trace, so absent a profile the
    budget splits uniformly across types and each type caches its
    lowest-id rows (every row is equally hot under the uniform profile;
    ``HotnessProfile.hottest`` then keeps ids stable) — benchmarks pass a
    Zipf-skewed profile to model a production request mix."""
    tables = store.embeddings
    uniform = hotness is None
    if uniform:
        hotness = HotnessProfile(
            counts={t: np.ones(a.shape[0], np.float64) for t, a in tables.items()}
        )
    total = int(cache_mb) << 20
    budget = total // max(1, len(tables))
    rows = {
        t: min(a.shape[0], budget // max(1, a.shape[1] * 4))
        for t, a in tables.items()
    }
    alloc = CacheAllocation(
        rows=rows,
        bytes_={t: rows[t] * tables[t].shape[1] * 4 for t in tables},
        total_bytes=total,
        policy="serve-uniform" if uniform else "serve",
    )
    return FeatureCache(tables, {}, alloc, hotness, kernels=kernels)


class EmbeddingServer:
    """Serve embeddings / class scores from a materialized store.

    One :class:`MicroBatcher` fronts the device: a flush groups queued
    ``(ntype, nids)`` lookups per type, gathers each type's union of rows
    in a single ``FeatureCache.fetch_many`` call, scores the target-type
    rows with one jitted head application, and splits the device batch back
    per request.  ``query`` blocks; ``submit`` returns a future for
    closed-loop concurrency tests and benchmarks."""

    def __init__(
        self,
        store: EmbeddingStore,
        *,
        max_batch: int = 64,
        max_wait_ms: float = 2.0,
        max_queue: int = 1024,
        cache_mb: int = 4,
        kernels=None,
        mesh=None,
        hotness: Optional[HotnessProfile] = None,
        readmit_every: int = 0,
        deadline_ms: float = 0.0,
        flush_retries: int = 2,
        retry_backoff_ms: float = 1.0,
        breaker_threshold: int = 3,
        breaker_cooldown_ms: float = 1000.0,
        faults=None,
    ):
        import jax
        import jax.numpy as jnp

        self.store = store
        # degradation policy (DESIGN.md §12) + deterministic fault plan
        self.deadline_ms = float(deadline_ms)
        self.flush_retries = int(flush_retries)
        self.retry_backoff_ms = float(retry_backoff_ms)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_ms = float(breaker_cooldown_ms)
        self.faults = faults
        self.breaker_state = "closed"
        self.breaker_trips = 0
        self.breaker_recoveries = 0
        self.degraded_count = 0
        self.retry_count = 0
        self._consec_failures = 0
        self._breaker_opened_t = 0.0
        self._flush_index = 0  # attempted flushes (fault-plan coordinate)
        self.cache = _build_serve_cache(store, cache_mb, kernels, hotness)
        # online re-admission from the served-id trace: every fetch_many
        # already bumps the cache's access counters, so after every
        # `readmit_every` flushes the flusher thread re-splits the same
        # byte budget across types ∝ observed traffic and re-admits each
        # type's observed-hottest rows (0 = off).  Serving fronts
        # read-only materialized embeddings, so the re-allocation is the
        # hotness-only policy (all types share one miss penalty).
        self.readmit_every = int(readmit_every)
        self.readmits = 0
        self._flush_count = 0
        self._cache_bytes = int(cache_mb) << 20
        self._hotness_ema = {
            t: (
                hotness.counts[t].astype(np.float64)
                if hotness is not None and t in hotness.counts
                else np.ones(a.shape[0], np.float64)
            )
            for t, a in store.embeddings.items()
        }
        w = jnp.asarray(store.head["w"])
        b = jnp.asarray(store.head["b"])
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            rep = NamedSharding(mesh, P())
            w = jax.device_put(w, rep)
            b = jax.device_put(b, rep)
        self._score = jax.jit(lambda e: jax.nn.relu(e) @ w + b)
        self._latencies: deque = deque(maxlen=100_000)
        self._count = 0
        self._stats_lock = threading.Lock()
        self._t_start = time.monotonic()
        self.batcher = MicroBatcher(
            self._flush,
            max_batch=max_batch,
            max_wait_ms=max_wait_ms,
            max_queue=max_queue,
        )

    # -- the flush (device hot path) ----------------------------------------

    @staticmethod
    def _group(items):
        """Group requests per type, remembering each one's batch slice."""
        grouped: Dict[str, List[np.ndarray]] = {}
        offsets: List[Tuple[str, int, int]] = []
        for ntype, nids, _ in items:
            lo = sum(len(x) for x in grouped.get(ntype, []))
            grouped.setdefault(ntype, []).append(nids)
            offsets.append((ntype, lo, lo + len(nids)))
        return ({t: np.concatenate(parts) for t, parts in grouped.items()},
                offsets)

    def _package(self, items, offsets, host_rows, scores, degraded=False):
        now = time.monotonic()
        out = []
        target = self.store.target_type
        for (ntype, nids, t_submit), (_, lo, hi) in zip(items, offsets):
            lat_ms = (now - t_submit) * 1e3
            out.append(
                ServeResult(
                    ntype=ntype,
                    embeddings=host_rows[ntype][lo:hi] if len(nids) else
                    np.zeros((0, self.store.hidden), np.float32),
                    scores=(
                        scores[lo:hi]
                        if ntype == target and scores is not None
                        else None
                    ),
                    latency_ms=lat_ms,
                )
            )
        with self._stats_lock:
            self._count += len(items)
            if degraded:
                self.degraded_count += len(items)
            for r in out:
                self._latencies.append(r.latency_ms)
        return out

    def _primary(self, items) -> List[ServeResult]:
        """The device hot path: cache gather + jitted scoring.  The fault
        plan's ``fail_flush``/``delay_flush`` triggers fire here, at the
        ``fetch_many`` call site, exactly as a transient device/cache error
        would; the plan's coordinate is the primary-*attempt* index (each
        retry advances it, so a ``count=1`` fault is a clean transient and
        ``count >= breaker_threshold * (flush_retries + 1)`` forces a
        trip)."""
        requests, offsets = self._group(items)
        if self.faults is not None and self.faults:
            from repro.data.faults import InjectedFault

            fi = self._flush_index
            self._flush_index += 1
            delay = self.faults.flush_delay(fi)
            if delay > 0:
                time.sleep(delay)
            if self.faults.flush_fault(fi) is not None:
                raise InjectedFault(
                    f"scheduled fail_flush fault at primary attempt {fi}")
        rows = self.cache.fetch_many(requests)  # one gather per type
        target = self.store.target_type
        scores = (
            np.asarray(self._score(rows[target])) if target in rows else None
        )
        host_rows = {t: np.asarray(r) for t, r in rows.items()}
        return self._package(items, offsets, host_rows, scores)

    def _degraded(self, items) -> List[ServeResult]:
        """The cache-bypass path: direct host gather from the store's
        embedding arrays + numpy head scoring.  Device- and cache-free, so
        it survives whatever broke the primary path; slower, never wrong."""
        requests, offsets = self._group(items)
        host_rows = {
            t: np.asarray(self.store.embeddings[t])[nids]
            for t, nids in requests.items()
        }
        target = self.store.target_type
        scores = None
        if target in host_rows:
            w = np.asarray(self.store.head["w"], np.float32)
            b = np.asarray(self.store.head["b"], np.float32)
            scores = np.maximum(host_rows[target], 0.0) @ w + b
        return self._package(items, offsets, host_rows, scores, degraded=True)

    def _oldest_deadline_blown(self, items, extra_ms: float = 0.0) -> bool:
        if self.deadline_ms <= 0:
            return False
        age_ms = (time.monotonic() - min(t for _, _, t in items)) * 1e3
        return age_ms + extra_ms >= self.deadline_ms

    def _flush(self, items: List[Tuple[str, np.ndarray, float]]) -> List[ServeResult]:
        out = self._flush_with_degradation(items)
        self._flush_count += 1
        if self.readmit_every and self._flush_count % self.readmit_every == 0:
            self._readmit()
        return out

    def _flush_with_degradation(self, items) -> List[ServeResult]:
        """Breaker + retry state machine around :meth:`_primary` (module
        docstring; DESIGN.md §12).  Every exit answers the flush — the
        degraded path is the fallback, never an exception to callers."""
        if self.breaker_state == "open":
            since_ms = (time.monotonic() - self._breaker_opened_t) * 1e3
            if since_ms < self.breaker_cooldown_ms:
                return self._degraded(items)
            self.breaker_state = "half_open"
        if self.breaker_state == "half_open":
            # one probe, no retries: failure re-opens, success closes
            try:
                out = self._primary(items)
            except Exception:
                self.breaker_state = "open"
                self._breaker_opened_t = time.monotonic()
                return self._degraded(items)
            with self._stats_lock:
                self.breaker_state = "closed"
                self.breaker_recoveries += 1
                self._consec_failures = 0
            return out
        # closed: primary with bounded retries under the oldest deadline
        attempts = self.flush_retries + 1
        for a in range(attempts):
            try:
                out = self._primary(items)
                self._consec_failures = 0
                return out
            except Exception:
                backoff_ms = self.retry_backoff_ms * (2 ** a)
                if (a + 1 < attempts
                        and not self._oldest_deadline_blown(items, backoff_ms)):
                    with self._stats_lock:
                        self.retry_count += 1
                    time.sleep(backoff_ms / 1e3)
                    continue
                break
        self._consec_failures += 1
        if self._consec_failures >= self.breaker_threshold:
            with self._stats_lock:
                self.breaker_state = "open"
                self.breaker_trips += 1
            self._breaker_opened_t = time.monotonic()
        return self._degraded(items)

    def _readmit(self, decay: float = 0.5) -> None:
        """Re-allocate the serve cache from the served-id trace.

        Runs on the flusher thread — the only thread that calls
        ``fetch_many`` — so the cache swap needs no extra locking.  The
        drained access counters fold into a decayed running profile, the
        unchanged byte budget re-splits across types ∝ observed traffic
        (hotness-only: materialized embeddings are read-only and
        penalty-uniform), and ``update_residency`` moves only the delta."""
        window = self.cache.take_access_counts()
        for t, ema in self._hotness_ema.items():
            ema *= decay
            if t in window:
                ema += window[t]
        profile = HotnessProfile(counts=self._hotness_ema)
        tables = self.store.embeddings
        pen = MissPenaltyProfile(
            ratios={t: 1.0 for t in tables},
            learnable={t: False for t in tables},
            dims={t: a.shape[1] for t, a in tables.items()},
        )
        alloc = allocate_cache(
            profile, pen, self._cache_bytes,
            {t: a.shape[0] for t, a in tables.items()}, hotness_only=True,
        )
        self.cache.update_residency(alloc, profile)
        self.readmits += 1

    # -- client surface ------------------------------------------------------

    def submit(self, nids: Sequence[int], ntype: Optional[str] = None) -> _Future:
        """Async lookup: returns a future resolving to a :class:`ServeResult`."""
        t = ntype or self.store.target_type
        if t not in self.store.embeddings:
            raise KeyError(
                f"no materialized embeddings for type {t!r} "
                f"(have {sorted(self.store.embeddings)})"
            )
        arr = np.asarray(nids, dtype=np.int64).reshape(-1)
        return self.batcher.submit((t, arr, time.monotonic()))

    def query(
        self, nids: Sequence[int], ntype: Optional[str] = None,
        timeout: Optional[float] = None,
    ) -> ServeResult:
        """Blocking lookup (submit + wait for the micro-batch flush).

        With ``deadline_ms`` configured the wait is bounded by it by
        default (explicit ``timeout`` wins); retries and breaker trips are
        budgeted against the same deadline, so a degraded answer normally
        lands inside it."""
        if timeout is None and self.deadline_ms > 0:
            timeout = self.deadline_ms / 1e3
        return self.submit(nids, ntype).result(timeout)

    # -- stats / lifecycle ---------------------------------------------------

    def stats(self) -> ServeStats:
        with self._stats_lock:
            lats = np.asarray(self._latencies, np.float64)
            count = self._count
        wall = max(time.monotonic() - self._t_start, 1e-9)
        return ServeStats(
            count=count,
            flushes=self.batcher.flushes,
            p50_ms=float(np.percentile(lats, 50)) if len(lats) else 0.0,
            p99_ms=float(np.percentile(lats, 99)) if len(lats) else 0.0,
            qps=count / wall,
            hit_rates=self.cache.hit_rates(),
            breaker_state=self.breaker_state,
            breaker_trips=self.breaker_trips,
            breaker_recoveries=self.breaker_recoveries,
            degraded=self.degraded_count,
            retries=self.retry_count,
        )

    def reset_stats(self) -> None:
        with self._stats_lock:
            self._latencies.clear()
            self._count = 0
            self._t_start = time.monotonic()
        self.cache.reset_stats()

    def close(self) -> None:
        self.batcher.close()

    def __enter__(self) -> "EmbeddingServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
