"""Online inference tier: materialize once, serve forever (DESIGN.md §10).

Two halves, contracted in DESIGN.md §10:

  * :mod:`repro.serve.full_graph` — layer-wise full-graph inference: level-l
    representations for *every* node of every type are computed (in node
    blocks, through the same stacked-relation kernels the trainer runs)
    before level l+1, then materialized into a per-type
    :class:`~repro.serve.full_graph.EmbeddingStore` — optionally backed by a
    ``repro.graph.shm`` segment so serving processes attach zero-copy.
    Prop-1 carries over: the layer-wise embedding of any node equals the
    minibatch ``raf_spmd`` forward for that node.

  * :mod:`repro.serve.server` — the serving executor: a
    :class:`~repro.serve.server.MicroBatcher` coalesces concurrent lookups
    under a latency budget (flush on ``max_batch`` or ``max_wait_ms``,
    bounded queue with backpressure) and the
    :class:`~repro.serve.server.EmbeddingServer` answers each flush with one
    ``FeatureCache`` gather per node type plus a jitted head application.

Session surface: ``Heta.infer_all()`` builds the store, ``Heta.serve()``
starts a server over it, and the ``"serve"`` executor entry scores
evaluation batches against the store instead of re-sampling.
"""

from repro.serve.full_graph import (
    EmbeddingStore,
    bounded_graph,
    exhaustive_batch,
    exhaustive_fanouts,
    infer_all,
    spmd_logits_for_batch,
)
from repro.serve.server import (
    EmbeddingServer,
    MicroBatcher,
    ServeResult,
    ServeStats,
)

__all__ = [
    "EmbeddingStore",
    "EmbeddingServer",
    "MicroBatcher",
    "ServeResult",
    "ServeStats",
    "bounded_graph",
    "exhaustive_batch",
    "exhaustive_fanouts",
    "infer_all",
    "spmd_logits_for_batch",
]
