"""Pre-training profilers: node hotness and per-type miss-penalty ratios.

Paper §6: cache size is allocated per node type in proportion to
``count_a × o_a`` where ``count_a`` is the type's total visit count from a
pre-sampling pass (two epochs, as in GNNLab [50]) and ``o_a`` is the
*miss-penalty ratio* — the time penalty per byte of cache incurred when a
node of type ``a`` misses.

Miss penalties differ across node types because

  * small feature dims pay a larger fixed per-transfer overhead per byte
    (PCIe/DMA transaction setup, paper Fig. 7a);
  * learnable features must also move their optimizer states and be written
    *back*, roughly (1 read + 1 write) × (1 + 2×Adam states) (paper Fig. 7b).

On this CPU-only container we *measure* host→device copies (memcpy through
the JAX CPU client) for the real-measurement path, and provide an analytic
PCIe model with the paper's qualitative shape for TPU-scale projections.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.graph.hetgraph import HetGraph
from repro.graph.sampler import NeighborSampler, SampleSpec

__all__ = [
    "HotnessProfile",
    "presample_hotness",
    "presample_hotness_pooled",
    "measure_miss_penalty",
    "analytic_miss_penalty",
    "MissPenaltyProfile",
    "profile_miss_penalties",
]


# --------------------------------------------------------------------------
# hotness (pre-sampling)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class HotnessProfile:
    counts: Dict[str, np.ndarray]  # ntype -> visit count per node id

    def total(self, ntype: str) -> int:
        return int(self.counts[ntype].sum())

    def hottest(self, ntype: str, n: int) -> np.ndarray:
        """Node ids sorted by descending visit count, truncated to n."""
        c = self.counts[ntype]
        order = np.argsort(-c, kind="stable")
        return order[: min(n, len(order))]

    def skew(self, ntype: str, top_frac: float = 0.1) -> float:
        """Fraction of visits captured by the hottest ``top_frac`` of nodes."""
        c = np.sort(self.counts[ntype])[::-1]
        k = max(1, int(len(c) * top_frac))
        tot = c.sum()
        return float(c[:k].sum() / tot) if tot else 0.0


def presample_hotness(
    graph: HetGraph,
    spec: SampleSpec,
    batch_size: int,
    epochs: int = 2,
    max_batches: Optional[int] = None,
    seed: int = 7,
) -> HotnessProfile:
    """Sample ``epochs`` epochs before training and count node visits
    (paper §6, following GNNLab's pre-sampling)."""
    counts = {t: np.zeros(n, dtype=np.int64) for t, n in graph.num_nodes.items()}
    sampler = NeighborSampler(graph, spec, batch_size, seed=seed)
    done = 0
    for ep in range(epochs):
        for batch in sampler.epoch(shuffle=True, seed=seed + ep):
            batch.count_visits(counts)
            done += 1
            if max_batches and done >= max_batches:
                return HotnessProfile(counts)
    return HotnessProfile(counts)


def presample_hotness_pooled(
    graph: HetGraph,
    spec: SampleSpec,
    batch_size: int,
    num_workers: int,
    epochs: int = 2,
    max_batches: Optional[int] = None,
    seed: int = 7,
    depth: int = 2,
) -> HotnessProfile:
    """:func:`presample_hotness` over the sampler worker pool.

    The §6 sweep is the same ``batch_at`` walk the training pool runs
    (epoch ``ep`` shuffles with ``seed + ep``, i.e. ``seed_stride=1``), and
    visit counting is an order-independent sum — each worker accumulates
    its stripe's counts locally and ships one partial dict at stripe end,
    so the summed profile is bit-identical to the serial loop at any worker
    count."""
    from repro.data.worker_pool import EpochSchedule, HotnessCountTask, WorkerPool
    from repro.graph.shm import share_graph

    if num_workers < 1:
        return presample_hotness(graph, spec, batch_size, epochs=epochs,
                                 max_batches=max_batches, seed=seed)
    counts = {t: np.zeros(n, dtype=np.int64) for t, n in graph.num_nodes.items()}
    steps_per_epoch = NeighborSampler(graph, spec, batch_size,
                                      seed=seed).steps_per_epoch()
    n = epochs * steps_per_epoch
    if max_batches:
        n = min(n, max_batches)
    if n <= 0:
        return HotnessProfile(counts)
    store = share_graph(graph, include_features=False)
    try:
        task = HotnessCountTask(
            handle=store.handle, spec=spec, batch_size=batch_size,
            sampler_seed=seed,
            schedule=EpochSchedule(epoch_seed_base=seed,
                                   steps_per_epoch=steps_per_epoch,
                                   seed_stride=1),
            num_items=n, num_workers=num_workers,
        )
        with WorkerPool(task, num_workers=num_workers, depth=depth,
                        num_items=n, name="hotness-pool") as pool:
            for partial in pool:
                if partial is not None:
                    for t, c in partial.items():
                        counts[t] += c
    finally:
        store.unlink()
    return HotnessProfile(counts)


# --------------------------------------------------------------------------
# miss-penalty ratios
# --------------------------------------------------------------------------


ADAM_STATE_MULT = 2  # moment + variance rows, same shape as the feature row


def row_bytes(dim: int, learnable: bool, bytes_per_elem: int = 4) -> int:
    """Cache footprint of one row: learnable rows carry their Adam states
    (paper §6 'extend caching to optimizer states')."""
    mult = 1 + (ADAM_STATE_MULT if learnable else 0)
    return dim * bytes_per_elem * mult


def measure_miss_penalty(
    dim: int,
    learnable: bool,
    n_rows: int = 4096,
    repeats: int = 5,
    bytes_per_elem: int = 4,
) -> float:
    """Measured miss-penalty ratio o_a in seconds/byte.

    Read-only rows: host→device transfer time per cached byte.  Learnable
    rows: read + write of features *and* optimizer states.
    """
    import jax  # lazy: hotness profiling must stay importable jax-free

    dev = jax.devices()[0]
    host = np.random.default_rng(0).standard_normal((n_rows, dim)).astype(np.float32)
    mult = 1 + (ADAM_STATE_MULT if learnable else 0)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(mult):
            d = jax.device_put(host, dev)
            d.block_until_ready()
            if learnable:
                _ = np.asarray(d)  # write-back path
        best = min(best, time.perf_counter() - t0)
    cache_bytes = n_rows * row_bytes(dim, learnable, bytes_per_elem)
    return best / cache_bytes


def analytic_miss_penalty(
    dim: int,
    learnable: bool,
    bytes_per_elem: int = 4,
    link_gbps: float = 16.0,  # PCIe 3.0 x16 effective, paper's T4 testbed
    fixed_us: float = 10.0,  # per-transfer setup cost (paper Fig. 7a)
) -> float:
    """Analytic o_a with the paper's qualitative shape: fixed per-transfer
    overhead dominates small rows; learnable rows pay read+write × states."""
    data = dim * bytes_per_elem
    t_read = fixed_us * 1e-6 + data / (link_gbps * 1e9)
    mult = 1 + (ADAM_STATE_MULT if learnable else 0)
    t = t_read * mult * (2.0 if learnable else 1.0)  # writes mirror reads
    return t / row_bytes(dim, learnable, bytes_per_elem)


@dataclasses.dataclass
class MissPenaltyProfile:
    ratios: Dict[str, float]  # ntype -> o_a (s/byte)
    learnable: Dict[str, bool]
    dims: Dict[str, int]

    def render(self) -> str:
        lines = ["  type                 dim  learnable  o_a (us/KB)"]
        for t in sorted(self.ratios):
            lines.append(
                f"  {t:<18} {self.dims[t]:>5}  {str(self.learnable[t]):<9}"
                f"  {self.ratios[t] * 1e6 * 1024:10.3f}"
            )
        return "\n".join(lines)


def profile_miss_penalties(
    graph: HetGraph,
    learnable_dim: int = 64,
    measured: bool = True,
    **analytic_kwargs,
) -> MissPenaltyProfile:
    """o_a per node type (paper Fig. 7).  ``measured=False`` uses the PCIe
    model (used when projecting to the paper's GPU testbed)."""
    ratios, learn, dims = {}, {}, {}
    for t in graph.node_types:
        is_learn = t not in graph.features
        dim = learnable_dim if is_learn else graph.feat_dim(t)
        fn = measure_miss_penalty if measured else analytic_miss_penalty
        ratios[t] = fn(dim, is_learn, **({} if measured else analytic_kwargs))
        learn[t], dims[t] = is_learn, dim
    return MissPenaltyProfile(ratios=ratios, learnable=learn, dims=dims)
