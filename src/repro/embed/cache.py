"""Miss-penalty-aware device feature cache (paper §6).

Two pieces:

  * :func:`allocate_cache` — the hierarchical allocation policy: the per-type
    cache budget is proportional to ``count_a × o_a`` (hotness × miss-penalty
    ratio), then each type's budget is filled with its hottest nodes.  A
    ``hotness_only`` switch reproduces the paper's ablation baseline
    (Fig. 11's 'hotness only').

  * :class:`FeatureCache` — a functional device cache in front of host
    feature tables.  Read-only types cache feature rows; learnable types
    cache the row *and* its Adam states, and writes go to the cached copy
    (non-replicative: each row lives in exactly one place — a device shard
    or host memory — so there is never a second version to invalidate,
    paper §6 'Cache Consistency').  Multi-device splits use the paper's
    mod-hash: row ``nid`` belongs to shard ``nid % num_shards``.

Online admission (§6 extension): the one-shot allocation above scores
residency from a *pre-sampled* hotness trace.  The cache additionally
keeps per-node access counters (accumulated on every ``fetch`` under the
same stats lock as the hit/miss counters) so a caller can periodically
re-score residency from *observed* traffic: ``take_access_counts`` drains
the counters, the caller folds them into a hotness profile and re-runs
:func:`allocate_cache` under the unchanged byte budget, and
:meth:`FeatureCache.update_residency` applies the new plan
*incrementally* — rows resident under both plans stay on device (no
re-transfer), evicted learnable rows write their authoritative copy (row
+ Adam states) back to host before leaving, and only admitted rows move
host→device.  ``EmbedEngine.rebalance`` and the serving tier's
``EmbeddingServer`` both drive this hook.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.embed.profiler import (
    ADAM_STATE_MULT,
    HotnessProfile,
    MissPenaltyProfile,
    row_bytes,
)

__all__ = ["CacheAllocation", "allocate_cache", "FeatureCache"]


# --------------------------------------------------------------------------
# allocation policy
# --------------------------------------------------------------------------


@dataclasses.dataclass
class CacheAllocation:
    rows: Dict[str, int]  # ntype -> number of cached rows
    bytes_: Dict[str, int]  # ntype -> bytes allotted
    total_bytes: int
    policy: str

    def render(self) -> str:
        lines = [f"  cache allocation ({self.policy}, {self.total_bytes/2**20:.0f} MiB):"]
        for t in sorted(self.rows):
            lines.append(
                f"    {t:<18} rows={self.rows[t]:>9,}  {self.bytes_[t]/2**20:8.1f} MiB"
            )
        return "\n".join(lines)


def allocate_cache(
    hotness: HotnessProfile,
    penalties: MissPenaltyProfile,
    total_bytes: int,
    num_nodes: Dict[str, int],
    hotness_only: bool = False,
    bytes_per_elem: int = 4,
) -> CacheAllocation:
    """Split ``total_bytes`` across node types ∝ count_a × o_a (paper §6).

    ``hotness_only=True`` drops the o_a factor (ablation baseline).  Budgets
    are capped at the type's full table size; freed budget is redistributed
    proportionally among uncapped types.
    """
    types = sorted(penalties.ratios)
    score = {
        t: float(hotness.total(t)) * (1.0 if hotness_only else penalties.ratios[t])
        for t in types
    }
    rbytes = {
        t: row_bytes(penalties.dims[t], penalties.learnable[t], bytes_per_elem)
        for t in types
    }
    cap = {t: num_nodes[t] * rbytes[t] for t in types}
    alloc = {t: 0.0 for t in types}
    remaining, active = float(total_bytes), set(t for t in types if score[t] > 0)
    # waterfill: proportional split, capping saturated types and reflowing
    while remaining > 1 and active:
        tot = sum(score[t] for t in active)
        newly_capped = set()
        spent = 0.0
        for t in active:
            give = remaining * score[t] / tot
            room = cap[t] - alloc[t]
            take = min(give, room)
            alloc[t] += take
            spent += take
            if alloc[t] >= cap[t] - 1e-6:
                newly_capped.add(t)
        remaining -= spent
        active -= newly_capped
        if not newly_capped:
            break
    rows = {t: int(alloc[t] // rbytes[t]) for t in types}
    return CacheAllocation(
        rows=rows,
        bytes_={t: rows[t] * rbytes[t] for t in types},
        total_bytes=total_bytes,
        policy="hotness-only" if hotness_only else "hotness×miss-penalty",
    )


# --------------------------------------------------------------------------
# the cache itself
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _TypeCache:
    ids: np.ndarray  # [C] cached node ids (host copy for bookkeeping)
    slot_of: np.ndarray  # [num_nodes] -> cache slot or -1
    data: jnp.ndarray  # [C, d] cached rows (device)
    m: Optional[jnp.ndarray]  # [C, d] Adam moment (learnable only)
    v: Optional[jnp.ndarray]  # [C, d] Adam variance
    shard_of: np.ndarray  # [C] mod-hash shard of each cached row
    hits: int = 0
    misses: int = 0


class FeatureCache:
    """Device cache over host tables with per-type budgets.

    ``host_tables``: ntype -> np.ndarray features.  For learnable types the
    host table *is* the learnable parameter store; its Adam states live in
    ``host_m``/``host_v``.  ``fetch`` returns gathered rows (device), and for
    learnable types :meth:`write_learnable` pushes updated rows + states back
    to wherever each row lives (cache or host) — a single authoritative copy.
    """

    def __init__(
        self,
        host_tables: Dict[str, np.ndarray],
        learnable_types: Dict[str, int],  # ntype -> dim
        allocation: CacheAllocation,
        hotness: HotnessProfile,
        num_shards: int = 1,
        kernels=None,
    ):
        self.host = dict(host_tables)
        self.learnable = dict(learnable_types)
        self.num_shards = num_shards
        # guards the hit/miss counters: fetch() runs in the async pipeline's
        # producer thread while hit_rates()/miss_time() read from the
        # consumer — same lock discipline EmbedEngine uses for snapshots
        self._stats_lock = threading.Lock()
        # per-node access counters for online re-admission: every fetch
        # bumps the rows it touched (hits and misses alike — residency is
        # scored from demand, not from the current plan's hit pattern)
        self._access: Dict[str, np.ndarray] = {
            t: np.zeros(a.shape[0], np.float64) for t, a in self.host.items()
        }
        # kernels config knob: device-resident hit gathers go through the
        # scalar-prefetch gather_rows kernel when the backend supports it
        self.kernels = kernels
        self.host_m: Dict[str, np.ndarray] = {}
        self.host_v: Dict[str, np.ndarray] = {}
        self.caches: Dict[str, _TypeCache] = {}
        for t, dim in learnable_types.items():
            if t not in self.host:
                raise ValueError(f"learnable type {t} missing host table")
            self.host_m[t] = np.zeros_like(self.host[t])
            self.host_v[t] = np.zeros_like(self.host[t])
        for t, n_rows in allocation.rows.items():
            if n_rows <= 0 or t not in self.host:
                continue
            ids = hotness.hottest(t, n_rows)
            slot_of = np.full(self.host[t].shape[0], -1, dtype=np.int64)
            slot_of[ids] = np.arange(len(ids))
            self.caches[t] = _TypeCache(
                ids=ids,
                slot_of=slot_of,
                data=jnp.asarray(self.host[t][ids]),
                m=jnp.asarray(self.host_m[t][ids]) if t in self.learnable else None,
                v=jnp.asarray(self.host_v[t][ids]) if t in self.learnable else None,
                shard_of=ids % num_shards,
            )

    # -- reads --------------------------------------------------------------

    def _device_gather(self, data: jnp.ndarray, slots: np.ndarray) -> jnp.ndarray:
        """Device-side row gather of cached rows — the paper-§6 cache fetch
        hot path, routed through the scalar-prefetch ``gather_rows`` kernel
        when the ``kernels.gather`` knob resolves to it for this backend."""
        from repro.kernels.gather_rows import gather_rows_cfg

        return gather_rows_cfg(data, jnp.asarray(slots), self.kernels)

    def fetch(self, ntype: str, nids: np.ndarray) -> jnp.ndarray:
        """Gather rows for ``nids``; cache hits read device memory, misses
        transfer from host.  Returns a device array [len(nids), d]."""
        with self._stats_lock:
            np.add.at(self._access[ntype], nids, 1.0)
        c = self.caches.get(ntype)
        if c is None:
            return jnp.asarray(self.host[ntype][nids])
        slots = c.slot_of[nids]
        hit = slots >= 0
        with self._stats_lock:
            c.hits += int(hit.sum())
            c.misses += int((~hit).sum())
        if hit.all():
            return self._device_gather(c.data, slots)
        rows_miss = jnp.asarray(self.host[ntype][nids[~hit]])
        # partial hits: `slots[hit]` has a different length nearly every
        # batch — a jitted Pallas call would recompile per length, so the
        # mixed path stays on the XLA gather (only the stable batch-sized
        # full-hit shape goes through the kernel)
        rows_hit = c.data[jnp.asarray(slots[hit])]
        out = jnp.zeros((len(nids), self.host[ntype].shape[1]), rows_hit.dtype)
        out = out.at[jnp.asarray(np.nonzero(hit)[0])].set(rows_hit)
        out = out.at[jnp.asarray(np.nonzero(~hit)[0])].set(rows_miss)
        return out

    def fetch_many(self, requests: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
        """Batched multi-type lookup: one device gather per node type.

        ``requests`` maps ntype -> nid array (any integer dtype / shape [n]).
        The serving hot path coalesces every request in a micro-batch flush
        into a single ``fetch_many`` call, so a flush costs one gather per
        *type* rather than one per request; hit/miss counters accrue exactly
        as the equivalent sequence of :meth:`fetch` calls would."""
        return {
            t: self.fetch(t, np.asarray(nids, dtype=np.int64))
            for t, nids in requests.items()
            if len(nids)
        }

    def fetch_states(self, ntype: str, nids: np.ndarray):
        """(rows, m, v) for a learnable type (row-aligned Adam states)."""
        rows = self.fetch(ntype, nids)
        c = self.caches.get(ntype)
        if c is None or c.m is None:
            return rows, jnp.asarray(self.host_m[ntype][nids]), jnp.asarray(self.host_v[ntype][nids])
        slots = c.slot_of[nids]
        hit = slots >= 0
        m = np.asarray(self.host_m[ntype][nids])
        v = np.asarray(self.host_v[ntype][nids])
        m[hit] = np.asarray(c.m[jnp.asarray(slots[hit])])
        v[hit] = np.asarray(c.v[jnp.asarray(slots[hit])])
        return rows, jnp.asarray(m), jnp.asarray(v)

    # -- writes (learnable rows + optimizer states) ---------------------------

    def write_learnable(
        self, ntype: str, nids: np.ndarray, rows: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray
    ) -> None:
        """Write updated learnable rows to their single authoritative copy."""
        if ntype not in self.learnable:
            raise ValueError(f"{ntype} is not learnable")
        c = self.caches.get(ntype)
        if c is None:
            self.host[ntype][nids] = np.asarray(rows)
            self.host_m[ntype][nids] = np.asarray(m)
            self.host_v[ntype][nids] = np.asarray(v)
            return
        slots = c.slot_of[nids]
        hit = slots >= 0
        if hit.any():
            sl = jnp.asarray(slots[hit])
            sel = jnp.asarray(np.nonzero(hit)[0])
            c.data = c.data.at[sl].set(rows[sel])
            c.m = c.m.at[sl].set(m[sel])
            c.v = c.v.at[sl].set(v[sel])
        if (~hit).any():
            miss = nids[~hit]
            self.host[ntype][miss] = np.asarray(rows)[~hit]
            self.host_m[ntype][miss] = np.asarray(m)[~hit]
            self.host_v[ntype][miss] = np.asarray(v)[~hit]

    # -- online admission (observed-traffic residency) -------------------------

    def take_access_counts(self, reset: bool = True) -> Dict[str, np.ndarray]:
        """Drain the per-node access counters (ntype -> float64 [num_nodes]).

        ``reset=True`` (the default) zeroes them, so successive calls see
        disjoint observation windows — the natural input for an EMA."""
        with self._stats_lock:
            out = {t: a.copy() for t, a in self._access.items()}
            if reset:
                for a in self._access.values():
                    a[:] = 0.0
        return out

    def update_residency(
        self, allocation: CacheAllocation, hotness: HotnessProfile
    ) -> Dict[str, Dict[str, int]]:
        """Incrementally move the cache to a new allocation/hotness plan.

        Per type: the new resident set is the plan's ``rows[t]`` hottest
        ids.  Rows resident under both plans are *kept* — their device
        copy is gathered in place, no host traffic.  Evicted learnable
        rows write row + Adam states back to host before leaving (the
        non-replicative invariant: the authoritative copy moves, it is
        never duplicated).  Only admitted rows transfer host→device.

        Each type's cache is rebuilt as a fresh ``_TypeCache`` and swapped
        in with one attribute assignment: a concurrent ``fetch`` that
        already grabbed the old object sees a coherent (merely stale)
        view.  Callers that also write (``write_learnable`` /
        ``fetch_states``) must serialize against this method — EmbedEngine
        holds its table lock around both.

        Returns ntype -> {"kept", "admitted", "evicted"} row counts.
        """
        moves: Dict[str, Dict[str, int]] = {}
        for t in sorted(self.host):
            n_rows = int(allocation.rows.get(t, 0))
            old = self.caches.get(t)
            if n_rows <= 0 and old is None:
                continue
            new_ids = (
                np.asarray(hotness.hottest(t, n_rows), np.int64)
                if n_rows > 0 else np.zeros(0, np.int64)
            )
            old_slots = (
                old.slot_of[new_ids] if old is not None
                else np.full(len(new_ids), -1, np.int64)
            )
            kept = old_slots >= 0
            n_evicted = 0
            if old is not None:
                stay = np.zeros(len(old.ids), bool)
                stay[old_slots[kept]] = True
                ev = ~stay
                n_evicted = int(ev.sum())
                if n_evicted and t in self.learnable:
                    ev_ids = old.ids[ev]
                    ev_sl = jnp.asarray(np.nonzero(ev)[0])
                    self.host[t][ev_ids] = np.asarray(old.data[ev_sl])
                    self.host_m[t][ev_ids] = np.asarray(old.m[ev_sl])
                    self.host_v[t][ev_ids] = np.asarray(old.v[ev_sl])
            if n_rows <= 0:
                del self.caches[t]
                moves[t] = {"kept": 0, "admitted": 0, "evicted": n_evicted}
                continue
            dim = self.host[t].shape[1]
            dtype = self.host[t].dtype
            learn = t in self.learnable
            data = jnp.zeros((len(new_ids), dim), dtype)
            m = jnp.zeros((len(new_ids), dim), dtype) if learn else None
            v = jnp.zeros((len(new_ids), dim), dtype) if learn else None
            if kept.any():
                dst = jnp.asarray(np.nonzero(kept)[0])
                src = jnp.asarray(old_slots[kept])
                data = data.at[dst].set(old.data[src])
                if learn:
                    m = m.at[dst].set(old.m[src])
                    v = v.at[dst].set(old.v[src])
            if (~kept).any():
                dst = jnp.asarray(np.nonzero(~kept)[0])
                admit = new_ids[~kept]
                data = data.at[dst].set(jnp.asarray(self.host[t][admit]))
                if learn:
                    m = m.at[dst].set(jnp.asarray(self.host_m[t][admit]))
                    v = v.at[dst].set(jnp.asarray(self.host_v[t][admit]))
            slot_of = np.full(self.host[t].shape[0], -1, dtype=np.int64)
            slot_of[new_ids] = np.arange(len(new_ids))
            self.caches[t] = _TypeCache(
                ids=new_ids,
                slot_of=slot_of,
                data=data,
                m=m,
                v=v,
                shard_of=new_ids % self.num_shards,
                hits=old.hits if old is not None else 0,
                misses=old.misses if old is not None else 0,
            )
            moves[t] = {
                "kept": int(kept.sum()),
                "admitted": int((~kept).sum()),
                "evicted": n_evicted,
            }
        return moves

    # -- checkpoint support (DESIGN.md §12) ------------------------------------

    def merged_learnable_state(self):
        """(tables, m, v): per learnable type, the host array with cached
        rows merged in — the coherent full-table state a checkpoint stores.
        Caller must hold the engine's table lock."""
        tables, m, v = {}, {}, {}
        for t in self.learnable:
            tab = self.host[t].copy()
            mm = self.host_m[t].copy()
            vv = self.host_v[t].copy()
            c = self.caches.get(t)
            if c is not None:
                tab[c.ids] = np.asarray(c.data)
                if c.m is not None:
                    mm[c.ids] = np.asarray(c.m)
                    vv[c.ids] = np.asarray(c.v)
            tables[t], m[t], v[t] = tab, mm, vv
        return tables, m, v

    def residency(self) -> Dict[str, np.ndarray]:
        """ntype -> cached node ids (the §6 residency profile)."""
        return {t: c.ids.copy() for t, c in self.caches.items()}

    def set_residency(self, ids_by_type: Dict[str, np.ndarray]) -> None:
        """Rebuild every per-type cache to exactly these resident ids,
        sourcing row data (and Adam states) from the host tables — restore
        path only: callers must have written authoritative full tables to
        host first (:meth:`merged_learnable_state` inverse).  Caller holds
        the engine's table lock."""
        for t in list(self.caches):
            if t not in ids_by_type:
                del self.caches[t]
        for t, ids in ids_by_type.items():
            if t not in self.host:
                continue
            ids = np.asarray(ids, np.int64)
            slot_of = np.full(self.host[t].shape[0], -1, dtype=np.int64)
            slot_of[ids] = np.arange(len(ids))
            learn = t in self.learnable
            old = self.caches.get(t)
            self.caches[t] = _TypeCache(
                ids=ids,
                slot_of=slot_of,
                data=jnp.asarray(self.host[t][ids]),
                m=jnp.asarray(self.host_m[t][ids]) if learn else None,
                v=jnp.asarray(self.host_v[t][ids]) if learn else None,
                shard_of=ids % self.num_shards,
                hits=old.hits if old is not None else 0,
                misses=old.misses if old is not None else 0,
            )

    # -- stats ----------------------------------------------------------------

    def hit_rates(self) -> Dict[str, float]:
        out = {}
        with self._stats_lock:
            for t, c in self.caches.items():
                tot = c.hits + c.misses
                out[t] = c.hits / tot if tot else 0.0
        return out

    def reset_stats(self) -> None:
        with self._stats_lock:
            for c in self.caches.values():
                c.hits = c.misses = 0

    def miss_time(self, penalties: MissPenaltyProfile, bytes_per_elem: int = 4) -> float:
        """Estimated seconds spent on cache misses so far (penalty model)."""
        t_total = 0.0
        with self._stats_lock:
            for t, c in self.caches.items():
                rb = row_bytes(penalties.dims[t], penalties.learnable[t], bytes_per_elem)
                t_total += c.misses * penalties.ratios[t] * rb
        return t_total

    def consistency_check(self) -> bool:
        """Non-replicative invariant: a cached row's host copy is never read
        or written — verify slots are unique and shard assignment follows the
        mod-hash rule (paper §6)."""
        for t, c in self.caches.items():
            if len(np.unique(c.ids)) != len(c.ids):
                return False
            if not np.array_equal(c.shard_of, c.ids % self.num_shards):
                return False
        return True
