"""EmbedEngine — learnable feature tables behind the miss-penalty cache.

Ties together the pieces of Heta's learnable-feature pipeline (paper §2.3
Challenge 3 / §6): featureless node types get trainable rows + Adam states;
a minibatch *fetches* the unique rows it touches (through the cache),
the training step returns row gradients, and the engine applies a sparse
Adam step and writes rows + states back to their single authoritative copy.

This replaces the vanilla model's random host-DRAM read/modify/write storm
(24-35% of DGL's epoch time, paper Fig. 4) with mostly device-resident
traffic once the cache is warm.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.embed.cache import CacheAllocation, FeatureCache, allocate_cache
from repro.embed.profiler import HotnessProfile, MissPenaltyProfile
from repro.graph.hetgraph import HetGraph
from repro.optim.adam import AdamConfig, sparse_adam_rows

__all__ = ["EmbedEngine"]


class EmbedEngine:
    def __init__(
        self,
        graph: HetGraph,
        learnable_dim: int,
        hotness: HotnessProfile,
        penalties: MissPenaltyProfile,
        cache_bytes: int,
        adam: Optional[AdamConfig] = None,
        hotness_only: bool = False,
        num_shards: int = 1,
        seed: int = 0,
        kernels=None,
    ):
        self.graph = graph
        self.learnable_dim = learnable_dim
        self.adam = adam or AdamConfig(lr=1e-2)
        self.steps = {t: 0 for t in graph.num_nodes}
        # serializes table snapshots against sparse write-backs: the async
        # pipeline snapshots from a producer thread while the training loop
        # applies row grads, and the staleness contract promises whole-row
        # states some step actually held — never torn mid-write rows
        self.lock = threading.RLock()
        rng = np.random.default_rng(seed)

        self.learnable_types = {
            t: learnable_dim for t in graph.num_nodes if t not in graph.features
        }
        host: Dict[str, np.ndarray] = {
            t: f.astype(np.float32, copy=False) for t, f in graph.features.items()
        }
        for t in self.learnable_types:
            host[t] = (
                rng.standard_normal((graph.num_nodes[t], learnable_dim)) * 0.1
            ).astype(np.float32)

        self.allocation: CacheAllocation = allocate_cache(
            hotness, penalties, cache_bytes, graph.num_nodes, hotness_only
        )
        self.cache = FeatureCache(
            host, self.learnable_types, self.allocation, hotness, num_shards,
            kernels=kernels,
        )
        self.penalties = penalties
        self.cache_bytes = cache_bytes
        self.hotness_only = hotness_only
        # online re-admission state: EMA over observed per-node access
        # counts, seeded from the pre-sampled profile so the first
        # rebalance blends prior and trace rather than trusting a short
        # window outright
        self._hotness_ema: Dict[str, np.ndarray] = {
            t: hotness.counts[t].astype(np.float64)
            if t in hotness.counts
            else np.zeros(graph.num_nodes[t], np.float64)
            for t in graph.num_nodes
        }
        self.rebalances = 0

    # -- table access ----------------------------------------------------------

    def table(self, ntype: str) -> np.ndarray:
        """Host view of a feature table.  For learnable types, cached rows
        are authoritative on device; this materializes a coherent snapshot
        (used by the test oracles and single-host executors)."""
        with self.lock:
            tab = self.cache.host[ntype].copy()
            c = self.cache.caches.get(ntype)
            if c is not None:
                tab[c.ids] = np.asarray(c.data)
            return tab

    def tables_snapshot(self) -> Dict[str, np.ndarray]:
        """Coherent snapshot of every table — atomic w.r.t. concurrent
        :meth:`apply_row_grads` (the async pipeline's "stale" policy means a
        snapshot may *lag*, never interleave a half-applied update)."""
        with self.lock:
            return {t: self.table(t) for t in self.graph.num_nodes}

    def fetch(self, ntype: str, nids: np.ndarray) -> jnp.ndarray:
        return self.cache.fetch(ntype, np.asarray(nids))

    # -- the sparse update path (paper Fig. 3 step 5, cache-accelerated) --------

    def apply_row_grads(self, ntype: str, nids: np.ndarray, grads: jnp.ndarray) -> None:
        """Sparse Adam on the unique rows of one type touched by a batch.

        ``nids`` may contain duplicates (multiple branches sample the same
        node); duplicates are summed into unique rows first, matching dense
        autodiff semantics.
        """
        if ntype not in self.learnable_types:
            raise ValueError(f"{ntype} has fixed features")
        nids = np.asarray(nids)
        uniq, inv = np.unique(nids, return_inverse=True)
        g = np.zeros((len(uniq), grads.shape[-1]), np.float32)
        np.add.at(g, inv, np.asarray(grads, np.float32).reshape(len(nids), -1))
        with self.lock:
            rows, m, v = self.cache.fetch_states(ntype, uniq)
            new_rows, new_m, new_v = sparse_adam_rows(
                self.adam, rows, jnp.asarray(g), m, v, jnp.asarray(self.steps[ntype])
            )
            self.steps[ntype] += 1
            self.cache.write_learnable(ntype, uniq, new_rows, new_m, new_v)

    # -- checkpoint support (DESIGN.md §12) -------------------------------------

    def state_snapshot(self) -> Dict[str, object]:
        """The engine's restorable state: per learnable type the coherent
        full table + Adam moments (cached rows merged in), per-type Adam
        step counters, the online-readmission hotness EMA, and the cache
        residency profile.  Atomic w.r.t. concurrent ``apply_row_grads``."""
        with self.lock:
            tables, m, v = self.cache.merged_learnable_state()
            return {
                "tables": tables,
                "m": m,
                "v": v,
                "steps": {t: int(s) for t, s in self.steps.items()},
                "hotness_ema": {t: e.copy()
                                for t, e in self._hotness_ema.items()},
                "residency": self.cache.residency(),
            }

    def load_state(self, state: Dict[str, object]) -> None:
        """Inverse of :meth:`state_snapshot`: write the full tables home,
        then re-gather cached rows from host — bit-exact, because the
        merged snapshot *was* the authoritative value of every row."""
        with self.lock:
            for t in self.learnable_types:
                self.cache.host[t][:] = state["tables"][t]
                self.cache.host_m[t][:] = state["m"][t]
                self.cache.host_v[t][:] = state["v"][t]
            res = state.get("residency")
            if res is not None:
                self.cache.set_residency(res)
            else:  # keep current residency; refresh cached learnable rows
                for t in self.learnable_types:
                    c = self.cache.caches.get(t)
                    if c is not None:
                        c.data = jnp.asarray(self.cache.host[t][c.ids])
                        c.m = jnp.asarray(self.cache.host_m[t][c.ids])
                        c.v = jnp.asarray(self.cache.host_v[t][c.ids])
            for t, s in state.get("steps", {}).items():
                if t in self.steps:
                    self.steps[t] = int(s)
            for t, e in state.get("hotness_ema", {}).items():
                if t in self._hotness_ema:
                    self._hotness_ema[t][:] = np.asarray(e)

    # -- online penalty-aware re-admission (paper §6, observed traffic) ---------

    def rebalance(self, decay: float = 0.5) -> Dict[str, object]:
        """Re-score cache residency from observed traffic (paper §6 online).

        The one-shot allocation trusts the pre-sampled hotness; once
        training runs, the cache's access counters record what the
        workload *actually* touches.  This folds the drained counters
        into a decayed running profile (``ema = decay·ema + window`` —
        the same decay for every type preserves the cross-type ratios
        ``allocate_cache`` scores on), re-runs the hotness × miss-penalty
        allocation under the unchanged byte budget, and applies the plan
        incrementally via :meth:`FeatureCache.update_residency`: kept
        rows never leave the device, evicted learnable rows write row +
        Adam states home first, admitted rows transfer once.

        Safe against the async pipeline: runs under the same table lock
        as ``apply_row_grads``/snapshots, and the per-type cache swap is
        atomic w.r.t. lock-free concurrent ``fetch``.

        Returns ``{"allocation": rows, "moves": per-type counts}``.
        """
        with self.lock:
            window = self.cache.take_access_counts()
            for t, ema in self._hotness_ema.items():
                ema *= decay
                if t in window:
                    ema += window[t]
            profile = HotnessProfile(counts=self._hotness_ema)
            self.allocation = allocate_cache(
                profile, self.penalties, self.cache_bytes,
                self.graph.num_nodes, self.hotness_only,
            )
            moves = self.cache.update_residency(self.allocation, profile)
            self.rebalances += 1
        return {"allocation": dict(self.allocation.rows), "moves": moves}

    # -- reporting ---------------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        return {
            "hit_rates": self.cache.hit_rates(),
            "allocation": {t: r for t, r in self.allocation.rows.items()},
            "miss_time_s": self.cache.miss_time(self.penalties),
            "rebalances": self.rebalances,
        }
