from repro.embed.profiler import (
    HotnessProfile,
    presample_hotness,
    presample_hotness_pooled,
    measure_miss_penalty,
    analytic_miss_penalty,
    MissPenaltyProfile,
    profile_miss_penalties,
)
from repro.embed.cache import CacheAllocation, allocate_cache, FeatureCache
from repro.embed.engine import EmbedEngine

__all__ = [
    "HotnessProfile",
    "presample_hotness",
    "presample_hotness_pooled",
    "measure_miss_penalty",
    "analytic_miss_penalty",
    "MissPenaltyProfile",
    "profile_miss_penalties",
    "CacheAllocation",
    "allocate_cache",
    "FeatureCache",
    "EmbedEngine",
]
