"""The ``Heta`` session — explicit, resumable pipeline stages.

One session wires the paper's full pipeline (Fig. 5) behind five stages,
each individually runnable and inspectable:

    sess = Heta(config)
    g      = sess.build_graph()        # HetG (synthetic dataset family)
    part   = sess.partition()          # §5 meta-partitioning -> PartitionReport
    cache  = sess.profile_and_cache()  # §6 hotness/penalty profiling -> CacheReport
    sess.compile(executor="raf_spmd")  # §4 executor via the registry
    result = sess.fit()                # train; same keys as train_hgnn

Calling a stage out of order raises :class:`HetaStageError` with the missing
prerequisite; ``run()`` executes whatever stages remain and then ``fit()``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.api import executors as _executors
from repro.api.config import HetaConfig

__all__ = ["Heta", "HetaStageError", "PartitionReport", "CacheReport"]


class HetaStageError(RuntimeError):
    """A lifecycle method was called before its prerequisite stage."""


@dataclasses.dataclass
class PartitionReport:
    """Inspectable result of the §5 partitioning stage."""

    summary: str
    meta_local: bool
    num_partitions: int
    metatree: object  # MetaTreeNode (render() for the figure-style tree)
    mp: object  # MetaPartitioning
    spec: object  # SampleSpec
    assignment: object  # BranchAssignment (pre-fold)

    def raf_bytes(self, batch_size: int, hidden: int, bytes_per_elem: int = 2,
                  style: str = "designated") -> int:
        """Per-batch RAF exchange bytes under this assignment (paper §4)."""
        from repro.core.raf import raf_comm_bytes

        return raf_comm_bytes(self.spec, self.assignment, batch_size, hidden,
                              bytes_per_elem, style=style)


@dataclasses.dataclass
class CacheReport:
    """Inspectable result of the §6 profiling + cache-allocation stage."""

    allocation_rows: Dict[str, int]
    learnable_types: Dict[str, int]
    hotness: object  # HotnessProfile
    penalties: object  # MissPenaltyProfile
    engine: object  # EmbedEngine


class Heta:
    """Session over one :class:`HetaConfig` (see module docstring)."""

    def __init__(self, config: Optional[HetaConfig] = None, **sections):
        if config is None:
            config = HetaConfig().updated(**sections) if sections else HetaConfig()
        elif sections:
            config = config.updated(**sections)
        self.config = config
        from repro.optim.adam import AdamConfig

        self.adam_cfg = AdamConfig(lr=config.run.lr)
        self.stage_times: Dict[str, float] = {}
        # stage products
        self.graph = None
        self.hgnn_cfg = None
        self.feat_dims = None
        self.fixed_tables = None
        self.mp = None
        self.spec = None
        self.assignment = None
        self.meta_local = None
        self.engine = None
        self.executor = None
        self.plan = None
        self.state = None
        self.sampler = None
        self.losses: List[float] = []
        self.step_times: List[float] = []
        self._steps_done = 0

    # -- stage guards --------------------------------------------------------

    def _require(self, attr: str, stage: str, needed_by: str):
        if getattr(self, attr) is None:
            raise HetaStageError(
                f"{needed_by}() requires the {stage}() stage; "
                f"run session.{stage}() first (or session.run() for all stages)"
            )

    # -- stage 1: data ------------------------------------------------------

    def build_graph(self, graph=None):
        """Materialize the HetG and the model config derived from it.

        Pass ``graph`` to reuse a pre-built :class:`HetGraph` (sweeps over
        partition counts / fanouts, or real datasets loaded elsewhere)
        instead of synthesizing from ``DataConfig``."""
        import jax.numpy as jnp

        from repro.graph.synthetic import make_dataset

        t0 = time.perf_counter()
        cfg = self.config
        self.graph = graph if graph is not None else make_dataset(
            cfg.data.dataset, scale=cfg.data.scale, seed=cfg.run.seed)
        self.feat_dims = {
            t: self.graph.feat_dim(t)
            for t in self.graph.num_nodes if self.graph.feat_dim(t)
        }
        self.fixed_tables = {t: jnp.asarray(f) for t, f in self.graph.features.items()}
        self.hgnn_cfg = cfg.model.to_hgnn_config(cfg.num_layers, self.graph.num_classes)
        self.stage_times["build_graph"] = time.perf_counter() - t0
        return self.graph

    # -- stage 2: §5 meta-partitioning ---------------------------------------

    def partition(self) -> PartitionReport:
        """Meta-partition the graph and place relation branches."""
        from repro.core.meta_partition import meta_partition
        from repro.core.raf import assign_branches, random_branch_assignment
        from repro.graph.sampler import SampleSpec

        self._require("graph", "build_graph", "partition")
        t0 = time.perf_counter()
        cfg = self.config
        self.mp = meta_partition(self.graph, cfg.partition.num_partitions,
                                 num_layers=cfg.num_layers)
        self.spec = SampleSpec.from_metatree(self.mp.metatree, cfg.data.fanouts)
        self.assignment = (
            random_branch_assignment(self.spec, cfg.partition.num_partitions,
                                     seed=cfg.run.seed)
            if cfg.partition.placement == "naive"
            else assign_branches(self.spec, self.mp)
        )
        self.meta_local = self.assignment.meta_local
        self.stage_times["partition"] = time.perf_counter() - t0
        return PartitionReport(
            summary=self.mp.summary(),
            meta_local=self.meta_local,
            num_partitions=cfg.partition.num_partitions,
            metatree=self.mp.metatree,
            mp=self.mp,
            spec=self.spec,
            assignment=self.assignment,
        )

    def comm_report(self, bytes_per_elem: int = 2, hidden: Optional[int] = None,
                    include_topology: bool = True) -> Dict[str, int]:
        """Per-batch communication accounting, all three execution models
        (the paper's §4 worked example: 92.3 → 8.0 → 0.5 MB).

        Returns bytes for: ``vanilla_feat`` (edge-cut feature fetching),
        ``vanilla_update`` (remote learnable-row read+write), ``raf_naive``
        (RAF, random placement) and ``raf_meta`` (RAF under the §5 meta
        placement — computed from ``assign_branches`` even when this
        session's configured placement is naive, so the comparison always
        shows the meta-partitioning gain).
        """
        from repro.core.comm import vanilla_comm_bytes, vanilla_update_bytes
        from repro.core.meta_partition import random_edge_cut
        from repro.core.raf import assign_branches, raf_comm_bytes, random_branch_assignment
        from repro.graph.sampler import NeighborSampler

        self._require("spec", "partition", "comm_report")
        cfg = self.config
        B = cfg.data.batch_size
        h = hidden or cfg.model.hidden
        P = cfg.partition.num_partitions
        seed = cfg.run.seed
        batch = NeighborSampler(self.graph, self.spec, B, seed=seed).sample_batch(
            self.graph.train_nodes[:B]
        )
        cut = random_edge_cut(self.graph, P, seed=seed)
        ld = cfg.model.learnable_dim
        return {
            "vanilla_feat": vanilla_comm_bytes(
                batch, cut, self.feat_dims, learnable_dim=ld,
                bytes_per_elem=bytes_per_elem, include_topology=include_topology,
            ),
            "vanilla_update": vanilla_update_bytes(
                batch, cut, self.graph, learnable_dim=ld,
                bytes_per_elem=bytes_per_elem,
            ),
            "raf_naive": raf_comm_bytes(
                self.spec, random_branch_assignment(self.spec, P, seed=seed + 1),
                B, h, bytes_per_elem,
            ),
            "raf_meta": raf_comm_bytes(
                self.spec,
                self.assignment if self.meta_local
                else assign_branches(self.spec, self.mp),
                B, h, bytes_per_elem,
            ),
        }

    # -- stage 3: §6 profiling + cache ---------------------------------------

    def profile_and_cache(self) -> CacheReport:
        """Pre-sample hotness, profile miss penalties, allocate the cache."""
        from repro.embed import EmbedEngine, presample_hotness, profile_miss_penalties

        self._require("spec", "partition", "profile_and_cache")
        t0 = time.perf_counter()
        cfg = self.config
        hotness = presample_hotness(
            self.graph, self.spec, cfg.data.batch_size,
            epochs=cfg.cache.presample_epochs,
            max_batches=cfg.cache.presample_max_batches, seed=cfg.run.seed,
        )
        penalties = profile_miss_penalties(
            self.graph, learnable_dim=cfg.model.learnable_dim,
            measured=cfg.cache.measured_penalties,
        )
        self.engine = EmbedEngine(
            self.graph, cfg.model.learnable_dim, hotness, penalties,
            cache_bytes=cfg.cache.cache_bytes, adam=self.adam_cfg,
            hotness_only=cfg.cache.hotness_only,
            num_shards=int(np.prod(cfg.run.mesh_shape)), seed=cfg.run.seed,
        )
        self.stage_times["profile_and_cache"] = time.perf_counter() - t0
        return CacheReport(
            allocation_rows=dict(self.engine.allocation.rows),
            learnable_types=dict(self.engine.learnable_types),
            hotness=hotness,
            penalties=penalties,
            engine=self.engine,
        )

    # -- stage 4: executor compilation ----------------------------------------

    def compile(self, executor: Optional[str] = None) -> "Heta":
        """Build the executor plan + initial state via the registry."""
        from repro.graph.sampler import NeighborSampler

        self._require("engine", "profile_and_cache", "compile")
        t0 = time.perf_counter()
        name = executor or self.config.run.executor
        self.executor = _executors.get(name)  # raises KeyError w/ available list
        self.plan = self.executor.build_plan(self)
        self.state = self.executor.init_state(self, self.plan)
        self.sampler = NeighborSampler(
            self.graph, self.spec, self.config.data.batch_size,
            seed=self.config.run.seed + 1,
        )
        self.stage_times["compile"] = time.perf_counter() - t0
        return self

    # -- stage 5: training / evaluation ---------------------------------------

    def step(self, batch=None) -> float:
        """One optimization step (samples the next batch when none given).

        Recorded step times come from the executor's own timed region —
        compute + sparse update, host staging excluded — matching the
        historical ``train_hgnn`` accounting."""
        self._require("state", "compile", "step")
        if batch is None:
            batch = self._next_batch()
        self.state, loss, dt = self.executor.step(self, self.plan, self.state, batch)
        self.step_times.append(dt)
        self.losses.append(loss)
        self._steps_done += 1
        return loss

    def fit(self, steps: Optional[int] = None) -> Dict:
        """Train for ``steps`` (default ``RunConfig.steps``); returns the
        result dict (same keys the legacy ``train_hgnn`` returned)."""
        self._require("state", "compile", "fit")
        steps = self.config.run.steps if steps is None else steps
        log_every = self.config.run.log_every
        for _ in range(steps):
            loss = self.step()
            i = self._steps_done - 1
            if log_every and i % log_every == 0:
                print(f"step {i:4d} loss {loss:.4f} "
                      f"({self.step_times[-1]*1e3:.1f} ms)")
        return self.results()

    def evaluate(self, num_batches: int = 1) -> Dict:
        """Mean held-out-batch loss via the executor's eval path (no update)."""
        from repro.graph.sampler import NeighborSampler

        self._require("state", "compile", "evaluate")
        sampler = NeighborSampler(
            self.graph, self.spec, self.config.data.batch_size,
            seed=self.config.run.seed + 9999,
        )
        it = sampler.epoch(shuffle=True, seed=self.config.run.seed + 9999)
        losses, metrics = [], {}
        for _ in range(num_batches):
            try:
                b = next(it)
            except StopIteration:
                break
            loss, metrics = self.executor.loss_and_metrics(self, self.plan,
                                                           self.state, b)
            losses.append(loss)
        return {"loss": float(np.mean(losses)), "num_batches": len(losses),
                **{k: v for k, v in metrics.items() if k != "loss"}}

    # -- convenience -----------------------------------------------------------

    def run(self) -> Dict:
        """Execute whatever stages remain, then ``fit()``."""
        if self.graph is None:
            self.build_graph()
        if self.spec is None:
            self.partition()
        if self.engine is None:
            self.profile_and_cache()
        if self.state is None:
            self.compile()
        return self.fit()

    def results(self) -> Dict:
        """The legacy ``train_hgnn`` result dict."""
        self._require("engine", "profile_and_cache", "results")
        # exclude jit-compile warmup from the reported step time
        timed = (self.step_times[2:] if len(self.step_times) > 4
                 else self.step_times) or [0.0]
        setup = sum(self.stage_times.values())
        return {
            "losses": list(self.losses),
            "step_time_s": float(np.median(timed)),
            "setup_s": setup,
            "hit_rates": self.engine.cache.hit_rates(),
            "partitioning": self.mp.summary(),
            "meta_local": self.meta_local,
            "cache_allocation": dict(self.engine.allocation.rows),
            "executor": self.executor.name if self.executor else None,
        }

    # -- internal ---------------------------------------------------------------

    def _next_batch(self):
        it = getattr(self, "_epoch_iter", None)
        if it is None:
            it = iter([])
        try:
            return next(it)
        except StopIteration:
            seed = self.config.run.seed + 2 + self._steps_done
            self._epoch_iter = self.sampler.epoch(shuffle=True, seed=seed)
            return next(self._epoch_iter)
