"""The ``Heta`` session — explicit, resumable pipeline stages.

One session wires the paper's full pipeline (Fig. 5) behind five stages,
each individually runnable and inspectable:

    sess = Heta(config)
    g      = sess.build_graph()        # HetG (synthetic dataset family)
    part   = sess.partition()          # §5 meta-partitioning -> PartitionReport
    cache  = sess.profile_and_cache()  # §6 hotness/penalty profiling -> CacheReport
    sess.compile(executor="raf_spmd")  # §4 executor via the registry
    result = sess.fit()                # train; same keys as train_hgnn

Calling a stage out of order raises :class:`HetaStageError` with the missing
prerequisite; ``run()`` executes whatever stages remain and then ``fit()``.

After training, the online inference tier (``repro.serve``, DESIGN.md §10)
hangs off two more stages: ``infer_all()`` materializes top-layer
embeddings for every node via layer-wise full-graph inference, and
``serve()`` starts the micro-batching :class:`EmbeddingServer` over the
materialized store (``close_serving()`` releases both).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.api import executors as _executors
from repro.api.config import HetaConfig

__all__ = ["Heta", "HetaStageError", "PartitionReport", "CacheReport"]


class HetaStageError(RuntimeError):
    """A lifecycle method was called before its prerequisite stage."""


@dataclasses.dataclass
class PartitionReport:
    """Inspectable result of the §5 partitioning stage."""

    summary: str
    meta_local: bool
    num_partitions: int
    metatree: object  # MetaTreeNode (render() for the figure-style tree)
    mp: object  # MetaPartitioning
    spec: object  # SampleSpec
    assignment: object  # BranchAssignment (pre-fold)

    def raf_bytes(self, batch_size: int, hidden: int, bytes_per_elem: int = 2,
                  style: str = "designated") -> int:
        """Per-batch RAF exchange bytes under this assignment (paper §4)."""
        from repro.core.raf import raf_comm_bytes

        return raf_comm_bytes(self.spec, self.assignment, batch_size, hidden,
                              bytes_per_elem, style=style)


@dataclasses.dataclass
class CacheReport:
    """Inspectable result of the §6 profiling + cache-allocation stage."""

    allocation_rows: Dict[str, int]
    learnable_types: Dict[str, int]
    hotness: object  # HotnessProfile
    penalties: object  # MissPenaltyProfile
    engine: object  # EmbedEngine


class Heta:
    """Session over one :class:`HetaConfig` (see module docstring)."""

    def __init__(self, config: Optional[HetaConfig] = None, **sections):
        if config is None:
            config = HetaConfig().updated(**sections) if sections else HetaConfig()
        elif sections:
            config = config.updated(**sections)
        self.config = config
        from repro.optim.adam import AdamConfig

        self.adam_cfg = AdamConfig(lr=config.run.lr)
        self.stage_times: Dict[str, float] = {}
        # stage products
        self.graph = None
        self.hgnn_cfg = None
        self.feat_dims = None
        self.fixed_tables = None
        self.mp = None
        self.spec = None
        self.assignment = None
        self.meta_local = None
        self.engine = None
        self.executor = None
        self.plan = None
        self.state = None
        self.sampler = None
        self.losses: List[float] = []
        self.step_times: List[float] = []
        self.host_times: List[float] = []  # per-step sample+stage seconds
        # fit-loop overlap accounting (wall vs serial sum; see results())
        self._fit_wall_s = 0.0
        self._fit_serial_s = 0.0
        self._fit_steps = 0
        self._steps_done = 0
        self._queue_bytes: List[int] = []  # pooled fits: per-item queue size
        # persistent sampler pool:
        # [store, arena, pool, next_global_step, workers]
        # (spawn + shm export amortize across fit() calls; see _acquire_pool)
        self._pool_cache = None
        self._pool_atexit_cb = None
        # online inference tier (repro.serve)
        self.embedding_store = None
        self._server = None
        # deterministic chaos drills (repro.data.faults.FaultPlan, or None):
        # threaded into the training pool's SampleStageTask and consumed by
        # the supervision batteries / benchmarks/fault_drill.py
        self.fault_plan = None

    # -- stage guards --------------------------------------------------------

    def _require(self, attr: str, stage: str, needed_by: str):
        if getattr(self, attr) is None:
            raise HetaStageError(
                f"{needed_by}() requires the {stage}() stage; "
                f"run session.{stage}() first (or session.run() for all stages)"
            )

    # -- stage 1: data ------------------------------------------------------

    def build_graph(self, graph=None):
        """Materialize the HetG and the model config derived from it.

        Pass ``graph`` to reuse a pre-built :class:`HetGraph` (sweeps over
        partition counts / fanouts, or real datasets loaded elsewhere)
        instead of synthesizing from ``DataConfig``."""
        import jax.numpy as jnp

        from repro.graph.synthetic import make_dataset

        t0 = time.perf_counter()
        # shm janitor (DESIGN.md §12/§13): a hard-crashed prior run can leave
        # orphaned graph/arena segments the resource tracker never saw — and,
        # since the scale-out tier, on-disk mmap stores too; sweep both kinds
        # whose owner pid is gone before allocating new ones
        try:
            from repro.graph.shm import cleanup_stale_segments

            cleanup_stale_segments()
        except Exception:
            pass  # best-effort: /dev/shm may be absent on this platform
        try:
            from repro.graph.mmap_store import cleanup_stale_stores

            cleanup_stale_stores()
        except Exception:
            pass  # best-effort: never fail session start over a sweep
        cfg = self.config
        self.graph = graph if graph is not None else make_dataset(
            cfg.data.dataset, scale=cfg.data.scale, seed=cfg.run.seed)
        self.feat_dims = {
            t: self.graph.feat_dim(t)
            for t in self.graph.num_nodes if self.graph.feat_dim(t)
        }
        self.fixed_tables = {t: jnp.asarray(f) for t, f in self.graph.features.items()}
        self.hgnn_cfg = cfg.model.to_hgnn_config(cfg.num_layers, self.graph.num_classes)
        self.stage_times["build_graph"] = time.perf_counter() - t0
        return self.graph

    # -- stage 2: §5 meta-partitioning ---------------------------------------

    def partition(self) -> PartitionReport:
        """Meta-partition the graph and place relation branches."""
        from repro.core.meta_partition import meta_partition
        from repro.core.raf import assign_branches, random_branch_assignment
        from repro.graph.sampler import SampleSpec

        self._require("graph", "build_graph", "partition")
        t0 = time.perf_counter()
        cfg = self.config
        self.mp = meta_partition(self.graph, cfg.partition.num_partitions,
                                 num_layers=cfg.num_layers)
        self.spec = SampleSpec.from_metatree(self.mp.metatree, cfg.data.fanouts)
        self.assignment = (
            random_branch_assignment(self.spec, cfg.partition.num_partitions,
                                     seed=cfg.run.seed)
            if cfg.partition.placement == "naive"
            else assign_branches(self.spec, self.mp)
        )
        self.meta_local = self.assignment.meta_local
        self.stage_times["partition"] = time.perf_counter() - t0
        return PartitionReport(
            summary=self.mp.summary(),
            meta_local=self.meta_local,
            num_partitions=cfg.partition.num_partitions,
            metatree=self.mp.metatree,
            mp=self.mp,
            spec=self.spec,
            assignment=self.assignment,
        )

    def comm_report(self, bytes_per_elem: int = 2, hidden: Optional[int] = None,
                    include_topology: bool = True) -> Dict[str, int]:
        """Per-batch communication accounting, all three execution models
        (the paper's §4 worked example: 92.3 → 8.0 → 0.5 MB).

        Returns bytes for: ``vanilla_feat`` (edge-cut feature fetching),
        ``vanilla_update`` (remote learnable-row read+write), ``raf_naive``
        (RAF, random placement) and ``raf_meta`` (RAF under the §5 meta
        placement — computed from ``assign_branches`` even when this
        session's configured placement is naive, so the comparison always
        shows the meta-partitioning gain).

        When the scale-out tier is configured (``scale.num_trainers > 1``
        or an explicit ``scale.hierarchy``), ``hier_*`` keys from
        :func:`repro.core.comm.hierarchical_comm_bytes` ride along —
        exact per-level wire bytes under the two-level hierarchical
        partition, including the Prop-2 level-0 RAF bound
        ``2(G-1)·|B|·hidden·bpe`` and the DP tier's gradient all-reduce
        bytes (DESIGN.md §13)."""
        from repro.core.comm import vanilla_comm_bytes, vanilla_update_bytes
        from repro.core.meta_partition import random_edge_cut
        from repro.core.raf import assign_branches, raf_comm_bytes, random_branch_assignment
        from repro.graph.sampler import NeighborSampler

        self._require("spec", "partition", "comm_report")
        cfg = self.config
        B = cfg.data.batch_size
        h = hidden or cfg.model.hidden
        P = cfg.partition.num_partitions
        seed = cfg.run.seed
        batch = NeighborSampler(self.graph, self.spec, B, seed=seed).sample_batch(
            self.graph.train_nodes[:B]
        )
        cut = random_edge_cut(self.graph, P, seed=seed)
        ld = cfg.model.learnable_dim
        out = {
            "vanilla_feat": vanilla_comm_bytes(
                batch, cut, self.feat_dims, learnable_dim=ld,
                bytes_per_elem=bytes_per_elem, include_topology=include_topology,
            ),
            "vanilla_update": vanilla_update_bytes(
                batch, cut, self.graph, learnable_dim=ld,
                bytes_per_elem=bytes_per_elem,
            ),
            "raf_naive": raf_comm_bytes(
                self.spec, random_branch_assignment(self.spec, P, seed=seed + 1),
                B, h, bytes_per_elem,
            ),
            "raf_meta": raf_comm_bytes(
                self.spec,
                self.assignment if self.meta_local
                else assign_branches(self.spec, self.mp),
                B, h, bytes_per_elem,
            ),
        }
        sc = cfg.scale
        if sc.enabled or sc.hierarchy is not None:
            from repro.core.comm import hierarchical_comm_bytes
            from repro.core.meta_partition import hierarchical_partition

            g, s = sc.resolved_hierarchy
            hier = hierarchical_partition(
                self.graph, g, s, num_layers=cfg.num_layers, seed=seed)
            grad_bytes = 0
            if self.state is not None:
                # DP all-reduce volume = one gradient set (= param bytes)
                import jax

                params = (self.state.get("stacks")
                          or self.state.get("bundle")) if isinstance(
                              self.state, dict) else None
                if params is not None:
                    grad_bytes = int(sum(
                        np.asarray(leaf).nbytes
                        for leaf in jax.tree_util.tree_leaves(params)))
            rep = hierarchical_comm_bytes(
                batch, hier, h, feat_dims=self.feat_dims, learnable_dim=ld,
                bytes_per_elem=bytes_per_elem, grad_bytes=grad_bytes)
            out.update({f"hier_{k}": int(v) for k, v in rep.items()})
        return out

    # -- stage 3: §6 profiling + cache ---------------------------------------

    def profile_and_cache(self) -> CacheReport:
        """Pre-sample hotness, profile miss penalties, allocate the cache.

        With ``pipeline.num_workers > 0`` the §6 pre-sampling epoch — the
        same ``batch_at`` sweep the training pool runs — fans out over a
        worker pool (bit-identical counts; visit counting is an
        order-independent sum)."""
        from repro.embed import EmbedEngine, profile_miss_penalties
        from repro.embed.profiler import presample_hotness, presample_hotness_pooled

        self._require("spec", "partition", "profile_and_cache")
        t0 = time.perf_counter()
        cfg = self.config
        if cfg.pipeline.enabled and cfg.pipeline.num_workers > 0:
            hotness = presample_hotness_pooled(
                self.graph, self.spec, cfg.data.batch_size,
                num_workers=cfg.pipeline.num_workers,
                epochs=cfg.cache.presample_epochs,
                max_batches=cfg.cache.presample_max_batches,
                seed=cfg.run.seed, depth=cfg.pipeline.depth,
            )
        else:
            hotness = presample_hotness(
                self.graph, self.spec, cfg.data.batch_size,
                epochs=cfg.cache.presample_epochs,
                max_batches=cfg.cache.presample_max_batches, seed=cfg.run.seed,
            )
        penalties = profile_miss_penalties(
            self.graph, learnable_dim=cfg.model.learnable_dim,
            measured=cfg.cache.measured_penalties,
        )
        self.engine = EmbedEngine(
            self.graph, cfg.model.learnable_dim, hotness, penalties,
            cache_bytes=cfg.cache.cache_bytes, adam=self.adam_cfg,
            hotness_only=cfg.cache.hotness_only,
            num_shards=int(np.prod(cfg.run.mesh_shape)), seed=cfg.run.seed,
            kernels=cfg.kernels,
        )
        self.stage_times["profile_and_cache"] = time.perf_counter() - t0
        return CacheReport(
            allocation_rows=dict(self.engine.allocation.rows),
            learnable_types=dict(self.engine.learnable_types),
            hotness=hotness,
            penalties=penalties,
            engine=self.engine,
        )

    # -- stage 4: executor compilation ----------------------------------------

    def compile(self, executor: Optional[str] = None) -> "Heta":
        """Build the executor plan + initial state via the registry."""
        from repro.graph.sampler import NeighborSampler

        self._require("engine", "profile_and_cache", "compile")
        t0 = time.perf_counter()
        name = executor or self.config.run.executor
        self.executor = _executors.get(name)  # raises KeyError w/ available list
        self.plan = self.executor.build_plan(self)
        self.state = self.executor.init_state(self, self.plan)
        self.sampler = NeighborSampler(
            self.graph, self.spec, self.config.data.batch_size,
            seed=self.config.run.seed + 1,
        )
        self.stage_times["compile"] = time.perf_counter() - t0
        return self

    # -- stage 5: training / evaluation ---------------------------------------

    def step(self, batch=None) -> float:
        """One optimization step (samples the next batch when none given).

        Recorded step times come from the executor's own timed region —
        compute + sparse update, host staging excluded — matching the
        historical ``train_hgnn`` accounting.  Host sample+stage time is
        recorded separately in ``host_times``."""
        self._require("state", "compile", "step")
        t0 = time.perf_counter()
        if batch is None:
            batch = self._next_batch()
        if not self._staged_protocol():
            # legacy executor: only the composed step() is overridden
            host_s = time.perf_counter() - t0
            self.state, loss, dt = self.executor.step(
                self, self.plan, self.state, batch)
            self.host_times.append(host_s)
            self.step_times.append(dt)
            self.losses.append(loss)
            self._steps_done += 1
            self._maybe_rebalance()
            self._maybe_checkpoint()
            return loss
        arrays = self.executor.stage(self, self.plan, batch)
        return self._consume(batch, arrays, time.perf_counter() - t0)

    def _staged_protocol(self) -> bool:
        """Whether the executor implements the staged-step seam (custom
        executors registered before the pipeline may only override the
        composed ``step``; they keep working on the serial path)."""
        return type(self.executor).stage is not _executors.Executor.stage

    def _consume(self, batch, arrays, host_s: float) -> float:
        """Run the device step on pre-staged arrays and record the books."""
        self.state, loss, dt = self.executor.step_staged(
            self, self.plan, self.state, batch, arrays)
        self.host_times.append(host_s)
        self.step_times.append(dt)
        self.losses.append(loss)
        self._steps_done += 1
        self._maybe_rebalance()
        self._maybe_checkpoint()
        return loss

    def _maybe_rebalance(self) -> None:
        """Online §6 re-admission: every ``cache.readmit_every`` consumed
        steps, re-score cache residency from the observed access trace
        (``EmbedEngine.rebalance``).  Holds the engine's table lock, so
        it is safe against the async pipeline's producer-side fetches."""
        every = self.config.cache.readmit_every
        if every > 0 and self.engine is not None and self._steps_done % every == 0:
            self.engine.rebalance()

    def fit(self, steps: Optional[int] = None) -> Dict:
        """Train for ``steps`` (default ``RunConfig.steps``); returns the
        result dict (same keys the legacy ``train_hgnn`` returned).

        With ``pipeline.enabled`` the loop is driven by a
        :class:`repro.data.SampleStream`: sampling + staging for batch
        *i+1* runs in the background while batch *i* trains, under the
        configured snapshot staleness policy — in one producer thread by
        default, or in ``pipeline.num_workers`` sampler processes over a
        shared-memory graph store (DESIGN.md §9), batches flowing through
        the zero-pickle batch arena (DESIGN.md §11) unless
        ``pipeline.arena`` is off.  The pool + store + arena persist
        across consecutive ``fit()`` calls (spawn cost amortizes; see
        :meth:`close_pipeline`) and are torn down on error.  Batches are
        bit-identical to the serial path for any worker count (per-batch
        RNG); losses are bit-identical too except pooled learnable
        training under ``snapshot="stale"``, where workers stage against
        bounded-stale tables (staleness ≤ ring depth)."""
        self._require("state", "compile", "fit")
        steps = self.config.run.steps if steps is None else steps
        if steps and self.config.scale.enabled:
            # multi-process data-parallel tier (DESIGN.md §13): rank 0 is
            # this process; scale.num_trainers-1 trainer processes attach
            # the shared store and the loop runs in repro.data.dp_trainer
            from repro.data.dp_trainer import run_dp_fit

            return run_dp_fit(self, steps)
        log_every = self.config.run.log_every

        def logged(loss: float) -> None:
            i = self._steps_done - 1
            if log_every and i % log_every == 0:
                print(f"step {i:4d} loss {loss:.4f} "
                      f"({self.step_times[-1]*1e3:.1f} ms)")

        t_wall = time.perf_counter()
        n0 = len(self.step_times)
        if steps and self.config.pipeline.enabled:
            if not self._staged_protocol():
                raise HetaStageError(
                    f"executor {self.executor.name!r} does not implement the "
                    "staged-step protocol (stage/step_staged) required by "
                    "pipeline.enabled; disable the pipeline or implement it"
                )
            from repro.data.sample_stream import SampleStream

            pcfg = self.config.pipeline
            start = self._steps_done
            defer = (pcfg.snapshot == "fresh"
                     and self.executor.stage_reads_tables(self, self.plan))
            stream_kw = {}
            arena = None
            if pcfg.num_workers > 0:
                pool, arena = self._acquire_pool(start)
                stream_kw = dict(
                    num_workers=pcfg.num_workers,
                    pool=pool,
                    arena=arena,
                    spec=self.spec,
                    finish_stage=lambda b, host: self.executor.stage_from_host(
                        self, self.plan, b, host),
                )
            # learnable-"stale" worker staging: after every consumed step,
            # republish the updated learnable tables into the arena's
            # seqlock'd region so workers stage batch i+k against tables at
            # most the ring depth behind the trainer (DESIGN.md §11)
            republish = (arena is not None and arena.handle.tables_mutable)
            try:
                with SampleStream(
                    lambda i: self._batch_for_step(start + i),
                    lambda b: self.executor.stage(self, self.plan, b),
                    num_steps=steps, depth=pcfg.depth, defer_stage=defer,
                    **stream_kw,
                ) as stream:
                    for batch, arrays, host_s in stream:
                        logged(self._consume(batch, arrays, host_s))
                        if self._pool_cache is not None and stream_kw:
                            self._pool_cache[3] += 1  # pool stays in sync
                        if republish:
                            arena.publish_tables({
                                t: self.engine.table(t)
                                for t in self.engine.learnable_types
                            })
                    self._queue_bytes.extend(stream.queue_bytes)
            except BaseException:
                # a failed pooled fit leaves pool position and _steps_done
                # out of sync (and possibly dead workers): tear down so the
                # next fit starts a fresh, aligned pool
                self.close_pipeline()
                raise
        else:
            for _ in range(steps):
                logged(self.step())
        self._fit_wall_s += time.perf_counter() - t_wall
        self._fit_steps += len(self.step_times) - n0
        self._fit_serial_s += sum(self.host_times[n0:]) + sum(self.step_times[n0:])
        return self.results()

    def evaluate(self, num_batches: int = 1, use_full_graph: bool = False) -> Dict:
        """Mean held-out-batch loss via the executor's eval path (no update).

        With ``pipeline.enabled``, batches are prefetched in the background
        — by a thread, or by ``pipeline.num_workers`` sampler processes
        over a shared-memory graph store (eval staging never trains tables,
        so any producer is always bit-exact).

        ``use_full_graph=True`` scores the *same* held-out batches against
        the embeddings :meth:`infer_all` materialized instead of running the
        executor's sampled forward — identical numbers when sampling is
        exhaustive (fanouts >= max in-degree; see ``repro.serve``)."""
        from repro.graph.sampler import NeighborSampler

        self._require("state", "compile", "evaluate")
        sampler = NeighborSampler(
            self.graph, self.spec, self.config.data.batch_size,
            seed=self.config.run.seed + 9999,
        )
        eval_seed = self.config.run.seed + 9999
        n = min(num_batches, sampler.steps_per_epoch())
        losses, metrics = [], {}

        if use_full_graph:
            self._require("embedding_store", "infer_all",
                          "evaluate(use_full_graph=True)")
            it = sampler.epoch(shuffle=True, seed=eval_seed)
            for _ in range(n):
                b = next(it)
                logits = self.embedding_store.scores(b.seeds)
                logits = logits.astype(np.float64)
                logits -= logits.max(axis=-1, keepdims=True)
                logp = logits - np.log(
                    np.exp(logits).sum(axis=-1, keepdims=True))
                losses.append(float(
                    -logp[np.arange(len(b.seeds)), b.labels].mean()))
            return {"loss": float(np.mean(losses)),
                    "num_batches": len(losses), "full_graph": True}

        def consume(b):
            loss, m = self.executor.loss_and_metrics(self, self.plan,
                                                     self.state, b)
            losses.append(loss)
            return m

        pcfg = self.config.pipeline
        if pcfg.enabled and pcfg.num_workers > 0:
            from repro.data.sample_stream import SampleStream
            from repro.data.worker_pool import EpochSchedule, WorkerPool

            store, arena, task = self._pool_task(
                EpochSchedule(eval_seed, sampler.steps_per_epoch()),
                eval_seed,
            )
            try:
                with WorkerPool(task, num_workers=pcfg.num_workers,
                                depth=pcfg.depth, num_items=n,
                                name="eval-pool",
                                **self._supervision_kw(arena)) as pool:
                    # the stream resolves arena SlotRefs (and passes legacy
                    # tuples through); eval consumes raw batches, so the
                    # consumer-side completion is a no-op
                    with SampleStream(
                        num_steps=n, num_workers=pcfg.num_workers,
                        pool=pool, arena=arena, spec=self.spec,
                        finish_stage=lambda b, host: None,
                    ) as stream:
                        for b, _, _ in stream:
                            metrics = consume(b)
            finally:
                try:
                    store.unlink()
                finally:
                    if arena is not None:
                        arena.unlink()
        elif pcfg.enabled:
            from repro.data.prefetch import Prefetcher

            with Prefetcher(
                lambda i: sampler.batch_at(i, epoch_seed=eval_seed),
                depth=pcfg.depth, num_items=n,
                name="eval-stream",
            ) as pf:
                for b in pf:
                    metrics = consume(b)
        else:
            it = sampler.epoch(shuffle=True, seed=eval_seed)
            for _ in range(n):
                metrics = consume(next(it))
        return {"loss": float(np.mean(losses)), "num_batches": len(losses),
                **{k: v for k, v in metrics.items() if k != "loss"}}

    # -- stage 6: the online inference tier (repro.serve) ----------------------

    def infer_all(self, node_block: Optional[int] = None,
                  shm: Optional[bool] = None):
        """Materialize top-layer embeddings for every node of every type via
        layer-wise full-graph inference (DESIGN.md §10), from the trained
        SPMD stacks.  ``node_block``/``shm`` default to ``ServeConfig``.
        Returns (and parks on the session) the
        :class:`~repro.serve.full_graph.EmbeddingStore`."""
        from repro.serve.full_graph import infer_all as _infer_all

        self._require("state", "compile", "infer_all")
        plan = getattr(self.plan, "plan", None)
        stacks = self.state.get("stacks") if isinstance(self.state, dict) else None
        if plan is None or stacks is None:
            raise HetaStageError(
                f"infer_all() needs the stacked SPMD plan, but executor "
                f"{self.executor.name!r} does not expose one; "
                "compile(executor='raf_spmd') first"
            )
        t0 = time.perf_counter()
        scfg = self.config.serve
        store = _infer_all(
            self.graph, plan, stacks, self.engine.tables_snapshot(),
            node_block=scfg.node_block if node_block is None else node_block,
            kernels=self.config.kernels,
            shm=scfg.shm if shm is None else shm,
        )
        if self.embedding_store is not None:
            self.close_serving()
        self.embedding_store = store
        self.stage_times["infer_all"] = time.perf_counter() - t0
        return store

    def serve(self, **overrides):
        """Start (or return) the micro-batching
        :class:`~repro.serve.server.EmbeddingServer` over the materialized
        store.  Flush policy / cache budget come from ``ServeConfig``
        (keyword overrides win); the scoring step runs on
        ``make_production_mesh`` when ``serve.production_mesh`` is set, else
        on the run's mesh.  ``close_serving()`` stops it."""
        if self._server is not None:
            return self._server
        self._require("embedding_store", "infer_all", "serve")
        from repro.serve.server import EmbeddingServer

        scfg = self.config.serve
        if scfg.production_mesh:
            from repro.launch.mesh import make_production_mesh

            mesh = make_production_mesh()
        else:
            mesh = getattr(self.plan, "mesh", None)
        kw = dict(
            max_batch=scfg.max_batch, max_wait_ms=scfg.max_wait_ms,
            max_queue=scfg.max_queue, cache_mb=scfg.cache_mb,
            kernels=self.config.kernels, mesh=mesh,
            readmit_every=scfg.readmit_every,
            deadline_ms=scfg.deadline_ms,
            flush_retries=scfg.flush_retries,
            retry_backoff_ms=scfg.retry_backoff_ms,
            breaker_threshold=scfg.breaker_threshold,
            breaker_cooldown_ms=scfg.breaker_cooldown_ms,
            faults=self.fault_plan,
        )
        kw.update(overrides)
        self._server = EmbeddingServer(self.embedding_store, **kw)
        return self._server

    def close_serving(self) -> None:
        """Stop the embedding server and release the store (unlinking its
        shm segment when shm-backed).  Idempotent."""
        srv, self._server = self._server, None
        if srv is not None:
            srv.close()
        store, self.embedding_store = self.embedding_store, None
        if store is not None:
            store.close()

    # -- convenience -----------------------------------------------------------

    def run(self) -> Dict:
        """Execute whatever stages remain, then ``fit()``."""
        if self.graph is None:
            self.build_graph()
        if self.spec is None:
            self.partition()
        if self.engine is None:
            self.profile_and_cache()
        if self.state is None:
            self.compile()
        return self.fit()

    # -- checkpoint / resume (DESIGN.md §12) ------------------------------------

    def config_fingerprint(self) -> str:
        """sha256 over the canonical config dict — stamped into every
        checkpoint manifest so :meth:`restore` refuses state trained under
        a different configuration."""
        import hashlib
        import json

        blob = json.dumps(self.config.to_dict(), sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()

    def _ckpt_tree(self) -> Dict:
        """The checkpointable pytree: executor state (param stacks +
        optimizer), learnable embed tables + Adam rows + step counters,
        readmission EMA, and the cache-residency profile."""
        snap = self.engine.state_snapshot()
        return {
            "state": self.state,
            "embed": {
                "tables": snap["tables"],
                "m": snap["m"],
                "v": snap["v"],
                "steps": {t: np.int64(s) for t, s in snap["steps"].items()},
                "hotness_ema": snap["hotness_ema"],
                "residency": {t: np.asarray(ids, np.int64)
                              for t, ids in snap["residency"].items()},
            },
        }

    def save(self, directory: Optional[str] = None, name: str = "ckpt") -> str:
        """Atomically checkpoint the full session state at the current step.

        Written via :func:`repro.checkpoint.save_checkpoint` (npz tmp +
        rename, then manifest rename as the commit point; per-array sha256
        hashes).  The manifest's ``extra`` records the config fingerprint,
        the sampler position ``(steps_done, epoch_seed, step_in_epoch)``
        and the run seed, so :meth:`restore` resumes the loss trajectory
        bit-for-bit.  ``directory`` defaults to ``checkpoint.dir``."""
        from repro.checkpoint import save_checkpoint

        self._require("state", "compile", "save")
        directory = directory or self.config.checkpoint.dir
        if directory is None:
            raise ValueError(
                "save() needs a directory (argument or checkpoint.dir config)")
        step = self._steps_done
        epoch_seed, idx = self._schedule().seed_and_index(step)
        extra = {
            "fingerprint": self.config_fingerprint(),
            "steps_done": step,
            "epoch_seed": int(epoch_seed),
            "step_in_epoch": int(idx),
            "seed": int(self.config.run.seed),
        }
        path = save_checkpoint(directory, step, self._ckpt_tree(),
                               name=name, extra=extra)
        self._prune_checkpoints(directory, name)
        return path

    def restore(self, directory: Optional[str] = None,
                step: Optional[int] = None, name: str = "ckpt") -> int:
        """Load a committed checkpoint and position the session at its step.

        Runs any missing pipeline stages first (the restored arrays load
        into freshly-compiled templates), verifies the config fingerprint
        and every array's content hash (:class:`CheckpointError` on any
        mismatch or torn write), and realigns the sampler so the next
        ``fit``/``step`` continues the interrupted run's loss trajectory
        bit-for-bit.  Returns the restored step."""
        from repro.checkpoint import (CheckpointError, latest_step,
                                      load_checkpoint, read_manifest)

        directory = directory or self.config.checkpoint.dir
        if directory is None:
            raise ValueError(
                "restore() needs a directory (argument or checkpoint.dir)")
        if step is None:
            step = latest_step(directory, name)
            if step is None:
                raise CheckpointError(
                    f"no committed checkpoint found in {directory!r}")
        if self.graph is None:
            self.build_graph()
        if self.spec is None:
            self.partition()
        if self.engine is None:
            self.profile_and_cache()
        if self.state is None:
            self.compile()
        manifest = read_manifest(directory, step, name)
        extra = manifest.get("extra", {})
        fp = extra.get("fingerprint")
        if fp and fp != self.config_fingerprint():
            raise CheckpointError(
                f"checkpoint at step {step} was written under a different "
                f"HetaConfig (fingerprint {fp[:12]}… != "
                f"{self.config_fingerprint()[:12]}…)")
        template = self._ckpt_tree()
        # residency sets change size across rebalances: template shapes for
        # them come from the manifest, not from the session's current cache
        template["embed"]["residency"] = {
            key.split("/", 2)[2]: np.zeros(tuple(manifest["shapes"][key]),
                                           np.int64)
            for key in manifest.get("keys", [])
            if key.startswith("embed/residency/")
        }
        tree = load_checkpoint(directory, step, template, name=name)
        self.state = tree["state"]
        emb = tree["embed"]
        self.engine.load_state({
            "tables": emb["tables"],
            "m": emb["m"],
            "v": emb["v"],
            "steps": {t: int(s) for t, s in emb["steps"].items()},
            "hotness_ema": emb["hotness_ema"],
            "residency": emb["residency"],
        })
        self._steps_done = int(extra.get("steps_done", step))
        # the persistent pool (if any) is positioned at the pre-restore
        # step; tear it down so the next fit respawns aligned
        self.close_pipeline()
        return step

    def _maybe_checkpoint(self) -> None:
        """Periodic checkpointing: every ``checkpoint.every_steps`` consumed
        steps, :meth:`save` to ``checkpoint.dir`` (both config-driven)."""
        c = self.config.checkpoint
        if (c.every_steps > 0 and self._steps_done > 0
                and self._steps_done % c.every_steps == 0):
            self.save(c.dir)

    def _prune_checkpoints(self, directory: str, name: str) -> None:
        """Keep only the newest ``checkpoint.keep`` committed checkpoints
        (0 = keep everything)."""
        import os
        import re

        keep = self.config.checkpoint.keep
        if keep <= 0:
            return
        steps = sorted(
            int(m.group(1))
            for f in os.listdir(directory)
            if (m := re.fullmatch(rf"{name}_(\d+)\.npz", f))
            and os.path.exists(os.path.join(directory, f + ".json"))
        )
        for s in steps[:-keep]:
            base = os.path.join(directory, f"{name}_{s:08d}.npz")
            for p in (base, base + ".json"):
                try:
                    os.remove(p)
                except OSError:
                    pass

    def results(self) -> Dict:
        """The legacy ``train_hgnn`` result dict."""
        self._require("engine", "profile_and_cache", "results")
        # exclude jit-compile warmup from the reported step time
        timed = (self.step_times[2:] if len(self.step_times) > 4
                 else self.step_times) or [0.0]
        setup = sum(self.stage_times.values())
        # overlap fraction: share of serial host+device work hidden by the
        # pipeline (0 when serial: wall >= host + step by construction)
        serial = self._fit_serial_s
        overlap = max(0.0, 1.0 - self._fit_wall_s / serial) if serial > 0 else 0.0
        # seeds consumed per second of fit() wall time — the host-pipeline
        # throughput figure the worker-pool benchmarks sweep
        samples_per_s = (
            self._fit_steps * self.config.data.batch_size / self._fit_wall_s
            if self._fit_wall_s > 0 else 0.0
        )
        return {
            "losses": list(self.losses),
            "step_time_s": float(np.median(timed)),
            "host_time_s": float(np.median(self.host_times or [0.0])),
            "setup_s": setup,
            "pipeline": bool(self.config.pipeline.enabled),
            "sampler_workers": (self.config.pipeline.num_workers
                                if self.config.pipeline.enabled else 0),
            "samples_per_s": float(samples_per_s),
            "overlap_fraction": float(overlap),
            # mean pickled bytes per worker→consumer queue item — ~1e2 with
            # the batch arena (SlotRef descriptors), ~1e6 legacy (ndarrays)
            "queue_bytes_per_step": (
                float(np.mean(self._queue_bytes)) if self._queue_bytes
                else 0.0),
            "hit_rates": self.engine.cache.hit_rates(),
            "partitioning": self.mp.summary(),
            "meta_local": self.meta_local,
            "cache_allocation": dict(self.engine.allocation.rows),
            "executor": self.executor.name if self.executor else None,
        }

    # -- internal ---------------------------------------------------------------

    def _schedule(self, start_step: int = 0):
        """The epoch schedule of the training loop: epoch ``e`` starts at
        step ``e * steps_per_epoch`` and shuffles with the seed the legacy
        epoch-iterator used at that boundary (``run.seed + 2 +
        first_step_of_epoch``).  One shared object — serial loop, thread
        stream and every pool worker all derive batches from it."""
        from repro.data.worker_pool import EpochSchedule

        E = self.sampler.steps_per_epoch()
        if E == 0:
            raise ValueError(
                f"batch_size ({self.config.data.batch_size}) exceeds the "
                f"number of train nodes ({len(self.graph.train_nodes)})"
            )
        return EpochSchedule(self.config.run.seed + 2, E,
                             start_step=start_step)

    def _batch_for_step(self, s: int):
        """The training batch of global step ``s`` — a pure function of
        ``(config seed, s)``, so the serial loop and the async stream (which
        materializes batches ahead, possibly out of thread or out of
        process) see identical data."""
        epoch_seed, i = self._schedule().seed_and_index(s)
        return self.sampler.batch_at(i, epoch_seed=epoch_seed)

    def _acquire_pool(self, start_step: int):
        """The persistent sampler pool positioned at ``start_step``.

        Spawning workers and exporting the shm store cost ~a second; one
        pool therefore serves consecutive ``fit()`` calls as long as the
        requested start lines up with where the pool's stripe left off
        (tracked in ``_pool_cache``) and the worker count is unchanged.
        Misalignment — a serial ``step()`` in between, a config change, a
        prior failure — tears the old pool down and spawns a fresh one.
        ``close_pipeline()`` (also invoked on fit errors) releases
        everything explicitly; GC of the session is the fallback."""
        from repro.data.worker_pool import WorkerPool

        pcfg = self.config.pipeline
        if self._pool_cache is not None:
            store, arena, pool, next_step, workers = self._pool_cache
            if (workers == pcfg.num_workers and next_step == start_step
                    and not pool._closed):
                return pool, arena
            self.close_pipeline()
        store, arena, task = self._pool_task(
            self._schedule(start_step), self.config.run.seed + 1,
            recipe=self.executor.worker_stage_recipe(self, self.plan),
            faults=self.fault_plan,
        )
        pool = WorkerPool(task, num_workers=pcfg.num_workers,
                          depth=pcfg.depth, num_items=None,
                          **self._supervision_kw(arena))
        self._pool_cache = [store, arena, pool, start_step, pcfg.num_workers]
        if self._pool_atexit_cb is None:
            # scripts that train and simply exit must not leave the store
            # to the resource tracker's leaked-segment shutdown path (it
            # cleans up, but warns); weakref so the hook never pins the
            # session alive
            import atexit
            import weakref

            ref = weakref.ref(self)

            def _cleanup(_ref=ref):
                sess = _ref()
                if sess is not None:
                    sess.close_pipeline()

            atexit.register(_cleanup)
            self._pool_atexit_cb = _cleanup
        return pool, arena

    def close_pipeline(self) -> None:
        """Tear down the persistent sampler pool and unlink its shm store.

        Idempotent; safe to call any time.  Sessions that ran pooled fits
        release their workers and segments here (or implicitly at GC)."""
        cb, self._pool_atexit_cb = self._pool_atexit_cb, None
        if cb is not None:
            import atexit

            try:  # don't accumulate dead hooks across many sessions
                atexit.unregister(cb)
            except Exception:
                pass
        if self._pool_cache is None:
            return
        store, arena, pool, _, _ = self._pool_cache
        self._pool_cache = None
        try:
            pool.close()
        finally:
            try:
                store.unlink()
            finally:
                if arena is not None:
                    arena.unlink()

    def _supervision_kw(self, arena) -> Dict:
        """WorkerPool supervision kwargs from ``FaultConfig`` (DESIGN.md
        §12): restart budget, backoff, and the death hook that poisons the
        dead worker's arena sub-ring so stale ``SlotRef``\\ s fail loudly
        before the replacement replays the stripe."""
        fcfg = self.config.faults
        kw = dict(max_restarts=fcfg.max_worker_restarts,
                  restart_backoff_s=fcfg.worker_backoff_s)
        if arena is not None:
            kw["on_worker_death"] = arena.invalidate_worker_slots
        return kw

    def _pool_task(self, schedule, sampler_seed: int, recipe=None,
                   faults=None):
        """Shared-memory graph store, batch arena and picklable sampling
        task for a worker pool following ``schedule`` (the caller owns
        both: ``_acquire_pool`` parks them in ``_pool_cache``, ``evaluate``
        unlinks per call).  Staging moves into the workers when the
        executor provides a ``recipe`` — exactly the tables its branches
        read travel with the batch pipeline; with ``recipe=None`` workers
        sample only and staging stays consumer-side.

        With ``pipeline.arena`` (default) batches flow through a
        fixed-slot shm ring buffer (DESIGN.md §11): the tables live in the
        arena segment — seqlock-republishable when learnable tables train
        under the ``"stale"`` policy — and the queues carry only
        :class:`SlotRef` descriptors.  ``arena=False`` keeps the legacy
        pickle path (tables exported read-only into the graph store)."""
        from repro.data.staging import arena_fields
        from repro.data.worker_pool import SampleStageTask
        from repro.graph.shm import create_arena, share_graph

        pcfg = self.config.pipeline
        tables = None
        if recipe is not None:
            snapshot = self.engine.tables_snapshot()
            tables = {t: snapshot[t] for t in recipe.table_types()}
        arena = None
        if pcfg.arena:
            store = share_graph(self.graph, include_features=False)
            probe = self._batch_for_step(0)  # padded shapes: any step works
            mutable = (recipe is not None
                       and bool(getattr(self.plan, "learn_feats", False)))
            arena = create_arena(
                arena_fields(probe, recipe=recipe, tables=tables),
                num_workers=pcfg.num_workers, depth=pcfg.depth,
                tables=tables, tables_mutable=mutable,
            )
        else:
            store = share_graph(self.graph, include_features=False,
                                tables=tables)
        task = SampleStageTask(
            handle=store.handle,
            spec=self.spec,
            batch_size=self.config.data.batch_size,
            sampler_seed=sampler_seed,
            schedule=schedule,
            recipe=recipe,
            arena=arena.handle if arena is not None else None,
            faults=faults,
            write_timeout_s=self.config.faults.arena_write_timeout_s,
            pin_cpus=pcfg.pin_workers,
        )
        return store, arena, task

    def _next_batch(self):
        return self._batch_for_step(self._steps_done)
