"""HetaConfig — the typed, validated configuration tree of the public API.

One config object describes a complete Heta run.  It composes eleven
section dataclasses mirroring the pipeline stages:

  * :class:`DataConfig`      — dataset, scale, fanouts, batch size
  * :class:`PartitionConfig` — partition count + relation placement policy
  * :class:`ModelConfig`     — HGNN architecture (wraps ``HGNNConfig``)
  * :class:`CacheConfig`     — miss-penalty cache budget + profiling knobs
  * :class:`RunConfig`       — executor, mesh, steps, lr, seed
  * :class:`PipelineConfig`  — async host pipeline (prefetch depth, snapshot
    staleness policy; see the ``repro.data`` package docstring)
  * :class:`KernelConfig`    — fused Pallas kernel layer (per-op toggles,
    interpret override; see ``repro.kernels`` and DESIGN.md §8)
  * :class:`ServeConfig`     — online inference tier (layer-wise inference
    node block, micro-batch flush policy, serve cache budget, degradation
    policy — deadlines, flush retries, circuit breaker; see ``repro.serve``
    and DESIGN.md §10/§12)
  * :class:`CheckpointConfig`— periodic session checkpointing
    (``Heta.save``/``restore``; see ``repro.checkpoint`` and DESIGN.md §12)
  * :class:`FaultConfig`     — fault-tolerance policy (worker restart
    budget/backoff, arena write stall timeout; DESIGN.md §12)
  * :class:`ScaleConfig`     — hierarchical scale-out (trainer process
    count, group hierarchy, store flavor, allreduce overlap; see
    ``repro.data.dp_trainer`` and DESIGN.md §13)

Three interchange formats round-trip losslessly:

  * nested dicts          — ``to_dict()`` / ``from_dict()`` (JSON-friendly)
  * the legacy kwargs blob — ``from_flat_kwargs()`` / ``to_flat_kwargs()``
    (the historical ``train_hgnn(...)`` surface)
  * CLI flags             — ``add_config_args(parser)`` /
    ``config_from_args(args)``; ``python -m repro.launch.train`` flags are
    *derived* from the dataclass fields below, not duplicated by hand.

This module is deliberately jax-free so CLI/arg handling stays cheap.
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = [
    "DataConfig",
    "PartitionConfig",
    "ModelConfig",
    "CacheConfig",
    "RunConfig",
    "PipelineConfig",
    "KernelConfig",
    "ServeConfig",
    "CheckpointConfig",
    "FaultConfig",
    "ScaleConfig",
    "HetaConfig",
    "add_config_args",
    "config_from_args",
]

PLACEMENTS = ("meta", "naive")
CACHE_POLICIES = ("miss_penalty", "hotness")
# the built-in relation modules; the authoritative registry is
# ``repro.core.relmod`` (a test asserts the two agree)
HGNN_MODELS = ("rgcn", "rgat", "hgt")
SNAPSHOT_POLICIES = ("stale", "fresh")


def _known_models() -> Tuple[str, ...]:
    """Model names accepted by validation: the relation-module registry when
    it is loaded, else the built-in list.  Consulting ``sys.modules`` (never
    importing) keeps this module jax-free for cheap CLI parsing while letting
    user-registered relation modules pass config validation."""
    import sys

    relmod = sys.modules.get("repro.core.relmod")
    if relmod is not None:
        return tuple(relmod.available_models())
    return HGNN_MODELS


@dataclasses.dataclass(frozen=True)
class DataConfig:
    """What to train on and how to sample it."""

    dataset: str = "ogbn-mag"
    scale: Optional[float] = None  # None = the dataset's default scale
    fanouts: Tuple[int, ...] = (4, 3)  # per-hop fanouts; len == num HGNN layers
    batch_size: int = 32

    def __post_init__(self):
        object.__setattr__(self, "fanouts", tuple(int(f) for f in self.fanouts))
        if not self.fanouts or any(f < 1 for f in self.fanouts):
            raise ValueError(f"fanouts must be non-empty positive ints, got {self.fanouts}")
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    """§5 meta-partitioning: how many partitions, and how relations land."""

    num_partitions: int = 4
    placement: str = "meta"  # meta (Alg. 2) | naive (random, the ablation)

    def __post_init__(self):
        if self.num_partitions < 1:
            raise ValueError(f"num_partitions must be >= 1, got {self.num_partitions}")
        if self.placement not in PLACEMENTS:
            raise ValueError(f"placement must be one of {PLACEMENTS}, got {self.placement!r}")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """HGNN architecture.  ``num_layers`` / ``num_classes`` are derived from
    the data (fanouts length, graph label count) when the session builds the
    underlying :class:`repro.core.hgnn.HGNNConfig`."""

    model: str = "rgcn"  # any registered relation module (rgcn | rgat | hgt built in)
    hidden: int = 64
    num_heads: int = 4
    learnable_dim: int = 64
    # False freezes the learnable feature tables (no sparse updates) — used
    # by device-compute-only benchmarks and feature-transfer experiments
    train_learnable: bool = True

    def __post_init__(self):
        known = _known_models()
        if self.model not in known:
            raise ValueError(f"model must be one of {known}, got {self.model!r}")
        if self.hidden < 1 or self.hidden % self.num_heads:
            raise ValueError(
                f"hidden ({self.hidden}) must be positive and divisible by "
                f"num_heads ({self.num_heads})"
            )
        if self.learnable_dim < 1:
            raise ValueError(f"learnable_dim must be >= 1, got {self.learnable_dim}")

    def to_hgnn_config(self, num_layers: int, num_classes: int):
        from repro.core.hgnn import HGNNConfig

        return HGNNConfig(
            model=self.model,
            hidden=self.hidden,
            num_layers=num_layers,
            num_heads=self.num_heads,
            num_classes=num_classes,
            learnable_dim=self.learnable_dim,
        )


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """§6 miss-penalty cache + the pre-training profilers that feed it."""

    cache_mb: int = 4
    policy: str = "miss_penalty"  # miss_penalty (Heta) | hotness (GNNLab-style)
    presample_epochs: int = 2
    presample_max_batches: int = 20
    measured_penalties: bool = False  # measure real copies vs analytic model
    # online re-admission: every N training steps, re-score residency from
    # the cache's observed access counters (EmbedEngine.rebalance) under
    # the same byte budget.  0 = one-shot allocation only.
    readmit_every: int = 0

    def __post_init__(self):
        if self.cache_mb < 0:
            raise ValueError(f"cache_mb must be >= 0, got {self.cache_mb}")
        if self.policy not in CACHE_POLICIES:
            raise ValueError(f"policy must be one of {CACHE_POLICIES}, got {self.policy!r}")
        if self.readmit_every < 0:
            raise ValueError(
                f"readmit_every must be >= 0, got {self.readmit_every}")

    @property
    def cache_bytes(self) -> int:
        return self.cache_mb << 20

    @property
    def hotness_only(self) -> bool:
        return self.policy == "hotness"


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution: which executor, on what mesh, for how long."""

    executor: str = "raf_spmd"  # a name registered in repro.api.executors
    mesh_shape: Tuple[int, int] = (1, 1)  # (data, model) mesh axes
    steps: int = 20
    lr: float = 5e-3
    seed: int = 0
    log_every: int = 0

    def __post_init__(self):
        object.__setattr__(self, "mesh_shape", tuple(int(x) for x in self.mesh_shape))
        if len(self.mesh_shape) != 2 or any(x < 1 for x in self.mesh_shape):
            raise ValueError(f"mesh_shape must be 2 positive ints, got {self.mesh_shape}")
        if self.steps < 0:
            raise ValueError(f"steps must be >= 0, got {self.steps}")
        if self.lr <= 0:
            raise ValueError(f"lr must be > 0, got {self.lr}")


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Async host pipeline: overlap sampling + feature staging with the
    device step (see the ``repro.data`` package docstring for the design
    and the staleness semantics of ``snapshot``).

    ``num_workers`` selects the producer: 0 (default) keeps the single
    background thread; N > 0 runs a pool of N sampler *processes* over a
    shared-memory graph store (``repro.data.worker_pool``, DESIGN.md §9) —
    bit-identical batches for any worker count, ``depth`` prefetched items
    per worker.

    ``arena`` (pool mode only) moves batch payloads off the queues into a
    fixed-slot shared-memory ring buffer (the batch arena, DESIGN.md §11):
    workers write sampled + pre-staged arrays straight into seqlock-stamped
    slots and the queues carry only slot descriptors — zero pickled
    ndarrays on the hot path.  With the arena and ``snapshot="stale"``,
    learnable-table staging runs *inside* workers against bounded-stale
    table snapshots republished each step (staleness ≤ ring depth); with
    ``snapshot="fresh"`` (or ``arena=False``) learnable staging stays on
    the consumer and is bit-exact."""

    enabled: bool = False
    depth: int = 2  # prefetched batches kept ready ahead of the device step
    snapshot: str = "stale"  # stale (max overlap) | fresh (bit-exact staging)
    num_workers: int = 0  # 0 = thread producer; N > 0 = sampler process pool
    arena: bool = True  # pool mode: shm ring-buffer slots, descriptor queues
    # opt-in CPU-affinity pin: sampler worker w sticks to core (w+1) % ncpu,
    # biasing core 0 toward the consumer (best-effort; Linux only)
    pin_workers: bool = False

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        if self.snapshot not in SNAPSHOT_POLICIES:
            raise ValueError(
                f"snapshot must be one of {SNAPSHOT_POLICIES}, got {self.snapshot!r}"
            )
        if self.num_workers < 0:
            raise ValueError(
                f"num_workers must be >= 0, got {self.num_workers}"
            )
        if self.num_workers > 0 and not self.enabled:
            raise ValueError(
                "pipeline.num_workers > 0 requires pipeline.enabled "
                "(pass --pipeline / pipeline=dict(enabled=True, ...)); a "
                "worker pool only exists inside the async host pipeline"
            )


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Fused Pallas kernel layer (``repro.kernels``, DESIGN.md §8).

    ``enabled`` gates the whole layer; the per-op toggles select individual
    kernels (``stacked_agg`` — the SPMD executor's stacked relation
    aggregation; ``relation_agg`` — the unstacked dict-form variant;
    ``gather`` — the cache-fetch row gather).  Backend policy lives in
    ``repro.kernels.ops.kernel_choice``: compiled kernels run on TPU by
    default, the jnp/vmap oracles elsewhere — unless ``interpret`` is
    forced ``True``, which runs the Pallas interpreter anywhere (parity
    tests/CI; a Python emulation, never a perf path).

    ``fuse_epilogue`` keeps the attention family on the fully fused
    epilogue kernel (per-slot projections streamed from the weight stacks);
    off, the ``attn_parts`` factoring — the parity oracle — runs instead.
    Block sizes resolve per (op, shape-class): the explicit ``block_n`` /
    ``block_out`` / ``block_in`` overrides beat the committed tuning table
    (consulted when ``autotune`` is on) beat the built-in defaults
    (``repro.kernels.ops.resolve_blocks``).
    """

    enabled: bool = True
    stacked_agg: bool = True
    relation_agg: bool = True
    gather: bool = True
    interpret: Optional[bool] = None  # None = auto per backend
    fuse_epilogue: bool = True
    autotune: bool = False  # consult the committed block-size tuning table
    block_n: Optional[int] = None  # explicit node-block override
    block_out: Optional[int] = None  # explicit d_out-block override
    block_in: Optional[int] = None  # explicit d_in-chunk override

    def __post_init__(self):
        for f in ("enabled", "stacked_agg", "relation_agg", "gather",
                  "fuse_epilogue", "autotune"):
            if not isinstance(getattr(self, f), bool):
                raise ValueError(f"kernels.{f} must be a bool")
        if self.interpret is not None and not isinstance(self.interpret, bool):
            raise ValueError("kernels.interpret must be True, False or None")
        for f in ("block_n", "block_out", "block_in"):
            v = getattr(self, f)
            if v is None:
                continue
            if not isinstance(v, int) or isinstance(v, bool) or v <= 0:
                raise ValueError(
                    f"kernels.{f} must be a positive int or None, got {v!r}"
                )


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Online inference tier (``repro.serve``, DESIGN.md §10).

    ``node_block`` chunks the layer-wise full-graph inference sweep;
    ``max_batch`` / ``max_wait_ms`` / ``max_queue`` are the micro-batcher's
    flush-and-backpressure policy; ``cache_mb`` budgets the serve-side
    ``FeatureCache`` over the materialized embeddings; ``shm`` backs the
    embedding store with a shared-memory segment for zero-copy attach;
    ``production_mesh`` places the scoring step on ``make_production_mesh``
    (256 devices) instead of the run's mesh; ``readmit_every`` re-admits
    the serve cache from the served-id trace every N flushes (0 = off).

    Degradation policy (DESIGN.md §12): ``deadline_ms`` is the default
    per-request deadline (0 = none) — ``query`` waits at most this long and
    the flusher stops retrying once the oldest queued request would blow
    it; a failing flush is retried ``flush_retries`` times with exponential
    backoff from ``retry_backoff_ms``; ``breaker_threshold`` consecutive
    primary-path failures trip a circuit breaker that serves requests from
    a degraded direct-store gather (cache bypass) until a probe succeeds
    after ``breaker_cooldown_ms``."""

    node_block: int = 1024
    max_batch: int = 64
    max_wait_ms: float = 2.0
    max_queue: int = 1024
    cache_mb: int = 4
    shm: bool = False
    production_mesh: bool = False
    readmit_every: int = 0
    deadline_ms: float = 0.0
    flush_retries: int = 2
    retry_backoff_ms: float = 1.0
    breaker_threshold: int = 3
    breaker_cooldown_ms: float = 1000.0

    def __post_init__(self):
        if self.node_block < 1:
            raise ValueError(f"node_block must be >= 1, got {self.node_block}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {self.max_wait_ms}")
        if self.max_queue < self.max_batch:
            raise ValueError(
                f"max_queue ({self.max_queue}) must be >= max_batch "
                f"({self.max_batch})"
            )
        if self.cache_mb < 0:
            raise ValueError(f"cache_mb must be >= 0, got {self.cache_mb}")
        if self.readmit_every < 0:
            raise ValueError(
                f"readmit_every must be >= 0, got {self.readmit_every}")
        if self.deadline_ms < 0:
            raise ValueError(
                f"deadline_ms must be >= 0, got {self.deadline_ms}")
        if self.flush_retries < 0:
            raise ValueError(
                f"flush_retries must be >= 0, got {self.flush_retries}")
        if self.retry_backoff_ms < 0:
            raise ValueError(
                f"retry_backoff_ms must be >= 0, got {self.retry_backoff_ms}")
        if self.breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {self.breaker_threshold}")
        if self.breaker_cooldown_ms < 0:
            raise ValueError(
                f"breaker_cooldown_ms must be >= 0, got "
                f"{self.breaker_cooldown_ms}")


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """Periodic session checkpointing (``repro.checkpoint``, DESIGN.md §12).

    With ``every_steps > 0`` the fit loop calls ``Heta.save(dir)`` after
    every N consumed steps; checkpoints are written atomically (tmp +
    rename, content-hashed manifest) and ``Heta.restore(dir)`` resumes the
    loss trajectory bit-for-bit.  ``keep`` prunes all but the newest K
    checkpoints (0 = keep everything)."""

    every_steps: int = 0
    dir: Optional[str] = None
    keep: int = 0

    def __post_init__(self):
        if self.every_steps < 0:
            raise ValueError(
                f"every_steps must be >= 0, got {self.every_steps}")
        if self.keep < 0:
            raise ValueError(f"keep must be >= 0, got {self.keep}")
        if self.every_steps > 0 and not self.dir:
            raise ValueError(
                "checkpoint.every_steps > 0 requires checkpoint.dir")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault-tolerance policy (DESIGN.md §12).

    ``max_worker_restarts`` bounds how many times the pool supervisor
    respawns a silently-dead sampler worker per fit (0 disables respawn —
    a death raises :class:`~repro.data.worker_pool.WorkerDiedError`
    immediately); respawn ``r`` backs off ``worker_backoff_s * 2**r``
    seconds first.  ``arena_write_timeout_s`` bounds the batch-arena
    writer's backpressure poll: a worker whose consumer vanished raises
    ``ArenaStalledError`` instead of spinning forever."""

    max_worker_restarts: int = 2
    worker_backoff_s: float = 0.05
    arena_write_timeout_s: float = 60.0

    def __post_init__(self):
        if self.max_worker_restarts < 0:
            raise ValueError(
                f"max_worker_restarts must be >= 0, got "
                f"{self.max_worker_restarts}")
        if self.worker_backoff_s < 0:
            raise ValueError(
                f"worker_backoff_s must be >= 0, got {self.worker_backoff_s}")
        if self.arena_write_timeout_s <= 0:
            raise ValueError(
                f"arena_write_timeout_s must be > 0, got "
                f"{self.arena_write_timeout_s}")


@dataclasses.dataclass(frozen=True)
class ScaleConfig:
    """Hierarchical scale-out (``repro.data.dp_trainer``, DESIGN.md §13).

    ``num_trainers`` spawns that many data-parallel trainer processes in
    ``Heta.fit`` (1 = today's in-process loop, no spawn).  Each trainer
    owns one edge-cut sub-partition of a *shared* graph store, samples its
    own seed slice locally, and synchronizes gradients through a shm
    all-reduce folded into the ``sync_stack_grads`` discipline.

    ``hierarchy`` is the two-level layout ``(groups, trainers_per_group)``
    of :func:`repro.core.meta_partition.hierarchical_partition` — schema-
    level meta-partitioning across groups, greedy edge-cut within.  The
    default ``None`` resolves to ``(1, num_trainers)``; when given, the
    product must equal ``num_trainers``.

    ``store`` picks the shared-store flavor trainers attach: ``"shm"``
    (``/dev/shm`` segment, RAM-resident) or ``"mmap"`` (on-disk
    memory-mapped store, out-of-core).  ``overlap`` keeps the gradient
    all-reduce overlapped against the next batch's host sampling
    (scale-out adds bandwidth, not a barrier); off, trainers synchronize
    at a barrier each step (debugging aid).

    ``mode`` selects the data-parallel discipline (DESIGN.md §13):

    * ``"global"`` (default) — trainers stripe-own the *global* batch
      schedule (trainer ``r`` computes steps ``r, r+N, …`` with the fused
      train step and publishes the updated state through the shm
      exchange); the loss trajectory is **bit-identical** to the
      single-process fit.
    * ``"local"`` — each trainer draws sub-batches from the train nodes
      its hierarchy sub-partition owns; raw stack gradients are summed
      across trainers in fixed rank order, then ``sync_stack_grads`` +
      Adam run on the sum.  Deterministic and bit-identical *across
      trainers*, but a different (equally valid) trajectory from the
      single-process schedule."""

    num_trainers: int = 1
    hierarchy: Optional[Tuple[int, int]] = None  # (groups, trainers_per_group)
    store: str = "shm"  # shm (RAM segment) | mmap (out-of-core store)
    overlap: bool = True
    mode: str = "global"  # global (stripe, single-process-identical) | local

    def __post_init__(self):
        if self.num_trainers < 1:
            raise ValueError(
                f"num_trainers must be >= 1, got {self.num_trainers}")
        if self.hierarchy is not None:
            object.__setattr__(
                self, "hierarchy", tuple(int(x) for x in self.hierarchy))
            if len(self.hierarchy) != 2 or any(x < 1 for x in self.hierarchy):
                raise ValueError(
                    f"hierarchy must be 2 positive ints (groups, "
                    f"trainers_per_group), got {self.hierarchy}")
            g, s = self.hierarchy
            if g * s != self.num_trainers:
                raise ValueError(
                    f"hierarchy {g}x{s} must multiply to num_trainers "
                    f"({self.num_trainers})")
        if self.store not in ("shm", "mmap"):
            raise ValueError(
                f"store must be 'shm' or 'mmap', got {self.store!r}")
        if self.mode not in ("global", "local"):
            raise ValueError(
                f"mode must be 'global' or 'local', got {self.mode!r}")

    @property
    def resolved_hierarchy(self) -> Tuple[int, int]:
        """(groups, trainers_per_group); default = one flat group."""
        return self.hierarchy or (1, self.num_trainers)

    @property
    def enabled(self) -> bool:
        return self.num_trainers > 1


@dataclasses.dataclass(frozen=True)
class HetaConfig:
    """The full run description; the single argument of :class:`repro.api.Heta`."""

    data: DataConfig = dataclasses.field(default_factory=DataConfig)
    partition: PartitionConfig = dataclasses.field(default_factory=PartitionConfig)
    model: ModelConfig = dataclasses.field(default_factory=ModelConfig)
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)
    run: RunConfig = dataclasses.field(default_factory=RunConfig)
    pipeline: PipelineConfig = dataclasses.field(default_factory=PipelineConfig)
    kernels: KernelConfig = dataclasses.field(default_factory=KernelConfig)
    serve: ServeConfig = dataclasses.field(default_factory=ServeConfig)
    checkpoint: CheckpointConfig = dataclasses.field(
        default_factory=CheckpointConfig)
    faults: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    scale: ScaleConfig = dataclasses.field(default_factory=ScaleConfig)

    SECTIONS = ("data", "partition", "model", "cache", "run", "pipeline",
                "kernels", "serve", "checkpoint", "faults", "scale")

    # -- derived ------------------------------------------------------------

    @property
    def num_layers(self) -> int:
        return len(self.data.fanouts)

    # -- functional updates --------------------------------------------------

    def updated(self, **sections: Dict[str, Any]) -> "HetaConfig":
        """Replace fields inside sections: ``cfg.updated(run=dict(steps=5))``."""
        repl = {}
        for name, kw in sections.items():
            if name not in self.SECTIONS:
                raise TypeError(f"unknown config section {name!r}; sections: {self.SECTIONS}")
            repl[name] = dataclasses.replace(getattr(self, name), **kw)
        return dataclasses.replace(self, **repl)

    def with_executor(self, name: str) -> "HetaConfig":
        """The one-liner benchmarks use to sweep the executor registry."""
        return self.updated(run=dict(executor=name))

    # -- dict round-trip ------------------------------------------------------

    def to_dict(self) -> Dict[str, Dict[str, Any]]:
        d = dataclasses.asdict(self)
        for sec in d.values():
            for k, v in sec.items():
                if isinstance(v, tuple):
                    sec[k] = list(v)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Dict[str, Any]]) -> "HetaConfig":
        sections = {}
        for name, sec in d.items():
            if name not in cls.SECTIONS:
                raise TypeError(f"unknown config section {name!r}; sections: {cls.SECTIONS}")
            sec_cls = {"data": DataConfig, "partition": PartitionConfig,
                       "model": ModelConfig, "cache": CacheConfig,
                       "run": RunConfig, "pipeline": PipelineConfig,
                       "kernels": KernelConfig, "serve": ServeConfig,
                       "checkpoint": CheckpointConfig,
                       "faults": FaultConfig, "scale": ScaleConfig}[name]
            known = {f.name for f in dataclasses.fields(sec_cls)}
            bad = set(sec) - known
            if bad:
                raise TypeError(f"unknown {name} config fields: {sorted(bad)}")
            sections[name] = sec_cls(**sec)
        return cls(**sections)

    # -- the legacy train_hgnn kwargs blob ------------------------------------

    @classmethod
    def from_flat_kwargs(cls, **kwargs: Any) -> "HetaConfig":
        """Build a config from the historical ``train_hgnn(...)`` keyword
        surface (plus ``executor=``/``placement=``).  Unknown keys raise."""
        sections: Dict[str, Dict[str, Any]] = {s: {} for s in cls.SECTIONS}
        for key, value in kwargs.items():
            if key not in _FLAT_MAP:
                raise TypeError(
                    f"unknown train_hgnn kwarg {key!r}; known: {sorted(_FLAT_MAP)}"
                )
            section, field, to_cfg, _ = _FLAT_MAP[key]
            sections[section][field] = to_cfg(value)
        return cls().updated(**{s: kw for s, kw in sections.items() if kw})

    def to_flat_kwargs(self) -> Dict[str, Any]:
        """Inverse of :meth:`from_flat_kwargs` (lossless round-trip)."""
        out = {}
        for key, (section, field, _, to_flat) in _FLAT_MAP.items():
            out[key] = to_flat(getattr(getattr(self, section), field))
        return out


def _parse_fanouts(s) -> Tuple[int, ...]:
    if isinstance(s, (tuple, list)):
        return tuple(int(x) for x in s)
    return tuple(int(x) for x in str(s).split(","))


def _parse_mesh(s) -> Tuple[int, int]:
    if isinstance(s, (tuple, list)):
        return tuple(int(x) for x in s)
    return tuple(int(x) for x in str(s).lower().split("x"))


_FLAT_MAP: Dict[str, Tuple[str, str, Callable, Callable]] = {
    "dataset": ("data", "dataset", str, str),
    "scale": ("data", "scale", lambda v: v, lambda v: v),
    "fanouts": ("data", "fanouts", _parse_fanouts, tuple),
    "batch_size": ("data", "batch_size", int, int),
    "num_partitions": ("partition", "num_partitions", int, int),
    "naive_placement": (
        "partition", "placement",
        lambda v: "naive" if v else "meta", lambda v: v == "naive",
    ),
    "model": ("model", "model", str, str),
    "hidden": ("model", "hidden", int, int),
    "num_heads": ("model", "num_heads", int, int),
    "learnable_dim": ("model", "learnable_dim", int, int),
    "train_learnable": ("model", "train_learnable", bool, bool),
    "cache_mb": ("cache", "cache_mb", int, int),
    "hotness_only": (
        "cache", "policy",
        lambda v: "hotness" if v else "miss_penalty", lambda v: v == "hotness",
    ),
    "presample_epochs": ("cache", "presample_epochs", int, int),
    "presample_max_batches": ("cache", "presample_max_batches", int, int),
    "measured_penalties": ("cache", "measured_penalties", bool, bool),
    "readmit_every": ("cache", "readmit_every", int, int),
    "executor": ("run", "executor", str, str),
    "mesh_shape": ("run", "mesh_shape", _parse_mesh, tuple),
    "steps": ("run", "steps", int, int),
    "lr": ("run", "lr", float, float),
    "seed": ("run", "seed", int, int),
    "log_every": ("run", "log_every", int, int),
    "pipeline": ("pipeline", "enabled", bool, bool),
    "prefetch_depth": ("pipeline", "depth", int, int),
    "snapshot_policy": ("pipeline", "snapshot", str, str),
    "num_workers": ("pipeline", "num_workers", int, int),
    "batch_arena": ("pipeline", "arena", bool, bool),
    "pin_workers": ("pipeline", "pin_workers", bool, bool),
    "kernels": ("kernels", "enabled", bool, bool),
    "kernel_stacked_agg": ("kernels", "stacked_agg", bool, bool),
    "kernel_relation_agg": ("kernels", "relation_agg", bool, bool),
    "kernel_gather": ("kernels", "gather", bool, bool),
    "kernel_interpret": ("kernels", "interpret", lambda v: v, lambda v: v),
    "kernel_fuse_epilogue": ("kernels", "fuse_epilogue", bool, bool),
    "kernel_autotune": ("kernels", "autotune", bool, bool),
    "kernel_block_n": ("kernels", "block_n", lambda v: v, lambda v: v),
    "kernel_block_out": ("kernels", "block_out", lambda v: v, lambda v: v),
    "kernel_block_in": ("kernels", "block_in", lambda v: v, lambda v: v),
    "serve_node_block": ("serve", "node_block", int, int),
    "serve_max_batch": ("serve", "max_batch", int, int),
    "serve_max_wait_ms": ("serve", "max_wait_ms", float, float),
    "serve_max_queue": ("serve", "max_queue", int, int),
    "serve_cache_mb": ("serve", "cache_mb", int, int),
    "serve_shm": ("serve", "shm", bool, bool),
    "serve_production_mesh": ("serve", "production_mesh", bool, bool),
    "serve_readmit_every": ("serve", "readmit_every", int, int),
    "serve_deadline_ms": ("serve", "deadline_ms", float, float),
    "serve_flush_retries": ("serve", "flush_retries", int, int),
    "serve_retry_backoff_ms": ("serve", "retry_backoff_ms", float, float),
    "serve_breaker_threshold": ("serve", "breaker_threshold", int, int),
    "serve_breaker_cooldown_ms": ("serve", "breaker_cooldown_ms", float, float),
    "checkpoint_every_steps": ("checkpoint", "every_steps", int, int),
    "checkpoint_dir": ("checkpoint", "dir", lambda v: v, lambda v: v),
    "checkpoint_keep": ("checkpoint", "keep", int, int),
    "max_worker_restarts": ("faults", "max_worker_restarts", int, int),
    "worker_backoff_s": ("faults", "worker_backoff_s", float, float),
    "arena_write_timeout_s": ("faults", "arena_write_timeout_s", float, float),
    "num_trainers": ("scale", "num_trainers", int, int),
    "hierarchy": (
        "scale", "hierarchy",
        lambda v: None if v is None else _parse_mesh(v),
        lambda v: v,
    ),
    "scale_store": ("scale", "store", str, str),
    "scale_overlap": ("scale", "overlap", bool, bool),
    "scale_mode": ("scale", "mode", str, str),
}


# --------------------------------------------------------------------------
# CLI generation — flags are derived from the dataclass fields above
# --------------------------------------------------------------------------

# (section, field) -> (flag override, parse fn, help); fields not listed get
# --<field-with-dashes> and their annotated scalar type.  A parse fn of None
# marks a boolean flag (BooleanOptionalAction).
_CLI_OVERRIDES: Dict[Tuple[str, str], Tuple[str, Optional[Callable], str]] = {
    ("data", "fanouts"): ("--fanouts", _parse_fanouts, "per-hop fanouts, e.g. 4,3"),
    ("partition", "num_partitions"): ("--partitions", int, "number of meta-partitions"),
    ("partition", "placement"): ("--placement", str, f"relation placement {PLACEMENTS}"),
    ("cache", "policy"): ("--cache-policy", str, f"cache allocation policy {CACHE_POLICIES}"),
    ("cache", "readmit_every"): (
        "--readmit-every", int,
        "online cache re-admission period in steps (0 = one-shot)"),
    ("run", "mesh_shape"): ("--mesh", _parse_mesh, "DATAxMODEL mesh, e.g. 2x4"),
    ("pipeline", "enabled"): ("--pipeline", None, "async host pipeline on/off"),
    ("pipeline", "depth"): ("--prefetch-depth", int, "pipeline prefetch depth"),
    ("pipeline", "snapshot"): (
        "--snapshot-policy", str, f"learnable-table snapshot policy {SNAPSHOT_POLICIES}"),
    ("pipeline", "num_workers"): (
        "--num-workers", int, "sampler worker processes (0 = single thread)"),
    ("pipeline", "arena"): (
        "--batch-arena", None, "shm ring-buffer batch arena (pool mode)"),
    ("pipeline", "pin_workers"): (
        "--pin-workers", None,
        "pin sampler workers to distinct CPU cores (Linux, best-effort)"),
    ("kernels", "enabled"): ("--kernels", None, "fused Pallas kernel layer on/off"),
    ("kernels", "stacked_agg"): (
        "--kernel-stacked-agg", None, "stacked relation-aggregation kernel"),
    ("kernels", "relation_agg"): (
        "--kernel-relation-agg", None, "unstacked relation-aggregation kernel"),
    ("kernels", "gather"): ("--kernel-gather", None, "cache-fetch row-gather kernel"),
    ("kernels", "interpret"): (
        "--kernel-interpret", None, "force Pallas interpret mode (parity debugging)"),
    ("kernels", "fuse_epilogue"): (
        "--kernel-fuse-epilogue", None,
        "fully fused attention epilogue (stack-streamed projections)"),
    ("kernels", "autotune"): (
        "--kernel-autotune", None, "consult the committed block-size tuning table"),
    ("kernels", "block_n"): (
        "--kernel-block-n", int, "explicit node-block size override"),
    ("kernels", "block_out"): (
        "--kernel-block-out", int, "explicit d_out-block size override"),
    ("kernels", "block_in"): (
        "--kernel-block-in", int, "explicit d_in-chunk size override"),
    ("serve", "node_block"): (
        "--serve-node-block", int, "layer-wise inference node-block size"),
    ("serve", "max_batch"): (
        "--serve-max-batch", int, "micro-batch flush size"),
    ("serve", "max_wait_ms"): (
        "--serve-max-wait-ms", float, "micro-batch latency budget (ms)"),
    ("serve", "max_queue"): (
        "--serve-max-queue", int, "bounded request queue (backpressure)"),
    ("serve", "cache_mb"): (
        "--serve-cache-mb", int, "serve-side embedding cache budget (MiB)"),
    ("serve", "shm"): (
        "--serve-shm", None, "shm-backed embedding store (zero-copy attach)"),
    ("serve", "production_mesh"): (
        "--serve-production-mesh", None,
        "score on make_production_mesh instead of the run mesh"),
    ("serve", "readmit_every"): (
        "--serve-readmit-every", int,
        "serve-cache re-admission period in flushes (0 = one-shot)"),
    ("serve", "deadline_ms"): (
        "--serve-deadline-ms", float,
        "default per-request deadline in ms (0 = none)"),
    ("serve", "flush_retries"): (
        "--serve-flush-retries", int,
        "retries of a failing flush before the breaker counts it"),
    ("serve", "retry_backoff_ms"): (
        "--serve-retry-backoff-ms", float,
        "base backoff between flush retries (doubles per attempt)"),
    ("serve", "breaker_threshold"): (
        "--serve-breaker-threshold", int,
        "consecutive flush failures that trip the circuit breaker"),
    ("serve", "breaker_cooldown_ms"): (
        "--serve-breaker-cooldown-ms", float,
        "open-breaker cooldown before a half-open probe"),
    ("checkpoint", "every_steps"): (
        "--checkpoint-every-steps", int,
        "save a session checkpoint every N steps (0 = off)"),
    ("checkpoint", "dir"): (
        "--checkpoint-dir", str, "checkpoint directory"),
    ("checkpoint", "keep"): (
        "--checkpoint-keep", int,
        "retain only the newest K checkpoints (0 = all)"),
    ("faults", "max_worker_restarts"): (
        "--max-worker-restarts", int,
        "pool supervisor restart budget per worker (0 = fail fast)"),
    ("faults", "worker_backoff_s"): (
        "--worker-backoff-s", float,
        "base respawn backoff in seconds (doubles per restart)"),
    ("faults", "arena_write_timeout_s"): (
        "--arena-write-timeout-s", float,
        "arena writer backpressure stall timeout (seconds)"),
    ("scale", "num_trainers"): (
        "--num-trainers", int,
        "data-parallel trainer processes (1 = in-process loop)"),
    ("scale", "hierarchy"): (
        "--hierarchy", _parse_mesh,
        "GROUPSxTRAINERS partition hierarchy, e.g. 2x2"),
    ("scale", "store"): (
        "--scale-store", str,
        "shared graph store flavor: shm | mmap (out-of-core)"),
    ("scale", "overlap"): (
        "--scale-overlap", None,
        "overlap the gradient all-reduce with next-batch sampling"),
    ("scale", "mode"): (
        "--scale-mode", str,
        "DP discipline: global (stripe, single-process-identical) | local "
        "(hierarchy-owned sub-batches, gradient allreduce)"),
}

_SCALAR_PARSERS = {int: int, float: float, str: str, Optional[float]: float, bool: None}


def _cli_specs():
    """Yield (section, field_name, flag, parse_fn, is_bool, help)."""
    import typing

    for section, sec_cls in (("data", DataConfig), ("partition", PartitionConfig),
                             ("model", ModelConfig), ("cache", CacheConfig),
                             ("run", RunConfig), ("pipeline", PipelineConfig),
                             ("kernels", KernelConfig), ("serve", ServeConfig),
                             ("checkpoint", CheckpointConfig),
                             ("faults", FaultConfig), ("scale", ScaleConfig)):
        hints = typing.get_type_hints(sec_cls)
        for f in dataclasses.fields(sec_cls):
            default = getattr(sec_cls(), f.name)
            if (section, f.name) in _CLI_OVERRIDES:
                flag, parse, help_ = _CLI_OVERRIDES[(section, f.name)]
                yield (section, f.name, flag, parse, parse is None,
                       f"{help_} (default: {default})")
                continue
            hint = hints[f.name]
            if hint is bool:
                yield (section, f.name, "--" + f.name.replace("_", "-"), None, True,
                       f"[{section}] (default: {default})")
                continue
            parse = _SCALAR_PARSERS.get(hint, None)
            if parse is None:  # Optional[float] etc: unwrap
                args = typing.get_args(hint)
                parse = next((a for a in args if a in (int, float, str)), str)
            yield (section, f.name, "--" + f.name.replace("_", "-"), parse, False,
                   f"[{section}] (default: {default})")


def add_config_args(parser: argparse.ArgumentParser) -> None:
    """Add one flag per HetaConfig field (defaults deferred to the config, so
    only explicitly-passed flags override)."""
    for _, _, flag, parse, is_bool, help_ in _cli_specs():
        if is_bool:
            parser.add_argument(flag, action=argparse.BooleanOptionalAction,
                                default=None, help=help_)
        else:
            parser.add_argument(flag, type=parse, default=None, help=help_)


def config_from_args(args: argparse.Namespace,
                     base: Optional[HetaConfig] = None) -> HetaConfig:
    """Merge explicitly-passed CLI flags onto ``base`` (default HetaConfig())."""
    cfg = base or HetaConfig()
    sections: Dict[str, Dict[str, Any]] = {}
    for section, field, flag, _, _, _ in _cli_specs():
        dest = flag.lstrip("-").replace("-", "_")
        value = getattr(args, dest, None)
        if value is not None:
            sections.setdefault(section, {})[field] = value
    return cfg.updated(**sections) if sections else cfg
