"""Executor registry — one protocol, three execution models.

Every way of running an HGNN training step in this repo satisfies the same
four-method protocol, so executor choice is a config string
(``RunConfig.executor``) and callers — the session, benchmarks, equivalence
tests — iterate executors uniformly:

  * ``vanilla``  — the baseline execution model: one dense parameter bundle,
    full-batch forward (``hgnn_loss``).  The correctness oracle.
  * ``raf``      — simulated multi-partition RAF (paper §4 Alg. 1): explicit
    per-partition parameter dicts, partial aggregations summed in Python.
  * ``raf_spmd`` — the production SPMD executor: relation branches stacked
    along the ``"model"`` mesh axis, learnable features updated sparsely
    through the §6 miss-penalty cache engine.

All three run every registered HGNN model (rgcn/rgat/hgt built in) through
the relation-module IR (``repro.core.relmod``, DESIGN.md §3) — executors
consume each model's declared parameter scopes and ``aggregate``, so a new
HGNN variant needs no executor changes.

Protocol (all methods take the owning :class:`repro.api.Heta` session, which
exposes graph / spec / assignment / engine / hgnn_cfg):

  ``build_plan(sess) -> plan``            static artifacts (jitted fns, plans)
  ``init_state(sess, plan) -> state``     parameters + optimizer state
  ``stage(sess, plan, batch) -> arrays``
      host-side staging: turn a :class:`SampledBatch` into the device-ready
      arrays the step consumes (table snapshot / stack / shard for the SPMD
      executor, ``batch_to_arrays`` for the dense ones).  Pure host work —
      the async pipeline (``repro.data``) runs it in a producer thread for
      batch *i+1* while batch *i* trains.
  ``step_staged(sess, plan, state, batch, arrays) -> (state, loss, step_time_s)``
      the device step on pre-staged arrays; ``step_time_s`` times the
      compute + sparse-update region only, so reported step times stay
      comparable with the historical ``train_hgnn`` accounting.  Executors
      with a sparse-update stage record its share in
      ``plan.last_update_s`` (the breakdown benchmark's update column).
  ``step(sess, plan, state, batch) -> (state, loss, step_time_s)``
      the serial composition ``step_staged(..., stage(...))`` — kept for
      callers that don't pipeline.
  ``stage_reads_tables(sess, plan) -> bool``
      whether ``stage`` reads the learnable feature tables (drives the
      pipeline's snapshot staleness policy; see ``repro.data``).
  ``worker_stage_recipe(sess, plan) -> picklable | None``
      a picklable recipe with which a *sampler worker process* can perform
      the host part of ``stage`` against tables exported into the
      shared-memory graph store or batch arena
      (``repro.data.staging.stack_batch_host``), or None when staging must
      stay consumer-side (default; also when staging reads learnable tables
      that train, *unless* the batch arena's seqlock'd table region carries
      republished bounded-stale snapshots under the ``"stale"`` policy —
      DESIGN.md §9/§11).  Drives the worker pool's staging placement.
  ``stage_from_host(sess, plan, batch, host_arrays) -> arrays``
      consumer-side completion of worker staging: device placement of the
      host arrays a worker produced under the recipe; with
      ``host_arrays=None`` falls back to the full ``stage`` (the default).
      ``host_arrays`` may be read-only views into an arena slot — safe
      because the stream defers the slot release past the consuming step.
  ``loss_and_metrics(sess, plan, state, batch) -> (loss, metrics)``  eval only

Register your own with ``@executors.register("name")``.
"""

from __future__ import annotations

import time
from types import SimpleNamespace
from typing import Dict, Tuple, Type

import numpy as np

__all__ = ["Executor", "register", "get", "available", "apply_feature_grads"]

_REGISTRY: Dict[str, Type["Executor"]] = {}


def register(name: str):
    """Class decorator: ``@register("myexec")`` adds it to the registry."""

    def deco(cls: Type["Executor"]) -> Type["Executor"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get(name: str) -> "Executor":
    """Instantiate the executor registered under ``name``."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown executor {name!r}; available: {available()}"
        )
    return _REGISTRY[name]()


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


class Executor:
    """Base protocol.  Stateless: everything mutable lives in ``state``."""

    name = "?"

    def build_plan(self, sess):
        raise NotImplementedError

    def init_state(self, sess, plan):
        raise NotImplementedError

    def stage(self, sess, plan, batch):
        raise NotImplementedError

    def step_staged(self, sess, plan, state, batch, arrays):
        raise NotImplementedError

    def step(self, sess, plan, state, batch):
        """Serial stage + device step (the pre-pipeline surface)."""
        return self.step_staged(sess, plan, state, batch,
                                self.stage(sess, plan, batch))

    def stage_reads_tables(self, sess, plan) -> bool:
        """True when ``stage`` snapshots the learnable feature tables, i.e.
        background staging can observe stale rows (see ``repro.data``)."""
        return False

    def worker_stage_recipe(self, sess, plan):
        """Picklable host-staging recipe for sampler worker processes, or
        None when staging must stay consumer-side (the default)."""
        return None

    def stage_from_host(self, sess, plan, batch, host_arrays):
        """Finish staging from worker-produced host arrays.  The base
        protocol has no worker staging, so this is the full ``stage``."""
        return self.stage(sess, plan, batch)

    def loss_and_metrics(self, sess, plan, state, batch):
        raise NotImplementedError


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------


def _init_full_params(sess):
    """Dense parameter bundle seeded identically across executors (the
    name-derived keys in ``init_hgnn_params`` make partition-restricted inits
    bit-identical — Prop 1)."""
    import jax

    from repro.core.hgnn import init_hgnn_params

    return init_hgnn_params(
        jax.random.PRNGKey(sess.config.run.seed), sess.hgnn_cfg, sess.spec,
        sess.feat_dims,
    )


def _engine_embed(sess):
    """Learnable tables as jnp arrays from the cache engine's authoritative
    copy, so every executor starts from the same rows."""
    import jax.numpy as jnp

    return {t: jnp.asarray(sess.engine.table(t)) for t in sess.engine.learnable_types}


# --------------------------------------------------------------------------
# vanilla — the single-bundle oracle
# --------------------------------------------------------------------------


def _lookup_tables(sess):
    """Feature tables visible to the dense executors: fixed features, plus —
    when learnable training is frozen — the engine's learnable rows as
    constants (otherwise those travel in the bundle and stay trainable)."""
    if sess.config.model.train_learnable:
        return sess.fixed_tables
    return {**sess.fixed_tables, **_engine_embed(sess)}


@register("vanilla")
class VanillaExecutor(Executor):
    def build_plan(self, sess):
        import jax

        from repro.core.hgnn import batch_to_arrays, hgnn_loss

        cfg, spec, tables = sess.hgnn_cfg, sess.spec, _lookup_tables(sess)

        def loss(bundle, arrs):
            return hgnn_loss(cfg, bundle, tables, arrs, spec)

        return SimpleNamespace(
            to_arrays=batch_to_arrays,
            grad=jax.jit(jax.value_and_grad(loss)),
            loss=jax.jit(loss),
        )

    def init_state(self, sess, plan):
        from repro.optim.adam import adam_init

        bundle = _init_full_params(sess)
        if sess.config.model.train_learnable:
            bundle["embed"] = _engine_embed(sess)
        return {"bundle": bundle, "opt": adam_init(bundle)}

    def stage(self, sess, plan, batch):
        return plan.to_arrays(batch)

    def step_staged(self, sess, plan, state, batch, arrays):
        return _bundle_step_staged(sess, plan, state, arrays)

    def loss_and_metrics(self, sess, plan, state, batch):
        loss = float(plan.loss(state["bundle"], plan.to_arrays(batch)))
        return loss, {"loss": loss}


def _bundle_step_staged(sess, plan, state, arrs):
    """Shared dense-bundle device step on pre-staged arrays: grad + Adam
    timed — mirrors the historical step-time accounting (staging excluded)."""
    from repro.optim.adam import adam_update

    t0 = time.perf_counter()
    loss, grads = plan.grad(state["bundle"], arrs)
    bundle, opt = adam_update(sess.adam_cfg, state["bundle"], grads, state["opt"])
    loss = float(loss)
    return {"bundle": bundle, "opt": opt}, loss, time.perf_counter() - t0


# --------------------------------------------------------------------------
# raf — simulated multi-partition execution (Alg. 1, explicit partitions)
# --------------------------------------------------------------------------


@register("raf")
class RafSimExecutor(Executor):
    def build_plan(self, sess):
        import jax

        from repro.core.hgnn import batch_to_arrays
        from repro.core.raf import raf_loss

        cfg, spec, tables = sess.hgnn_cfg, sess.spec, _lookup_tables(sess)
        assignment = sess.assignment
        P = assignment.num_partitions
        kernels = sess.config.kernels

        def loss(bundle, arrs):
            # one logical copy of the shared leaves (embed tables + head),
            # merged into every partition's local relation parameters
            parts = [
                {**bundle["parts"][p], "embed": bundle.get("embed", {}),
                 "head": bundle["head"]}
                for p in range(P)
            ]
            return raf_loss(cfg, parts, tables, arrs, spec, assignment, kernels)

        return SimpleNamespace(
            to_arrays=batch_to_arrays,
            grad=jax.jit(jax.value_and_grad(loss)),
            loss=jax.jit(loss),
            num_partitions=P,
        )

    def init_state(self, sess, plan):
        import jax

        from repro.core.hgnn import init_hgnn_params
        from repro.optim.adam import adam_init

        full = _init_full_params(sess)
        key = jax.random.PRNGKey(sess.config.run.seed)
        parts = [
            {k: init_hgnn_params(
                key, sess.hgnn_cfg, sess.spec, sess.feat_dims,
                restrict_rels=sess.assignment.relations_of(p, sess.spec),
            )[k] for k in ("rel", "ntype", "etype")}
            for p in range(plan.num_partitions)
        ]
        bundle = {"parts": parts, "head": full["head"]}
        if sess.config.model.train_learnable:
            bundle["embed"] = _engine_embed(sess)
        return {"bundle": bundle, "opt": adam_init(bundle)}

    def stage(self, sess, plan, batch):
        return plan.to_arrays(batch)

    def step_staged(self, sess, plan, state, batch, arrays):
        return _bundle_step_staged(sess, plan, state, arrays)

    def loss_and_metrics(self, sess, plan, state, batch):
        loss = float(plan.loss(state["bundle"], plan.to_arrays(batch)))
        return loss, {"loss": loss}


# --------------------------------------------------------------------------
# raf_spmd — the production mesh executor + cache-mediated feature updates
# --------------------------------------------------------------------------


@register("raf_spmd")
class RafSpmdExecutor(Executor):
    def build_plan(self, sess):
        import jax

        from repro.core import raf_spmd

        run = sess.config.run
        assignment = sess.assignment
        if assignment.num_partitions != run.mesh_shape[1]:
            # mesh model axis ≠ partition count: fold partitions onto shards
            # (p % shards) — meta-locality is preserved (BranchAssignment.fold)
            assignment = assignment.fold(run.mesh_shape[1], sess.spec)
        plan = raf_spmd.build_plan(sess.spec, assignment, sess.hgnn_cfg, sess.feat_dims)
        mesh = jax.make_mesh(run.mesh_shape, ("data", "model"))
        local_combine = sess.config.partition.placement == "meta"
        learn = (bool(sess.engine.learnable_types)
                 and sess.config.model.train_learnable)
        return SimpleNamespace(
            plan=plan,
            mesh=mesh,
            learn_feats=learn,
            step=raf_spmd.make_train_step(
                plan, mesh, sess.adam_cfg, data_axes=("data",),
                local_combine=local_combine, learn_feats=learn,
                kernels=sess.config.kernels,
            ),
            loss=raf_spmd.make_loss_fn(
                plan, mesh, data_axes=("data",), local_combine=local_combine,
                kernels=sess.config.kernels,
            ),
        )

    def init_state(self, sess, plan):
        from repro.core import raf_spmd
        from repro.optim.adam import adam_init

        params = _init_full_params(sess)
        stacks = raf_spmd.shard_stacks(
            plan.plan, plan.mesh, raf_spmd.stack_params_from_dict(plan.plan, params)
        )
        return {"stacks": stacks, "opt": adam_init(stacks)}

    def stage(self, sess, plan, batch):
        """Snapshot tables, stack the batch to branch-major arrays, shard.

        When the pipeline pre-stages in a producer thread and learnable
        tables are training, the snapshot may lag the device step by up to
        ``pipeline.depth + 1`` steps — the documented ``"stale"`` policy
        (``stage_reads_tables`` tells the stream when this applies)."""
        from repro.core import raf_spmd

        if not plan.learn_feats:
            # tables are static when features are frozen -> re-staging the
            # same batch (fixed-batch timing loops) would rebuild identical
            # arrays; memoize the last one
            cached = getattr(plan, "_stage_cache", None)
            if cached is not None and cached[0] is batch:
                return cached[1]
        tables = sess.engine.tables_snapshot()
        arrays = raf_spmd.shard_arrays(
            plan.plan, plan.mesh, raf_spmd.stack_batch(plan.plan, batch, tables)
        )
        if not plan.learn_feats:
            plan._stage_cache = (batch, arrays)
        return arrays

    def stage_reads_tables(self, sess, plan) -> bool:
        return bool(plan.learn_feats)

    def worker_stage_recipe(self, sess, plan):
        """With frozen tables the whole host side of :meth:`stage` — the
        padded feature gathers of ``stack_batch`` — can run inside sampler
        workers against tables exported into the shm store or batch arena;
        the consumer only device-puts.

        While learnable tables train, workers normally cannot see the
        trainer's row updates, so staging stays consumer-side (None) —
        *except* under the batch arena with the ``"stale"`` snapshot
        policy: the session republishes learnable tables into the arena's
        seqlock'd table region after every step, so workers stage against
        bounded-stale snapshots (staleness ≤ ring depth, DESIGN.md §11 —
        the same contract the thread pipeline's ``"stale"`` policy makes)."""
        if plan.learn_feats:
            p = sess.config.pipeline
            if not (p.arena and p.num_workers > 0 and p.snapshot == "stale"):
                return None
        from repro.core import raf_spmd

        return raf_spmd.stack_recipe(plan.plan)

    def stage_from_host(self, sess, plan, batch, host_arrays):
        """Device-put-free consumer completion: the worker-staged host
        arrays (read-only arena-slot views) go straight into
        ``shard_arrays``'s sharded ``device_put`` — no intermediate
        ``jnp.asarray`` copy.  Safe against slot reuse because the stream
        defers each slot's release past the consuming step, and the step's
        ``float(loss)`` sync completes before the deferred release runs."""
        if host_arrays is None:
            return self.stage(sess, plan, batch)
        from repro.core import raf_spmd

        return raf_spmd.shard_arrays(plan.plan, plan.mesh, host_arrays)

    def step_staged(self, sess, plan, state, batch, arrays):
        t0 = time.perf_counter()
        if plan.learn_feats:
            stacks, opt, loss, gf = plan.step(state["stacks"], state["opt"], arrays)
            t1 = time.perf_counter()
            apply_feature_grads(sess.engine, plan.plan, batch, gf)
            plan.last_update_s = time.perf_counter() - t1
        else:
            stacks, opt, loss = plan.step(state["stacks"], state["opt"], arrays)
            plan.last_update_s = 0.0
        loss = float(loss)
        return {"stacks": stacks, "opt": opt}, loss, time.perf_counter() - t0

    def loss_and_metrics(self, sess, plan, state, batch):
        loss = float(plan.loss(state["stacks"], self.stage(sess, plan, batch)))
        return loss, {"loss": loss, "hit_rates": sess.engine.cache.hit_rates()}


# --------------------------------------------------------------------------
# serve — the online inference tier (materialized embeddings, no training)
# --------------------------------------------------------------------------


@register("serve")
class ServeExecutor(Executor):
    """Score batches against the materialized embedding store (DESIGN.md §10).

    Not a training executor: ``step``/``step_staged`` raise.  ``build_plan``
    requires :meth:`Heta.infer_all` to have materialized the store;
    ``loss_and_metrics`` answers through the micro-batching
    :class:`~repro.serve.server.EmbeddingServer` (same NLL as the training
    executors), reporting per-type serve-cache hit rates."""

    def build_plan(self, sess):
        from repro.api.session import HetaStageError

        store = getattr(sess, "embedding_store", None)
        if store is None:
            raise HetaStageError(
                "the 'serve' executor requires materialized embeddings; run "
                "session.infer_all() (after compile+fit with a training "
                "executor) before compile(executor='serve')"
            )
        return SimpleNamespace(server=sess.serve(), store=store)

    def init_state(self, sess, plan):
        return {}

    def stage(self, sess, plan, batch):
        return None

    def step_staged(self, sess, plan, state, batch, arrays):
        from repro.api.session import HetaStageError

        raise HetaStageError(
            "the 'serve' executor is inference-only; train with a training "
            "executor (e.g. raf_spmd), then infer_all() + serve()"
        )

    def loss_and_metrics(self, sess, plan, state, batch):
        res = plan.server.query(batch.seeds)
        logits = res.scores.astype(np.float64)
        logits -= logits.max(axis=-1, keepdims=True)
        logp = logits - np.log(np.exp(logits).sum(axis=-1, keepdims=True))
        loss = float(-logp[np.arange(len(batch.seeds)), batch.labels].mean())
        return loss, {
            "loss": loss,
            "hit_rates": plan.server.cache.hit_rates(),
            "latency_ms": res.latency_ms,
        }


def apply_feature_grads(engine, plan, batch, gf: Dict) -> None:
    """Route gradients of the gathered feature arrays back to the learnable
    tables (paper Fig. 3 step 5, via the §6 cache)."""
    learnable = set(engine.learnable_types)
    spec = plan.spec
    k = spec.num_layers
    for d in range(1, k + 1):
        lp = plan.levels[d - 1]
        for key, types, get_ids in (
            (f"hfeat{d}", plan.src_types[d - 1], lambda b: batch.levels[d - 1].nids[b]),
            (
                f"qfeat{d}",
                plan.dst_types[d - 1],
                lambda b: (
                    batch.seeds if d == 1
                    else batch.levels[d - 2].nids[spec.levels[d - 1][b].parent]
                ),
            ),
        ):
            if key not in gf:
                continue
            grad = np.asarray(gf[key])  # [P*rb, N, d_pad]
            grad = grad.reshape(plan.num_shards, lp.rb, *grad.shape[1:])
            per_type: Dict[str, list] = {}
            for p in range(plan.num_shards):
                for s in range(lp.rb):
                    b = lp.slot_branch[p, s]
                    if b < 0:
                        continue
                    t = types[b]
                    if t not in learnable:
                        continue
                    dim = engine.learnable_dim
                    per_type.setdefault(t, []).append(
                        (get_ids(b), grad[p, s][:, :dim])
                    )
            for t, chunks in per_type.items():
                ids = np.concatenate([c[0] for c in chunks])
                gr = np.concatenate([c[1] for c in chunks])
                engine.apply_row_grads(t, ids, gr)


# deprecated alias (pre-pipeline name); use apply_feature_grads
_apply_feature_grads = apply_feature_grads
