"""``repro.api`` — the public surface of the Heta reproduction.

Quickstart
==========

One config object, one session, five explicit stages::

    from repro.api import Heta, HetaConfig, DataConfig, RunConfig

    cfg = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.01, fanouts=(10, 10),
                        batch_size=64),
        run=RunConfig(executor="raf_spmd", steps=10),
    )
    sess = Heta(cfg)

    g      = sess.build_graph()        # HetG (paper's dataset family)
    part   = sess.partition()          # §5 meta-partitioning
    print(part.metatree.render(), part.summary)
    print(sess.comm_report())          # §4: vanilla vs naive-RAF vs meta-RAF bytes
    cache  = sess.profile_and_cache()  # §6 hotness + miss-penalty cache
    sess.compile()                     # executor from the registry
    result = sess.fit()                # {"losses", "step_time_s", "hit_rates", ...}

Or collapse all stages: ``result = Heta(cfg).run()``.

Configuration
=============

:class:`HetaConfig` is a typed tree of eleven sections — ``data``,
``partition``, ``model``, ``cache``, ``run``, ``pipeline``, ``kernels``,
``serve``, ``checkpoint``, ``faults``, ``scale`` — that round-trips through
nested dicts (``to_dict``/``from_dict``), the historical flat-kwargs surface
(``from_flat_kwargs``/``to_flat_kwargs``) and auto-generated CLI flags
(``add_config_args``/``config_from_args`` — what ``python -m
repro.launch.train`` uses, so flags are derived, never duplicated).

Executors
=========

The three execution models all satisfy one staged-step protocol
(``build_plan / init_state / stage / step_staged / loss_and_metrics``, with
``step`` as the serial ``stage``+``step_staged`` composition) and are
selected by name through the registry.  The ``stage``/``step_staged`` split
is the seam the async host pipeline (``repro.data``, enabled via
``PipelineConfig``) uses to overlap sampling + feature staging with the
device step::

    from repro.api import executors
    executors.available()                  # ("raf", "raf_spmd", "vanilla")
    cfg.with_executor("raf")               # same run, simulated-RAF executor

* ``vanilla``  — single-bundle dense baseline (the correctness oracle)
* ``raf``      — simulated multi-partition RAF, all HGNN models (§4 Alg. 1)
* ``raf_spmd`` — production SPMD executor over the (data, model) mesh
* ``serve``    — online inference tier: scores against the embeddings
  ``Heta.infer_all()`` materialized, through the micro-batching
  ``Heta.serve()`` server (``repro.serve``, DESIGN.md §10; eval-only)

Register new executors with ``@executors.register("name")``.

Deprecation
===========

``repro.launch.train.train_hgnn(...)`` — the old 18-kwarg entry point — is
now a thin wrapper over ``Heta(HetaConfig.from_flat_kwargs(...)).run()``.
New code should use the session API directly.
"""

from repro.api.config import (
    CacheConfig,
    DataConfig,
    HetaConfig,
    KernelConfig,
    ModelConfig,
    PartitionConfig,
    PipelineConfig,
    RunConfig,
    ServeConfig,
    CheckpointConfig,
    FaultConfig,
    ScaleConfig,
    add_config_args,
    config_from_args,
)
from repro.api import executors
from repro.api.session import CacheReport, Heta, HetaStageError, PartitionReport

__all__ = [
    "HetaConfig",
    "DataConfig",
    "PartitionConfig",
    "ModelConfig",
    "CacheConfig",
    "RunConfig",
    "PipelineConfig",
    "KernelConfig",
    "ServeConfig",
    "CheckpointConfig",
    "FaultConfig",
    "ScaleConfig",
    "Heta",
    "HetaStageError",
    "PartitionReport",
    "CacheReport",
    "executors",
    "add_config_args",
    "config_from_args",
]
