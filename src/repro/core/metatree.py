"""Metatree construction (paper §5, Step 1).

The metatree encodes the HGNN computation dependency: starting from the
target node type (the only type with labels), k-hop neighborhood sampling can
only traverse relations whose *destination* is the currently-expanded type
(messages flow src → dst, so sampling walks edges backwards).  A k-depth BFS
over the metagraph from the target type therefore enumerates exactly the
relations an k-layer HGNN touches, in the order hierarchical aggregation
consumes them.

Alternatively the user provides metapaths (sequences of relations starting at
the root), mirroring Heta's optional ``metapaths`` argument.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.graph.hetgraph import Metagraph, Relation

__all__ = ["MetaTreeNode", "build_metatree", "build_metatree_from_metapaths"]


@dataclasses.dataclass
class MetaTreeNode:
    """A vertex occurrence in the metatree.

    ``rel`` is the relation connecting this node to its *parent* (messages
    flow from this node's type to the parent's type); ``None`` at the root.
    """

    ntype: str
    rel: Optional[Relation] = None
    depth: int = 0
    children: List["MetaTreeNode"] = dataclasses.field(default_factory=list)

    # -- traversal helpers ----------------------------------------------------

    def walk(self) -> Iterator["MetaTreeNode"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def relations(self) -> List[Relation]:
        """All relations in this (sub)tree, in BFS-ish order, with duplicates
        (duplicates arise from cycles in the metagraph; paper §5 Step 4
        deduplicates per partition)."""
        return [n.rel for n in self.walk() if n.rel is not None]

    def vertex_types(self) -> List[str]:
        return [n.ntype for n in self.walk()]

    def max_depth(self) -> int:
        return max(n.depth for n in self.walk())

    def num_nodes(self) -> int:
        return sum(1 for _ in self.walk())

    def render(self, indent: int = 0) -> str:
        via = f" <-[{self.rel.etype}]-" if self.rel else ""
        lines = [f"{'  ' * indent}{via} {self.ntype}"]
        for c in self.children:
            lines.append(c.render(indent + 1))
        return "\n".join(lines)


def build_metatree(meta: Metagraph, root: str, depth: int) -> MetaTreeNode:
    """k-depth BFS from the target node type (paper Algorithm 2, line 4).

    Each level expands every in-relation of the frontier types; a relation may
    recur at deeper levels (e.g. Paper<-cites-Paper), exactly as multi-hop
    sampling revisits it.
    """
    if root not in meta.node_types:
        raise ValueError(f"unknown root type {root!r}")
    tree = MetaTreeNode(ntype=root, depth=0)
    frontier = [tree]
    for d in range(1, depth + 1):
        nxt: List[MetaTreeNode] = []
        for node in frontier:
            for rel in sorted(meta.in_relations(node.ntype)):
                child = MetaTreeNode(ntype=rel.src, rel=rel, depth=d)
                node.children.append(child)
                nxt.append(child)
        frontier = nxt
    return tree


def build_metatree_from_metapaths(
    meta: Metagraph, root: str, metapaths: Sequence[Sequence[Relation]]
) -> MetaTreeNode:
    """Construct a metatree from user metapaths (paper Algorithm 2, line 2).

    Each metapath is a sequence of relations walked from the root: relation i
    must have ``dst`` equal to the current type, and the walk steps to its
    ``src`` type (the node type sampled at hop i+1).
    """
    tree = MetaTreeNode(ntype=root, depth=0)
    for path in metapaths:
        cur = tree
        for rel in path:
            if rel not in meta.relations:
                raise ValueError(f"metapath relation {rel} not in metagraph")
            if rel.dst != cur.ntype:
                raise ValueError(
                    f"metapath relation {rel} does not extend type {cur.ntype!r}"
                )
            # merge shared prefixes so the tree reflects the union of paths
            nxt = next(
                (c for c in cur.children if c.rel == rel and c.ntype == rel.src),
                None,
            )
            if nxt is None:
                nxt = MetaTreeNode(ntype=rel.src, rel=rel, depth=cur.depth + 1)
                cur.children.append(nxt)
            cur = nxt
    return tree
