"""RAF — Relation-Aggregation-First execution paradigm (paper §4, Alg. 1).

Each partition holds complete mono-relation subgraphs for its relations plus
the relation-specific parameters, computes *partial aggregations* for the
target-node batch entirely locally, and only the partials (and, in backprop,
their gradients) cross partition boundaries.  The cross-relation aggregation
(AGG_all = masked sum) plus loss runs after the exchange.

Per-branch math comes from the relation-module IR (``repro.core.relmod``,
DESIGN.md §3): a partition materializes exactly the scoped parameter groups
its relations declare (``restrict_rels`` in ``init_hgnn_params``), so this
executor is model-agnostic — any registered HGNN variant runs unchanged.

Two executors:

  * :func:`raf_forward` / :func:`raf_loss` — *simulated* multi-partition
    execution on however many real devices exist (including 1).  Partitions
    are explicit Python structure; the cross-partition exchange is an actual
    sum of per-partition partials.  Used for Prop-1 equivalence tests,
    accuracy-equivalence experiments and communication accounting.

  * :mod:`repro.core.raf_spmd` — the SPMD `shard_map` executor that lays the
    relation axis along the ``"model"`` mesh axis (the production path used
    by ``launch/train.py`` and the multi-pod dry-run).

Exchange styles (both implemented, compared in EXPERIMENTS.md §Perf):

  * ``designated`` — the paper's Alg. 1: gather partials on one worker,
    scatter gradients back (Gloo gather/scatter on GPU clusters).
  * ``allreduce``  — TPU-idiomatic: because AGG_all is a sum and the loss is
    computed once, gather→combine→backprop→scatter is mathematically an
    all-reduce of partials (fwd) and an identity fan-out (bwd).  Removes the
    designated-worker serialization point (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hgnn import BatchArrays, HGNNConfig, Params, hgnn_forward
from repro.core.meta_partition import MetaPartitioning
from repro.graph.sampler import SampleSpec

__all__ = [
    "BranchAssignment",
    "assign_branches",
    "random_branch_assignment",
    "raf_forward",
    "raf_loss",
    "raf_comm_bytes",
]


# --------------------------------------------------------------------------
# branch -> partition assignment
# --------------------------------------------------------------------------


@dataclasses.dataclass
class BranchAssignment:
    """Owner partition of every metatree branch, plus derived masks.

    ``meta_local`` is True iff every branch lives in the same partition as its
    parent (the meta-partitioning invariant: sub-metatrees are never split),
    in which case the only cross-partition traffic is the root-level exchange
    of [B, hidden] partials — Θ(|targets|) as in paper §5 Step 2.
    """

    owner: List[np.ndarray]  # per level d: int array [R_d] of partition ids
    num_partitions: int

    @property
    def meta_local(self) -> bool:
        return len(self.violations()) == 0

    def violations(self) -> List[Tuple[int, int]]:
        """(depth, branch) pairs whose owner differs from their parent's."""
        bad = []
        for d in range(1, len(self.owner)):
            parents = self._parents[d]
            for b in range(len(self.owner[d])):
                if self.owner[d][b] != self.owner[d - 1][parents[b]]:
                    bad.append((d + 1, b))
        return bad

    def attach_parents(self, spec: SampleSpec) -> "BranchAssignment":
        self._parents = [None] + [
            np.array([bs.parent for bs in lv], dtype=np.int64)
            for lv in spec.levels[1:]
        ]
        return self

    def branch_mask(self, part: int) -> Dict[Tuple[int, int], bool]:
        """hgnn_forward-style inclusion mask for one partition."""
        mask: Dict[Tuple[int, int], bool] = {}
        for d, own in enumerate(self.owner, start=1):
            for b, p in enumerate(own):
                if int(p) == part:
                    mask[(d, b)] = True
        return mask

    def fold(self, num_shards: int, spec: SampleSpec) -> "BranchAssignment":
        """Fold P partitions onto ``num_shards`` model shards (p % shards).

        Used when the mesh's model axis is smaller than the partition count
        (e.g. single-device tests, or more sub-metatrees than chips).  The
        fold is a function of the partition id alone, so parent/child
        branches stay co-located and meta-locality is preserved.
        """
        folded = BranchAssignment(
            [o % num_shards for o in self.owner], num_shards
        )
        return folded.attach_parents(spec)

    def relations_of(self, part: int, spec: SampleSpec) -> List[str]:
        rels: List[str] = []
        for d, own in enumerate(self.owner, start=1):
            for b, p in enumerate(own):
                if int(p) == part:
                    rels.append(spec.levels[d - 1][b].rel.key)
        return list(dict.fromkeys(rels))


def assign_branches(spec: SampleSpec, parting: MetaPartitioning) -> BranchAssignment:
    """Assign every branch to the partition owning its root-level sub-metatree.

    The metatree used to build ``spec`` and the one inside ``parting`` share
    BFS child order, so root-child index b at level 1 corresponds to
    ``parting.metatree.children[b]``; descendants inherit the owner (the
    sub-metatree is assigned wholesale — Algorithm 2, Step 3).
    """
    root_children = parting.metatree.children
    if len(root_children) != len(spec.levels[0]):
        raise ValueError("spec/partitioning metatree mismatch")
    child_owner: Dict[int, int] = {}
    for p in parting.partitions:
        for s in p.sub_metatrees:
            for i, c in enumerate(root_children):
                if c is s.root_child and i not in child_owner:
                    child_owner[i] = p.index
    owner: List[np.ndarray] = [
        np.array([child_owner[i] for i in range(len(spec.levels[0]))], np.int64)
    ]
    for d in range(2, spec.num_layers + 1):
        prev = owner[-1]
        owner.append(
            np.array([prev[bs.parent] for bs in spec.levels[d - 1]], np.int64)
        )
    return BranchAssignment(owner, parting.num_partitions).attach_parents(spec)


def random_branch_assignment(
    spec: SampleSpec, num_partitions: int, seed: int = 0
) -> BranchAssignment:
    """Naive relation placement (no metatree awareness): branches land on
    random partitions, so parent/child branches split across machines and the
    inner-hop partials must cross the network (paper §4's 8.0 MB case)."""
    rng = np.random.default_rng(seed)
    owner = [
        rng.integers(0, num_partitions, len(lv)).astype(np.int64)
        for lv in spec.levels
    ]
    return BranchAssignment(owner, num_partitions).attach_parents(spec)


# --------------------------------------------------------------------------
# simulated multi-partition execution
# --------------------------------------------------------------------------


def raf_forward(
    cfg: HGNNConfig,
    params_parts: Sequence[Params],
    tables: Dict[str, jnp.ndarray],
    batch: BatchArrays,
    spec: SampleSpec,
    assignment: BranchAssignment,
    kernels=None,
) -> jnp.ndarray:
    """Alg. 1 forward: per-partition partial aggregations, then AGG_all + head.

    ``params_parts[p]`` holds partition p's relation parameters (and its
    learnable-feature tables under ``params['embed']``).  The designated
    worker's extra work (loss + head) is partition 0 by convention; with the
    ``allreduce`` exchange every partition computes it redundantly — both are
    the same math, so this function is exchange-style agnostic.
    ``kernels`` opts the per-relation aggregations into the fused Pallas
    path (see ``repro.core.hgnn.agg_relation``).
    """
    partials = []
    for p, params in enumerate(params_parts):
        partials.append(
            hgnn_forward(
                cfg, params, tables, batch, spec,
                branch_mask=assignment.branch_mask(p),
                return_partial=True,
                kernels=kernels,
            )
        )
    root = sum(partials)  # AGG_all (cross-relation aggregation, paper Eq. 1)
    h = jax.nn.relu(root)
    head = params_parts[0]["head"]
    return h @ head["w"] + head["b"]


def raf_loss(
    cfg: HGNNConfig,
    params_parts: Sequence[Params],
    tables: Dict[str, jnp.ndarray],
    batch: BatchArrays,
    spec: SampleSpec,
    assignment: BranchAssignment,
    kernels=None,
) -> jnp.ndarray:
    logits = raf_forward(cfg, params_parts, tables, batch, spec, assignment, kernels)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.mean(-jnp.take_along_axis(logp, batch.labels[:, None], axis=-1))


# --------------------------------------------------------------------------
# communication accounting (paper §4 "Communication Reduction" example)
# --------------------------------------------------------------------------


def raf_comm_bytes(
    spec: SampleSpec,
    assignment: BranchAssignment,
    batch_size: int,
    hidden: int,
    bytes_per_elem: int = 2,
    style: str = "designated",
) -> int:
    """Bytes RAF moves for one batch: root-level partial exchange + any
    inner-level partials whose branch sits on a different partition than its
    parent (zero under meta-partitioning, Prop 2 / §5 Step 2).

    Forward partials and backward gradients are symmetric, hence the ×2.
    ``designated``: (P-1) workers send to / receive from the designated one.
    ``allreduce``: bidirectional ring all-reduce moves 2·(P-1)/P × size per
    device; total wire bytes across the job are comparable — we report the
    designated style by default to match the paper's accounting.
    """
    P = assignment.num_partitions
    if P <= 1:
        return 0
    n_at = {0: batch_size}
    n = batch_size
    for d, f in enumerate(spec.fanouts, start=1):
        n *= f
        n_at[d] = n

    total_elems = 0
    # root-level exchange: every non-designated partition with ≥1 root branch
    # sends its [B, hidden] partial (fwd) and receives its gradient (bwd)
    parts_with_root = {int(p) for p in assignment.owner[0]}
    senders = len(parts_with_root - {0}) if style == "designated" else P - 1
    total_elems += 2 * senders * batch_size * hidden
    # inner-level violations (only non-meta placements have any):
    for d in range(2, spec.num_layers + 1):
        parents = assignment._parents[d - 1]
        for b in range(len(assignment.owner[d - 1])):
            if assignment.owner[d - 1][b] != assignment.owner[d - 2][parents[b]]:
                total_elems += 2 * n_at[d - 1] * hidden
    return int(total_elems * bytes_per_elem)
