"""Communication-volume accounting for both execution models (paper §4).

The vanilla execution model (DGL/GraphLearn, paper Fig. 3) fetches raw
features of every remotely-stored sampled neighbor; RAF exchanges only
partial aggregations and their gradients.  These functions reproduce the
paper's §4 worked example (92.3 MB vanilla → 8.0 MB RAF-random → 0.5 MB
RAF+meta-partitioning on MAG240M-like settings) and drive
``benchmarks/comm_volume.py``.

All byte counts are *exact* given a sampled batch and a partition assignment;
nothing is modeled or estimated here.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.meta_partition import EdgeCutPartition, HierarchicalPartition
from repro.graph.hetgraph import HetGraph
from repro.graph.sampler import SampledBatch

__all__ = [
    "vanilla_comm_bytes",
    "vanilla_update_bytes",
    "hierarchical_comm_bytes",
    "CommReport",
]


def _seed_owner(batch: SampledBatch, cut: EdgeCutPartition) -> np.ndarray:
    """DistDGL processes each training node on its home partition."""
    return cut.part_of(batch.spec.target_type, batch.seeds)


def vanilla_comm_bytes(
    batch: SampledBatch,
    cut: EdgeCutPartition,
    feat_dims: Dict[str, int],
    learnable_dim: int = 64,
    bytes_per_elem: int = 2,
    include_topology: bool = True,
    index_bytes: int = 8,
) -> int:
    """Bytes the vanilla model moves for one batch: features of every unique
    remotely-stored sampled node, fetched by the worker processing the seed
    (+ the sampled topology: one node id per sampled slot that is remote)."""
    owner = _seed_owner(batch, cut)
    B = batch.batch_size
    total = 0
    # (requester, ntype) -> set of remote node ids, deduplicated
    for lv, branches in zip(batch.levels, batch.spec.levels):
        n_per_seed = lv.nids.shape[1] // B
        req = np.repeat(owner, n_per_seed)  # [N_d] requester per slot
        for b, bs in enumerate(branches):
            nids, mask = lv.nids[b], lv.mask[b]
            node_part = cut.part_of(bs.src_type, nids)
            remote = (node_part != req) & mask
            if not remote.any():
                continue
            dim = feat_dims.get(bs.src_type, learnable_dim)
            pairs = np.stack([req[remote], nids[remote]], axis=1)
            uniq = np.unique(pairs, axis=0)
            total += len(uniq) * dim * bytes_per_elem
            if include_topology:
                total += int(remote.sum()) * index_bytes
    return int(total)


def vanilla_update_bytes(
    batch: SampledBatch,
    cut: EdgeCutPartition,
    graph: HetGraph,
    learnable_dim: int = 64,
    bytes_per_elem: int = 2,
    optimizer_state_mult: int = 2,  # Adam: moment + variance (paper §2.2)
) -> int:
    """Write-back traffic for learnable features: the vanilla model pushes
    updated learnable features + optimizer states to their home KVStore
    (paper Fig. 3 step 5); remote rows cross the network twice (read+write)."""
    owner = _seed_owner(batch, cut)
    B = batch.batch_size
    total = 0
    featless = [t for t in graph.num_nodes if t not in graph.features]
    for lv, branches in zip(batch.levels, batch.spec.levels):
        n_per_seed = lv.nids.shape[1] // B
        req = np.repeat(owner, n_per_seed)
        for b, bs in enumerate(branches):
            if bs.src_type not in featless:
                continue
            nids, mask = lv.nids[b], lv.mask[b]
            remote = (cut.part_of(bs.src_type, nids) != req) & mask
            if not remote.any():
                continue
            pairs = np.stack([req[remote], nids[remote]], axis=1)
            uniq = np.unique(pairs, axis=0)
            row = learnable_dim * bytes_per_elem * (1 + optimizer_state_mult)
            total += len(uniq) * row * 2  # read + write-back
    return int(total)


def hierarchical_comm_bytes(
    batch: SampledBatch,
    hier: HierarchicalPartition,
    hidden: int,
    feat_dims: Optional[Dict[str, int]] = None,
    learnable_dim: int = 64,
    bytes_per_elem: int = 2,
    grad_bytes: int = 0,
) -> "CommReport":
    """Exact per-level, per-batch byte accounting for the two-level
    hierarchy (DESIGN.md §13; DistDGL-style layout, PAPERS.md 2112.15345).

    * ``level0_raf`` — inter-group RAF partial-aggregate exchange.  Every
      group holds ≥1 root branch by construction (one sub-metatree per
      root child, paper §5), so each of the ``G-1`` non-designated groups
      moves one ``[B, hidden]`` partial forward and its gradient back:
      ``2·(G-1)·B·hidden`` elements — independent of the relation module
      and of every feature dimension (Prop 2).
    * ``level0_grad`` — inter-group model sync: group leaders all-reduce
      the shared gradient buffer (``2·(G-1)·grad_bytes`` wire bytes,
      designated style, fwd+bwd symmetric reduce+broadcast).
    * ``level1_grad`` — intra-group data parallelism: per group, a ring
      all-reduce of ``grad_bytes`` among ``S`` trainers moves
      ``2·(S-1)·grad_bytes`` aggregate wire bytes; summed over groups.
    * ``level1_local_read`` — feature bytes each batch pulls from the
      *shared* store (unique sampled nodes × dim).  These are DRAM /
      page-cache reads, **not** network traffic: trainers inside a group
      attach the same shm/mmap store, which is exactly why level 1 adds
      bandwidth, not bytes.  Reported for the vanilla contrast (an
      edge-cut-only system ships a large share of these over the wire).

    ``total_wire`` sums the three network levels and excludes the local
    reads.  All counts are exact given the batch and the hierarchy.
    """
    G, S = hier.num_groups, hier.trainers_per_group
    B = int(batch.batch_size)
    level0_raf = 2 * max(0, G - 1) * B * hidden * bytes_per_elem
    level0_grad = 2 * max(0, G - 1) * int(grad_bytes)
    level1_grad = G * 2 * max(0, S - 1) * int(grad_bytes)
    local_read = 0
    fd = feat_dims or {}
    for lv, branches in zip(batch.levels, batch.spec.levels):
        for b, bs in enumerate(branches):
            nids, mask = lv.nids[b], lv.mask[b]
            uniq = np.unique(nids[mask])
            dim = fd.get(bs.src_type, learnable_dim)
            local_read += uniq.size * dim * bytes_per_elem
    return CommReport(
        level0_raf=int(level0_raf),
        level0_grad=int(level0_grad),
        level1_grad=int(level1_grad),
        level1_local_read=int(local_read),
        total_wire=int(level0_raf + level0_grad + level1_grad),
    )


class CommReport(dict):
    """Convenience dict with pretty printing for benchmark output."""

    def render(self) -> str:
        width = max(len(k) for k in self)
        return "\n".join(
            f"  {k:<{width}}  {v / 1e6:10.3f} MB" if isinstance(v, (int, float))
            else f"  {k:<{width}}  {v}"
            for k, v in self.items()
        )
