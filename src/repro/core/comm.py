"""Communication-volume accounting for both execution models (paper §4).

The vanilla execution model (DGL/GraphLearn, paper Fig. 3) fetches raw
features of every remotely-stored sampled neighbor; RAF exchanges only
partial aggregations and their gradients.  These functions reproduce the
paper's §4 worked example (92.3 MB vanilla → 8.0 MB RAF-random → 0.5 MB
RAF+meta-partitioning on MAG240M-like settings) and drive
``benchmarks/comm_volume.py``.

All byte counts are *exact* given a sampled batch and a partition assignment;
nothing is modeled or estimated here.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.meta_partition import EdgeCutPartition
from repro.graph.hetgraph import HetGraph
from repro.graph.sampler import SampledBatch

__all__ = ["vanilla_comm_bytes", "vanilla_update_bytes", "CommReport"]


def _seed_owner(batch: SampledBatch, cut: EdgeCutPartition) -> np.ndarray:
    """DistDGL processes each training node on its home partition."""
    return cut.part_of(batch.spec.target_type, batch.seeds)


def vanilla_comm_bytes(
    batch: SampledBatch,
    cut: EdgeCutPartition,
    feat_dims: Dict[str, int],
    learnable_dim: int = 64,
    bytes_per_elem: int = 2,
    include_topology: bool = True,
    index_bytes: int = 8,
) -> int:
    """Bytes the vanilla model moves for one batch: features of every unique
    remotely-stored sampled node, fetched by the worker processing the seed
    (+ the sampled topology: one node id per sampled slot that is remote)."""
    owner = _seed_owner(batch, cut)
    B = batch.batch_size
    total = 0
    # (requester, ntype) -> set of remote node ids, deduplicated
    for lv, branches in zip(batch.levels, batch.spec.levels):
        n_per_seed = lv.nids.shape[1] // B
        req = np.repeat(owner, n_per_seed)  # [N_d] requester per slot
        for b, bs in enumerate(branches):
            nids, mask = lv.nids[b], lv.mask[b]
            node_part = cut.part_of(bs.src_type, nids)
            remote = (node_part != req) & mask
            if not remote.any():
                continue
            dim = feat_dims.get(bs.src_type, learnable_dim)
            pairs = np.stack([req[remote], nids[remote]], axis=1)
            uniq = np.unique(pairs, axis=0)
            total += len(uniq) * dim * bytes_per_elem
            if include_topology:
                total += int(remote.sum()) * index_bytes
    return int(total)


def vanilla_update_bytes(
    batch: SampledBatch,
    cut: EdgeCutPartition,
    graph: HetGraph,
    learnable_dim: int = 64,
    bytes_per_elem: int = 2,
    optimizer_state_mult: int = 2,  # Adam: moment + variance (paper §2.2)
) -> int:
    """Write-back traffic for learnable features: the vanilla model pushes
    updated learnable features + optimizer states to their home KVStore
    (paper Fig. 3 step 5); remote rows cross the network twice (read+write)."""
    owner = _seed_owner(batch, cut)
    B = batch.batch_size
    total = 0
    featless = [t for t in graph.num_nodes if t not in graph.features]
    for lv, branches in zip(batch.levels, batch.spec.levels):
        n_per_seed = lv.nids.shape[1] // B
        req = np.repeat(owner, n_per_seed)
        for b, bs in enumerate(branches):
            if bs.src_type not in featless:
                continue
            nids, mask = lv.nids[b], lv.mask[b]
            remote = (cut.part_of(bs.src_type, nids) != req) & mask
            if not remote.any():
                continue
            pairs = np.stack([req[remote], nids[remote]], axis=1)
            uniq = np.unique(pairs, axis=0)
            row = learnable_dim * bytes_per_elem * (1 + optimizer_state_mult)
            total += len(uniq) * row * 2  # read + write-back
    return int(total)


class CommReport(dict):
    """Convenience dict with pretty printing for benchmark output."""

    def render(self) -> str:
        width = max(len(k) for k in self)
        return "\n".join(
            f"  {k:<{width}}  {v / 1e6:10.3f} MB" if isinstance(v, (int, float))
            else f"  {k:<{width}}  {v}"
            for k, v in self.items()
        )
