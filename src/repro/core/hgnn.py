"""HGNN models (R-GCN, R-GAT, HGT) over the sampled branch representation.

An HGNN layer (paper Eq. 1) is

    h_v^(l) = AGG_all( { AGG_r( {h_u^(l-1) : u ∈ N_r(v)} ) : r ∈ R } )

The sampler (``repro.graph.sampler``) materializes the metatree as *branches*;
this module evaluates them bottom-up.  Branch at depth d carries nodes whose
embeddings live at layer (k - d); the relation-specific aggregation AGG_r maps
child-branch embeddings to the parent's next layer, and AGG_all is a masked
sum over sibling branches followed by a nonlinearity.

Parameters are tied per (relation, layer) — one weight set per relation per
layer, shared across metatree occurrences at the same layer (matches DGL's
HeteroGraphConv).  Model variants:

  * R-GCN  — masked-mean neighbor aggregation + per-relation linear [39]
  * R-GAT  — per-relation multi-head attention [3]; attention queries are the
             destination nodes' *input* features (tree-sampling variant; see
             DESIGN.md §7)
  * HGT    — per-node-type K/Q/V projections + per-edge-type attention and
             message matrices [21] (simplified: no residual/prior-μ tricks)

All functions are pure and jit-able.  The same forward is used by the vanilla
executor, the simulated RAF executor, and (stacked/padded) the SPMD RAF
executor, so Prop-1 equivalence tests compare identical math.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.hetgraph import Relation
from repro.graph.sampler import BranchSpec, SampleSpec, SampledBatch

__all__ = [
    "HGNNConfig",
    "init_hgnn_params",
    "init_embed_tables",
    "hgnn_forward",
    "hgnn_loss",
    "batch_to_arrays",
    "branch_layer",
    "masked_mean",
    "masked_softmax",
]

Params = Dict


@dataclasses.dataclass(frozen=True)
class HGNNConfig:
    model: str = "rgcn"  # rgcn | rgat | hgt
    hidden: int = 64
    num_layers: int = 2
    num_heads: int = 4
    num_classes: int = 2
    learnable_dim: int = 64  # dim of learnable features for featureless types
    dtype: str = "float32"

    def __post_init__(self):
        if self.model not in ("rgcn", "rgat", "hgt"):
            raise ValueError(f"unknown HGNN model {self.model!r}")
        if self.hidden % self.num_heads:
            raise ValueError("hidden must be divisible by num_heads")

    @property
    def head_dim(self) -> int:
        return self.hidden // self.num_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def branch_layer(spec: SampleSpec, depth: int) -> int:
    """HGNN layer index (1-based) a branch at ``depth`` feeds: layer k-d+1."""
    return spec.num_layers - depth + 1


# --------------------------------------------------------------------------
# masked reductions
# --------------------------------------------------------------------------


def masked_mean(h: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """h [..., f, d], mask [..., f] -> [..., d]; empty groups give zeros."""
    w = mask.astype(h.dtype)
    s = jnp.einsum("...fd,...f->...d", h, w)
    return s / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1.0)


def masked_softmax(e: jnp.ndarray, mask: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Softmax with masked slots excluded; all-masked groups give zeros."""
    neg = jnp.asarray(jnp.finfo(e.dtype).min, e.dtype)
    e = jnp.where(mask, e, neg)
    e = e - jax.lax.stop_gradient(jnp.max(e, axis=axis, keepdims=True))
    z = jnp.exp(e) * mask.astype(e.dtype)
    return z / jnp.maximum(jnp.sum(z, axis=axis, keepdims=True), 1e-9)


# --------------------------------------------------------------------------
# parameter initialization
# --------------------------------------------------------------------------


def _glorot(key, shape, dtype):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def _rel_param_specs(
    cfg: HGNNConfig, spec: SampleSpec, feat_dims: Dict[str, int]
) -> Dict[Tuple[str, int], Tuple[str, str, int, int]]:
    """Unique (relation-key, layer) -> (src_type, dst_type, d_src, d_dst)."""
    dims = lambda t: feat_dims.get(t, cfg.learnable_dim)
    out: Dict[Tuple[str, int], Tuple[str, str, int, int]] = {}
    parents: List[str] = [spec.target_type]
    for d, branches in enumerate(spec.levels, start=1):
        layer = branch_layer(spec, d)
        nxt = []
        for b in branches:
            dst_t = parents[b.parent]
            d_src = dims(b.rel.src) if layer == 1 else cfg.hidden
            d_dst = dims(dst_t)  # queries always come from input features
            out.setdefault((b.rel.key, layer), (b.rel.src, dst_t, d_src, d_dst))
            nxt.append(b.rel.src)
        parents = nxt
    return out


def init_hgnn_params(
    key: jax.Array,
    cfg: HGNNConfig,
    spec: SampleSpec,
    feat_dims: Dict[str, int],
    restrict_rels: Optional[List[str]] = None,
) -> Params:
    """Initialize per-(relation, layer) parameters plus the classifier head.

    ``restrict_rels``: only materialize params for these relation keys (RAF
    partitions hold only the parameters of their local relations, paper §4).
    """
    dt = cfg.jdtype
    specs = _rel_param_specs(cfg, spec, feat_dims)
    params: Params = {"rel": {}, "ntype": {}, "etype": {}}
    nh, dh, H = cfg.num_heads, cfg.head_dim, cfg.hidden

    # Keys are derived per parameter *name*, not by consumption order, so a
    # partition-restricted init (RAF workers hold only their relations'
    # parameters) produces bit-identical weights to the full init — required
    # for the Prop-1 equivalence tests.
    def _keys(name: str, n: int):
        base = jax.random.fold_in(key, zlib.crc32(name.encode()))
        return iter(jax.random.split(base, n))

    for i, ((rk, layer), (src_t, dst_t, d_src, d_dst)) in enumerate(
        sorted(specs.items())
    ):
        if restrict_rels is not None and rk not in restrict_rels:
            continue
        name = f"{rk}@{layer}"
        kit = _keys(name, 8)
        if cfg.model == "rgcn":
            params["rel"][name] = {
                "w": _glorot(next(kit), (d_src, H), dt),
                "b": jnp.zeros((H,), dt),
            }
        elif cfg.model == "rgat":
            params["rel"][name] = {
                "w": _glorot(next(kit), (d_src, H), dt),
                "w_dst": _glorot(next(kit), (d_dst, H), dt),
                "a_src": _glorot(next(kit), (nh, dh), dt) * 0.1,
                "a_dst": _glorot(next(kit), (nh, dh), dt) * 0.1,
                "b": jnp.zeros((H,), dt),
            }
        else:  # hgt: per-type K/Q/V + per-etype att/msg
            etype = rk.split("-")[1]
            # per-type / per-etype params derive their keys from their own
            # names (not the relation's) so shared params are bit-identical
            # no matter which relation triggered their creation
            for (kind, t, din) in (("kqv_src", src_t, d_src), ("q_dst", dst_t, d_dst)):
                tkey = f"{t}@{layer}" if kind == "kqv_src" else f"{t}@{layer}:q"
                if tkey not in params["ntype"]:
                    tkit = _keys(tkey, 2)
                    if kind == "kqv_src":
                        params["ntype"][tkey] = {
                            "wk": _glorot(next(tkit), (din, H), dt),
                            "wv": _glorot(next(tkit), (din, H), dt),
                        }
                    else:
                        params["ntype"][tkey] = {
                            "wq": _glorot(next(tkit), (din, H), dt),
                        }
            ekey = f"{etype}@{layer}"
            if ekey not in params["etype"]:
                params["etype"][ekey] = {
                    "w_att": _glorot(next(_keys(ekey, 2)), (nh, dh, dh), dt),
                    "w_msg": _glorot(next(_keys(ekey + ":m", 1)), (nh, dh, dh), dt),
                }
            params["rel"][name] = {"_uses": (f"{src_t}@{layer}", f"{dst_t}@{layer}:q", ekey)}

    hk = _keys("head", 1)
    params["head"] = {
        "w": _glorot(next(hk), (H, cfg.num_classes), dt),
        "b": jnp.zeros((cfg.num_classes,), dt),
    }
    return params


def init_embed_tables(
    key: jax.Array,
    cfg: HGNNConfig,
    num_nodes: Dict[str, int],
    featured: Dict[str, int],
) -> Dict[str, jnp.ndarray]:
    """Learnable feature tables for featureless node types (paper §2.1)."""
    tables = {}
    types = [t for t in sorted(num_nodes) if t not in featured]
    for t, k in zip(types, jax.random.split(key, max(len(types), 1))):
        tables[t] = (
            jax.random.normal(k, (num_nodes[t], cfg.learnable_dim), cfg.jdtype) * 0.1
        )
    return tables


# --------------------------------------------------------------------------
# relation-specific aggregations (AGG_r)
# --------------------------------------------------------------------------


def _agg_rgcn(p, h_src, q_feats, mask):
    # mean over neighbors, then per-relation linear
    agg = masked_mean(h_src, mask)
    return agg @ p["w"] + p["b"]


def _agg_rgat(p, h_src, q_feats, mask, nh: int, dh: int):
    n, f, _ = h_src.shape
    z = (h_src @ p["w"]).reshape(n, f, nh, dh)
    qz = (q_feats @ p["w_dst"]).reshape(n, nh, dh)
    e_src = jnp.einsum("nfhd,hd->nfh", z, p["a_src"])
    e_dst = jnp.einsum("nhd,hd->nh", qz, p["a_dst"])
    e = jax.nn.leaky_relu(e_src + e_dst[:, None, :], negative_slope=0.2)
    alpha = masked_softmax(e, mask[:, :, None], axis=1)
    out = jnp.einsum("nfh,nfhd->nhd", alpha, z).reshape(n, nh * dh)
    return out + p["b"]


def _agg_hgt(p_rel, params, h_src, q_feats, mask, nh: int, dh: int):
    src_key, dst_key, ekey = p_rel["_uses"]
    pt, pq, pe = params["ntype"][src_key], params["ntype"][dst_key], params["etype"][ekey]
    n, f, _ = h_src.shape
    k = (h_src @ pt["wk"]).reshape(n, f, nh, dh)
    v = (h_src @ pt["wv"]).reshape(n, f, nh, dh)
    q = (q_feats @ pq["wq"]).reshape(n, nh, dh)
    kw = jnp.einsum("nfhd,hde->nfhe", k, pe["w_att"])
    att = jnp.einsum("nfhe,nhe->nfh", kw, q) / jnp.sqrt(jnp.asarray(dh, h_src.dtype))
    alpha = masked_softmax(att, mask[:, :, None], axis=1)
    msg = jnp.einsum("nfhd,hde->nfhe", v, pe["w_msg"])
    return jnp.einsum("nfh,nfhe->nhe", alpha, msg).reshape(n, nh * dh)


def agg_relation(
    cfg: HGNNConfig, params: Params, rel_name: str, h_src, q_feats, mask
):
    """AGG_r: [n, f, d_src] x [n, d_dst_feat] x [n, f] -> [n, hidden]."""
    p = params["rel"][rel_name]
    if cfg.model == "rgcn":
        return _agg_rgcn(p, h_src, q_feats, mask)
    if cfg.model == "rgat":
        return _agg_rgat(p, h_src, q_feats, mask, cfg.num_heads, cfg.head_dim)
    return _agg_hgt(p, params, h_src, q_feats, mask, cfg.num_heads, cfg.head_dim)


# --------------------------------------------------------------------------
# batch arrays + full forward (the vanilla execution model's compute)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class BatchArrays:
    """Device-side view of a :class:`SampledBatch` (static tree structure,
    traced arrays).  Feature gathers happen inside the forward so learnable
    tables stay differentiable.  Registered as a pytree so steps jit over it.
    """

    seeds: jnp.ndarray  # [B]
    labels: jnp.ndarray  # [B]
    nids: Tuple[jnp.ndarray, ...]  # per level: [R_d, N_d]
    masks: Tuple[jnp.ndarray, ...]  # per level: [R_d, N_d]


jax.tree_util.register_dataclass(
    BatchArrays,
    data_fields=["seeds", "labels", "nids", "masks"],
    meta_fields=[],
)


def batch_to_arrays(batch: SampledBatch) -> BatchArrays:
    return BatchArrays(
        seeds=jnp.asarray(batch.seeds),
        labels=jnp.asarray(batch.labels),
        nids=tuple(jnp.asarray(lv.nids) for lv in batch.levels),
        masks=tuple(jnp.asarray(lv.mask) for lv in batch.levels),
    )


def _branch_io(spec: SampleSpec) -> List[List[Tuple[BranchSpec, str]]]:
    """Per level: (branch, dst_type) — dst type is the parent's src type."""
    out: List[List[Tuple[BranchSpec, str]]] = []
    parents = [spec.target_type]
    for branches in spec.levels:
        row = [(b, parents[b.parent]) for b in branches]
        out.append(row)
        parents = [b.rel.src for b in branches]
    return out


def hgnn_forward(
    cfg: HGNNConfig,
    params: Params,
    tables: Dict[str, jnp.ndarray],
    batch: BatchArrays,
    spec: SampleSpec,
    branch_mask: Optional[Dict[Tuple[int, int], bool]] = None,
    return_partial: bool = False,
) -> jnp.ndarray:
    """Evaluate the full metatree bottom-up; returns logits [B, classes].

    ``tables`` maps node type -> feature table ([num_nodes, d]); learnable
    tables should be passed via ``params['embed']`` by the caller merging them
    in (they are gathered identically).  ``branch_mask`` drops branches (used
    by the RAF executors to evaluate only a partition's sub-metatrees).

    ``return_partial=True`` returns the root's *partial aggregation* — the
    pre-AGG_all accumulation [B, hidden] — which is exactly what RAF workers
    exchange (paper Alg. 1 line 6); the caller sums partials across
    partitions, applies the nonlinearity and the classifier head.
    """
    k = spec.num_layers
    io = _branch_io(spec)
    embed = params.get("embed", {})
    lookup = lambda t: embed[t] if t in embed else tables[t]

    def feats_of(depth: int, b: int) -> jnp.ndarray:
        if depth == 0:
            return lookup(spec.target_type)[batch.seeds]
        sp = spec.levels[depth - 1][b]
        return lookup(sp.rel.src)[batch.nids[depth - 1][b]]

    def included(depth: int, b: int) -> bool:
        return branch_mask is None or branch_mask.get((depth, b), False)

    # bottom-up: combined[b] accumulates AGG_r outputs into parent embeddings
    child_sum: List[Optional[jnp.ndarray]] = [None]  # per parent at level d-1
    for depth in range(k, 0, -1):
        branches = io[depth - 1]
        f = spec.fanouts[depth - 1]
        n_parent_prev = None
        sums: List[Optional[jnp.ndarray]] = [None] * (
            len(io[depth - 2]) if depth > 1 else 1
        )
        for b, (bs, dst_t) in enumerate(branches):
            if not included(depth, b):
                continue
            # embeddings of this branch's nodes at layer (k - depth)
            if depth == k:
                h_nodes = feats_of(depth, b)
            else:
                acc = child_sum[b]
                if acc is None:
                    # leaf-at-intermediate-depth: type had no in-relations
                    h_nodes = jnp.zeros(
                        (batch.nids[depth - 1][b].shape[0], cfg.hidden), cfg.jdtype
                    )
                else:
                    h_nodes = jax.nn.relu(acc)
            n = h_nodes.shape[0] // f
            h_src = h_nodes.reshape(n, f, -1)
            mask = batch.masks[depth - 1][b].reshape(n, f)
            q_feats = feats_of(depth - 1, bs.parent)
            name = f"{bs.rel.key}@{branch_layer(spec, depth)}"
            out = agg_relation(cfg, params, name, h_src, q_feats, mask)
            if sums[bs.parent] is None:
                sums[bs.parent] = out
            else:
                sums[bs.parent] = sums[bs.parent] + out
        child_sum = sums

    root = child_sum[0]
    if root is None:
        root = jnp.zeros((batch.seeds.shape[0], cfg.hidden), cfg.jdtype)
    if return_partial:
        return root
    h = jax.nn.relu(root)
    return h @ params["head"]["w"] + params["head"]["b"]


def hgnn_loss(
    cfg: HGNNConfig,
    params: Params,
    tables: Dict[str, jnp.ndarray],
    batch: BatchArrays,
    spec: SampleSpec,
) -> jnp.ndarray:
    logits = hgnn_forward(cfg, params, tables, batch, spec)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch.labels[:, None], axis=-1)
    return jnp.mean(nll)
