"""HGNN models (R-GCN, R-GAT, HGT) over the sampled branch representation.

An HGNN layer (paper Eq. 1) is

    h_v^(l) = AGG_all( { AGG_r( {h_u^(l-1) : u ∈ N_r(v)} ) : r ∈ R } )

The sampler (``repro.graph.sampler``) materializes the metatree as *branches*;
this module evaluates them bottom-up.  Branch at depth d carries nodes whose
embeddings live at layer (k - d); the relation-specific aggregation AGG_r maps
child-branch embeddings to the parent's next layer, and AGG_all is a masked
sum over sibling branches followed by a nonlinearity.

Everything model-specific lives in the relation-module IR
(``repro.core.relmod``, DESIGN.md §3): each model declares its parameter
leaves by *scope* — per-(relation, layer), per-(node-type, layer),
per-(edge-type, layer) — plus one pure ``aggregate``.  This module only
walks the metatree: it initializes whatever the declaration asks for
(:func:`init_hgnn_params`) and calls the module's aggregate per branch
(:func:`hgnn_forward`); there is no per-model branching anywhere.

The built-in zoo (see ``relmod`` for the declarations):

  * R-GCN  — masked-mean neighbor aggregation + per-relation linear [39]
  * R-GAT  — per-relation multi-head attention [3]; attention queries are the
             destination nodes' *input* features (tree-sampling variant; see
             DESIGN.md §7)
  * HGT    — per-node-type K/Q/V projections + per-edge-type attention and
             message matrices [21] (simplified: no residual/prior-μ tricks)

All functions are pure and jit-able.  The same forward is used by the vanilla
executor, the simulated RAF executor, and (stacked/padded) the SPMD RAF
executor, so Prop-1 equivalence tests compare identical math.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.relmod import (
    RelContext,
    ShapeCtx,
    _glorot,
    available_models,
    get_relation_module,
    init_module_params,
    masked_mean,
    masked_softmax,
    resolve_params,
)
from repro.graph.hetgraph import Relation
from repro.graph.sampler import BranchSpec, SampleSpec, SampledBatch

__all__ = [
    "HGNNConfig",
    "init_hgnn_params",
    "init_embed_tables",
    "hgnn_forward",
    "hgnn_loss",
    "batch_to_arrays",
    "branch_layer",
    "rel_context",
    "agg_relation",
    "masked_mean",
    "masked_softmax",
]

Params = Dict


@dataclasses.dataclass(frozen=True)
class HGNNConfig:
    model: str = "rgcn"  # any name registered in repro.core.relmod
    hidden: int = 64
    num_layers: int = 2
    num_heads: int = 4
    num_classes: int = 2
    learnable_dim: int = 64  # dim of learnable features for featureless types
    dtype: str = "float32"

    def __post_init__(self):
        if self.model not in available_models():
            raise ValueError(
                f"unknown HGNN model {self.model!r}; registered relation "
                f"modules: {available_models()}"
            )
        if self.hidden % self.num_heads:
            raise ValueError("hidden must be divisible by num_heads")

    @property
    def head_dim(self) -> int:
        return self.hidden // self.num_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def module(self):
        """The relation module (IR declaration) this config names."""
        return get_relation_module(self.model)

    def shape_ctx(self, d_src: int, d_dst: int) -> ShapeCtx:
        return ShapeCtx(self.hidden, self.num_heads, self.head_dim, d_src, d_dst)


def branch_layer(spec: SampleSpec, depth: int) -> int:
    """HGNN layer index (1-based) a branch at ``depth`` feeds: layer k-d+1."""
    return spec.num_layers - depth + 1


def rel_context(rel: Relation, dst_type: str, layer: int) -> RelContext:
    """The :class:`RelContext` of one relation occurrence (scope keys derive
    from it)."""
    return RelContext(
        rel_key=rel.key,
        etype=rel.etype,
        src_type=rel.src,
        dst_type=dst_type,
        layer=layer,
    )


# --------------------------------------------------------------------------
# parameter initialization
# --------------------------------------------------------------------------


def _rel_param_specs(
    cfg: HGNNConfig, spec: SampleSpec, feat_dims: Dict[str, int]
) -> Dict[Tuple[str, int], Tuple[Relation, str, int, int]]:
    """Unique (relation-key, layer) -> (relation, dst_type, d_src, d_dst)."""
    dims = lambda t: feat_dims.get(t, cfg.learnable_dim)
    out: Dict[Tuple[str, int], Tuple[Relation, str, int, int]] = {}
    parents: List[str] = [spec.target_type]
    for d, branches in enumerate(spec.levels, start=1):
        layer = branch_layer(spec, d)
        nxt = []
        for b in branches:
            dst_t = parents[b.parent]
            d_src = dims(b.rel.src) if layer == 1 else cfg.hidden
            d_dst = dims(dst_t)  # queries always come from input features
            out.setdefault((b.rel.key, layer), (b.rel, dst_t, d_src, d_dst))
            nxt.append(b.rel.src)
        parents = nxt
    return out


def init_hgnn_params(
    key: jax.Array,
    cfg: HGNNConfig,
    spec: SampleSpec,
    feat_dims: Dict[str, int],
    restrict_rels: Optional[List[str]] = None,
) -> Params:
    """Initialize the relation module's scoped parameters plus the classifier
    head, walking every relation occurrence of the metatree.

    ``restrict_rels``: only materialize params for these relation keys (RAF
    partitions hold only the parameters of their local relations, paper §4);
    shared-scope leaves (per-node-type / per-edge-type) are created for
    whatever those relations use.  Keys are derived per parameter *name*
    (see ``relmod.init_leaf``), so a restricted init is bit-identical to the
    full one — required for the Prop-1 equivalence tests.
    """
    dt = cfg.jdtype
    module = cfg.module
    occurrences = _rel_param_specs(cfg, spec, feat_dims)
    params: Params = {"rel": {}, "ntype": {}, "etype": {}}
    for (rk, layer), (rel, dst_t, d_src, d_dst) in sorted(occurrences.items()):
        if restrict_rels is not None and rk not in restrict_rels:
            continue
        ctx = rel_context(rel, dst_t, layer)
        init_module_params(key, module, params, ctx, cfg.shape_ctx(d_src, d_dst), dt)

    hk = jax.random.fold_in(key, zlib.crc32(b"head/w"))
    params["head"] = {
        "w": _glorot(hk, (cfg.hidden, cfg.num_classes), dt),
        "b": jnp.zeros((cfg.num_classes,), dt),
    }
    return params


def init_embed_tables(
    key: jax.Array,
    cfg: HGNNConfig,
    num_nodes: Dict[str, int],
    featured: Dict[str, int],
) -> Dict[str, jnp.ndarray]:
    """Learnable feature tables for featureless node types (paper §2.1)."""
    tables = {}
    types = [t for t in sorted(num_nodes) if t not in featured]
    for t, k in zip(types, jax.random.split(key, max(len(types), 1))):
        tables[t] = (
            jax.random.normal(k, (num_nodes[t], cfg.learnable_dim), cfg.jdtype) * 0.1
        )
    return tables


# --------------------------------------------------------------------------
# relation-specific aggregation (AGG_r) — resolve + delegate to the module
# --------------------------------------------------------------------------


def agg_relation(
    cfg: HGNNConfig, params: Params, ctx: RelContext, h_src, q_feats, mask,
    kernels=None,
):
    """AGG_r: [n, f, d_src] x [n, d_dst_feat] x [n, f] -> [n, hidden].

    ``kernels`` routes ``mean_linear``-family modules through the fused
    ``relation_agg`` Pallas kernel (its custom VJP keeps the op trainable);
    other modules — and the default off-TPU backend — use the module's own
    ``aggregate``.  The stacked variant on the SPMD executor lives in
    ``repro.core.raf_spmd._agg_level``."""
    module = cfg.module
    p = resolve_params(module, params, ctx)
    if kernels is not None and module.fused == "mean_linear":
        from repro.kernels.ops import kernel_choice
        from repro.kernels.relation_agg import relation_agg

        use, interp = kernel_choice(kernels, "relation_agg")
        if use:
            return relation_agg(h_src, mask, p["w"], p["b"], interpret=interp)
    return module.aggregate(p, h_src, q_feats, mask)


# --------------------------------------------------------------------------
# batch arrays + full forward (the vanilla execution model's compute)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class BatchArrays:
    """Device-side view of a :class:`SampledBatch` (static tree structure,
    traced arrays).  Feature gathers happen inside the forward so learnable
    tables stay differentiable.  Registered as a pytree so steps jit over it.
    """

    seeds: jnp.ndarray  # [B]
    labels: jnp.ndarray  # [B]
    nids: Tuple[jnp.ndarray, ...]  # per level: [R_d, N_d]
    masks: Tuple[jnp.ndarray, ...]  # per level: [R_d, N_d]


jax.tree_util.register_dataclass(
    BatchArrays,
    data_fields=["seeds", "labels", "nids", "masks"],
    meta_fields=[],
)


def batch_to_arrays(batch: SampledBatch) -> BatchArrays:
    return BatchArrays(
        seeds=jnp.asarray(batch.seeds),
        labels=jnp.asarray(batch.labels),
        nids=tuple(jnp.asarray(lv.nids) for lv in batch.levels),
        masks=tuple(jnp.asarray(lv.mask) for lv in batch.levels),
    )


def _branch_io(spec: SampleSpec) -> List[List[Tuple[BranchSpec, str]]]:
    """Per level: (branch, dst_type) — dst type is the parent's src type."""
    out: List[List[Tuple[BranchSpec, str]]] = []
    parents = [spec.target_type]
    for branches in spec.levels:
        row = [(b, parents[b.parent]) for b in branches]
        out.append(row)
        parents = [b.rel.src for b in branches]
    return out


def hgnn_forward(
    cfg: HGNNConfig,
    params: Params,
    tables: Dict[str, jnp.ndarray],
    batch: BatchArrays,
    spec: SampleSpec,
    branch_mask: Optional[Dict[Tuple[int, int], bool]] = None,
    return_partial: bool = False,
    kernels=None,
) -> jnp.ndarray:
    """Evaluate the full metatree bottom-up; returns logits [B, classes].

    ``tables`` maps node type -> feature table ([num_nodes, d]); learnable
    tables should be passed via ``params['embed']`` by the caller merging them
    in (they are gathered identically).  ``branch_mask`` drops branches (used
    by the RAF executors to evaluate only a partition's sub-metatrees).
    ``kernels`` (see :func:`agg_relation`) opts per-relation aggregations
    into the fused Pallas path — the vanilla oracle never passes it.

    ``return_partial=True`` returns the root's *partial aggregation* — the
    pre-AGG_all accumulation [B, hidden] — which is exactly what RAF workers
    exchange (paper Alg. 1 line 6); the caller sums partials across
    partitions, applies the nonlinearity and the classifier head.
    """
    k = spec.num_layers
    io = _branch_io(spec)
    embed = params.get("embed", {})
    lookup = lambda t: embed[t] if t in embed else tables[t]

    def feats_of(depth: int, b: int) -> jnp.ndarray:
        if depth == 0:
            return lookup(spec.target_type)[batch.seeds]
        sp = spec.levels[depth - 1][b]
        return lookup(sp.rel.src)[batch.nids[depth - 1][b]]

    def included(depth: int, b: int) -> bool:
        return branch_mask is None or branch_mask.get((depth, b), False)

    # bottom-up: combined[b] accumulates AGG_r outputs into parent embeddings
    child_sum: List[Optional[jnp.ndarray]] = [None]  # per parent at level d-1
    for depth in range(k, 0, -1):
        branches = io[depth - 1]
        f = spec.fanouts[depth - 1]
        sums: List[Optional[jnp.ndarray]] = [None] * (
            len(io[depth - 2]) if depth > 1 else 1
        )
        for b, (bs, dst_t) in enumerate(branches):
            if not included(depth, b):
                continue
            # embeddings of this branch's nodes at layer (k - depth)
            if depth == k:
                h_nodes = feats_of(depth, b)
            else:
                acc = child_sum[b]
                if acc is None:
                    # leaf-at-intermediate-depth: type had no in-relations
                    h_nodes = jnp.zeros(
                        (batch.nids[depth - 1][b].shape[0], cfg.hidden), cfg.jdtype
                    )
                else:
                    h_nodes = jax.nn.relu(acc)
            n = h_nodes.shape[0] // f
            h_src = h_nodes.reshape(n, f, -1)
            mask = batch.masks[depth - 1][b].reshape(n, f)
            q_feats = feats_of(depth - 1, bs.parent)
            ctx = rel_context(bs.rel, dst_t, branch_layer(spec, depth))
            out = agg_relation(cfg, params, ctx, h_src, q_feats, mask, kernels)
            if sums[bs.parent] is None:
                sums[bs.parent] = out
            else:
                sums[bs.parent] = sums[bs.parent] + out
        child_sum = sums

    root = child_sum[0]
    if root is None:
        root = jnp.zeros((batch.seeds.shape[0], cfg.hidden), cfg.jdtype)
    if return_partial:
        return root
    h = jax.nn.relu(root)
    return h @ params["head"]["w"] + params["head"]["b"]


def hgnn_loss(
    cfg: HGNNConfig,
    params: Params,
    tables: Dict[str, jnp.ndarray],
    batch: BatchArrays,
    spec: SampleSpec,
) -> jnp.ndarray:
    logits = hgnn_forward(cfg, params, tables, batch, spec)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch.labels[:, None], axis=-1)
    return jnp.mean(nll)
