"""Relation-module IR — HGNN variants as pure declarations (DESIGN.md §3).

Heta's core factorization (paper Eq. 1) says *any* HGNN layer is a set of
independent relation-specific aggregations (AGG_r) followed by one
cross-relation aggregation (AGG_all).  Everything model-specific therefore
fits in a small declarative unit, the **relation module**:

  * a tuple of :class:`ParamSpec` — each parameter leaf named, shaped, and
    *scoped*: does one copy exist per (relation, layer), per (source
    node-type, layer), per (destination node-type, layer) or per
    (edge-type, layer)?
  * one pure ``aggregate(params, h_src, q_feats, mask)`` — AGG_r for a
    single relation occurrence, written for unbatched ``[n, f, d]`` inputs.

Every executor consumes the declaration instead of branching on model-name
strings:

  * the dict-form executors (``vanilla``, simulated ``raf``) resolve scoped
    storage keys per relation occurrence and call ``aggregate`` directly;
  * the SPMD executor (``raf_spmd``) stacks each scope's parameters into
    per-shard slabs, gathers per-slot leaves via the plan's index arrays and
    ``jax.vmap``s the *same* ``aggregate`` over the branch axis.

Adding an HGNN variant is: subclass :class:`RelationModule`, declare specs,
write ``aggregate``, decorate with :func:`register_relation_module` — all
three executors (and the parameter stacking, sharding specs and shared-
gradient synchronization) follow from the declaration.

Scope -> storage layout inside the parameter dict (``init_hgnn_params``):

  ================  =============  =============================
  scope             container      storage key
  ================  =============  =============================
  ``relation``      ``rel``        ``{rel_key}@{layer}``
  ``src_type``      ``ntype``      ``{src_type}@{layer}``
  ``dst_type``      ``ntype``      ``{dst_type}@{layer}:q``
  ``etype``         ``etype``      ``{etype}@{layer}``
  ================  =============  =============================

RNG keys are derived from the *storage key + leaf name*, never from
consumption order, so a partition-restricted init (RAF workers materialize
only their relations' parameters, plus the shared-scope parameters those
relations use) is bit-identical to the full init — the property the Prop-1
equivalence tests rest on.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SCOPES",
    "SCOPE_CONTAINER",
    "ShapeCtx",
    "ParamSpec",
    "RelContext",
    "AttnEpilogue",
    "RelationModule",
    "register_relation_module",
    "get_relation_module",
    "available_models",
    "storage_key",
    "resolve_params",
    "init_module_params",
    "init_leaf",
    "masked_mean",
    "masked_softmax",
]

SCOPES = ("relation", "src_type", "dst_type", "etype")

# scope -> top-level container inside the parameter dict
SCOPE_CONTAINER = {
    "relation": "rel",
    "src_type": "ntype",
    "dst_type": "ntype",
    "etype": "etype",
}


# --------------------------------------------------------------------------
# masked reductions (shared by the built-in aggregates and the executors)
# --------------------------------------------------------------------------


def masked_mean(h: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """h [..., f, d], mask [..., f] -> [..., d]; empty groups give zeros."""
    w = mask.astype(h.dtype)
    s = jnp.einsum("...fd,...f->...d", h, w)
    return s / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1.0)


def masked_softmax(e: jnp.ndarray, mask: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Softmax with masked slots excluded; all-masked groups give zeros."""
    neg = jnp.asarray(jnp.finfo(e.dtype).min, e.dtype)
    e = jnp.where(mask, e, neg)
    e = e - jax.lax.stop_gradient(jnp.max(e, axis=axis, keepdims=True))
    z = jnp.exp(e) * mask.astype(e.dtype)
    return z / jnp.maximum(jnp.sum(z, axis=axis, keepdims=True), 1e-9)


# --------------------------------------------------------------------------
# the IR
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCtx:
    """Dims a :class:`ParamSpec` shape function may depend on.

    ``d_src`` is the aggregation-input dim of the relation's source nodes at
    this layer (their feature dim at layer 1, ``hidden`` above); ``d_dst``
    is the destination nodes' *input-feature* dim (attention queries always
    come from input features — the tree-sampling variant, DESIGN.md §7).
    """

    hidden: int
    num_heads: int
    head_dim: int
    d_src: int
    d_dst: int


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named parameter leaf of a relation module.

    ``shape`` maps a :class:`ShapeCtx` to the leaf's shape; dims derived
    from ``d_src``/``d_dst`` are the ones the SPMD executor zero-pads when
    stacking heterogeneous feature dims to a common ``d_pad``.
    """

    name: str
    scope: str  # one of SCOPES
    shape: Callable[[ShapeCtx], Tuple[int, ...]]
    init: str = "glorot"  # glorot | zeros
    scale: float = 1.0

    def __post_init__(self):
        if self.scope not in SCOPES:
            raise ValueError(f"unknown param scope {self.scope!r}; scopes: {SCOPES}")
        if self.init not in ("glorot", "zeros"):
            raise ValueError(f"unknown init {self.init!r}")


@dataclasses.dataclass(frozen=True)
class RelContext:
    """One relation occurrence: everything scope keys may derive from."""

    rel_key: str
    etype: str
    src_type: str
    dst_type: str
    layer: int


def storage_key(scope: str, ctx: RelContext) -> str:
    """Storage key of a ``scope``-scoped parameter group for ``ctx``."""
    if scope == "relation":
        return f"{ctx.rel_key}@{ctx.layer}"
    if scope == "src_type":
        return f"{ctx.src_type}@{ctx.layer}"
    if scope == "dst_type":
        return f"{ctx.dst_type}@{ctx.layer}:q"
    if scope == "etype":
        return f"{ctx.etype}@{ctx.layer}"
    raise ValueError(f"unknown param scope {scope!r}")


@dataclasses.dataclass
class AttnEpilogue:
    """Canonical operand form of a fully-fused attention epilogue.

    Every ``softmax_combine`` module's AGG_r factors (DESIGN.md §8) as

        z0 = h_src @ we[ue[s]]                       # logits projection
        zt = z0                 if pe is None else   # per-etype transform
             einsum("nfhd,hde->nfhe", z0, pe[ua[s]])
        e0 = einsum("nfhe,nhe->nfh", zt, qv) * scale (+ eb)
        e  = leaky_relu(e0, slope)  (slope=None -> identity)
        v0 = h_src @ wv[uv[s]]   (shared with z0 when we is wv and ue is uv)
        vt = v0                 if pv is None else
             einsum("nfhd,hde->nfhe", v0, pv[ua[s]])
        out = einsum("nfh,nfhd->nhd", masked_softmax(e), vt) (+ bias)

    where ``we``/``wv`` are the *stacked* ``[U, d_in, nh*dh]`` projection
    slabs and ``ue``/``uv``/``ua`` the per-slot stack rows — the form the
    fused Pallas kernel streams via scalar prefetch, so the big projection
    weights are never materialized per slot.  Small per-slot operands
    (``qv``/``eb``/``bias`` and the ``[nh, dh, dh]`` transforms) may be
    gathered; they are vectors/tiny tensors, not the ``[rb, d_in, H]``
    weight copies the gather-then-vmap path pays for.
    """

    we: jnp.ndarray  # [Ue, d_in, nh*dh] logits-projection stack
    ue: jnp.ndarray  # [rb] int — slot -> stack row of `we`
    qv: jnp.ndarray  # [rb, n, nh*dh] per-destination query vectors
    wv: Optional[jnp.ndarray] = None  # [Uv, d_in, nh*dh]; None -> shares `we`
    uv: Optional[jnp.ndarray] = None  # [rb] int; None -> `ue`
    pe: Optional[jnp.ndarray] = None  # [Ua, nh, dh, dh] logits transform
    pv: Optional[jnp.ndarray] = None  # [Ua, nh, dh, dh] values transform
    ua: Optional[jnp.ndarray] = None  # [rb] int (required with pe/pv)
    eb: Optional[jnp.ndarray] = None  # [rb, n, nh] additive logit term
    bias: Optional[jnp.ndarray] = None  # [rb, hidden] additive output bias
    num_heads: int = 1
    head_dim: int = 1
    scale: float = 1.0
    slope: Optional[float] = None  # leaky_relu negative slope on logits


class RelationModule:
    """Base relation module: declared parameter specs + one pure AGG_r.

    ``aggregate`` takes the *resolved* flat leaf dict (``{spec.name:
    array}``) and unbatched inputs:

        h_src   [n, f, d_src]   neighbor embeddings, f per destination
        q_feats [n, d_dst]      destination nodes' input features
        mask    [n, f]          True for real (non-padded) neighbors

    and returns ``[n, hidden]``.  It must be pure and shape-polymorphic in
    ``n``/``f`` — the SPMD executor ``vmap``s it over a stacked branch axis,
    so hyperparameters like head counts must be read off parameter shapes,
    not captured state.

    ``fused`` optionally names the stacked Pallas kernel family entry this
    module's aggregate lowers to (DESIGN.md §8); ``None`` keeps the module
    on the gather-then-vmap oracle path:

      * ``"mean_linear"``      — masked-mean + projection.  Contract: leaves
        named ``w`` ``[d_src, hidden]`` and ``b`` ``[hidden]`` sharing one
        scope, and ``aggregate == masked_mean(h, mask) @ w + b``.
      * ``"softmax_combine"``  — attention epilogue.  Contract: the module
        implements :meth:`attn_parts` (and optionally :meth:`attn_bias`)
        such that ``aggregate`` factors as logits/values projections
        followed by ``masked_softmax`` + head-wise weighted combine; the
        base-class ``_softmax_aggregate`` is that factoring, so modules
        declaring this family should route ``aggregate`` through it.
    """

    name: str = "?"
    specs: Tuple[ParamSpec, ...] = ()
    fused: Optional[str] = None  # "mean_linear" | "softmax_combine" | None

    @property
    def scopes(self) -> Tuple[str, ...]:
        """Scopes this module uses, in spec order, deduplicated."""
        return tuple(dict.fromkeys(s.scope for s in self.specs))

    def aggregate(self, p: Dict[str, jnp.ndarray], h_src, q_feats, mask):
        raise NotImplementedError

    # -- softmax_combine family hooks -------------------------------------

    def attn_parts(self, p: Dict[str, jnp.ndarray], h_src, q_feats):
        """(logits ``[n, f, nh]``, values ``[n, f, nh, dh]``) of the masked
        softmax+combine epilogue — everything of AGG_r *before* the softmax
        (the weight-touching projections, which stay under XLA autodiff)."""
        raise NotImplementedError

    def attn_bias(self, p: Dict[str, jnp.ndarray]) -> Optional[jnp.ndarray]:
        """Additive output bias ``[hidden]`` applied after the combine."""
        return None

    def attn_epilogue(self, stacks, slot_u, q_feats, linear) -> Optional[AttnEpilogue]:
        """Stacked-operand form of this module's attention epilogue.

        ``stacks`` / ``slot_u`` are the SPMD executor's per-scope parameter
        slabs and per-slot stack rows; ``q_feats`` is ``[rb, n, d_dst]``;
        ``linear(w_stack, u, x)`` computes the per-slot projection
        ``x @ w_stack[u]`` *without* materializing a gathered weight copy
        (injected by the kernel layer — it carries a stack-form VJP).

        Returning ``None`` keeps the module on the vmapped ``attn_parts``
        path; returning an :class:`AttnEpilogue` lets the fused Pallas
        epilogue stream the projections from the stacks.
        """
        return None

    def _softmax_aggregate(self, p, h_src, q_feats, mask):
        """The canonical ``softmax_combine`` factoring of ``aggregate`` —
        the fused path replaces only the epilogue below with the Pallas
        kernel, so oracle and fused math agree by construction."""
        e, v = self.attn_parts(p, h_src, q_feats)
        n, f, nh, dh = v.shape
        alpha = masked_softmax(e, mask[:, :, None], axis=1)
        out = jnp.einsum("nfh,nfhd->nhd", alpha, v).reshape(n, nh * dh)
        b = self.attn_bias(p)
        return out if b is None else out + b

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        leaves = ", ".join(f"{s.name}:{s.scope}" for s in self.specs)
        return f"<RelationModule {self.name} [{leaves}]>"


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_MODULES: Dict[str, RelationModule] = {}


def register_relation_module(cls: Type[RelationModule]) -> Type[RelationModule]:
    """Class decorator: instantiate + register under ``cls.name``."""
    mod = cls()
    if mod.name == "?":
        raise ValueError(f"{cls.__name__} must set a `name` before registration")
    if mod.name in _MODULES:
        raise ValueError(
            f"relation module {mod.name!r} is already registered "
            f"({type(_MODULES[mod.name]).__name__}); pick a distinct name"
        )
    names = [s.name for s in mod.specs]
    if len(set(names)) != len(names):
        raise ValueError(f"module {mod.name!r} declares duplicate leaf names: {names}")
    _MODULES[mod.name] = mod
    return cls


def get_relation_module(name: str) -> RelationModule:
    if name not in _MODULES:
        raise KeyError(
            f"unknown HGNN model {name!r}; registered: {available_models()}"
        )
    return _MODULES[name]


def available_models() -> Tuple[str, ...]:
    return tuple(sorted(_MODULES))


# --------------------------------------------------------------------------
# initialization + resolution
# --------------------------------------------------------------------------


def _glorot(key, shape, dtype):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def init_leaf(key: jax.Array, spec: ParamSpec, skey: str, sc: ShapeCtx, dtype):
    """Initialize one leaf; the RNG key is a pure function of the *names*
    (storage key + leaf), so creation order never changes values."""
    shape = tuple(spec.shape(sc))
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    k = jax.random.fold_in(key, zlib.crc32(f"{skey}/{spec.name}".encode()))
    w = _glorot(k, shape, dtype)
    return w * spec.scale if spec.scale != 1.0 else w


def init_module_params(
    key: jax.Array,
    module: RelationModule,
    params: Dict,
    ctx: RelContext,
    sc: ShapeCtx,
    dtype,
) -> None:
    """Materialize (idempotently) the parameters ``module`` needs for one
    relation occurrence into the scoped containers of ``params``.  Shared-
    scope groups already present are left untouched, so any relation of a
    partition-restricted init reproduces exactly the leaves the full init
    would have given them."""
    for spec in module.specs:
        container = params[SCOPE_CONTAINER[spec.scope]]
        skey = storage_key(spec.scope, ctx)
        group = container.setdefault(skey, {})
        if spec.name not in group:
            group[spec.name] = init_leaf(key, spec, skey, sc, dtype)


def resolve_params(
    module: RelationModule, params: Dict, ctx: RelContext
) -> Dict[str, jnp.ndarray]:
    """Flat ``{leaf name: array}`` view of one relation occurrence's
    parameters, gathered across the scoped containers."""
    return {
        s.name: params[SCOPE_CONTAINER[s.scope]][storage_key(s.scope, ctx)][s.name]
        for s in module.specs
    }


# --------------------------------------------------------------------------
# the built-in model zoo
# --------------------------------------------------------------------------


@register_relation_module
class RGCNModule(RelationModule):
    """R-GCN [39] — masked-mean neighbor aggregation + per-relation linear."""

    name = "rgcn"
    fused = "mean_linear"
    specs = (
        ParamSpec("w", "relation", lambda c: (c.d_src, c.hidden)),
        ParamSpec("b", "relation", lambda c: (c.hidden,), init="zeros"),
    )

    def aggregate(self, p, h_src, q_feats, mask):
        return masked_mean(h_src, mask) @ p["w"] + p["b"]


@register_relation_module
class RGATModule(RelationModule):
    """R-GAT [3] — per-relation multi-head attention; queries are the
    destination nodes' *input* features (tree-sampling variant, DESIGN.md
    §7)."""

    name = "rgat"
    fused = "softmax_combine"
    specs = (
        ParamSpec("w", "relation", lambda c: (c.d_src, c.hidden)),
        ParamSpec("w_dst", "relation", lambda c: (c.d_dst, c.hidden)),
        ParamSpec("a_src", "relation", lambda c: (c.num_heads, c.head_dim), scale=0.1),
        ParamSpec("a_dst", "relation", lambda c: (c.num_heads, c.head_dim), scale=0.1),
        ParamSpec("b", "relation", lambda c: (c.hidden,), init="zeros"),
    )

    def attn_parts(self, p, h_src, q_feats):
        nh, dh = p["a_src"].shape
        n, f, _ = h_src.shape
        z = (h_src @ p["w"]).reshape(n, f, nh, dh)
        qz = (q_feats @ p["w_dst"]).reshape(n, nh, dh)
        e_src = jnp.einsum("nfhd,hd->nfh", z, p["a_src"])
        e_dst = jnp.einsum("nhd,hd->nh", qz, p["a_dst"])
        e = jax.nn.leaky_relu(e_src + e_dst[:, None, :], negative_slope=0.2)
        return e, z

    def attn_bias(self, p):
        return p["b"]

    def attn_epilogue(self, stacks, slot_u, q_feats, linear):
        u = slot_u["relation"]
        nh, dh = stacks["a_src"].shape[1:]
        rb, n, _ = q_feats.shape
        # e_dst per destination: q-side projection through the stacked
        # kernel (stack-form VJP), contracted with the tiny gathered a_dst
        qz = linear(stacks["w_dst"], u, q_feats).reshape(rb, n, nh, dh)
        eb = jnp.einsum("rnhd,rhd->rnh", qz, stacks["a_dst"][u])
        # e_src = einsum(z, a_src) fits the canonical qv contraction with
        # qv = a_src broadcast over destinations
        qv = jnp.broadcast_to(
            stacks["a_src"][u][:, None], (rb, n, nh, dh)
        ).reshape(rb, n, nh * dh)
        return AttnEpilogue(
            we=stacks["w"], ue=u, qv=qv, eb=eb, bias=stacks["b"][u],
            num_heads=nh, head_dim=dh, scale=1.0, slope=0.2,
        )

    def aggregate(self, p, h_src, q_feats, mask):
        return self._softmax_aggregate(p, h_src, q_feats, mask)


@register_relation_module
class HGTModule(RelationModule):
    """HGT [21] — per-node-type K/Q/V projections + per-edge-type attention
    and message matrices (simplified: no residual/prior-μ tricks).  The
    per-node-type scopes are exactly the parameter-sharing structure the
    SPMD stacking layer carries as ``src_type``/``dst_type`` index arrays."""

    name = "hgt"
    fused = "softmax_combine"
    specs = (
        ParamSpec("wk", "src_type", lambda c: (c.d_src, c.hidden)),
        ParamSpec("wv", "src_type", lambda c: (c.d_src, c.hidden)),
        ParamSpec("wq", "dst_type", lambda c: (c.d_dst, c.hidden)),
        ParamSpec("w_att", "etype", lambda c: (c.num_heads, c.head_dim, c.head_dim)),
        ParamSpec("w_msg", "etype", lambda c: (c.num_heads, c.head_dim, c.head_dim)),
    )

    def attn_parts(self, p, h_src, q_feats):
        nh, dh, _ = p["w_att"].shape
        n, f, _ = h_src.shape
        k = (h_src @ p["wk"]).reshape(n, f, nh, dh)
        v = (h_src @ p["wv"]).reshape(n, f, nh, dh)
        q = (q_feats @ p["wq"]).reshape(n, nh, dh)
        kw = jnp.einsum("nfhd,hde->nfhe", k, p["w_att"])
        att = jnp.einsum("nfhe,nhe->nfh", kw, q) / jnp.sqrt(
            jnp.asarray(dh, h_src.dtype)
        )
        msg = jnp.einsum("nfhd,hde->nfhe", v, p["w_msg"])
        return att, msg

    def attn_epilogue(self, stacks, slot_u, q_feats, linear):
        us, ud, ue = slot_u["src_type"], slot_u["dst_type"], slot_u["etype"]
        nh, dh = stacks["w_att"].shape[1:3]
        qv = linear(stacks["wq"], ud, q_feats)  # [rb, n, nh*dh]
        return AttnEpilogue(
            we=stacks["wk"], ue=us, wv=stacks["wv"], uv=us,
            pe=stacks["w_att"], pv=stacks["w_msg"], ua=ue, qv=qv,
            num_heads=nh, head_dim=dh,
            scale=float(1.0 / np.sqrt(dh)), slope=None,
        )

    def aggregate(self, p, h_src, q_feats, mask):
        return self._softmax_aggregate(p, h_src, q_feats, mask)
