"""jax version-portability shims shared across subsystems.

The repo targets current jax but must run on 0.4.x images; the handful of
renamed surfaces live here so HGNN executors, LLM models and tests don't
each carry their own try/except.
"""

from __future__ import annotations

try:  # jax >= 0.5: top-level export, replication check renamed to check_vma
    from jax import shard_map as _shard_map

    _SHARD_MAP_NOCHECK = {"check_vma": False}
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_NOCHECK = {"check_rep": False}

__all__ = ["shard_map_nocheck"]


def shard_map_nocheck(body, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` with replication checking disabled."""
    return _shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **_SHARD_MAP_NOCHECK)
