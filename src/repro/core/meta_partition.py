"""Meta-partitioning of a HetG (paper §5, Algorithm 2).

Four steps:
  1. build a metatree from the metagraph (k-depth BFS from the target type,
     or from user metapaths);
  2. split it into sub-metatrees, one per child of the root — each keeps the
     root, so every partition holds all target nodes and complete aggregation
     paths, confining boundary nodes to the target type;
  3. LPT-assign sub-metatrees to p partitions by weight (greedy longest-
     processing-time-first on the p-way number-partitioning problem);
  4. deduplicate relations within each partition and materialize complete
     mono-relation subgraphs.

Also provides the generic edge-cut partition analysis used by the vanilla
baseline and the Prop-2/3 communication-complexity checks.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.metatree import (
    MetaTreeNode,
    build_metatree,
    build_metatree_from_metapaths,
)
from repro.graph.hetgraph import HetGraph, Metagraph, Relation

__all__ = [
    "SubMetatree",
    "MetaPartition",
    "MetaPartitioning",
    "meta_partition",
    "EdgeCutPartition",
    "random_edge_cut",
    "greedy_edge_cut",
    "boundary_nodes",
    "cross_edges",
    "HierarchicalPartition",
    "hierarchical_partition",
]


# --------------------------------------------------------------------------
# Steps 1-2: sub-metatrees
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SubMetatree:
    """S_c: the root, one child c of the root, and all of c's descendants."""

    root_child: MetaTreeNode
    root_type: str
    weight: int  # sum of unique vertex + link weights (Algorithm 2, line 8)

    def relations(self) -> List[Relation]:
        rels = [self.root_child.rel] if self.root_child.rel else []
        rels += self.root_child.relations()
        return rels

    def unique_relations(self) -> List[Relation]:
        return list(dict.fromkeys(self.relations()))

    def vertex_types(self) -> List[str]:
        return list(dict.fromkeys([self.root_type] + self.root_child.vertex_types()))


def _subtree_weight(sub: "SubMetatree", meta: Metagraph) -> int:
    """Weight = Σ node counts of unique vertex types + Σ edge counts of unique
    relations in S_c.  Unique (deduplicated) counts reflect the actual size of
    the partition the sub-metatree will create."""
    w = sum(meta.node_types[t] for t in sub.vertex_types())
    w += sum(meta.relations[r] for r in sub.unique_relations())
    return int(w)


def split_metatree(tree: MetaTreeNode, meta: Metagraph) -> List[SubMetatree]:
    """Step 2: one sub-metatree per child of the root."""
    subs: List[SubMetatree] = []
    for child in tree.children:
        sub = SubMetatree(root_child=child, root_type=tree.ntype, weight=0)
        sub.weight = _subtree_weight(sub, meta)
        subs.append(sub)
    return subs


# --------------------------------------------------------------------------
# Steps 3-4: LPT assignment + dedup
# --------------------------------------------------------------------------


@dataclasses.dataclass
class MetaPartition:
    """One HetG partition produced by meta-partitioning."""

    index: int
    sub_metatrees: List[SubMetatree]
    relations: List[Relation]  # deduplicated
    weight: int
    graph: Optional[HetGraph] = None  # materialized complete mono-rel subgraphs
    replica_group: int = 0  # >0 partitions replicate sub-metatrees (paper §5
    #   discussion: more machines than sub-metatrees → replicate + split
    #   target nodes with data parallelism)

    @property
    def node_types(self) -> List[str]:
        ts: List[str] = []
        for s in self.sub_metatrees:
            ts += s.vertex_types()
        return list(dict.fromkeys(ts))


@dataclasses.dataclass
class MetaPartitioning:
    """The result of Algorithm 2 plus bookkeeping used by RAF and benchmarks."""

    partitions: List[MetaPartition]
    metatree: MetaTreeNode
    target_type: str
    elapsed_s: float
    replicated: bool = False

    @property
    def num_partitions(self) -> int:
        return len(self.partitions)

    def relation_to_partition(self) -> Dict[Relation, int]:
        """Owner of each relation at the *root level*; deeper duplicates are
        intentional replication, not ownership."""
        owner: Dict[Relation, int] = {}
        for p in self.partitions:
            for r in p.relations:
                owner.setdefault(r, p.index)
        return owner

    def max_boundary_nodes(self) -> int:
        """Meta-partitioning confines boundary nodes to the target type
        (paper §5 Step 2): every partition holds all target nodes and complete
        aggregation paths, so the only cross-partition dependency is the
        cross-relation reduce at target nodes."""
        if self.num_partitions <= 1:
            return 0
        g = self.partitions[0].graph
        n_target = g.num_nodes[self.target_type] if g is not None else 0
        return int(n_target)

    def summary(self) -> str:
        lines = [
            f"meta-partitioning: {self.num_partitions} partitions, "
            f"{self.elapsed_s * 1e3:.2f} ms"
        ]
        for p in self.partitions:
            g = p.graph
            extra = (
                f" nodes={g.total_nodes:,} edges={g.total_edges:,}" if g else ""
            )
            lines.append(
                f"  P{p.index}: {len(p.relations)} relations "
                f"weight={p.weight:,}{extra} (replica_group={p.replica_group})"
            )
        return "\n".join(lines)


def meta_partition(
    graph: HetGraph,
    num_partitions: int,
    num_layers: int = 2,
    metapaths: Optional[Sequence[Sequence[Relation]]] = None,
    materialize: bool = True,
) -> MetaPartitioning:
    """Paper Algorithm 2 (all four steps).

    Operates purely on the metagraph — O(|A| log |A| + |R|) — and only touches
    the HetG itself when materializing partitions (slicing out complete
    mono-relation subgraphs, no node/edge reshuffling).
    """
    t0 = time.perf_counter()
    meta = graph.metagraph()
    root = graph.target_type

    # Step 1: metatree
    if metapaths:
        tree = build_metatree_from_metapaths(meta, root, metapaths)
    else:
        tree = build_metatree(meta, root, num_layers)

    # Step 2: split into sub-metatrees
    subs = split_metatree(tree, meta)
    if not subs:
        raise ValueError(
            f"target type {root!r} has no in-relations; nothing to partition"
        )

    # Paper §5 discussion: more partitions than sub-metatrees → replicate the
    # heaviest sub-metatrees; replicas split target nodes (data parallelism).
    replicated = False
    if num_partitions > len(subs):
        replicated = True
        subs = sorted(subs, key=lambda s: -s.weight)
        i = 0
        while len(subs) < num_partitions:
            clone = SubMetatree(
                root_child=subs[i % len(subs)].root_child,
                root_type=root,
                weight=subs[i % len(subs)].weight,
            )
            subs.append(clone)
            i += 1

    # Step 3: LPT greedy assignment (sort desc, place on least-loaded)
    order = sorted(range(len(subs)), key=lambda i: -subs[i].weight)
    parts: List[List[SubMetatree]] = [[] for _ in range(num_partitions)]
    sums = np.zeros(num_partitions, dtype=np.int64)
    for i in order:
        j = int(np.argmin(sums))
        parts[j].append(subs[i])
        sums[j] += subs[i].weight

    # Step 4: dedup relations per partition + materialize
    partitions: List[MetaPartition] = []
    rel_seen: Dict[Tuple[Relation, ...], int] = {}
    for idx, plist in enumerate(parts):
        rels: List[Relation] = []
        for s in plist:
            rels += s.relations()
        rels = list(dict.fromkeys(rels))  # dedup (line 19)
        key = tuple(sorted(rels, key=str))
        group = rel_seen.setdefault(key, idx)
        partitions.append(
            MetaPartition(
                index=idx,
                sub_metatrees=plist,
                relations=rels,
                weight=int(sums[idx]),
                replica_group=group,
            )
        )
    elapsed = time.perf_counter() - t0  # algorithm time, excl. materialization

    if materialize:
        for p in partitions:
            p.graph = graph.restrict(p.relations, name=f"{graph.name}:part{p.index}")

    return MetaPartitioning(
        partitions=partitions,
        metatree=tree,
        target_type=root,
        elapsed_s=elapsed,
        replicated=replicated,
    )


# --------------------------------------------------------------------------
# Edge-cut baselines + boundary/cross-edge analysis (vanilla model, Prop 2/3)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class EdgeCutPartition:
    """Node-to-partition assignment per node type (edge-cut partitioning as in
    DGL-Random / GraphLearn; edges live with their dst node)."""

    assignment: Dict[str, np.ndarray]  # ntype -> [num_nodes[t]] partition id
    num_partitions: int
    elapsed_s: float = 0.0
    method: str = "random"

    def part_of(self, ntype: str, nids: np.ndarray) -> np.ndarray:
        return self.assignment[ntype][nids]


def random_edge_cut(
    graph: HetGraph, num_partitions: int, seed: int = 0
) -> EdgeCutPartition:
    """DGL-Random / GraphLearn analog: uniform random node assignment."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    assignment = {
        t: rng.integers(0, num_partitions, n).astype(np.int32)
        for t, n in graph.num_nodes.items()
    }
    return EdgeCutPartition(
        assignment, num_partitions, time.perf_counter() - t0, "random"
    )


def greedy_edge_cut(
    graph: HetGraph, num_partitions: int, seed: int = 0
) -> EdgeCutPartition:
    """Greedy LDG-style streaming edge-cut (METIS stand-in — METIS is not
    available offline; see DESIGN.md §7).  Nodes are streamed in degree order
    and placed on the partition holding most of their already-placed neighbors,
    penalized by load."""
    t0 = time.perf_counter()
    # flatten to a homogeneous view with global ids (as DGL does before METIS)
    offsets: Dict[str, int] = {}
    total = 0
    for t in graph.node_types:
        offsets[t] = total
        total += graph.num_nodes[t]
    # adjacency in global id space (undirected union over relations)
    srcs, dsts = [], []
    for rel, csr in graph.relations.items():
        s, d = csr.edges()
        srcs.append(s + offsets[rel.src])
        dsts.append(d + offsets[rel.dst])
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    und_src = np.concatenate([src, dst])
    und_dst = np.concatenate([dst, src])
    order = np.argsort(und_src, kind="stable")
    und_src, und_dst = und_src[order], und_dst[order]
    indptr = np.zeros(total + 1, dtype=np.int64)
    np.cumsum(np.bincount(und_src, minlength=total), out=indptr[1:])

    assign = np.full(total, -1, dtype=np.int32)
    load = np.zeros(num_partitions, dtype=np.int64)
    cap = max(1, total // num_partitions + 1)
    rng = np.random.default_rng(seed)
    visit = rng.permutation(total)
    for v in visit:
        nbrs = und_dst[indptr[v]:indptr[v + 1]]
        placed = assign[nbrs]
        score = np.bincount(placed[placed >= 0], minlength=num_partitions).astype(
            np.float64
        )
        score *= 1.0 - load / cap  # LDG load penalty
        assign[v] = int(np.argmax(score)) if score.any() else int(np.argmin(load))
        load[assign[v]] += 1
    assignment = {
        t: assign[offsets[t]: offsets[t] + graph.num_nodes[t]]
        for t in graph.node_types
    }
    return EdgeCutPartition(
        assignment, num_partitions, time.perf_counter() - t0, "greedy-ldg"
    )


def cross_edges(graph: HetGraph, cut: EdgeCutPartition) -> int:
    """E(G_i, G_j) summed over all partition pairs (vanilla comm ∝ this)."""
    n = 0
    for rel, csr in graph.relations.items():
        s, d = csr.edges()
        n += int(
            (cut.part_of(rel.src, s) != cut.part_of(rel.dst, d)).sum()
        )
    return n


def boundary_nodes(graph: HetGraph, cut: EdgeCutPartition) -> List[int]:
    """|B(G_i)| per partition: nodes with at least one neighbor in another
    partition (Prop 2/3)."""
    # boundary[t] = set of node ids of type t that touch a cross edge
    flags = {
        t: np.zeros(n, dtype=bool) for t, n in graph.num_nodes.items()
    }
    for rel, csr in graph.relations.items():
        s, d = csr.edges()
        cross = cut.part_of(rel.src, s) != cut.part_of(rel.dst, d)
        flags[rel.src][s[cross]] = True
        flags[rel.dst][d[cross]] = True
    counts = [0] * cut.num_partitions
    for t, fl in flags.items():
        ids = np.nonzero(fl)[0]
        parts = cut.part_of(t, ids)
        for p, c in zip(*np.unique(parts, return_counts=True)):
            counts[int(p)] += int(c)
    return counts


# --------------------------------------------------------------------------
# Hierarchical composition (DistDGL-style two-level scale-out, DESIGN.md §13)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class HierarchicalPartition:
    """Two-level partition hierarchy for multi-process scale-out.

    Composes the paper's schema-level meta-partitioning (level 0, across
    *trainer groups*) with greedy edge-cut partitioning (level 1, *inside*
    each group) — the DistDGL hybrid billion-scale layout (PAPERS.md,
    arxiv 2112.15345) applied to Heta:

    * **Level 0 — groups.**  ``meta_partition(graph, num_groups)`` assigns
      whole relation types to groups.  Each group holds complete
      mono-relation subgraphs plus all target nodes (paper §5 Step 2), so
      the only *inter-group* traffic is the RAF partial-aggregate exchange
      at target nodes — Θ(|B|·hidden) per batch, independent of the
      relation module (Prop 2).
    * **Level 1 — sub-partitions.**  Inside each group,
      ``greedy_edge_cut`` over the group's materialized subgraph splits
      nodes into ``trainers_per_group`` sub-partitions.  Trainers in a
      group run data-parallel over a *shared* store (shm or mmap), so
      *intra-group* traffic is the gradient allreduce only — edge-cut
      locality governs DRAM/page-cache reads, never network bytes.

    **Ownership invariant** (tested): every node of every type is owned by
    exactly one ``(group, sub_partition)`` pair.

    * Target-type nodes are *replicated* across groups at level 0; their
      unique owner group is the deterministic stripe ``nid % num_groups``
      (replicas split target nodes with data parallelism, paper §5
      discussion), and the owner sub-partition is that group's edge-cut
      assignment.
    * Every other type is owned by the first group whose schema contains
      it (deeper duplicates are replication, not ownership — same rule as
      :meth:`MetaPartitioning.relation_to_partition`); the sub-partition
      is that group's edge-cut assignment.
    * Types outside every group's schema (unreachable within
      ``num_layers`` of the metatree) fall back to group 0 with the
      stripe ``nid % trainers_per_group``.

    Global trainer ranks are row-major: ``rank = group * trainers_per_group
    + sub``.  Per-level byte accounting for this layout lives in
    :func:`repro.core.comm.hierarchical_comm_bytes` and is surfaced through
    ``Heta.comm_report``.
    """

    meta: MetaPartitioning
    cuts: List[EdgeCutPartition]  # one per group, over the group's subgraph
    group_of: Dict[str, np.ndarray]  # ntype -> [n] owning group id (int32)
    sub_of: Dict[str, np.ndarray]  # ntype -> [n] sub-partition in the group
    num_groups: int
    trainers_per_group: int
    elapsed_s: float = 0.0

    @property
    def num_trainers(self) -> int:
        return self.num_groups * self.trainers_per_group

    def owner(self, ntype: str, nids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(group, sub-partition) owning each node — exactly one per node."""
        nids = np.asarray(nids)
        return self.group_of[ntype][nids], self.sub_of[ntype][nids]

    def rank_of(self, ntype: str, nids: np.ndarray) -> np.ndarray:
        """Global trainer rank owning each node (row-major group × sub)."""
        g, s = self.owner(ntype, nids)
        return g.astype(np.int64) * self.trainers_per_group + s.astype(np.int64)

    def trainer_train_nodes(self, graph: HetGraph, rank: int) -> np.ndarray:
        """The disjoint slice of ``graph.train_nodes`` trainer ``rank`` owns.

        Concatenating over all ranks is a permutation of ``train_nodes``
        (every seed trained exactly once per epoch, no replication)."""
        if not 0 <= rank < self.num_trainers:
            raise ValueError(
                f"rank {rank} out of range for {self.num_trainers} trainers"
            )
        seeds = np.asarray(graph.train_nodes)
        return seeds[self.rank_of(graph.target_type, seeds) == rank]

    def validate_ownership(self, graph: HetGraph) -> None:
        """Assert the ownership invariant over every node of every type."""
        for t, n in graph.num_nodes.items():
            g, s = self.group_of.get(t), self.sub_of.get(t)
            if g is None or s is None or len(g) != n or len(s) != n:
                raise AssertionError(f"ownership missing/short for type {t!r}")
            if not ((g >= 0).all() and (g < self.num_groups).all()):
                raise AssertionError(f"group out of range for type {t!r}")
            if not ((s >= 0).all() and (s < self.trainers_per_group).all()):
                raise AssertionError(f"sub-partition out of range for {t!r}")

    def summary(self) -> str:
        lines = [
            f"hierarchical partition: {self.num_groups} group(s) x "
            f"{self.trainers_per_group} trainer(s) = {self.num_trainers} "
            f"ranks, {self.elapsed_s * 1e3:.2f} ms"
        ]
        for p in self.meta.partitions:
            cut = self.cuts[p.index]
            owned = sum(
                int((self.group_of[t] == p.index).sum())
                for t in self.group_of
            )
            lines.append(
                f"  G{p.index}: {len(p.relations)} relations, "
                f"{owned:,} owned nodes, edge-cut {cut.method} "
                f"({cut.elapsed_s * 1e3:.1f} ms)"
            )
        return "\n".join(lines)


def hierarchical_partition(
    graph: HetGraph,
    num_groups: int,
    trainers_per_group: int,
    num_layers: int = 2,
    metapaths: Optional[Sequence[Sequence[Relation]]] = None,
    seed: int = 0,
    edge_cut: str = "greedy",
) -> HierarchicalPartition:
    """Build the two-level hierarchy (see :class:`HierarchicalPartition`).

    Level 0 is Algorithm 2 verbatim (``meta_partition`` with
    ``materialize=True`` — level-1 cuts need the group subgraphs); level 1
    runs ``greedy_edge_cut`` (or ``random_edge_cut`` with
    ``edge_cut="random"``) per group with a per-group derived seed so group
    cuts are independent but deterministic in ``seed``.
    """
    if num_groups < 1 or trainers_per_group < 1:
        raise ValueError(
            f"num_groups and trainers_per_group must be >= 1, got "
            f"{num_groups} x {trainers_per_group}"
        )
    cut_fn = {"greedy": greedy_edge_cut, "random": random_edge_cut}.get(edge_cut)
    if cut_fn is None:
        raise ValueError(f"edge_cut must be 'greedy' or 'random', got {edge_cut!r}")
    t0 = time.perf_counter()
    meta = meta_partition(
        graph, num_groups, num_layers=num_layers, metapaths=metapaths,
        materialize=True,
    )
    cuts = [
        cut_fn(p.graph, trainers_per_group, seed=seed + 1000 * p.index)
        for p in meta.partitions
    ]

    # level-0 ownership: first group whose schema holds the type; target
    # nodes stripe across groups (replicas split seeds, paper §5).
    type_owner: Dict[str, int] = {}
    for p in meta.partitions:
        for t in p.node_types:
            type_owner.setdefault(t, p.index)
    target = graph.target_type
    G, S = len(meta.partitions), trainers_per_group
    group_of: Dict[str, np.ndarray] = {}
    sub_of: Dict[str, np.ndarray] = {}
    for t, n in graph.num_nodes.items():
        ids = np.arange(n, dtype=np.int64)
        if t == target:
            group_of[t] = (ids % G).astype(np.int32)
            sub = np.empty(n, dtype=np.int32)
            for g in range(G):
                mine = group_of[t] == g
                sub[mine] = cuts[g].part_of(t, ids[mine])
            sub_of[t] = sub
        elif t in type_owner:
            g = type_owner[t]
            group_of[t] = np.full(n, g, dtype=np.int32)
            sub_of[t] = cuts[g].part_of(t, ids).astype(np.int32)
        else:  # outside the metatree: deterministic fallback stripes
            group_of[t] = np.zeros(n, dtype=np.int32)
            sub_of[t] = (ids % S).astype(np.int32)

    return HierarchicalPartition(
        meta=meta,
        cuts=cuts,
        group_of=group_of,
        sub_of=sub_of,
        num_groups=G,
        trainers_per_group=S,
        elapsed_s=time.perf_counter() - t0,
    )
