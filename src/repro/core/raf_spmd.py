"""SPMD RAF executor — relations laid along the ``"model"`` mesh axis.

This is the production realization of paper Alg. 1 on a TPU mesh:

  * the metatree's branches are grouped by owning meta-partition and the
    branch axis is sharded over ``"model"`` — each model-shard holds its
    partition's relation parameters, sampled blocks and feature slices;
  * relation-specific aggregation + within-partition cross-relation combines
    are shard-local tensor ops (``segment_sum`` over the *local* branch axis);
  * the only model-axis collective is one ``psum`` of the root partials
    [batch, hidden] per step — Θ(|B|·hidden), the paper's Prop-2 bound —
    plus the loss scalar;
  * the batch axis is sharded over (``"pod"``, ``"data"``) — the paper's
    intra-machine data parallelism.

A ``local_combine=False`` mode emulates *naive* relation placement (branches
scattered without metatree awareness): inner-level partial aggregations must
then cross the model axis as full [R, N, hidden] psums — the paper's 8.0 MB
case, used as the ablation baseline in benchmarks and §Perf.

Everything is static-shaped: branch counts are padded per shard, dummy slots
carry zeroed parameters and all-False masks.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map_nocheck
from repro.core.hgnn import HGNNConfig, Params, masked_mean, masked_softmax
from repro.core.raf import BranchAssignment
from repro.graph.sampler import SampledBatch, SampleSpec

__all__ = [
    "StackedPlan",
    "build_plan",
    "stack_params_from_dict",
    "stack_batch",
    "raf_spmd_forward",
    "make_loss_fn",
    "make_train_step",
    "shard_map_nocheck",
]


# --------------------------------------------------------------------------
# static plan
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LevelPlan:
    depth: int
    layer: int
    fanout: int
    d_in: int  # aggregation input dim (d_pad at the leaf layer, hidden above)
    slot_branch: np.ndarray  # [P, rb] original branch index, -1 for dummies
    parent_local: np.ndarray  # [P, rb] parent slot within the shard, level d-1
    parent_global: np.ndarray  # [P, rb] parent global slot (naive mode)
    branch_u: np.ndarray  # [P, rb] index into the shard's layer-l param stack
    valid: np.ndarray  # [P, rb] bool

    @property
    def rb(self) -> int:
        return self.slot_branch.shape[1]


@dataclasses.dataclass
class StackedPlan:
    spec: SampleSpec
    cfg: HGNNConfig
    num_shards: int
    d_pad: int
    levels: List[LevelPlan]
    # per layer: list of (relation_key@layer) per shard slot — [P][U_l]
    layer_params: Dict[int, List[List[str]]]
    src_types: List[List[str]]  # per level: src type per original branch
    dst_types: List[List[str]]  # per level: dst type per original branch

    def u_of(self, layer: int) -> int:
        return max(len(names) for names in self.layer_params[layer])


def build_plan(
    spec: SampleSpec,
    assignment: BranchAssignment,
    cfg: HGNNConfig,
    feat_dims: Dict[str, int],
) -> StackedPlan:
    if cfg.model not in ("rgcn", "rgat"):
        raise NotImplementedError(
            "SPMD RAF executor supports rgcn/rgat; HGT uses the simulated "
            "executor (per-node-type parameter structure; see DESIGN.md)"
        )
    Pn = assignment.num_partitions
    k = spec.num_layers
    dims = lambda t: feat_dims.get(t, cfg.learnable_dim)
    all_types = set([spec.target_type])
    for lv in spec.levels:
        for b in lv:
            all_types.add(b.rel.src)
    d_pad = max(dims(t) for t in all_types)

    # paper-faithful bookkeeping of src/dst types per branch (feature gathers)
    src_types, dst_types = [], []
    parents = [spec.target_type]
    for lv in spec.levels:
        src_types.append([b.rel.src for b in lv])
        dst_types.append([parents[b.parent] for b in lv])
        parents = [b.rel.src for b in lv]

    # group branches by owner, pad to uniform per-shard counts
    slot_of: List[Dict[int, Tuple[int, int]]] = []  # per level: branch -> (p, slot)
    level_plans: List[LevelPlan] = []
    layer_params: Dict[int, List[List[str]]] = {}
    for d in range(1, k + 1):
        layer = k - d + 1
        owners = assignment.owner[d - 1]
        by_p: List[List[int]] = [[] for _ in range(Pn)]
        for b, o in enumerate(owners):
            by_p[int(o)].append(b)
        rb = max(1, max(len(x) for x in by_p))
        slot_branch = np.full((Pn, rb), -1, dtype=np.int64)
        valid = np.zeros((Pn, rb), dtype=bool)
        smap: Dict[int, Tuple[int, int]] = {}
        for p in range(Pn):
            for s, b in enumerate(by_p[p]):
                slot_branch[p, s] = b
                valid[p, s] = True
                smap[b] = (p, s)
        slot_of.append(smap)

        # per-shard unique (rel@layer) param list
        names = layer_params.setdefault(layer, [[] for _ in range(Pn)])
        branch_u = np.zeros((Pn, rb), dtype=np.int64)
        for p in range(Pn):
            for s, b in enumerate(by_p[p]):
                nm = f"{spec.levels[d - 1][b].rel.key}@{layer}"
                if nm not in names[p]:
                    names[p].append(nm)
                branch_u[p, s] = names[p].index(nm)

        # parent mapping
        parent_local = np.zeros((Pn, rb), dtype=np.int64)
        parent_global = np.zeros((Pn, rb), dtype=np.int64)
        if d > 1:
            prev = level_plans[-1]
            for p in range(Pn):
                for s in range(rb):
                    b = slot_branch[p, s]
                    if b < 0:
                        continue
                    pb = spec.levels[d - 1][b].parent
                    pp, ps = slot_of[d - 2][pb]
                    parent_global[p, s] = pp * prev.rb + ps
                    parent_local[p, s] = ps
                    if pp != p and assignment.meta_local:
                        raise AssertionError("meta-local assignment violated")
        level_plans.append(
            LevelPlan(
                depth=d,
                layer=layer,
                fanout=spec.fanouts[d - 1],
                d_in=d_pad if d == k else cfg.hidden,
                slot_branch=slot_branch,
                parent_local=parent_local,
                parent_global=parent_global,
                branch_u=branch_u,
                valid=valid,
            )
        )
    return StackedPlan(
        spec=spec,
        cfg=cfg,
        num_shards=Pn,
        d_pad=d_pad,
        levels=level_plans,
        layer_params=layer_params,
        src_types=src_types,
        dst_types=dst_types,
    )


# --------------------------------------------------------------------------
# parameter stacking
# --------------------------------------------------------------------------


def _pad_rows(w: np.ndarray, rows: int) -> np.ndarray:
    out = np.zeros((rows,) + w.shape[1:], dtype=w.dtype)
    out[: w.shape[0]] = w
    return out


def stack_params_from_dict(plan: StackedPlan, params: Params) -> Dict:
    """Pack dict-form parameters (``init_hgnn_params``) into per-layer stacks
    [P, U_l, ...] with input dims padded to ``d_pad`` at the leaf layer.
    Padding rows are zero, so padded feature slots contribute nothing and the
    stacked forward is bit-equivalent to the dict forward."""
    cfg = plan.cfg
    k = plan.spec.num_layers
    stacks: Dict = {}
    for layer, names_per_p in plan.layer_params.items():
        U = plan.u_of(layer)
        d_in = plan.d_pad if layer == 1 else cfg.hidden
        get = lambda nm: jax.tree.map(np.asarray, params["rel"][nm])
        w = np.zeros((plan.num_shards, U, d_in, cfg.hidden), np.float32)
        b = np.zeros((plan.num_shards, U, cfg.hidden), np.float32)
        extra = {}
        if cfg.model == "rgat":
            extra = {
                "w_dst": np.zeros((plan.num_shards, U, plan.d_pad, cfg.hidden), np.float32),
                "a_src": np.zeros((plan.num_shards, U, cfg.num_heads, cfg.head_dim), np.float32),
                "a_dst": np.zeros((plan.num_shards, U, cfg.num_heads, cfg.head_dim), np.float32),
            }
        for p, names in enumerate(names_per_p):
            for u, nm in enumerate(names):
                pr = get(nm)
                w[p, u] = _pad_rows(pr["w"], d_in)
                b[p, u] = pr["b"]
                if cfg.model == "rgat":
                    extra["w_dst"][p, u] = _pad_rows(pr["w_dst"], plan.d_pad)
                    extra["a_src"][p, u] = pr["a_src"]
                    extra["a_dst"][p, u] = pr["a_dst"]
        stacks[f"layer{layer}"] = {"w": jnp.asarray(w), "b": jnp.asarray(b),
                                   **{k2: jnp.asarray(v) for k2, v in extra.items()}}
    # copy (not alias) the head: the train step donates its inputs, and an
    # aliased caller-owned array would be deleted out from under the caller
    stacks["head"] = jax.tree.map(lambda a: jnp.array(a, copy=True), params["head"])
    return stacks


# --------------------------------------------------------------------------
# batch stacking (host-side feature gathers)
# --------------------------------------------------------------------------


def stack_batch(
    plan: StackedPlan,
    batch: SampledBatch,
    tables: Dict[str, np.ndarray],
) -> Dict:
    """Assemble the stacked device arrays for one sampled batch.

    ``tables`` must contain a feature table for every node type (learnable
    tables included — the embed engine supplies them).  Feature gathers for a
    shard's branches touch only node types present in its partition, matching
    Heta's locality argument; we materialize all shards' slices because the
    test/driver processes run every shard on one host.
    """
    spec, k = plan.spec, plan.spec.num_layers
    B = batch.batch_size
    dp = plan.d_pad

    def padded_gather(t: str, nids: np.ndarray) -> np.ndarray:
        tab = tables[t]
        out = np.zeros((len(nids), dp), np.float32)
        out[:, : tab.shape[1]] = tab[nids]
        return out

    arrays: Dict = {"seeds": jnp.asarray(batch.seeds), "labels": jnp.asarray(batch.labels)}
    n_prev = B
    for d in range(1, k + 1):
        lp = plan.levels[d - 1]
        lv = batch.levels[d - 1]
        n_d = lv.nids.shape[1]
        mask = np.zeros((plan.num_shards, lp.rb, n_d), bool)
        qfeat = np.zeros((plan.num_shards, lp.rb, n_prev, dp), np.float32)
        hfeat = (
            np.zeros((plan.num_shards, lp.rb, n_d, dp), np.float32) if d == k else None
        )
        for p in range(plan.num_shards):
            for s in range(lp.rb):
                b = lp.slot_branch[p, s]
                if b < 0:
                    continue
                mask[p, s] = lv.mask[b]
                dst_t = plan.dst_types[d - 1][b]
                parent_nids = (
                    batch.seeds if d == 1 else batch.levels[d - 2].nids[spec.levels[d - 1][b].parent]
                )
                qfeat[p, s] = padded_gather(dst_t, parent_nids)
                if d == k:
                    hfeat[p, s] = padded_gather(plan.src_types[d - 1][b], lv.nids[b])
        arrays[f"mask{d}"] = jnp.asarray(mask.reshape(plan.num_shards * lp.rb, n_d))
        arrays[f"qfeat{d}"] = jnp.asarray(qfeat.reshape(plan.num_shards * lp.rb, n_prev, dp))
        if d == k:
            arrays[f"hfeat{d}"] = jnp.asarray(hfeat.reshape(plan.num_shards * lp.rb, n_d, dp))
        n_prev = n_d
    return arrays


# --------------------------------------------------------------------------
# the sharded forward
# --------------------------------------------------------------------------


def _agg_level(cfg: HGNNConfig, lp: LevelPlan, stacks, h_in, qfeat, mask, shard_idx):
    """Relation-specific aggregation for one level on one shard.

    h_in  [rb, n_d, d_in] -> out [rb, n_prev, hidden]
    """
    layer = stacks[f"layer{lp.layer}"]
    u = jnp.asarray(lp.branch_u)[shard_idx]  # [rb]
    valid = jnp.asarray(lp.valid)[shard_idx]  # [rb]
    w = layer["w"][0][u]  # [rb, d_in, H]
    b = layer["b"][0][u]  # [rb, H]
    rb, n_d, d_in = h_in.shape
    f = lp.fanout
    n_prev = n_d // f
    hg = h_in.reshape(rb, n_prev, f, d_in)
    mg = mask.reshape(rb, n_prev, f)
    if cfg.model == "rgcn":
        agg = masked_mean(hg, mg)  # [rb, n_prev, d_in]
        out = jnp.einsum("rnd,rdh->rnh", agg, w) + b[:, None, :]
    else:  # rgat
        nh, dh = cfg.num_heads, cfg.head_dim
        w_dst = layer["w_dst"][0][u]
        a_src = layer["a_src"][0][u]
        a_dst = layer["a_dst"][0][u]
        z = jnp.einsum("rnfd,rdh->rnfh", hg, w).reshape(rb, n_prev, f, nh, dh)
        qz = jnp.einsum("rnd,rdh->rnh", qfeat, w_dst).reshape(rb, n_prev, nh, dh)
        e = jnp.einsum("rnfhd,rhd->rnfh", z, a_src) + jnp.einsum(
            "rnhd,rhd->rnh", qz, a_dst
        )[:, :, None, :]
        e = jax.nn.leaky_relu(e, negative_slope=0.2)
        alpha = masked_softmax(e, mg[..., None], axis=2)
        out = jnp.einsum("rnfh,rnfhd->rnhd", alpha, z).reshape(rb, n_prev, nh * dh)
        out = out + b[:, None, :]
    return out * valid[:, None, None].astype(out.dtype)


def raf_spmd_forward(
    plan: StackedPlan,
    stacks: Dict,
    arrays: Dict,
    model_axis: str = "model",
    local_combine: bool = True,
):
    """Per-shard body (runs inside shard_map).  Returns root embedding
    [B_local, hidden] (replicated over the model axis after the psum)."""
    cfg, k = plan.cfg, plan.spec.num_layers
    shard_idx = jax.lax.axis_index(model_axis)
    child: Optional[jnp.ndarray] = None
    for d in range(k, 0, -1):
        lp = plan.levels[d - 1]
        if d == k:
            h_in = arrays[f"hfeat{d}"]
        else:
            h_in = jax.nn.relu(child)
        out = _agg_level(
            cfg, lp, stacks, h_in, arrays[f"qfeat{d}"], arrays[f"mask{d}"], shard_idx
        )
        if d == 1:
            partial = jnp.sum(out, axis=0)  # shard's partial aggregation [B, H]
            root = jax.lax.psum(partial, model_axis)  # RAF exchange (Alg.1 l.6)
        else:
            prev_rb = plan.levels[d - 2].rb
            if local_combine:
                seg = jnp.asarray(lp.parent_local)[shard_idx]
                child = jax.ops.segment_sum(out, seg, num_segments=prev_rb)
            else:
                # naive placement: parents may be remote -> full inner-level
                # exchange of [R_{d-1}, N, H] partials (the ablation case)
                seg = jnp.asarray(lp.parent_global)[shard_idx]
                full = jax.ops.segment_sum(
                    out, seg, num_segments=prev_rb * plan.num_shards
                )
                full = jax.lax.psum(full, model_axis)
                child = jax.lax.dynamic_slice_in_dim(
                    full, shard_idx * prev_rb, prev_rb, axis=0
                )
    return root


# --------------------------------------------------------------------------
# jitted train step
# --------------------------------------------------------------------------


def _array_specs(plan: StackedPlan, data_axes, model_axis):
    k = plan.spec.num_layers
    specs = {"seeds": P(data_axes), "labels": P(data_axes)}
    for d in range(1, k + 1):
        specs[f"mask{d}"] = P(model_axis, data_axes)
        specs[f"qfeat{d}"] = P(model_axis, data_axes, None)
        if d == k:
            specs[f"hfeat{d}"] = P(model_axis, data_axes, None)
    return specs


def _stack_specs(plan: StackedPlan):
    specs = {}
    for layer in plan.layer_params:
        entry = {"w": P("model", None, None, None), "b": P("model", None, None)}
        if plan.cfg.model == "rgat":
            entry.update(
                w_dst=P("model", None, None, None),
                a_src=P("model", None, None, None),
                a_dst=P("model", None, None, None),
            )
        specs[f"layer{layer}"] = entry
    specs["head"] = {"w": P(None, None), "b": P(None)}
    return specs


def _build_loss_fn(
    plan: StackedPlan,
    mesh: Mesh,
    model_axis: str,
    data_axes: Tuple[str, ...],
    local_combine: bool,
):
    """Shared closure of the train and eval steps: ``(loss_fn, split_arrays)``
    where ``loss_fn(stacks, feats, rest)`` is the scalar SPMD loss."""
    da = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    arr_specs = _array_specs(plan, da, model_axis)
    stack_specs = _stack_specs(plan)
    rel_specs = {k2: v for k2, v in stack_specs.items() if k2 != "head"}

    def split_arrays(arrays):
        feats = {k2: v for k2, v in arrays.items() if "feat" in k2}
        rest = {k2: v for k2, v in arrays.items() if "feat" not in k2}
        return feats, rest

    def root_fn(rel_stacks, feats, rest):
        def body(stacks_s, feats_s, rest_s):
            return raf_spmd_forward(
                plan, stacks_s, {**feats_s, **rest_s}, model_axis, local_combine
            )

        return shard_map_nocheck(
            body,
            mesh=mesh,
            in_specs=(
                rel_specs,
                {k2: arr_specs[k2] for k2 in feats},
                {k2: arr_specs[k2] for k2 in rest},
            ),
            out_specs=P(da, None),
        )(rel_stacks, feats, rest)

    def loss_fn(stacks, feats, rest):
        rel_stacks = {k2: v for k2, v in stacks.items() if k2 != "head"}
        root = root_fn(rel_stacks, feats, rest)
        h = jax.nn.relu(root)
        logits = h @ stacks["head"]["w"] + stacks["head"]["b"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, rest["labels"][:, None], axis=-1)
        return jnp.mean(nll)

    return loss_fn, split_arrays


def make_loss_fn(
    plan: StackedPlan,
    mesh: Mesh,
    model_axis: str = "model",
    data_axes=("data",),
    local_combine: bool = True,
):
    """Jitted evaluation-only loss: ``loss(stacks, arrays) -> scalar``."""
    loss_fn, split_arrays = _build_loss_fn(plan, mesh, model_axis, data_axes, local_combine)

    @jax.jit
    def eval_loss(stacks, arrays):
        feats, rest = split_arrays(arrays)
        return loss_fn(stacks, feats, rest)

    return eval_loss


def make_train_step(
    plan: StackedPlan,
    mesh: Mesh,
    adam_cfg,
    model_axis: str = "model",
    data_axes=("data",),
    local_combine: bool = True,
    learn_feats: bool = False,
):
    """Build the jitted SPMD RAF train step.

    ``step(stacks, opt_state, arrays) -> (stacks, opt_state, loss[, feat_grads])``

    The shard_map body computes the root embedding (ending in the RAF psum);
    the classifier head + loss run outside under GSPMD, so gradients of the
    replicated head are exact.  With ``learn_feats=True`` the step also
    returns gradients w.r.t. the gathered feature arrays (``qfeat*``/``hfeat*``)
    for the embed engine's sparse row updates.
    """
    from repro.optim.adam import adam_update

    loss_fn, split_arrays = _build_loss_fn(plan, mesh, model_axis, data_axes, local_combine)

    if not learn_feats:
        grad_fn = jax.value_and_grad(loss_fn)

        @partial(jax.jit, donate_argnums=(0, 1))
        def step(stacks, opt_state, arrays):
            feats, rest = split_arrays(arrays)
            loss, grads = grad_fn(stacks, feats, rest)
            stacks, opt_state = adam_update(adam_cfg, stacks, grads, opt_state)
            return stacks, opt_state, loss

        return step

    grad_fn2 = jax.value_and_grad(loss_fn, argnums=(0, 1))

    @partial(jax.jit, donate_argnums=(0, 1))
    def step_feats(stacks, opt_state, arrays):
        feats, rest = split_arrays(arrays)
        loss, (gs, gf) = grad_fn2(stacks, feats, rest)
        stacks, opt_state = adam_update(adam_cfg, stacks, gs, opt_state)
        return stacks, opt_state, loss, gf

    return step_feats


def shard_arrays(plan: StackedPlan, mesh: Mesh, arrays: Dict, data_axes=("data",),
                 model_axis: str = "model") -> Dict:
    """Device-put stacked batch arrays with their production shardings."""
    da = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    specs = _array_specs(plan, da, model_axis)
    return {
        k2: jax.device_put(v, NamedSharding(mesh, specs[k2])) for k2, v in arrays.items()
    }


def shard_stacks(plan: StackedPlan, mesh: Mesh, stacks: Dict) -> Dict:
    specs = _stack_specs(plan)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        stacks,
        specs,
        is_leaf=lambda x: isinstance(x, jnp.ndarray),
    )
