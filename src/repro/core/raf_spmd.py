"""SPMD RAF executor — relations laid along the ``"model"`` mesh axis.

This is the production realization of paper Alg. 1 on a TPU mesh:

  * the metatree's branches are grouped by owning meta-partition and the
    branch axis is sharded over ``"model"`` — each model-shard holds its
    partition's relation parameters, sampled blocks and feature slices;
  * relation-specific aggregation + within-partition cross-relation combines
    are shard-local tensor ops (``segment_sum`` over the *local* branch axis);
  * the only model-axis collective is one ``psum`` of the root partials
    [batch, hidden] per step — Θ(|B|·hidden), the paper's Prop-2 bound —
    plus the loss scalar;
  * the batch axis is sharded over (``"pod"``, ``"data"``) — the paper's
    intra-machine data parallelism.

The stacking layer is **scope-driven** (relation-module IR, DESIGN.md §3):
for every parameter scope the model declares — per-(relation, layer),
per-(node-type, layer), per-(edge-type, layer) — the plan carries per-shard
unique storage-key lists, per-slot index arrays, and shared-slot groups.
``stack_params_from_dict`` packs each scope's parameters into ``[P, U, ...]``
slabs, the per-level aggregation gathers per-slot leaves and ``vmap``s the
module's *own* ``aggregate`` over the branch axis, and
:func:`sync_stack_grads` all-reduces gradients of parameters that appear in
more than one slot (HGT's per-node-type K/Q/V being the canonical case) so
shard-local copies follow the exact dict-mode optimizer trajectory.  All
registered models run here — there is no per-model branching.

A ``local_combine=False`` mode emulates *naive* relation placement (branches
scattered without metatree awareness): inner-level partial aggregations must
then cross the model axis as full [R, N, hidden] psums — the paper's 8.0 MB
case, used as the ablation baseline in benchmarks and §Perf.

Everything is static-shaped: branch counts are padded per shard, dummy slots
carry zeroed parameters and all-False masks.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map_nocheck
from repro.core.hgnn import HGNNConfig, Params, rel_context
from repro.core.raf import BranchAssignment
from repro.core.relmod import SCOPE_CONTAINER, storage_key
from repro.data.staging import StackRecipe, stack_batch_host
from repro.graph.sampler import SampledBatch, SampleSpec

__all__ = [
    "StackedPlan",
    "build_plan",
    "stack_params_from_dict",
    "stack_batch",
    "stack_recipe",
    "raf_spmd_forward",
    "sync_stack_grads",
    "make_loss_fn",
    "make_train_step",
    "make_grad_step",
    "make_apply_step",
    "shard_map_nocheck",
]


# --------------------------------------------------------------------------
# static plan
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LevelPlan:
    depth: int
    layer: int
    fanout: int
    d_in: int  # aggregation input dim (d_pad at the leaf layer, hidden above)
    slot_branch: np.ndarray  # [P, rb] original branch index, -1 for dummies
    parent_local: np.ndarray  # [P, rb] parent slot within the shard, level d-1
    parent_global: np.ndarray  # [P, rb] parent global slot (naive mode)
    # per scope the model declares: [P, rb] index into that scope's layer stack
    slot_u: Dict[str, np.ndarray]
    valid: np.ndarray  # [P, rb] bool

    @property
    def rb(self) -> int:
        return self.slot_branch.shape[1]


@dataclasses.dataclass
class StackedPlan:
    spec: SampleSpec
    cfg: HGNNConfig
    num_shards: int
    d_pad: int
    levels: List[LevelPlan]
    # (scope, layer) -> per-shard list of storage keys occupying stack slots
    scope_keys: Dict[Tuple[str, int], List[List[str]]]
    # (scope, layer) -> [P, U] global group id per slot (shared-param sync);
    # slots holding the same storage key share an id, unused slots get
    # singleton ids, so segment-summing gradients over groups is exact
    slot_groups: Dict[Tuple[str, int], np.ndarray]
    src_types: List[List[str]]  # per level: src type per original branch
    dst_types: List[List[str]]  # per level: dst type per original branch

    @property
    def module(self):
        return self.cfg.module

    @property
    def layers(self) -> List[int]:
        return sorted({layer for (_, layer) in self.scope_keys})

    def u_of(self, scope: str, layer: int) -> int:
        return max(1, max(len(row) for row in self.scope_keys[(scope, layer)]))

    def has_shared(self, scope: str, layer: int) -> bool:
        """Whether any storage key occupies more than one stack slot (then
        gradients need cross-slot summing to match the dict-mode trajectory)."""
        rows = self.scope_keys[(scope, layer)]
        keys = [nm for row in rows for nm in row]
        return len(keys) != len(set(keys))

    def layer_shape_ctx(self, layer: int):
        d_in = self.d_pad if layer == 1 else self.cfg.hidden
        return self.cfg.shape_ctx(d_src=d_in, d_dst=self.d_pad)


def build_plan(
    spec: SampleSpec,
    assignment: BranchAssignment,
    cfg: HGNNConfig,
    feat_dims: Dict[str, int],
) -> StackedPlan:
    module = cfg.module
    Pn = assignment.num_partitions
    k = spec.num_layers
    dims = lambda t: feat_dims.get(t, cfg.learnable_dim)
    all_types = set([spec.target_type])
    for lv in spec.levels:
        for b in lv:
            all_types.add(b.rel.src)
    d_pad = max(dims(t) for t in all_types)

    # paper-faithful bookkeeping of src/dst types per branch (feature gathers)
    src_types, dst_types = [], []
    parents = [spec.target_type]
    for lv in spec.levels:
        src_types.append([b.rel.src for b in lv])
        dst_types.append([parents[b.parent] for b in lv])
        parents = [b.rel.src for b in lv]

    # group branches by owner, pad to uniform per-shard counts
    slot_of: List[Dict[int, Tuple[int, int]]] = []  # per level: branch -> (p, slot)
    level_plans: List[LevelPlan] = []
    scope_keys: Dict[Tuple[str, int], List[List[str]]] = {}
    for d in range(1, k + 1):
        layer = k - d + 1
        owners = assignment.owner[d - 1]
        by_p: List[List[int]] = [[] for _ in range(Pn)]
        for b, o in enumerate(owners):
            by_p[int(o)].append(b)
        rb = max(1, max(len(x) for x in by_p))
        slot_branch = np.full((Pn, rb), -1, dtype=np.int64)
        valid = np.zeros((Pn, rb), dtype=bool)
        smap: Dict[int, Tuple[int, int]] = {}
        for p in range(Pn):
            for s, b in enumerate(by_p[p]):
                slot_branch[p, s] = b
                valid[p, s] = True
                smap[b] = (p, s)
        slot_of.append(smap)

        # per-scope, per-shard unique storage-key lists + per-slot indices
        slot_u: Dict[str, np.ndarray] = {}
        for scope in module.scopes:
            names = scope_keys.setdefault((scope, layer), [[] for _ in range(Pn)])
            u_arr = np.zeros((Pn, rb), dtype=np.int64)
            for p in range(Pn):
                for s, b in enumerate(by_p[p]):
                    bs = spec.levels[d - 1][b]
                    ctx = rel_context(bs.rel, dst_types[d - 1][b], layer)
                    nm = storage_key(scope, ctx)
                    if nm not in names[p]:
                        names[p].append(nm)
                    u_arr[p, s] = names[p].index(nm)
            slot_u[scope] = u_arr

        # parent mapping
        parent_local = np.zeros((Pn, rb), dtype=np.int64)
        parent_global = np.zeros((Pn, rb), dtype=np.int64)
        if d > 1:
            prev = level_plans[-1]
            for p in range(Pn):
                for s in range(rb):
                    b = slot_branch[p, s]
                    if b < 0:
                        continue
                    pb = spec.levels[d - 1][b].parent
                    pp, ps = slot_of[d - 2][pb]
                    parent_global[p, s] = pp * prev.rb + ps
                    parent_local[p, s] = ps
                    if pp != p and assignment.meta_local:
                        raise AssertionError("meta-local assignment violated")
        level_plans.append(
            LevelPlan(
                depth=d,
                layer=layer,
                fanout=spec.fanouts[d - 1],
                d_in=d_pad if d == k else cfg.hidden,
                slot_branch=slot_branch,
                parent_local=parent_local,
                parent_global=parent_global,
                slot_u=slot_u,
                valid=valid,
            )
        )

    # shared-slot groups: same storage key (any shard, any slot) -> same id;
    # unused padding slots get fresh singleton ids
    slot_groups: Dict[Tuple[str, int], np.ndarray] = {}
    for (scope, layer), names in scope_keys.items():
        U = max(1, max(len(row) for row in names))
        uniq = sorted({nm for row in names for nm in row})
        gid = {nm: i for i, nm in enumerate(uniq)}
        groups = np.zeros((Pn, U), dtype=np.int64)
        nxt = len(uniq)
        for p in range(Pn):
            for u in range(U):
                if u < len(names[p]):
                    groups[p, u] = gid[names[p][u]]
                else:
                    groups[p, u] = nxt
                    nxt += 1
        slot_groups[(scope, layer)] = groups

    return StackedPlan(
        spec=spec,
        cfg=cfg,
        num_shards=Pn,
        d_pad=d_pad,
        levels=level_plans,
        scope_keys=scope_keys,
        slot_groups=slot_groups,
        src_types=src_types,
        dst_types=dst_types,
    )


# --------------------------------------------------------------------------
# parameter stacking
# --------------------------------------------------------------------------


def stack_params_from_dict(plan: StackedPlan, params: Params) -> Dict:
    """Pack dict-form parameters (``init_hgnn_params``) into per-layer stacks
    ``{f"layer{l}": {leaf: [P, U_scope, ...]}}`` with input dims padded to
    the plan's common widths (``d_pad`` for feature-facing axes).  Padding
    regions are zero, so padded feature slots contribute nothing and the
    stacked forward is bit-equivalent to the dict forward."""
    module = plan.module
    stacks: Dict = {}
    for layer in plan.layers:
        sc = plan.layer_shape_ctx(layer)
        entry = {}
        for spec_ in module.specs:
            names = plan.scope_keys[(spec_.scope, layer)]
            U = plan.u_of(spec_.scope, layer)
            padded = tuple(spec_.shape(sc))
            arr = np.zeros((plan.num_shards, U) + padded, np.float32)
            container = params[SCOPE_CONTAINER[spec_.scope]]
            for p, row in enumerate(names):
                for u, nm in enumerate(row):
                    w = np.asarray(container[nm][spec_.name])
                    arr[(p, u) + tuple(slice(0, s) for s in w.shape)] = w
            entry[spec_.name] = jnp.asarray(arr)
        stacks[f"layer{layer}"] = entry
    # copy (not alias) the head: the train step donates its inputs, and an
    # aliased caller-owned array would be deleted out from under the caller
    stacks["head"] = jax.tree.map(lambda a: jnp.array(a, copy=True), params["head"])
    return stacks


# --------------------------------------------------------------------------
# batch stacking (host-side feature gathers)
# --------------------------------------------------------------------------


def stack_recipe(plan: StackedPlan) -> StackRecipe:
    """The plan's picklable host-staging recipe (memoized on the plan) —
    what a jax-free sampler worker needs to run :func:`stack_batch_host`
    (see ``repro.data.staging`` and DESIGN.md §9)."""
    recipe = getattr(plan, "_stack_recipe", None)
    if recipe is None:
        recipe = StackRecipe.from_plan(plan)
        plan._stack_recipe = recipe
    return recipe


def stack_batch(
    plan: StackedPlan,
    batch: SampledBatch,
    tables: Dict[str, np.ndarray],
) -> Dict:
    """Assemble the stacked device arrays for one sampled batch.

    ``tables`` must contain a feature table for every node type (learnable
    tables included — the embed engine supplies them).  Feature gathers for a
    shard's branches touch only node types present in its partition, matching
    Heta's locality argument; we materialize all shards' slices because the
    test/driver processes run every shard on one host.

    The host-side gather work is the shared numpy core
    :func:`repro.data.staging.stack_batch_host` — the multi-worker sampling
    pool runs the same function inside worker processes, so worker-staged
    and consumer-staged batches are bit-identical by construction.
    """
    host = stack_batch_host(stack_recipe(plan), batch, tables)
    return {k: jnp.asarray(v) for k, v in host.items()}


# --------------------------------------------------------------------------
# the sharded forward
# --------------------------------------------------------------------------


def _agg_level(plan: StackedPlan, lp: LevelPlan, stacks, h_in, qfeat, mask,
               shard_idx, kernels=None):
    """Relation-specific aggregation for one level on one shard.

    Dispatches through :func:`repro.kernels.stacked_relation_agg.stacked_agg`
    (DESIGN.md §8): on the fused path one Pallas call covers every branch
    slot, reading each slot's weight block straight from the ``[U, ...]``
    stack via scalar-prefetched scope indices; otherwise the historical
    oracle gathers per-slot leaves and ``vmap``s the module's ``aggregate``.
    The per-shard slot indices are *traced* (``shard_idx`` differs per
    shard), which is exactly what the scalar-prefetch indirection supports.

    h_in  [rb, n_d, d_in] -> out [rb, n_prev, hidden]
    """
    from repro.kernels.stacked_relation_agg import stacked_agg

    module = plan.module
    layer = stacks[f"layer{lp.layer}"]
    valid = jnp.asarray(lp.valid)[shard_idx]  # [rb]
    local = {s.name: layer[s.name][0] for s in module.specs}  # each [U, ...]
    slot_u = {
        scope: jnp.asarray(lp.slot_u[scope])[shard_idx] for scope in module.scopes
    }  # each [rb]
    rb, n_d, d_in = h_in.shape
    f = lp.fanout
    n_prev = n_d // f
    hg = h_in.reshape(rb, n_prev, f, d_in)
    mg = mask.reshape(rb, n_prev, f)
    out = stacked_agg(module, local, slot_u, hg, qfeat, mg, opts=kernels)
    return out * valid[:, None, None].astype(out.dtype)


def raf_spmd_forward(
    plan: StackedPlan,
    stacks: Dict,
    arrays: Dict,
    model_axis: str = "model",
    local_combine: bool = True,
    kernels=None,
):
    """Per-shard body (runs inside shard_map).  Returns root embedding
    [B_local, hidden] (replicated over the model axis after the psum).

    ``kernels`` (a ``KernelConfig``/``KernelOptions``-shaped object or
    ``None``) selects the aggregation backend per level — the fused stacked
    Pallas kernels by default on TPU, the vmap oracle elsewhere."""
    k = plan.spec.num_layers
    shard_idx = jax.lax.axis_index(model_axis)
    child: Optional[jnp.ndarray] = None
    for d in range(k, 0, -1):
        lp = plan.levels[d - 1]
        if d == k:
            h_in = arrays[f"hfeat{d}"]
        else:
            h_in = jax.nn.relu(child)
        out = _agg_level(
            plan, lp, stacks, h_in, arrays[f"qfeat{d}"], arrays[f"mask{d}"],
            shard_idx, kernels,
        )
        if d == 1:
            partial = jnp.sum(out, axis=0)  # shard's partial aggregation [B, H]
            root = jax.lax.psum(partial, model_axis)  # RAF exchange (Alg.1 l.6)
        else:
            prev_rb = plan.levels[d - 2].rb
            if local_combine:
                seg = jnp.asarray(lp.parent_local)[shard_idx]
                child = jax.ops.segment_sum(out, seg, num_segments=prev_rb)
            else:
                # naive placement: parents may be remote -> full inner-level
                # exchange of [R_{d-1}, N, H] partials (the ablation case)
                seg = jnp.asarray(lp.parent_global)[shard_idx]
                full = jax.ops.segment_sum(
                    out, seg, num_segments=prev_rb * plan.num_shards
                )
                full = jax.lax.psum(full, model_axis)
                child = jax.lax.dynamic_slice_in_dim(
                    full, shard_idx * prev_rb, prev_rb, axis=0
                )
    return root


# --------------------------------------------------------------------------
# shared-parameter gradient synchronization
# --------------------------------------------------------------------------


def sync_stack_grads(plan: StackedPlan, grads: Dict) -> Dict:
    """Sum gradients across stack slots holding the *same* parameter and
    broadcast the sum back to every copy.

    A storage key can occupy several slots — a node type feeding relations
    owned by different shards (HGT's K/Q/V), or one relation sampled into
    branches assigned to different partitions.  ``stack_params_from_dict``
    seeds all copies identically; summed (hence identical) gradients keep
    the per-copy Adam trajectories identical too, so the stacked run follows
    the dict-form run exactly — Prop 1 extends through training.  Under
    GSPMD the segment-sum over the ``[P·U]`` group axis lowers to the
    cross-shard collective this semantically is; scopes with no sharing are
    left untouched (no collective emitted).
    """
    scope_of = {s.name: s.scope for s in plan.module.specs}
    out = dict(grads)
    for layer in plan.layers:
        entry = dict(grads[f"layer{layer}"])
        for leaf, g in entry.items():
            scope = scope_of[leaf]
            if not plan.has_shared(scope, layer):
                continue
            groups = plan.slot_groups[(scope, layer)]
            seg = jnp.asarray(groups.reshape(-1))
            nseg = int(groups.max()) + 1
            flat = g.reshape((groups.size,) + g.shape[2:])
            summed = jax.ops.segment_sum(flat, seg, num_segments=nseg)
            entry[leaf] = summed[seg].reshape(g.shape)
        out[f"layer{layer}"] = entry
    return out


# --------------------------------------------------------------------------
# jitted train step
# --------------------------------------------------------------------------


def _array_specs(plan: StackedPlan, data_axes, model_axis):
    k = plan.spec.num_layers
    specs = {"seeds": P(data_axes), "labels": P(data_axes)}
    for d in range(1, k + 1):
        specs[f"mask{d}"] = P(model_axis, data_axes)
        specs[f"qfeat{d}"] = P(model_axis, data_axes, None)
        if d == k:
            specs[f"hfeat{d}"] = P(model_axis, data_axes, None)
    return specs


def _stack_specs(plan: StackedPlan):
    """Sharding specs for the parameter stacks: every leaf is sharded along
    the leading (shard) axis, replicated elsewhere — derived from the
    module's declared shapes, no per-model cases."""
    specs = {}
    for layer in plan.layers:
        sc = plan.layer_shape_ctx(layer)
        specs[f"layer{layer}"] = {
            s.name: P("model", *([None] * (1 + len(s.shape(sc)))))
            for s in plan.module.specs
        }
    specs["head"] = {"w": P(None, None), "b": P(None)}
    return specs


def _build_loss_fn(
    plan: StackedPlan,
    mesh: Mesh,
    model_axis: str,
    data_axes: Tuple[str, ...],
    local_combine: bool,
    kernels=None,
):
    """Shared closure of the train and eval steps: ``(loss_fn, split_arrays)``
    where ``loss_fn(stacks, feats, rest)`` is the scalar SPMD loss."""
    da = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    arr_specs = _array_specs(plan, da, model_axis)
    stack_specs = _stack_specs(plan)
    rel_specs = {k2: v for k2, v in stack_specs.items() if k2 != "head"}

    def split_arrays(arrays):
        feats = {k2: v for k2, v in arrays.items() if "feat" in k2}
        rest = {k2: v for k2, v in arrays.items() if "feat" not in k2}
        return feats, rest

    def root_fn(rel_stacks, feats, rest):
        def body(stacks_s, feats_s, rest_s):
            return raf_spmd_forward(
                plan, stacks_s, {**feats_s, **rest_s}, model_axis, local_combine,
                kernels,
            )

        return shard_map_nocheck(
            body,
            mesh=mesh,
            in_specs=(
                rel_specs,
                {k2: arr_specs[k2] for k2 in feats},
                {k2: arr_specs[k2] for k2 in rest},
            ),
            out_specs=P(da, None),
        )(rel_stacks, feats, rest)

    def loss_fn(stacks, feats, rest):
        rel_stacks = {k2: v for k2, v in stacks.items() if k2 != "head"}
        root = root_fn(rel_stacks, feats, rest)
        h = jax.nn.relu(root)
        logits = h @ stacks["head"]["w"] + stacks["head"]["b"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, rest["labels"][:, None], axis=-1)
        return jnp.mean(nll)

    return loss_fn, split_arrays


def make_loss_fn(
    plan: StackedPlan,
    mesh: Mesh,
    model_axis: str = "model",
    data_axes=("data",),
    local_combine: bool = True,
    kernels=None,
):
    """Jitted evaluation-only loss: ``loss(stacks, arrays) -> scalar``."""
    loss_fn, split_arrays = _build_loss_fn(plan, mesh, model_axis, data_axes,
                                           local_combine, kernels)

    @jax.jit
    def eval_loss(stacks, arrays):
        feats, rest = split_arrays(arrays)
        return loss_fn(stacks, feats, rest)

    return eval_loss


def make_train_step(
    plan: StackedPlan,
    mesh: Mesh,
    adam_cfg,
    model_axis: str = "model",
    data_axes=("data",),
    local_combine: bool = True,
    learn_feats: bool = False,
    kernels=None,
):
    """Build the jitted SPMD RAF train step.

    ``step(stacks, opt_state, arrays) -> (stacks, opt_state, loss[, feat_grads])``

    The shard_map body computes the root embedding (ending in the RAF psum);
    the classifier head + loss run outside under GSPMD, so gradients of the
    replicated head are exact.  Stack gradients pass through
    :func:`sync_stack_grads` before Adam, so parameters shared across shard
    slots stay consistent copies (the fused kernels' custom VJP already
    accumulates slot gradients into each shard's ``[U, ...]`` rows —
    cross-shard sharing remains this sync's job).  With ``learn_feats=True``
    the step also returns gradients w.r.t. the gathered feature arrays
    (``qfeat*``/``hfeat*``) for the embed engine's sparse row updates.
    """
    from repro.optim.adam import adam_update

    loss_fn, split_arrays = _build_loss_fn(plan, mesh, model_axis, data_axes,
                                           local_combine, kernels)

    if not learn_feats:
        grad_fn = jax.value_and_grad(loss_fn)

        @partial(jax.jit, donate_argnums=(0, 1))
        def step(stacks, opt_state, arrays):
            feats, rest = split_arrays(arrays)
            loss, grads = grad_fn(stacks, feats, rest)
            grads = sync_stack_grads(plan, grads)
            stacks, opt_state = adam_update(adam_cfg, stacks, grads, opt_state)
            return stacks, opt_state, loss

        return step

    grad_fn2 = jax.value_and_grad(loss_fn, argnums=(0, 1))

    @partial(jax.jit, donate_argnums=(0, 1))
    def step_feats(stacks, opt_state, arrays):
        feats, rest = split_arrays(arrays)
        loss, (gs, gf) = grad_fn2(stacks, feats, rest)
        gs = sync_stack_grads(plan, gs)
        stacks, opt_state = adam_update(adam_cfg, stacks, gs, opt_state)
        return stacks, opt_state, loss, gf

    return step_feats


def make_grad_step(
    plan: StackedPlan,
    mesh: Mesh,
    model_axis: str = "model",
    data_axes=("data",),
    local_combine: bool = True,
    kernels=None,
):
    """Jitted forward/backward half of :func:`make_train_step` for the
    multi-process data-parallel tier (``repro.data.dp_trainer``, DESIGN.md
    §13): ``grad(stacks, arrays) -> (loss, grads)`` with *raw* stack
    gradients.  The DP trainer allreduces these across trainer processes in
    fixed rank order and only then runs :func:`make_apply_step` — which
    performs :func:`sync_stack_grads` + Adam — so the cross-slot sync
    happens exactly once, on the cross-trainer sum, preserving the
    single-process sync discipline."""
    loss_fn, split_arrays = _build_loss_fn(plan, mesh, model_axis, data_axes,
                                           local_combine, kernels)
    grad_fn = jax.value_and_grad(loss_fn)

    @jax.jit
    def grad(stacks, arrays):
        feats, rest = split_arrays(arrays)
        return grad_fn(stacks, feats, rest)

    return grad


def make_apply_step(plan: StackedPlan, adam_cfg):
    """Jitted update half of :func:`make_train_step` (see
    :func:`make_grad_step`): ``apply(stacks, opt_state, grads) ->
    (stacks, opt_state)`` — :func:`sync_stack_grads` on the (already
    cross-trainer-summed) gradients, then Adam."""
    from repro.optim.adam import adam_update

    @partial(jax.jit, donate_argnums=(0, 1))
    def apply_grads(stacks, opt_state, grads):
        grads = sync_stack_grads(plan, grads)
        return adam_update(adam_cfg, stacks, grads, opt_state)

    return apply_grads


def shard_arrays(plan: StackedPlan, mesh: Mesh, arrays: Dict, data_axes=("data",),
                 model_axis: str = "model") -> Dict:
    """Device-put stacked batch arrays with their production shardings."""
    da = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    specs = _array_specs(plan, da, model_axis)
    return {
        k2: jax.device_put(v, NamedSharding(mesh, specs[k2])) for k2, v in arrays.items()
    }


def shard_stacks(plan: StackedPlan, mesh: Mesh, stacks: Dict) -> Dict:
    specs = _stack_specs(plan)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        stacks,
        specs,
        is_leaf=lambda x: isinstance(x, jnp.ndarray),
    )
