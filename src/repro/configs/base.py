"""Architecture + input-shape config system.

Every assigned architecture is a declarative :class:`ArchConfig`; the model
stack (``repro.models.transformer``) interprets it.  Layer structure is a
repeating *period* of blocks (e.g. Jamba's 1-attention:7-Mamba interleave is
``period=8`` with attention at slot 3), which lets every architecture lower
through a single ``lax.scan``-over-periods implementation with stacked
parameters — crucial for keeping the 398B-parameter dry-run HLO small.

``reduced()`` returns the smoke-test variant (≤2 periods, d_model ≤ 512,
≤4 experts) exercised on CPU; the full config is only ever lowered
abstractly via the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "register", "get_arch", "ARCHS"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # layer pattern
    period: int = 1  # layers per repeating group
    attn_slots: Tuple[int, ...] = (0,)  # slots within the period that are attention
    # (remaining slots are mamba blocks)
    moe_slots: Tuple[int, ...] = ()  # slots whose MLP is MoE
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_d_ff: int = 0  # per-expert FFN dim (0 -> d_ff)
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # attention details
    qkv_bias: bool = False
    rope_fraction: float = 1.0  # chatglm applies RoPE to half the dims
    rope_theta: float = 500_000.0
    causal: bool = True
    is_decoder: bool = True  # encoder-only archs have no decode step
    sliding_window: Optional[int] = None  # used for the long-context decode shape
    # modality frontend stubs (audio/vlm): input_specs provides embeddings
    frontend: Optional[str] = None  # None | "vision" | "audio"
    frontend_dim: int = 0
    frontend_tokens: int = 0  # vision: patches per example (anyres tiles folded)
    # numerics
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    citation: str = ""

    def __post_init__(self):
        if self.num_layers % self.period:
            raise ValueError(f"{self.name}: num_layers % period != 0")
        for s in self.moe_slots:
            assert 0 <= s < self.period
        for s in self.attn_slots:
            assert 0 <= s < self.period

    # -- derived --------------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def n_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def mamba_slots(self) -> Tuple[int, ...]:
        if self.family not in ("ssm", "hybrid"):
            return ()
        return tuple(s for s in range(self.period) if s not in self.attn_slots)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def attention_free(self) -> bool:
        return len(self.attn_slots) == 0

    def param_count(self) -> int:
        """Total parameters (exact, matches init shapes)."""
        D, V = self.d_model, self.vocab
        n = V * D  # embed
        if not self.tie_embeddings:
            n += D * V
        n += D  # final norm
        per_attn = D * self.num_heads * self.hd + 2 * D * self.num_kv_heads * self.hd
        per_attn += self.num_heads * self.hd * D + D  # wo + norm
        if self.qkv_bias:
            per_attn += (self.num_heads + 2 * self.num_kv_heads) * self.hd
        per_mlp = 3 * D * self.d_ff + D
        per_moe = self.moe_experts * 3 * D * self.expert_ff + D * self.moe_experts + D
        di, nh, N = self.d_inner, self.ssm_heads, self.ssm_state
        per_mamba = D * 2 * di + 2 * D * N + D * nh  # z,x,B,C,dt projections
        per_mamba += self.ssm_conv * di + 3 * nh + di + di * D + D  # conv,A,D,dtb,norm,out
        total_layers = 0
        for s in range(self.period):
            if s in self.attn_slots:
                blk = per_attn
            else:
                blk = per_mamba
            blk += per_moe if s in self.moe_slots else per_mlp
            total_layers += blk
        n += total_layers * self.n_periods
        if self.frontend:
            n += self.frontend_dim * D  # projector stub
        return n

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: top-k of E experts)."""
        if not self.moe_experts:
            return self.param_count()
        full = self.param_count()
        per_moe_all = self.moe_experts * 3 * self.d_model * self.expert_ff
        per_moe_act = self.moe_topk * 3 * self.d_model * self.expert_ff
        n_moe_layers = len(self.moe_slots) * self.n_periods
        return full - n_moe_layers * (per_moe_all - per_moe_act)

    # -- smoke-test reduction ---------------------------------------------------

    def reduced(self) -> "ArchConfig":
        """≤2-period, d_model≤512, ≤4-expert variant of the same family."""
        d = 256
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=self.period * min(2, self.n_periods),
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d // heads,
            d_ff=512,
            vocab=512,
            moe_experts=min(self.moe_experts, 4) if self.moe_experts else 0,
            moe_topk=min(self.moe_topk, 2) if self.moe_topk else 0,
            moe_d_ff=128 if self.moe_experts else 0,
            ssm_state=min(self.ssm_state, 64) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            frontend_dim=64 if self.frontend else 0,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


ARCHS: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    import repro.configs.all_archs  # noqa: F401  (populate registry)

    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    return ARCHS[name]
