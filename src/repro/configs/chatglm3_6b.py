"""chatglm3-6b — dense GQA decoder with 2d (half-dim) RoPE [arXiv:2406.12793]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab=65024, head_dim=128,
    rope_fraction=0.5, qkv_bias=True, rope_theta=10_000.0,
    citation="arXiv:2406.12793 (ChatGLM family); GLM 2d-RoPE",
))
