"""granite-moe-1b-a400m — 32-expert top-8 MoE decoder
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64,
    moe_slots=(0,), moe_experts=32, moe_topk=8, moe_d_ff=512,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
