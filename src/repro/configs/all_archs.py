"""Import every architecture config to populate the registry."""
import repro.configs.llama3_2_3b  # noqa: F401
import repro.configs.yi_6b  # noqa: F401
import repro.configs.jamba_1_5_large_398b  # noqa: F401
import repro.configs.mamba2_1_3b  # noqa: F401
import repro.configs.llava_next_34b  # noqa: F401
import repro.configs.qwen3_moe_30b_a3b  # noqa: F401
import repro.configs.qwen2_1_5b  # noqa: F401
import repro.configs.granite_moe_1b_a400m  # noqa: F401
import repro.configs.hubert_xlarge  # noqa: F401
import repro.configs.chatglm3_6b  # noqa: F401
