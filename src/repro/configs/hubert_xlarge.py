"""hubert-xlarge — encoder-only audio transformer (w2v2 arch)
[arXiv:2106.07447].

The mel-spectrogram + conv feature extractor is a STUB per the assignment
brief: ``input_specs`` provides precomputed frame embeddings.  Encoder-only
⇒ no decode step; decode_32k / long_500k are skipped (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab=504, head_dim=80,
    causal=False, is_decoder=False,
    frontend="audio", frontend_dim=512,
    citation="arXiv:2106.07447 (HuBERT)",
))
