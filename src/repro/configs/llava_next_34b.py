"""llava-next-34b — VLM decoder backbone with anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision tower + projector are a STUB per the assignment brief:
``input_specs`` provides precomputed patch embeddings (anyres tiles folded
into the token axis); this config is the language decoder that consumes them.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128,
    frontend="vision", frontend_dim=1152, frontend_tokens=576,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf (LLaVA-NeXT anyres)",
))
