"""llama3.2-3b — small Llama-3 dense decoder [hf:meta-llama/Llama-3.2-1B]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab=128256, head_dim=128,
    rope_theta=500_000.0,
    citation="hf:meta-llama/Llama-3.2-1B (Llama-3.2 family card)",
))
