"""yi-6b — llama-architecture dense decoder with GQA kv=4 [arXiv:2403.04652]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab=64000, head_dim=128,
    rope_theta=5_000_000.0,
    citation="arXiv:2403.04652 (Yi: Open Foundation Models)",
))
