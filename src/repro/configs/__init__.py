from repro.configs.base import (
    ARCHS,
    ArchConfig,
    INPUT_SHAPES,
    InputShape,
    get_arch,
    register,
)

__all__ = ["ARCHS", "ArchConfig", "INPUT_SHAPES", "InputShape", "get_arch", "register"]
