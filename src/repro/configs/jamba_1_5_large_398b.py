"""jamba-1.5-large-398b — hybrid Mamba+attention, 1:7 interleave, 16e top-2 MoE
[arXiv:2403.19887].

Period of 8 layers: attention at slot 3, Mamba elsewhere (1:7); MoE replaces
the dense MLP on every other slot (4 of 8), giving 36 MoE layers over 72.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab=65536, head_dim=128,
    period=8, attn_slots=(3,), moe_slots=(1, 3, 5, 7),
    moe_experts=16, moe_topk=2,
    ssm_state=128, ssm_head_dim=128,
    citation="arXiv:2403.19887 (Jamba); 1.5-large scale per model card",
))
