"""qwen3-moe-30b-a3b — 128-expert top-8 MoE decoder [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab=151936, head_dim=128,
    moe_slots=(0,), moe_experts=128, moe_topk=8, moe_d_ff=768,
    citation="hf:Qwen/Qwen3-30B-A3B",
))
