"""mamba2-1.3b — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab=50280,
    period=1, attn_slots=(), moe_slots=(),
    ssm_state=128, ssm_head_dim=64,
    citation="arXiv:2405.21060 (Mamba-2 / SSD)",
))
