"""AdamW from scratch (dense pytrees + sparse row updates).

Two entry points:

  * :func:`adam_update` — dense AdamW over an arbitrary pytree (model params,
    stacked relation weights, transformer stacks).  States are stored with the
    same sharding as the params, so model-parallel shards carry only their
    slice of optimizer state.

  * :func:`sparse_adam_rows` — per-row Adam for learnable feature tables
    (paper §2.2/§6): only the rows touched by a minibatch are updated, and the
    row-aligned moment/variance states travel with the rows through the cache
    engine.  This is the "learnable features + optimizer states" payload whose
    DRAM traffic Heta's cache eliminates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamConfig", "adam_init", "adam_update", "sparse_adam_rows", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0  # 0 disables clipping


def adam_init(params: Any) -> Dict[str, Any]:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.zeros(())


def adam_update(
    cfg: AdamConfig, params: Any, grads: Any, state: Dict[str, Any], lr_scale=1.0
) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    if cfg.grad_clip > 0:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        update = (m / b1t) / (jnp.sqrt(v / b2t) + cfg.eps)
        if cfg.weight_decay:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * lr_scale * update).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
            "step": step,
        },
    )


def sparse_adam_rows(
    cfg: AdamConfig,
    rows: jnp.ndarray,  # [n, d] current values of the touched rows
    grads: jnp.ndarray,  # [n, d]
    m: jnp.ndarray,  # [n, d] row-aligned first moment
    v: jnp.ndarray,  # [n, d] row-aligned second moment
    step: jnp.ndarray,  # scalar int (table-global step count)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One Adam step on a *row slice* of a learnable feature table.

    The caller (cache engine) fetched ``rows``/``m``/``v`` for the unique node
    ids of a minibatch, and scatters the returned values back — device-cached
    rows never touch host memory (paper §6's non-replicative mutable cache).
    """
    g32 = grads.astype(jnp.float32)
    t = step.astype(jnp.float32) + 1.0
    m = cfg.b1 * m + (1 - cfg.b1) * g32
    v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
    mhat = m / (1.0 - cfg.b1**t)
    vhat = v / (1.0 - cfg.b2**t)
    new = rows.astype(jnp.float32) - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
    return new.astype(rows.dtype), m, v
