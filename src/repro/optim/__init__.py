from repro.optim.adam import (
    AdamConfig,
    adam_init,
    adam_update,
    sparse_adam_rows,
    global_norm,
)
from repro.optim.schedule import cosine_schedule, linear_warmup

__all__ = [
    "AdamConfig",
    "adam_init",
    "adam_update",
    "sparse_adam_rows",
    "global_norm",
    "cosine_schedule",
    "linear_warmup",
]
