"""Checkpoint durability + session resume (DESIGN.md §12): atomic
commit-by-manifest, integrity verification (corrupt/torn/partial payloads
refused loudly), bf16 manifest-driven dtype restore, and the session
contract — Heta.save/restore resumes the loss trajectory bit-for-bit,
config-driven periodic checkpointing, pruning, and fingerprint checks."""

import json
import os

import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointError,
    latest_step,
    load_checkpoint,
    read_manifest,
    save_checkpoint,
)

jax = pytest.importorskip("jax")
jnp = jax.numpy


# --------------------------------------------------------------------------
# the checkpoint files themselves
# --------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(4, 3)).astype(np.float32),
        "steps": np.int64(7),
        "nested": {"ids": np.arange(5, dtype=np.int64)},
    }


def test_round_trip_with_extra_metadata(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_checkpoint(d, 3, tree, extra={"fingerprint": "abc", "seed": 0})
    assert latest_step(d) == 3
    manifest = read_manifest(d, 3)
    assert manifest["extra"] == {"fingerprint": "abc", "seed": 0}
    got = load_checkpoint(d, 3, jax.tree.map(np.zeros_like, tree))
    jax.tree.map(np.testing.assert_array_equal, got, tree)


def test_bf16_stored_as_uint16_restored_by_manifest_dtype(tmp_path):
    """npz can't hold bf16: the payload stores a uint16 view and the
    manifest keeps the logical dtype — restore returns bf16 even when the
    template leaf is float32."""
    d = str(tmp_path)
    tree = {"h": jnp.asarray(np.arange(6, dtype=np.float32).reshape(2, 3),
                             dtype=jnp.bfloat16)}
    save_checkpoint(d, 0, tree)
    m = read_manifest(d, 0)
    assert m["dtypes"]["h"] == "bfloat16"
    assert m["stored_dtypes"]["h"] == "uint16"
    got = load_checkpoint(d, 0, {"h": np.zeros((2, 3), np.float32)})
    assert np.asarray(got["h"]).dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["h"], np.float32),
                                  np.asarray(tree["h"], np.float32))


def test_latest_step_ignores_uncommitted(tmp_path):
    """An npz without its manifest is junk from a crash mid-save — it must
    be invisible, never restored."""
    d = str(tmp_path)
    assert latest_step(d) is None
    save_checkpoint(d, 2, _tree())
    # a torn save: payload renamed, crash before the manifest commit
    with open(os.path.join(d, "ckpt_00000009.npz"), "wb") as f:
        f.write(b"not a checkpoint")
    assert latest_step(d) == 2
    with pytest.raises(CheckpointError, match="manifest missing"):
        load_checkpoint(d, 9, _tree())


def test_corrupt_payload_refused(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    path = save_checkpoint(d, 1, tree)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF  # one flipped bit somewhere in an array
    with open(path, "wb") as f:
        f.write(blob)
    with pytest.raises(CheckpointError):
        load_checkpoint(d, 1, jax.tree.map(np.zeros_like, tree))


def test_truncated_payload_refused(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    path = save_checkpoint(d, 1, tree)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[: len(blob) // 3])
    with pytest.raises(CheckpointError):
        load_checkpoint(d, 1, jax.tree.map(np.zeros_like, tree))


def test_template_key_mismatch_refused(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    with pytest.raises(CheckpointError, match="key mismatch"):
        load_checkpoint(d, 1, {"other": np.zeros(3, np.float32)})


def test_corrupt_manifest_refused(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 1, _tree())
    with open(path + ".json", "w") as f:
        f.write("{ truncated")
    with pytest.raises(CheckpointError, match="unreadable manifest"):
        read_manifest(d, 1)


# --------------------------------------------------------------------------
# the session contract: save/restore resumes bit-for-bit
# --------------------------------------------------------------------------


def _cfg(**over):
    from repro.api import (CacheConfig, DataConfig, HetaConfig, ModelConfig,
                           PartitionConfig, RunConfig)

    cfg = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                        batch_size=8),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(hidden=32),
        cache=CacheConfig(cache_mb=2, presample_epochs=1),
        run=RunConfig(executor="raf_spmd", steps=8, lr=1e-2, seed=0),
    )
    return cfg.updated(**over) if over else cfg


def _stage(sess):
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    return sess


def test_session_resume_is_bit_identical(tmp_path):
    """ISSUE 9 acceptance (b): interrupt a run at step 4, restore in a
    *fresh* session, finish — the remaining losses are bit-identical to
    the uninterrupted trajectory (params, Adam moments, learnable tables
    + their Adam rows, and the sampler position all round-trip)."""
    from repro.api import Heta

    ref = Heta(_cfg()).run()["losses"]
    assert len(ref) == 8

    d = str(tmp_path / "ckpts")
    first = _stage(Heta(_cfg()))
    half = first.fit(4)["losses"]
    assert half == ref[:4]
    first.save(d)
    assert latest_step(d) == 4

    resumed = Heta(_cfg())  # fresh session: restore runs missing stages
    assert resumed.restore(d) == 4
    rest = resumed.fit(4)["losses"]
    assert rest == ref[4:]  # bit-identical tail


def test_restore_refuses_config_fingerprint_mismatch(tmp_path):
    from repro.api import Heta

    d = str(tmp_path)
    sess = _stage(Heta(_cfg()))
    sess.fit(2)
    sess.save(d)
    other = Heta(_cfg(model=dict(hidden=64)))
    with pytest.raises(CheckpointError, match="different"):
        other.restore(d)


def test_restore_without_checkpoint_raises(tmp_path):
    from repro.api import Heta

    with pytest.raises(CheckpointError, match="no committed checkpoint"):
        Heta(_cfg()).restore(str(tmp_path))
    with pytest.raises(ValueError, match="directory"):
        Heta(_cfg()).restore()  # no checkpoint.dir configured either


def test_periodic_checkpointing_and_pruning(tmp_path):
    """checkpoint.every_steps drives saves from the fit loop;
    checkpoint.keep prunes all but the newest committed pairs."""
    from repro.api import Heta

    d = str(tmp_path / "auto")
    cfg = _cfg(run=dict(steps=6),
               checkpoint=dict(every_steps=2, dir=d, keep=2))
    sess = Heta(cfg)
    sess.run()
    committed = sorted(
        int(f.split("_")[1].split(".")[0])
        for f in os.listdir(d)
        if f.endswith(".npz") and os.path.exists(os.path.join(d, f + ".json"))
    )
    assert committed == [4, 6]  # saved at 2, 4, 6; keep=2 pruned step 2
    assert latest_step(d) == 6

    # and the pruned directory still restores the newest step
    resumed = Heta(cfg)
    assert resumed.restore(d) == 6


def test_engine_state_snapshot_load_round_trip():
    """EmbedEngine.state_snapshot/load_state: mutate, load the snapshot
    back, and every table/moment/step/residency is restored exactly."""
    from repro.api import Heta

    sess = _stage(Heta(_cfg()))
    sess.fit(2)
    eng = sess.engine
    snap = eng.state_snapshot()
    before = {t: eng.table(t).copy() for t in eng.learnable_types}
    sess.fit(2)  # mutates learnable rows + Adam state
    after = {t: eng.table(t) for t in eng.learnable_types}
    assert any(not np.array_equal(before[t], after[t]) for t in before)
    eng.load_state(snap)
    for t in before:
        np.testing.assert_array_equal(eng.table(t), before[t])
    assert {t: int(s) for t, s in eng.steps.items()} == snap["steps"]
