"""Relation-module IR (DESIGN.md §3): registry/config agreement, scope-driven
parameter stacking round-trips (property test), shared-slot gradient sync,
and new-model-as-pure-declaration extensibility."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api.config import HGNN_MODELS, ModelConfig
from repro.core import raf_spmd, relmod
from repro.core.hgnn import (
    HGNNConfig,
    batch_to_arrays,
    hgnn_forward,
    init_embed_tables,
    init_hgnn_params,
)
from repro.core.meta_partition import meta_partition
from repro.core.raf import assign_branches
from repro.core.relmod import (
    SCOPE_CONTAINER,
    ParamSpec,
    RelationModule,
    available_models,
    get_relation_module,
    masked_mean,
    register_relation_module,
)
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import ogbn_mag_like

_GRAPH = ogbn_mag_like(scale=0.002)


def _plan_and_params(model, num_parts, seed, fold=None):
    g = _GRAPH
    mp = meta_partition(g, num_parts, num_layers=2)
    spec = SampleSpec.from_metatree(mp.metatree, (4, 3))
    cfg = HGNNConfig(model=model, hidden=32, num_layers=2,
                     num_classes=g.num_classes)
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    params = init_hgnn_params(jax.random.PRNGKey(seed), cfg, spec, feat_dims)
    assignment = assign_branches(spec, mp)
    if fold is not None:
        assignment = assignment.fold(fold, spec)
    plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
    return plan, params


# --------------------------------------------------------------------------
# registry <-> config agreement
# --------------------------------------------------------------------------


def test_registry_is_the_source_of_truth():
    assert tuple(sorted(HGNN_MODELS)) == available_models()
    for name in HGNN_MODELS:
        assert get_relation_module(name).name == name
    with pytest.raises(KeyError, match="registered"):
        get_relation_module("gcn")
    with pytest.raises(ValueError, match="registered relation"):
        HGNNConfig(model="gcn")


def test_scopes_and_spec_validation():
    with pytest.raises(ValueError, match="scope"):
        ParamSpec("w", "per_galaxy", lambda c: (c.hidden,))
    with pytest.raises(ValueError, match="init"):
        ParamSpec("w", "relation", lambda c: (c.hidden,), init="ones")
    hgt = get_relation_module("hgt")
    assert set(hgt.scopes) == {"src_type", "dst_type", "etype"}
    assert get_relation_module("rgcn").scopes == ("relation",)


# --------------------------------------------------------------------------
# property: stacking round-trips bit-exactly (all models, varying partitions)
# --------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    model=st.sampled_from(["rgcn", "rgat", "hgt"]),
    num_parts=st.integers(2, 3),
    seed=st.integers(0, 2**16),
)
def test_stack_round_trip_bit_exact(model, num_parts, seed):
    """``stack_params_from_dict`` followed by per-slot gather reproduces the
    dict params bit-for-bit, and every padding region is exactly zero."""
    plan, params = _plan_and_params(model, num_parts, seed)
    stacks = raf_spmd.stack_params_from_dict(plan, params)
    for layer in plan.layers:
        for spec_ in plan.module.specs:
            names = plan.scope_keys[(spec_.scope, layer)]
            stacked = np.asarray(stacks[f"layer{layer}"][spec_.name])
            seen = np.zeros(stacked.shape, bool)
            seen[:, len(max(names, key=len)):] = True  # fully-padded slots
            for p, row in enumerate(names):
                seen[p, len(row):] = True
                for u, nm in enumerate(row):
                    w = np.asarray(params[SCOPE_CONTAINER[spec_.scope]][nm][spec_.name])
                    sl = (p, u) + tuple(slice(0, s) for s in w.shape)
                    np.testing.assert_array_equal(stacked[sl], w)
                    seen[sl] = True
            # everything not covered by a real parameter is zero padding
            assert not stacked[~seen].any()


# --------------------------------------------------------------------------
# shared-slot gradient sync
# --------------------------------------------------------------------------


def test_sync_stack_grads_sums_shared_slots():
    """Slots holding the same storage key (hgt: a node type feeding relations
    on different shards) receive the cross-slot gradient sum; unshared and
    padding slots are untouched."""
    plan, params = _plan_and_params("hgt", 2, seed=0)
    shared = [(s, l) for (s, l) in plan.scope_keys if plan.has_shared(s, l)]
    assert shared, "ogbn-mag hgt plan must share node-type params across shards"

    stacks = raf_spmd.stack_params_from_dict(plan, params)
    # grads = distinct constant per slot, so sums are easy to predict
    grads = {}
    for key, entry in stacks.items():
        if key == "head":
            grads[key] = jax.tree.map(jnp.ones_like, entry)
            continue
        grads[key] = {
            leaf: (jnp.arange(g.shape[0] * g.shape[1], dtype=g.dtype)
                   .reshape(g.shape[0], g.shape[1], *([1] * (g.ndim - 2)))
                   * jnp.ones_like(g))
            for leaf, g in entry.items()
        }
    synced = raf_spmd.sync_stack_grads(plan, grads)
    scope_of = {s.name: s.scope for s in plan.module.specs}
    for layer in plan.layers:
        for leaf, g in grads[f"layer{layer}"].items():
            got = np.asarray(synced[f"layer{layer}"][leaf])
            names = plan.scope_keys[(scope_of[leaf], layer)]
            g = np.asarray(g)
            Pn, U = g.shape[:2]
            for p in range(Pn):
                for u in range(U):
                    if u >= len(names[p]):  # padding slot: identity
                        np.testing.assert_array_equal(got[p, u], g[p, u])
                        continue
                    total = sum(
                        g[p2, u2]
                        for p2, row in enumerate(names)
                        for u2, nm in enumerate(row)
                        if nm == names[p][u]
                    )
                    np.testing.assert_allclose(got[p, u], total, rtol=0, atol=0)
    # head gradients pass through untouched
    np.testing.assert_array_equal(
        np.asarray(synced["head"]["w"]), np.asarray(grads["head"]["w"])
    )


def test_restricted_init_matches_full_bit_exact():
    """Partition-restricted init (only a worker's relations) reproduces the
    full init's leaves exactly — name-derived keys, every model."""
    g = _GRAPH
    mp = meta_partition(g, 2, num_layers=2)
    spec = SampleSpec.from_metatree(mp.metatree, (4, 3))
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    assignment = assign_branches(spec, mp)
    for model in HGNN_MODELS:
        cfg = HGNNConfig(model=model, hidden=32, num_layers=2,
                         num_classes=g.num_classes)
        full = init_hgnn_params(jax.random.PRNGKey(3), cfg, spec, feat_dims)
        for p in range(2):
            rels = assignment.relations_of(p, spec)
            part = init_hgnn_params(jax.random.PRNGKey(3), cfg, spec,
                                    feat_dims, restrict_rels=rels)
            for container in ("rel", "ntype", "etype"):
                for skey, group in part[container].items():
                    for leaf, val in group.items():
                        np.testing.assert_array_equal(
                            np.asarray(val),
                            np.asarray(full[container][skey][leaf]),
                            err_msg=f"{model}/{container}/{skey}/{leaf}",
                        )


# --------------------------------------------------------------------------
# extensibility: a new HGNN variant as a pure declaration
# --------------------------------------------------------------------------


def test_new_model_is_a_pure_declaration():
    """Registering a relation module is all it takes: config validation, param
    init, the dict forward and the SPMD stacked forward all follow."""

    @register_relation_module
    class MaxPoolModule(RelationModule):
        name = "_test_maxpool"
        specs = (
            ParamSpec("w", "relation", lambda c: (c.d_src, c.hidden)),
            ParamSpec("w_self", "dst_type", lambda c: (c.d_dst, c.hidden)),
        )

        def aggregate(self, p, h_src, q_feats, mask):
            pooled = masked_mean(h_src, mask) @ p["w"]
            return pooled + q_feats @ p["w_self"]

    try:
        assert "_test_maxpool" in available_models()
        ModelConfig(model="_test_maxpool")  # registry-backed validation
        g = _GRAPH
        mp = meta_partition(g, 2, num_layers=2)
        spec = SampleSpec.from_metatree(mp.metatree, (3, 2))
        cfg = HGNNConfig(model="_test_maxpool", hidden=32, num_layers=2,
                         num_classes=g.num_classes)
        feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
        params = init_hgnn_params(jax.random.PRNGKey(0), cfg, spec, feat_dims)
        params["embed"] = init_embed_tables(jax.random.PRNGKey(1), cfg,
                                            g.num_nodes, feat_dims)
        sampler = NeighborSampler(g, spec, 8, seed=1)
        batch = sampler.sample_batch(g.train_nodes[:8])
        tables = {t: jnp.asarray(f) for t, f in g.features.items()}
        arrs = batch_to_arrays(batch)
        ref = hgnn_forward(cfg, params, tables, arrs, spec)
        assert np.all(np.isfinite(np.asarray(ref)))

        # the SPMD stacking layer needs no model-specific code either
        assignment = assign_branches(spec, mp).fold(1, spec)
        plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
        stacks = raf_spmd.stack_params_from_dict(plan, params)
        tables_np = {t: np.asarray(f) for t, f in g.features.items()}
        tables_np.update({t: np.asarray(v) for t, v in params["embed"].items()})
        arrays = raf_spmd.stack_batch(plan, batch, tables_np)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        loss = raf_spmd.make_loss_fn(plan, mesh)
        logits_loss = float(loss(stacks, arrays))
        assert np.isfinite(logits_loss)
    finally:
        del relmod._MODULES["_test_maxpool"]


def test_config_validation_without_registry_falls_back():
    """ModelConfig stays importable/jax-free: with the registry loaded it
    accepts exactly the registered names (plus rejects unknowns)."""
    with pytest.raises(ValueError, match="model must be one of"):
        ModelConfig(model="definitely_not_registered")
    for name in HGNN_MODELS:
        assert ModelConfig(model=name).model == name
