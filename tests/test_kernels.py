"""Pallas kernel validation: shape/dtype sweeps against the jnp oracles
(interpret mode on CPU; TPU is the target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.gather_rows import gather_rows, gather_rows_ref
from repro.kernels.relation_agg import relation_agg, relation_agg_ref

rng = np.random.default_rng(42)


# --------------------------------------------------------------------------
# relation_agg: fused masked-mean + projection
# --------------------------------------------------------------------------

AGG_SHAPES = [
    (200, 25, 128, 64),   # ogbn-mag layer-1 (paper fanout 25, feat 128)
    (64, 20, 64, 64),     # hidden layer (fanout 20, hidden 64)
    (64, 4, 789, 64),     # donor's widest feature type
    (128, 20, 64, 349),   # output classes
    (5, 3, 7, 16),        # tiny/ragged — exercises padding
    (256, 10, 1024, 64),  # IGB-HET feature dim
]


@pytest.mark.parametrize("n,f,di,do", AGG_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_relation_agg_sweep(n, f, di, do, dtype):
    h = jnp.asarray(rng.standard_normal((n, f, di)), dtype)
    m = jnp.asarray(rng.random((n, f)) > 0.3)
    w = jnp.asarray(rng.standard_normal((di, do)) * 0.1, dtype)
    b = jnp.asarray(rng.standard_normal(do) * 0.1, dtype)
    out = relation_agg(h, m, w, b)
    ref = relation_agg_ref(h, m, w, b)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_relation_agg_all_masked_rows():
    h = jnp.asarray(rng.standard_normal((16, 5, 32)), jnp.float32)
    m = jnp.zeros((16, 5), bool)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    b = jnp.zeros(8, jnp.float32)
    out = relation_agg(h, m, w, b)
    np.testing.assert_allclose(np.asarray(out), np.zeros((16, 8)), atol=1e-6)


@given(
    n=st.integers(1, 64), f=st.integers(1, 8),
    di=st.integers(1, 96), do=st.integers(1, 96),
)
@settings(max_examples=15, deadline=None)
def test_relation_agg_property(n, f, di, do):
    r = np.random.default_rng(n * 1000 + f * 100 + di)
    h = jnp.asarray(r.standard_normal((n, f, di)), jnp.float32)
    m = jnp.asarray(r.random((n, f)) > 0.5)
    w = jnp.asarray(r.standard_normal((di, do)) * 0.1, jnp.float32)
    b = jnp.asarray(r.standard_normal(do) * 0.1, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(relation_agg(h, m, w, b)),
        np.asarray(relation_agg_ref(h, m, w, b)),
        atol=1e-4, rtol=1e-4,
    )


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------

ATTN_CASES = [
    dict(b=2, h=4, hk=2, sq=256, sk=256, d=64, causal=True, window=None, off=0),
    dict(b=1, h=8, hk=8, sq=300, sk=300, d=64, causal=True, window=None, off=0),
    dict(b=1, h=4, hk=4, sq=256, sk=256, d=128, causal=True, window=64, off=0),
    dict(b=2, h=4, hk=2, sq=1, sk=512, d=64, causal=True, window=None, off=511),
    dict(b=1, h=2, hk=2, sq=1, sk=1024, d=64, causal=True, window=256, off=1023),
    dict(b=1, h=2, hk=2, sq=128, sk=128, d=64, causal=False, window=None, off=0),
    dict(b=1, h=16, hk=16, sq=160, sk=160, d=80, causal=False, window=None, off=0),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_sweep(case):
    c = case
    q = jnp.asarray(rng.standard_normal((c["b"], c["h"], c["sq"], c["d"])), jnp.float32)
    k = jnp.asarray(rng.standard_normal((c["b"], c["hk"], c["sk"], c["d"])), jnp.float32)
    v = jnp.asarray(rng.standard_normal((c["b"], c["hk"], c["sk"], c["d"])), jnp.float32)
    out = flash_attention(q, k, v, causal=c["causal"], window=c["window"], q_offset=c["off"])
    ref = attention_ref(q, k, v, causal=c["causal"], window=c["window"], q_offset=c["off"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_flash_attention_window_equals_full_when_wide():
    """A window ≥ sequence length must equal unwindowed causal attention."""
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, window=4096)
    b = flash_attention(q, k, v, causal=True, window=None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# --------------------------------------------------------------------------
# gather_rows
# --------------------------------------------------------------------------


@pytest.mark.parametrize("rows,d,n", [(100, 128, 32), (1000, 64, 256), (37, 8, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_rows_sweep(rows, d, n, dtype):
    tab = jnp.asarray(rng.standard_normal((rows, d)), dtype)
    idx = jnp.asarray(rng.integers(0, rows, n))
    np.testing.assert_array_equal(
        np.asarray(gather_rows(tab, idx)), np.asarray(gather_rows_ref(tab, idx))
    )


@given(st.integers(1, 200), st.integers(1, 64), st.integers(1, 100))
@settings(max_examples=15, deadline=None)
def test_gather_rows_property(rows, d, n):
    r = np.random.default_rng(rows + d + n)
    tab = jnp.asarray(r.standard_normal((rows, d)), jnp.float32)
    idx = jnp.asarray(r.integers(0, rows, n))
    np.testing.assert_array_equal(
        np.asarray(gather_rows(tab, idx)), np.asarray(gather_rows_ref(tab, idx))
    )
