"""Prop 1 (mathematical equivalence): RAF == vanilla, bit-for-bit.

Covers the simulated executor AND the SPMD stacked executor for all three
HGNN models (the relation-module IR drives both), across partition counts
and datasets — forward logits and parameter gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hgnn import (
    HGNNConfig,
    batch_to_arrays,
    hgnn_forward,
    hgnn_loss,
    init_embed_tables,
    init_hgnn_params,
)
from repro.core.meta_partition import meta_partition
from repro.core.raf import (
    assign_branches,
    raf_comm_bytes,
    raf_forward,
    random_branch_assignment,
)
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import donor_like, ogbn_mag_like


def _setup(graph, model, num_parts, fanouts=(4, 3), batch=16):
    mp = meta_partition(graph, num_parts, num_layers=len(fanouts))
    spec = SampleSpec.from_metatree(mp.metatree, fanouts)
    sampler = NeighborSampler(graph, spec, batch, seed=1)
    b = sampler.sample_batch(graph.train_nodes[:batch])
    cfg = HGNNConfig(model=model, hidden=32, num_layers=len(fanouts),
                     num_classes=graph.num_classes)
    feat_dims = {t: graph.feat_dim(t) for t in graph.num_nodes if graph.feat_dim(t)}
    key = jax.random.PRNGKey(0)
    params = init_hgnn_params(key, cfg, spec, feat_dims)
    params["embed"] = init_embed_tables(
        jax.random.PRNGKey(1), cfg, graph.num_nodes, feat_dims
    )
    tables = {t: jnp.asarray(f) for t, f in graph.features.items()}
    return mp, spec, b, cfg, feat_dims, key, params, tables


@pytest.mark.parametrize("model", ["rgcn", "rgat", "hgt"])
@pytest.mark.parametrize("num_parts", [2, 3])
def test_prop1_simulated(model, num_parts):
    g = ogbn_mag_like(scale=0.002)
    mp, spec, b, cfg, feat_dims, key, params, tables = _setup(g, model, num_parts)
    arrs = batch_to_arrays(b)
    ref = hgnn_forward(cfg, params, tables, arrs, spec)

    assignment = assign_branches(spec, mp)
    assert assignment.meta_local
    parts = []
    for p in range(num_parts):
        rels = assignment.relations_of(p, spec)
        pp = init_hgnn_params(key, cfg, spec, feat_dims, restrict_rels=rels)
        pp["embed"] = params["embed"]
        pp["head"] = params["head"]
        parts.append(pp)
    out = raf_forward(cfg, parts, tables, arrs, spec, assignment)
    # Prop 1 holds exactly in real arithmetic; fp32 reassociation of the
    # cross-partition sum gives O(1e-8) differences
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_prop1_featureless_and_varying_dims():
    """Donor-like: wildly varying feature dims (7..789) must not break
    equivalence (the padding path)."""
    g = donor_like(scale=0.001)
    mp, spec, b, cfg, feat_dims, key, params, tables = _setup(g, "rgcn", 2)
    arrs = batch_to_arrays(b)
    ref = hgnn_forward(cfg, params, tables, arrs, spec)
    assignment = assign_branches(spec, mp)
    parts = []
    for p in range(2):
        rels = assignment.relations_of(p, spec)
        pp = init_hgnn_params(key, cfg, spec, feat_dims, restrict_rels=rels)
        pp["embed"], pp["head"] = params["embed"], params["head"]
        parts.append(pp)
    out = raf_forward(cfg, parts, tables, arrs, spec, assignment)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("kernels_on", [False, True], ids=["kernels_off", "kernels_on"])
@pytest.mark.parametrize("model", ["rgcn", "rgat", "hgt"])
def test_prop1_spmd_stacked(model, kernels_on):
    """The stacked/padded SPMD representation is bit-equivalent to the dict
    forward for every registered model — including HGT's per-node-type
    parameter structure (single-device mesh; the multi-device case runs in
    test_multidevice.py via subprocess).  Parametrized over the kernel
    layer: ``kernels_on`` forces the fused Pallas path in interpret mode."""
    from repro.core import raf_spmd
    from repro.kernels.ops import KernelOptions

    kernels = KernelOptions(interpret=True) if kernels_on else KernelOptions(enabled=False)
    g = ogbn_mag_like(scale=0.002)
    mp, spec, b, cfg, feat_dims, key, params, tables = _setup(g, model, 2)
    arrs = batch_to_arrays(b)
    ref = hgnn_forward(cfg, params, tables, arrs, spec)

    # single real device: fold both partitions onto one model shard (the
    # multi-device path runs in test_multidevice.py)
    assignment = assign_branches(spec, mp).fold(1, spec)
    plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
    stacks = raf_spmd.stack_params_from_dict(plan, params)
    tables_np = {t: np.asarray(f) for t, f in g.features.items()}
    tables_np.update({t: np.asarray(v) for t, v in params["embed"].items()})
    arrays = raf_spmd.stack_batch(plan, b, tables_np)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P

    arr_specs = raf_spmd._array_specs(plan, ("data",), "model")
    rel_specs = {k: v for k, v in raf_spmd._stack_specs(plan).items() if k != "head"}
    feats = {k: v for k, v in arrays.items() if "feat" in k}
    rest = {k: v for k, v in arrays.items() if "feat" not in k}

    def body(st, fe, re_):
        return raf_spmd.raf_spmd_forward(plan, st, {**fe, **re_}, "model", True,
                                         kernels)

    root = raf_spmd.shard_map_nocheck(
        body,
        mesh=mesh,
        in_specs=(rel_specs, {k: arr_specs[k] for k in feats},
                  {k: arr_specs[k] for k in rest}),
        out_specs=P(("data",), None),
    )({k: v for k, v in stacks.items() if k != "head"}, feats, rest)
    logits = jax.nn.relu(root) @ stacks["head"]["w"] + stacks["head"]["b"]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("kernels_on", [False, True], ids=["kernels_off", "kernels_on"])
@pytest.mark.parametrize("model", ["rgcn", "rgat", "hgt"])
def test_prop1_spmd_gradients_match_vanilla(model, kernels_on):
    """Backprop through the stacked SPMD loss: gradients gathered back
    through the plan's scope index arrays equal the dict-form gradients
    (autodiff sums slot uses exactly like the dict forward sums relation
    occurrences).  With ``kernels_on`` the same holds through the fused
    Pallas kernels' custom VJPs (stack-form weight gradients)."""
    from repro.core import raf_spmd
    from repro.core.relmod import SCOPE_CONTAINER
    from repro.kernels.ops import KernelOptions

    kernels = KernelOptions(interpret=True) if kernels_on else KernelOptions(enabled=False)
    g = ogbn_mag_like(scale=0.002)
    mp, spec, b, cfg, feat_dims, key, params, tables = _setup(g, model, 2)
    arrs = batch_to_arrays(b)
    gref = jax.grad(lambda pr: hgnn_loss(cfg, pr, tables, arrs, spec))(params)

    assignment = assign_branches(spec, mp).fold(1, spec)
    plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
    stacks = raf_spmd.stack_params_from_dict(plan, params)
    tables_np = {t: np.asarray(f) for t, f in g.features.items()}
    tables_np.update({t: np.asarray(v) for t, v in params["embed"].items()})
    arrays = raf_spmd.stack_batch(plan, b, tables_np)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    loss_fn, split = raf_spmd._build_loss_fn(plan, mesh, "model", ("data",), True,
                                             kernels)
    feats, rest = split(arrays)
    gstacks = jax.grad(loss_fn)(stacks, feats, rest)
    gstacks = raf_spmd.sync_stack_grads(plan, gstacks)  # single shard: identity

    for layer in plan.layers:
        for spec_ in plan.module.specs:
            names = plan.scope_keys[(spec_.scope, layer)]
            for p, row in enumerate(names):
                for u, nm in enumerate(row):
                    want = np.asarray(gref[SCOPE_CONTAINER[spec_.scope]][nm][spec_.name])
                    got = np.asarray(gstacks[f"layer{layer}"][spec_.name][p, u])
                    got = got[tuple(slice(0, s) for s in want.shape)]
                    np.testing.assert_allclose(
                        got, want, atol=1e-5,
                        err_msg=f"{model} grad mismatch {nm}/{spec_.name}",
                    )
    np.testing.assert_allclose(
        np.asarray(gstacks["head"]["w"]), np.asarray(gref["head"]["w"]), atol=1e-5
    )


def test_comm_bytes_meta_vs_naive():
    """§4 comm accounting: meta-local placement exchanges only root partials;
    naive placement adds inner-level traffic (the 0.5 MB vs 8 MB gap)."""
    g = ogbn_mag_like(scale=0.002)
    mp = meta_partition(g, 2, num_layers=2)
    spec = SampleSpec.from_metatree(mp.metatree, (25, 20))
    meta = assign_branches(spec, mp)
    naive = random_branch_assignment(spec, 2, seed=3)
    b_meta = raf_comm_bytes(spec, meta, 1024, 64)
    b_naive = raf_comm_bytes(spec, naive, 1024, 64)
    assert meta.meta_local and not naive.meta_local
    # meta: 2 × (P-1) × B × hidden × 2 bytes = 2·1·1024·64·2 = 0.26 MB
    assert b_meta == 2 * 1 * 1024 * 64 * 2
    assert b_naive > 10 * b_meta  # inner levels dominate (×fanout)


def test_gradients_match_vanilla():
    """Backprop equivalence: d(loss)/d(params) identical between executors
    for the shared head (Alg. 1 lines 12-17)."""
    g = ogbn_mag_like(scale=0.002)
    mp, spec, b, cfg, feat_dims, key, params, tables = _setup(g, "rgcn", 2)
    arrs = batch_to_arrays(b)

    gref = jax.grad(lambda pr: hgnn_loss(cfg, pr, tables, arrs, spec))(params)

    assignment = assign_branches(spec, mp)
    from repro.core.raf import raf_loss

    parts = []
    for p in range(2):
        rels = assignment.relations_of(p, spec)
        pp = init_hgnn_params(key, cfg, spec, feat_dims, restrict_rels=rels)
        pp["embed"], pp["head"] = params["embed"], params["head"]
        parts.append(pp)
    graf = jax.grad(
        lambda ps: raf_loss(cfg, ps, tables, arrs, spec, assignment)
    )(parts)
    # head grads must agree (partition 0 holds the designated head)
    np.testing.assert_allclose(
        np.asarray(graf[0]["head"]["w"]), np.asarray(gref["head"]["w"]), atol=1e-5
    )
    # per-relation grads: a (relation, layer) pair is *evaluated* by exactly
    # one partition (its sub-metatree owner), but restrict_rels keys by
    # relation name, so a partition may also hold never-evaluated copies at
    # other layers (zero grads).  Summing across partitions recovers the
    # vanilla gradient exactly.
    summed: dict = {}
    for p in range(2):
        for name, g_p in graf[p]["rel"].items():
            for leaf, val in g_p.items():
                if leaf.startswith("_"):
                    continue
                key2 = (name, leaf)
                summed[key2] = summed.get(key2, 0) + np.asarray(val)
    for (name, leaf), val in summed.items():
        np.testing.assert_allclose(
            val, np.asarray(gref["rel"][name][leaf]), atol=1e-5,
            err_msg=f"grad mismatch {name}/{leaf}",
        )
