"""Miss-penalty cache (paper §6): allocation policy, hit accounting,
non-replicative consistency, sparse-Adam-through-cache correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metatree import build_metatree
from repro.embed import (
    EmbedEngine,
    allocate_cache,
    analytic_miss_penalty,
    presample_hotness,
    profile_miss_penalties,
)
from repro.embed.profiler import row_bytes
from repro.graph.sampler import SampleSpec
from repro.graph.synthetic import donor_like, ogbn_mag_like
from repro.optim.adam import AdamConfig, adam_init, adam_update


@pytest.fixture(scope="module")
def mag_setup():
    g = ogbn_mag_like(scale=0.002)
    tree = build_metatree(g.metagraph(), g.target_type, 2)
    spec = SampleSpec.from_metatree(tree, [4, 3])
    hot = presample_hotness(g, spec, batch_size=64, epochs=2, max_batches=20)
    pen = profile_miss_penalties(g, measured=False)
    return g, spec, hot, pen


@pytest.mark.parametrize("num_workers", [1, 2, 4])
def test_pooled_presample_bit_identical_to_serial(mag_setup, num_workers):
    """The §6 pre-sampling epoch through the sampler worker pool: visit
    counting is an order-independent sum over the same ``batch_at`` walk,
    so the pooled profile equals the serial one exactly."""
    from repro.embed import presample_hotness_pooled

    g, spec, hot, _ = mag_setup
    pooled = presample_hotness_pooled(g, spec, batch_size=64,
                                      num_workers=num_workers, epochs=2,
                                      max_batches=20)
    for t in hot.counts:
        np.testing.assert_array_equal(pooled.counts[t], hot.counts[t])


def test_miss_penalty_shape_matches_paper(mag_setup):
    """Paper Fig. 7: smaller dims ⇒ larger o_a; learnable > read-only at the
    same dim."""
    assert analytic_miss_penalty(7, False) > analytic_miss_penalty(789, False)
    assert analytic_miss_penalty(128, True) > analytic_miss_penalty(128, False)


def test_allocation_proportional_to_count_times_penalty(mag_setup):
    g, spec, hot, pen = mag_setup
    total = 1 << 20
    alloc = allocate_cache(hot, pen, total, g.num_nodes)
    # un-capped types get bytes ∝ count × o_a
    scores = {t: hot.total(t) * pen.ratios[t] for t in g.num_nodes}
    rb = {t: row_bytes(pen.dims[t], pen.learnable[t]) for t in g.num_nodes}
    uncapped = [
        t for t in g.num_nodes
        if alloc.rows[t] < g.num_nodes[t] and scores[t] > 0
    ]
    if len(uncapped) >= 2:
        a, b = uncapped[:2]
        ratio_alloc = (alloc.bytes_[a] + rb[a]) / (alloc.bytes_[b] + rb[b])
        ratio_score = scores[a] / scores[b]
        assert ratio_alloc == pytest.approx(ratio_score, rel=0.35)


def test_allocation_respects_budget_and_caps(mag_setup):
    g, spec, hot, pen = mag_setup
    total = 1 << 20
    alloc = allocate_cache(hot, pen, total, g.num_nodes)
    assert sum(alloc.bytes_.values()) <= total * 1.01
    for t in g.num_nodes:
        assert alloc.rows[t] <= g.num_nodes[t]


def test_hotness_only_differs(mag_setup):
    g, spec, hot, pen = mag_setup
    a = allocate_cache(hot, pen, 1 << 20, g.num_nodes)
    b = allocate_cache(hot, pen, 1 << 20, g.num_nodes, hotness_only=True)
    assert a.rows != b.rows  # the ablation changes the split (paper Fig. 11)


def test_cache_hit_rate_and_consistency(mag_setup):
    g, spec, hot, pen = mag_setup
    eng = EmbedEngine(g, 32, hot, pen, cache_bytes=1 << 18)
    # hot nodes should hit; the engine snapshot must reflect cached writes
    t = "author"
    hot_ids = hot.hottest(t, 8)
    eng.fetch(t, hot_ids)
    assert eng.cache.hit_rates()[t] > 0.9
    assert eng.cache.consistency_check()


def test_sparse_update_through_cache_matches_dense_adam(mag_setup):
    """Updating learnable rows through the cache must equal a dense Adam step
    on the full table restricted to the touched rows."""
    g, spec, hot, pen = mag_setup
    dim = 16
    adam = AdamConfig(lr=0.05)
    eng = EmbedEngine(g, dim, hot, pen, cache_bytes=1 << 16, adam=adam)
    t = "field_of_study"
    table0 = eng.table(t).copy()

    nids = np.array([1, 3, 3, 7])
    grads = np.stack([np.full(dim, 1.0), np.full(dim, 2.0),
                      np.full(dim, 2.0), np.full(dim, -1.0)]).astype(np.float32)
    eng.apply_row_grads(t, nids, jnp.asarray(grads))
    got = eng.table(t)

    # dense oracle: grad rows summed into unique ids, adam on the full table
    dense_g = np.zeros_like(table0)
    np.add.at(dense_g, nids, grads)
    params = {"w": jnp.asarray(table0)}
    state = adam_init(params)
    newp, _ = adam_update(adam, params, {"w": jnp.asarray(dense_g)}, state)
    want = np.asarray(newp["w"])

    touched = np.unique(nids)
    np.testing.assert_allclose(got[touched], want[touched], atol=1e-5)
    untouched = np.setdiff1d(np.arange(table0.shape[0]), touched)[:10]
    np.testing.assert_array_equal(got[untouched], table0[untouched])


def test_cache_write_hits_device_copy_not_host(mag_setup):
    """Non-replicative invariant: writing a cached row must not touch the
    host copy (single authoritative version, paper §6)."""
    g, spec, hot, pen = mag_setup
    eng = EmbedEngine(g, 8, hot, pen, cache_bytes=1 << 18)
    t = "author"
    c = eng.cache.caches[t]
    nid = int(c.ids[0])  # definitely cached
    host_before = eng.cache.host[t][nid].copy()
    eng.apply_row_grads(t, np.array([nid]), jnp.ones((1, 8)))
    assert np.array_equal(eng.cache.host[t][nid], host_before)  # host untouched
    assert not np.array_equal(np.asarray(eng.table(t)[nid]), host_before)


def test_stat_counters_thread_safe(mag_setup):
    """fetch() runs in the async pipeline's producer thread while
    hit_rates()/miss_time() read from the consumer: hammer both sides and
    check the counters come out exact (lost updates would undercount)."""
    import threading

    g, spec, hot, pen = mag_setup
    eng = EmbedEngine(g, 8, hot, pen, cache_bytes=1 << 18)
    t = "author"
    nids = np.arange(64) % g.num_nodes[t]
    rounds, threads = 50, 4
    errs = []

    def fetcher():
        try:
            for _ in range(rounds):
                eng.cache.fetch(t, nids)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    def reader():
        for _ in range(rounds):
            eng.cache.hit_rates()
            eng.cache.miss_time(pen)

    eng.cache.reset_stats()
    ts = [threading.Thread(target=fetcher) for _ in range(threads)]
    ts.append(threading.Thread(target=reader))
    for th in ts:
        th.start()
    for th in ts:
        th.join()
    assert not errs
    c = eng.cache.caches[t]
    assert c.hits + c.misses == rounds * threads * len(nids)


# --------------------------------------------------------------------------
# online penalty-aware admission (§6 extension)
# --------------------------------------------------------------------------


def _zipf_draw(rng, perm, n, k=256, a=1.5):
    """Zipf-skewed ids over a shuffled permutation (hot set ≠ low ids)."""
    return perm[np.minimum(rng.zipf(a, size=k) - 1, n - 1)]


@pytest.fixture()
def uniform_prior_engine(mag_setup):
    """Engine whose one-shot allocation trusts a *misleading* uniform
    hotness prior — the online path must recover from observed traffic."""
    from repro.embed.profiler import HotnessProfile

    g, spec, _, pen = mag_setup
    uni = HotnessProfile(counts={t: np.ones(n) for t, n in g.num_nodes.items()})
    return g, EmbedEngine(g, 16, uni, pen, cache_bytes=1 << 17)


def test_online_admission_converges_on_stationary_zipf(uniform_prior_engine):
    """Under a stationary Zipf trace, rebalancing from observed counters
    must push the hit rate far above the misleading one-shot allocation."""
    g, eng = uniform_prior_engine
    rng = np.random.default_rng(0)
    t = "author"
    perm = rng.permutation(g.num_nodes[t])

    eng.cache.reset_stats()
    for _ in range(30):
        eng.fetch(t, _zipf_draw(rng, perm, g.num_nodes[t]))
    one_shot = eng.cache.hit_rates()[t]

    eng.rebalance()
    eng.cache.reset_stats()
    for _ in range(30):
        eng.fetch(t, _zipf_draw(rng, perm, g.num_nodes[t]))
    online = eng.cache.hit_rates()[t]

    assert online > one_shot
    assert online > 0.8  # the observed-hottest rows are now resident
    assert eng.cache.consistency_check()
    assert eng.rebalances == 1


def test_online_admission_adapts_to_shifted_trace(uniform_prior_engine):
    """When the hot set *moves*, decayed re-admission follows it: the
    post-shift hit rate recovers after rebalances on the new trace."""
    g, eng = uniform_prior_engine
    rng = np.random.default_rng(1)
    t = "author"
    n = g.num_nodes[t]
    perm_a, perm_b = rng.permutation(n), rng.permutation(n)

    for _ in range(30):
        eng.fetch(t, _zipf_draw(rng, perm_a, n))
    eng.rebalance()

    # phase shift: traffic now follows a disjoint-ish hot set
    eng.cache.reset_stats()
    for _ in range(10):
        eng.fetch(t, _zipf_draw(rng, perm_b, n))
    stale = eng.cache.hit_rates()[t]
    eng.rebalance(decay=0.1)  # forget the old phase quickly
    for _ in range(10):
        eng.fetch(t, _zipf_draw(rng, perm_b, n))
    eng.rebalance(decay=0.1)

    eng.cache.reset_stats()
    for _ in range(20):
        eng.fetch(t, _zipf_draw(rng, perm_b, n))
    adapted = eng.cache.hit_rates()[t]
    assert adapted > stale
    assert adapted > 0.8


def test_rebalance_preserves_learnable_writeback_and_budget(uniform_prior_engine):
    """Evicted learnable rows must carry row + Adam states home (the
    non-replicative single-copy invariant), and every re-allocation stays
    under the original byte budget."""
    import jax.numpy as jnp
    from repro.embed.profiler import row_bytes

    g, eng = uniform_prior_engine
    rng = np.random.default_rng(2)
    lt = next(iter(eng.learnable_types))
    c = eng.cache.caches[lt]
    nid = int(c.ids[0])
    eng.apply_row_grads(lt, np.array([nid]), jnp.ones((1, 16)))
    val = eng.table(lt)[nid].copy()
    _, m0, v0 = eng.cache.fetch_states(lt, np.array([nid]))
    m0, v0 = np.asarray(m0).copy(), np.asarray(v0).copy()

    # starve lt of traffic so the rebalance evicts its rows entirely
    t = "author"
    perm = rng.permutation(g.num_nodes[t])
    for _ in range(50):
        eng.fetch(t, _zipf_draw(rng, perm, g.num_nodes[t], k=1024))
    eng.rebalance(decay=0.0)

    np.testing.assert_array_equal(eng.table(lt)[nid], val)
    _, m1, v1 = eng.cache.fetch_states(lt, np.array([nid]))
    np.testing.assert_array_equal(np.asarray(m1), m0)
    np.testing.assert_array_equal(np.asarray(v1), v0)
    pen = eng.penalties
    used = sum(
        len(tc.ids) * row_bytes(pen.dims[ty], pen.learnable[ty])
        for ty, tc in eng.cache.caches.items()
    )
    assert used <= eng.cache_bytes * 1.01
    assert eng.cache.consistency_check()


def test_update_residency_is_incremental(uniform_prior_engine):
    """A rebalance under an unchanged traffic profile keeps resident rows
    in place — no gratuitous evict/re-admit churn."""
    g, eng = uniform_prior_engine
    rng = np.random.default_rng(3)
    t = "author"
    perm = rng.permutation(g.num_nodes[t])
    for _ in range(30):
        eng.fetch(t, _zipf_draw(rng, perm, g.num_nodes[t]))
    eng.rebalance()
    before = {ty: tc.ids.copy() for ty, tc in eng.cache.caches.items()}

    # same trace again: the EMA barely moves, the plan barely moves
    for _ in range(30):
        eng.fetch(t, _zipf_draw(rng, perm, g.num_nodes[t]))
    out = eng.rebalance()
    mv = out["moves"].get(t)
    assert mv is not None
    assert mv["kept"] >= mv["admitted"]  # mostly stable residency
    # kept rows really were in the old resident set
    kept_ids = set(eng.cache.caches[t].ids) & set(before[t])
    assert len(kept_ids) >= mv["kept"] - mv["admitted"]


def test_access_counters_drain_and_reset(mag_setup):
    g, spec, hot, pen = mag_setup
    eng = EmbedEngine(g, 8, hot, pen, cache_bytes=1 << 16)
    t = "author"
    eng.fetch(t, np.array([1, 1, 2]))
    counts = eng.cache.take_access_counts()
    assert counts[t][1] == 2 and counts[t][2] == 1
    counts2 = eng.cache.take_access_counts()
    assert counts2[t].sum() == 0  # drained


def test_varying_dims_profile():
    g = donor_like(scale=0.001)
    pen = profile_miss_penalties(g, measured=False)
    # teacher (dim 7) must have a larger ratio than project (dim 789)
    assert pen.ratios["teacher"] > pen.ratios["project"]
